"""End-to-end LM training driver: train a reduced assigned-architecture
config for a few hundred steps with checkpointing/resume.

  PYTHONPATH=src python examples/lm_train.py --arch gemma2_2b --steps 200
  PYTHONPATH=src python examples/lm_train.py --arch gemma2_2b --full   # ~100M params

The reduced configs run on this CPU container; --full builds a ~100M-param
variant of the same family (a few s/step on CPU — intended for real
accelerators, runnable here with patience).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_arch, build_model
from repro.data import Prefetcher, token_batches
from repro.models import LMConfig
from repro.train import LoopConfig, run_train_loop
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.steps import make_lm_train_step


def build_cfg(arch: str, full: bool):
    spec = get_arch(arch)
    cfg = spec.smoke
    if full:
        if not isinstance(cfg, LMConfig):
            raise SystemExit("--full supports the LM-family archs in this example")
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=768, n_q=12, n_kv=4, head_dim=64, d_ff=2048, vocab=32768
        )  # ~100M params
    return dataclasses.replace(cfg, act_dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.arch, args.full)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} params={n_params/1e6:.1f}M vocab={cfg.vocab}")

    opt = AdamW(lr=warmup_cosine(args.lr, 20, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    step = jax.jit(make_lm_train_step(model, opt, loss_chunk=64))

    raw = token_batches(args.batch, args.seq, cfg.vocab, seed=0)
    data = Prefetcher(raw, depth=2, transform=lambda b: {k: jnp.asarray(v) for k, v in b.items()})

    out = run_train_loop(
        step,
        params,
        opt_state,
        data,
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=25),
    )
    first = out.history[0]["ce"] if out.history else float("nan")
    last = out.history[-1]["ce"] if out.history else float("nan")
    print(f"\nce: {first:.3f} -> {last:.3f} over {out.step} steps "
          f"({len(out.straggler_events)} straggler events)")


if __name__ == "__main__":
    main()
