"""The paper's standalone scheme, end to end: simultaneous MRI
reconstruction (Pix2Pix) + stroke detection (YOLOv8) on a CT stream,
scheduled HaX-CoNN-style across two engines and executed as a
double-buffered pipeline.

  PYTHONPATH=src python examples/mri_pipeline.py [--train-steps 60]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import core
from repro.core.scheduler import _haxconn_schedule_impl
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.data import PhantomConfig, detection_batches, phantom_batches
from repro.models import Pix2Pix, Pix2PixConfig, YOLOv8, YOLOv8Config
from repro.train.metrics import ssim, to_uint8_range
from repro.train.optimizer import Adam, AdamW
from repro.train.steps import make_pix2pix_train_step, make_yolo_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--img", type=int, default=64)
    ap.add_argument("--frames", type=int, default=4)
    args = ap.parse_args()
    img = args.img

    # --- 1. train the two models briefly on synthetic phantoms ---
    print("== training Pix2Pix (cropping variant: DLA-legal, zero fallback) ==")
    cfg = Pix2PixConfig(img_size=img, base=16, deconv_mode="cropping")
    gan = Pix2Pix(cfg)
    params = gan.init(jax.random.key(0))
    g_opt, d_opt = Adam(lr=2e-4, b1=0.5), Adam(lr=2e-4, b1=0.5)
    ost = {"g": g_opt.init(params["generator"]), "d": d_opt.init(params["discriminator"])}
    gstep = jax.jit(make_pix2pix_train_step(gan, g_opt, d_opt))
    gdata = phantom_batches(4, PhantomConfig(img_size=img), seed=0)
    for i in range(args.train_steps):
        b = next(gdata)
        params, ost, gm = gstep(params, ost, {"src": jnp.asarray(b["src"]), "dst": jnp.asarray(b["dst"])}, jax.random.key(i))

    print("== training YOLOv8 stroke detector ==")
    ycfg = YOLOv8Config(img_size=img, n_classes=2)
    yolo = YOLOv8(ycfg)
    yparams = yolo.init(jax.random.key(1))
    yopt = AdamW(lr=1e-3)
    yst = yopt.init(yparams)
    ystep = jax.jit(make_yolo_train_step(yolo, yopt))
    ydata = detection_batches(4, PhantomConfig(img_size=img, lesion_p=1.0), seed=2)
    for i in range(args.train_steps):
        yparams, yst, ym = ystep(yparams, yst, jax.tree.map(jnp.asarray, next(ydata)))
    print(f"   gan l1={float(gm['g_l1']):.4f}  yolo loss={float(ym['loss']):.3f}")

    # --- 2. schedule the two models across the engines ---
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    gsm = core.pix2pix_staged(cfg, params)
    ysm = core.yolo_staged(ycfg, yparams)
    plan = _haxconn_schedule_impl(gsm.graph, ysm.graph, dla, gpu)
    s = plan.schedule
    print("\n== HaX-CoNN schedule (cost model @ Jetson Orin constants) ==")
    for n in s.notes:
        print("  ", n)
    print(s.ascii_timeline())
    print(f"  predicted aggregate throughput: {s.aggregate_fps:.1f} FPS")

    # --- 3. execute the pipeline over a CT stream ---
    print("\n== executing the double-buffered pipeline ==")
    stream = phantom_batches(1, PhantomConfig(img_size=img, lesion_p=1.0), seed=42)
    frames = [jnp.asarray(next(stream)["src"]) for _ in range(args.frames)]
    pipe = core.TwoModelPipeline(gsm, ysm, plan)
    t0 = time.perf_counter()
    recons, detections = pipe.run_stream(frames, frames)
    jax.block_until_ready(recons[-1])
    dt = time.perf_counter() - t0
    print(f"  processed {len(frames)} CT frames in {dt:.2f}s (CPU container)")
    b = next(phantom_batches(args.frames, PhantomConfig(img_size=img), seed=42))
    mri_ref = jnp.asarray(b["dst"])
    rec = jnp.concatenate(recons, axis=0)
    print(f"  reconstruction SSIM vs ground-truth MRI: "
          f"{float(ssim(to_uint8_range(mri_ref), to_uint8_range(rec)).mean())*100:.1f}")
    cls_logits = detections[0]["p3"][..., 4 * ycfg.reg_max :]
    print(f"  detector max lesion score (p3): {float(jax.nn.sigmoid(cls_logits).max()):.3f}")
    print("\npipeline tick log (first 8):")
    for e in pipe.log[:8]:
        print(f"   tick {e.tick} [{e.engine:>4}] {e.work}")


if __name__ == "__main__":
    main()
