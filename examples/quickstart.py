"""Quickstart: train a small Pix2Pix CT->MRI reconstructor on synthetic
brain phantoms, apply the hardware-aware surgery, and verify it is free.

  PYTHONPATH=src python examples/quickstart.py [--steps 200] [--img 64]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import substitute_pix2pix
from repro.data import PhantomConfig, phantom_batches
from repro.models import Pix2Pix, Pix2PixConfig
from repro.train.metrics import psnr, ssim, to_uint8_range
from repro.train.optimizer import Adam
from repro.train.steps import make_pix2pix_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--img", type=int, default=64)
    ap.add_argument("--base", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = Pix2PixConfig(img_size=args.img, base=args.base, deconv_mode="padded")
    model = Pix2Pix(cfg)
    params = model.init(jax.random.key(0))
    g_opt, d_opt = Adam(lr=2e-4, b1=0.5), Adam(lr=2e-4, b1=0.5)
    opt_state = {"g": g_opt.init(params["generator"]), "d": d_opt.init(params["discriminator"])}
    step = jax.jit(make_pix2pix_train_step(model, g_opt, d_opt))
    data = phantom_batches(args.batch, PhantomConfig(img_size=args.img), seed=0)

    for i in range(args.steps):
        b = next(data)
        batch = {"src": jnp.asarray(b["src"]), "dst": jnp.asarray(b["dst"])}
        params, opt_state, m = step(params, opt_state, batch, jax.random.key(i))
        if (i + 1) % 50 == 0:
            print(f"step {i+1}: g_loss={float(m['g_loss']):.3f} l1={float(m['g_l1']):.4f} d_loss={float(m['d_loss']):.3f}")

    # evaluate
    b = next(phantom_batches(8, PhantomConfig(img_size=args.img), seed=99))
    src, dst = jnp.asarray(b["src"]), jnp.asarray(b["dst"])
    fake = model.generate(params, src)
    print(f"\neval SSIM={float(ssim(to_uint8_range(dst), to_uint8_range(fake)).mean())*100:.2f} "
          f"PSNR={float(psnr(to_uint8_range(dst), to_uint8_range(fake)).mean()):.2f}")

    # hardware-aware surgery is free: same weights, same outputs, DLA-legal
    cfg_c = substitute_pix2pix(cfg, "cropping")
    model_c = Pix2Pix(cfg_c)
    fake_c = model_c.generate(params, src)
    print(f"surgery max|delta| = {float(jnp.abs(fake - fake_c).max()):.2e} (exact by construction)")


if __name__ == "__main__":
    main()
