"""The paper's client-server scheme (§VI.D.1): TWO Pix2Pix instances
reconstructing independent MRI streams, swap-scheduled across the engines.
Compares the original (fallback-ridden) model against the hardware-aware
variants — the paper's headline 'double the DLA throughput' result.

  PYTHONPATH=src python examples/multi_stream_recon.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core
from repro.core.scheduler import _haxconn_schedule_impl
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.models import Pix2PixConfig, Pix2PixGenerator

GPU, DLA = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)


def main():
    print("== 2x Pix2Pix multi-stream reconstruction (256x256, cost model) ==\n")
    results = {}
    for mode in ("padded", "cropping", "conv"):
        g = Pix2PixGenerator(Pix2PixConfig(deconv_mode=mode)).layer_graph()
        ill, _ = core.check_graph(g, DLA)
        r = _haxconn_schedule_impl(g, g, DLA, GPU)
        s = r.schedule
        results[mode] = s
        print(f"--- {mode} ({len(ill)} DLA-illegal layers) ---")
        print(f"  partitions: instance A DLA[0:{r.p_a}) GPU[{r.p_a}:); instance B GPU[0:{r.p_b}) DLA[{r.p_b}:)")
        print(f"  per-stream {s.aggregate_fps/2:.1f} FPS, aggregate {s.aggregate_fps:.1f} FPS")
        print(s.ascii_timeline())
        print()
    gain = results["cropping"].aggregate_fps / results["padded"].aggregate_fps
    print(f"hardware-aware (cropping) vs original aggregate gain: {gain:.2f}x")
    print("(paper Table IV: DLA throughput 86.94 -> 147.66 FPS = 1.70x on Jetson)")

    # small-scale EXECUTABLE check: the two streams produce exact outputs
    cfg = Pix2PixConfig(img_size=64, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    params = {"generator": gen.init(jax.random.key(0))}
    sm_a = core.pix2pix_staged(cfg, params)
    sm_b = core.pix2pix_staged(cfg, params)
    plan = core.plan([sm_a.graph, sm_b.graph], [DLA, GPU], kind="haxconn")
    pipe = core.TwoModelPipeline(sm_a, sm_b, plan)
    frames = [jax.random.normal(jax.random.key(i), (1, 64, 64, 3)) for i in range(3)]
    outs_a, outs_b = pipe.run_stream(frames, list(reversed(frames)))
    ok = all(
        bool(jnp.allclose(sm_a.run_all(f), o, atol=1e-5)) for f, o in zip(frames, outs_a)
    )
    print(f"\nexecutable 2-stream pipeline functional check: {'OK' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
