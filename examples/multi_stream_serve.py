"""N-model multi-stream serving: 4 Pix2Pix reconstruction streams + 1
YOLOv8 detection stream, planned by the unified ``repro.core.plan``
scheduler and served through the ``repro.serve.build_server`` facade
(overlapped dispatch, double buffering, bounded queues, micro-batched
same-model frames).

This is the production generalization of the paper's two-instance swap
schedule: the planner balances the Pix2Pix/YOLO partition points across
the engines — under the analytic roofline or XLA-measured per-layer
costs (``--cost measured``) — and the server fans K frame queues onto
the planned routes. ``--norm instance`` builds the batch-independent
Pix2Pix variant so its streams are merge-micro-batched. ``--replan``
closes the online re-planning loop: profiled ticks feed per-engine
wall-time scales into an ``OnlineCost`` EMA and a drift detector
hot-swaps re-planned routes at frame boundaries (zero dropped frames).
``--open-loop`` drives the same server with Poisson arrivals under a
deadline SLO instead of the closed-loop submit/pump cycle.

  PYTHONPATH=src python examples/multi_stream_serve.py
  PYTHONPATH=src python examples/multi_stream_serve.py --cost measured --norm instance
  PYTHONPATH=src python examples/multi_stream_serve.py --replan
  PYTHONPATH=src python examples/multi_stream_serve.py --granularity fine
  PYTHONPATH=src python examples/multi_stream_serve.py --cost measured --impl auto
  PYTHONPATH=src python examples/multi_stream_serve.py --open-loop --rate 20 --deadline-ms 100
  PYTHONPATH=src python examples/multi_stream_serve.py --open-loop --replicas 2 --traffic-seed 7
  PYTHONPATH=src python examples/multi_stream_serve.py --open-loop --workers 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import TrafficConfig, build_server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cost", choices=("analytic", "measured", "blended"), default="analytic")
    ap.add_argument("--cost-cache", default=None, help="JSON cache for measured layer timings")
    ap.add_argument("--dispatch", choices=("overlapped", "serialized"), default="overlapped")
    ap.add_argument("--norm", choices=("batch", "instance", "group"), default="batch")
    ap.add_argument("--streams", type=int, default=4, help="Pix2Pix stream count")
    ap.add_argument("--yolo-streams", type=int, default=1)
    ap.add_argument("--frames", type=int, default=6)
    ap.add_argument("--img", type=int, default=64)
    ap.add_argument("--replan", action="store_true", help="online re-planning runtime")
    ap.add_argument(
        "--granularity",
        choices=("coarse", "fine"),
        default="coarse",
        help="plan at composite-node or expanded (primitive) granularity",
    )
    ap.add_argument(
        "--max-cuts",
        default="1",
        help="per-model cut budget (int), or 'auto' to escalate while the cycle improves",
    )
    ap.add_argument(
        "--impl",
        choices=("auto", "xla", "pallas"),
        default="xla",
        help="implementation planning: xla per-op lowering, pallas fused serving kernels, "
        "or auto (per-segment argmin over both)",
    )
    ap.add_argument("--open-loop", action="store_true", help="Poisson arrivals under an SLO")
    ap.add_argument("--rate", type=float, default=20.0, help="open-loop arrival rate (Hz/stream)")
    ap.add_argument("--duration", type=float, default=1.5, help="open-loop horizon (s)")
    ap.add_argument("--deadline-ms", type=float, default=100.0, help="open-loop SLO deadline")
    ap.add_argument(
        "--replicas", type=int, default=1,
        help="replicated serving pipelines behind the sticky load-aware fleet router",
    )
    ap.add_argument(
        "--workers", type=int, default=0,
        help="multi-process fleet: worker processes behind the IPC router "
        "(mutually exclusive with --replicas)",
    )
    ap.add_argument(
        "--traffic-seed", type=int, default=0,
        help="arrival-process seed (open-loop runs replay exactly, fleet included)",
    )
    args = ap.parse_args()
    max_cuts = "auto" if args.max_cuts == "auto" else int(args.max_cuts)

    provider = core.make_cost_provider(args.cost, cache_path=args.cost_cache)
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)

    # planner view: full-size graphs (what deploys on the Jetson/TPU)
    g_pix = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping", norm=args.norm)).layer_graph()
    g_yolo = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    plan_full = core.plan(
        [g_pix, g_yolo], [dla, gpu], cost=provider,
        granularity=args.granularity, max_cuts=max_cuts, impl=args.impl,
    )
    print(f"== planner (full-size graphs, {plan_full.cost_provider} cost, {plan_full.search} search) ==")
    print(f"cuts: {plan_full.cuts}  cycle={plan_full.expected_cycle*1e3:.2f} ms  budget={plan_full.cut_budget}")
    if args.impl != "xla":
        print(f"impl={args.impl} bindings={plan_full.impl_bindings()}")

    # executable view: small CPU-sized models, same machinery, one facade call
    bundle = build_server(
        img=args.img,
        n_pix=args.streams,
        n_yolo=args.yolo_streams,
        norm=args.norm,
        # worker processes rebuild the provider by name from the JSON spec
        cost=args.cost if args.workers else provider,
        granularity=args.granularity,
        max_cuts=max_cuts,
        impl=args.impl,
        max_queue=4,
        microbatch=2,
        dispatch=args.dispatch,
        replan=args.replan,
        deadline_ms=args.deadline_ms if args.open_loop else None,
        traffic=TrafficConfig(process="poisson", rate_hz=args.rate, seed=args.traffic_seed)
        if args.open_loop
        else None,
        admission=args.open_loop,
        replicas=args.replicas,
        workers=args.workers,
    )
    if args.cost_cache and hasattr(provider, "save"):
        provider.save()  # measured AND blended both persist their timings
    server, streams, models = bundle.server, bundle.streams, bundle.models
    sm_pix, sm_yolo = models
    merge = server.executor.merge_batches

    frames = {
        s.name: [
            jax.random.normal(jax.random.key(100 * si + t), (1, args.img, args.img, 3))
            for t in range(args.frames)
        ]
        for si, s in enumerate(streams)
    }
    for t in range(args.frames):
        for s in streams:
            server.submit(s.model_index, frames[s.name][t])
        server.pump()
    outs = server.drain()

    if args.open_loop:
        # the closed-loop pass above warmed the compiled segments; now the
        # open-loop phase measures service under Poisson arrivals + SLO
        bundle.run_open_loop(args.duration)

    rep = server.report()
    print(f"\n== serving report ({len(streams)} streams, {args.dispatch} dispatch, merge={merge}) ==")
    print(
        f"frames={rep['frames']} wall={rep['wall_s']:.2f}s "
        f"aggregate={rep['aggregate_fps']:.1f} FPS "
        f"p50={rep['latency_p50_ms']:.1f} ms p99={rep['latency_p99_ms']:.1f} ms "
        f"overlap_eff={rep['overlap']['overlap_efficiency']:.3f}"
    )
    for name, m in rep["per_stream"].items():
        print(
            f"  {name:>7}: {m['completed']} frames  "
            f"p50={m['latency_p50_ms']:.1f} ms  p99={m['latency_p99_ms']:.1f} ms"
        )
    if args.open_loop:
        adm = rep["admission"]
        print(
            f"open loop: goodput={rep['goodput_fps']:.1f} FPS under {args.deadline_ms:.0f} ms SLO  "
            f"offered={adm['offered']} admitted={adm['admitted']} "
            f"shed={adm['shed_res'] + adm['shed_route']} dropped={adm['dropped']}"
        )
        for t, tm in rep["tiers"].items():
            print(
                f"  tier {t}: offered={tm['offered']} goodput={tm['goodput_fps']:.1f} FPS "
                f"attainment={tm['slo_attainment']:.2f}"
            )
    if args.workers:
        ro = rep["router"]
        total = max(1, sum(ro["routed_frames"]))
        shares = "  ".join(
            f"worker{w}={n} ({n / total:.0%})" for w, n in enumerate(ro["routed_frames"])
        )
        print(
            f"proc fleet: {args.workers} worker processes  {shares}  "
            f"imbalance={ro['imbalance']:.2f}  failures={len(rep['worker_failures'])}"
        )
    elif args.replicas > 1:
        ro = rep["router"]
        print(
            f"fleet: {args.replicas} replicas  routed={ro['routed_frames']} "
            f"imbalance={ro['imbalance']:.2f}  assignments={ro['assignments']}"
        )
    if args.replan:
        rp = rep["replan"]
        if isinstance(rp, list):  # fleet: one summary per replica/worker; show the first
            rp = rp[0]
        scales = {k: f"x{v:.3g}" for k, v in rp["scales"].items()}
        print(
            f"replan: calibrated={rp['calibrated']} observations={rp['observations']} "
            f"scales={scales} swaps={rp['swaps']} (plan rev {rep['plan_revision']})"
        )

    # functional check: every stream's closed-loop outputs match the
    # monolithic model (least-loaded assignment can permute frames across
    # same-model streams, so compare against the union of reference
    # outputs per model)
    refs = {
        name: [sm_pix.run_all(f) if s.model_index == 0 else sm_yolo.run_all(f) for f in fs]
        for (name, fs), s in zip(frames.items(), streams)
    }
    def matches(out, ref):
        # jitted segments (the default) fuse ops, drifting low-order bits
        # vs the eager run_all reference — compare within that tolerance
        # (the YOLO head accumulates up to ~4e-3 at 64px; the bit-exact
        # contract is pinned by the eager-mode tests)
        return all(
            bool(jnp.allclose(a, b, atol=5e-3, rtol=1e-2))
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref))
        )
    ok = True
    for s in streams:
        pool = [r for s2 in streams if s2.model_index == s.model_index for r in refs[s2.name]]
        for o in outs[s.name][: args.frames]:
            ok &= any(matches(o, r) for r in pool)
    print(f"\nfunctional check vs monolithic run_all: {'OK' if ok else 'FAIL'}")
    bundle.close()
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
