"""Pallas TPU kernel: phase-decomposed stride-2 transposed conv + fused crop.

GPU implementations scatter each input pixel into a k x k output window —
a memory-bound pattern with no MXU analogue. The TPU-native adaptation
decomposes the (k=4, stride=2, torch-padding=1) deconv by *output parity
phase*: with (a, b) = output (row, col) parity, every output pixel is

    y[2u'+rp, 2v'+cp] = sum_{s,t in {0,1}}  W[a+2s, b+2t]^T . x[u-s, v-t]

i.e. 4 phases x 4 taps = 16 dense (Cin x Cout) GEMMs over the whole tile —
pure MXU work, zero inserted zeros, and the paper's crop (padding=1) is
folded into the phase/index arithmetic instead of a separate layer.

Tiling: grid (B, H/tile_h); each step loads its row-tile plus the
previous/next tiles (for the one-row halo each side) and writes a
(2*tile_h, 2W) output tile. Channels stay whole (Cin/Cout are the GEMM
dims — pad to 128 lanes upstream for full MXU utilization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import load_block


def _phase_matmuls(x_m1, x_0, x_p1, w, th, W):
    """All four parity phases for a row tile.

    x_m1/x_0/x_p1: (th, W, Cin) rows shifted -1/0/+1; w: (4,4,Cin,Cout).
    Returns (th, 2, W, 2, Cout) = interleaved (2*th, 2*W) output tile.
    """
    cin = x_0.shape[-1]
    cout = w.shape[-1]
    w = w[::-1, ::-1]  # conv_transpose applies the rot180'd kernel

    def shift_left(v):  # col v'+1
        return jnp.concatenate([v[:, 1:], jnp.zeros_like(v[:, :1])], axis=1)

    def shift_right(v):  # col v'-1
        return jnp.concatenate([jnp.zeros_like(v[:, :1]), v[:, :-1]], axis=1)

    def mm(xs, ki, kj):
        flat = xs.reshape(th * W, cin)
        return jax.lax.dot_general(
            flat,
            w[ki, kj],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(th, W, cout)

    # row parity 0 (even output rows): uses x rows u (W[1,:]) and u-1 (W[3,:])
    # row parity 1 (odd):              uses x rows u+1 (W[0,:]) and u (W[2,:])
    ph00 = mm(x_0, 1, 1) + mm(shift_right(x_0), 1, 3) + mm(x_m1, 3, 1) + mm(shift_right(x_m1), 3, 3)
    ph01 = mm(shift_left(x_0), 1, 0) + mm(x_0, 1, 2) + mm(shift_left(x_m1), 3, 0) + mm(x_m1, 3, 2)
    ph10 = mm(x_p1, 0, 1) + mm(shift_right(x_p1), 0, 3) + mm(x_0, 2, 1) + mm(shift_right(x_0), 2, 3)
    ph11 = mm(shift_left(x_p1), 0, 0) + mm(x_p1, 0, 2) + mm(shift_left(x_0), 2, 0) + mm(x_0, 2, 2)

    even = jnp.stack([ph00, ph01], axis=2)  # (th, W, 2, Cout)
    odd = jnp.stack([ph10, ph11], axis=2)
    tile = jnp.stack([even, odd], axis=1)  # (th, 2, W, 2, Cout)
    return tile


def _deconv_kernel(x_prev_ref, x_ref, x_next_ref, w_ref, o_ref, *, th, W, n_tiles):
    i = pl.program_id(1)
    # singleton batch axis via the shared jax-0.4.37 int-index workaround
    x_0 = load_block(x_ref, 0, slice(None), slice(None), slice(None))  # (th, W, Cin)
    # row u-1: last row of the previous tile on top; masked at global top
    prev_last = load_block(x_prev_ref, 0, slice(th - 1, th), slice(None), slice(None))
    prev_last = jnp.where(i > 0, prev_last, jnp.zeros_like(prev_last))
    x_m1 = jnp.concatenate([prev_last, x_0[:-1]], axis=0)
    # row u+1: first row of the next tile at the bottom; masked at bottom
    next_first = load_block(x_next_ref, 0, slice(0, 1), slice(None), slice(None))
    next_first = jnp.where(i < n_tiles - 1, next_first, jnp.zeros_like(next_first))
    x_p1 = jnp.concatenate([x_0[1:], next_first], axis=0)

    tile = _phase_matmuls(x_m1, x_0, x_p1, w_ref[...], th, W)
    o_ref[0] = tile.reshape(2 * th, 2 * W, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def deconv2d_pallas(x, w, tile_h: int = 8, interpret: bool = True):
    """Stride-2, k=4, torch-padding-1 transposed conv (the Pix2Pix up-op).

    x: (B, H, W, Cin) -> (B, 2H, 2W, Cout). Weights (4, 4, Cin, Cout).
    """
    B, H, W, Cin = x.shape
    assert w.shape[:2] == (4, 4), "phase decomposition is specialized to k=4"
    Cout = w.shape[-1]
    if H % tile_h:
        tile_h = H  # small inputs: single tile
    n_tiles = H // tile_h

    grid = (B, n_tiles)
    kernel = functools.partial(_deconv_kernel, th=tile_h, W=W, n_tiles=n_tiles)
    def x_spec(off):
        def imap(b, i):
            return (b, jnp.clip(i + off, 0, n_tiles - 1), 0, 0)

        return pl.BlockSpec((1, tile_h, W, Cin), imap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            x_spec(-1),
            x_spec(0),
            x_spec(+1),
            pl.BlockSpec((4, 4, Cin, Cout), lambda b, i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2 * tile_h, 2 * W, Cout), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2 * H, 2 * W, Cout), x.dtype),
        interpret=interpret,
    )(x, x, x, w)
