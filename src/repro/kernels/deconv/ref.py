"""Pure-jnp oracle for the hardware-aware transposed convolution.

Torch semantics: out = stride*(in-1) + k - 2*padding, implemented as a
VALID transposed conv followed by a border crop — the exact op pair the
paper substitutes for the DLA-illegal fused deconv (eq. 5+7 == eq. 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DN = ("NHWC", "HWIO", "NHWC")


def deconv2d_ref(x, w, b=None, stride: int = 2, padding: int = 1):
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout); torch-style ``padding``."""
    y = jax.lax.conv_transpose(
        x, w.astype(x.dtype), strides=(stride, stride), padding="VALID", dimension_numbers=DN
    )
    if padding:
        y = y[:, padding:-padding, padding:-padding, :]
    if b is not None:
        y = y + b.astype(x.dtype)
    return y
