"""Jit wrapper for the phase-decomposed deconv kernel.

On TPU set ``interpret=False`` (compiled Pallas); this CPU container
validates via interpret mode against the pure-jnp oracle in ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import deconv2d_pallas
from .ref import deconv2d_ref


def deconv2d(x, w, b=None, stride: int = 2, padding: int = 1, use_pallas: bool = True, interpret: bool = True, tile_h: int = 8):
    """Hardware-aware transposed conv (the Pix2Pix upsample op).

    The Pallas path is specialized to the paper's configuration
    (k=4, stride=2, torch padding=1); other configs fall back to the
    XLA reference implementation.
    """
    k = w.shape[0]
    if use_pallas and k == 4 and stride == 2 and padding == 1:
        y = deconv2d_pallas(x, w, tile_h=tile_h, interpret=interpret)
        if b is not None:
            y = y + b.astype(y.dtype)
        return y
    return deconv2d_ref(x, w, b=b, stride=stride, padding=padding)
