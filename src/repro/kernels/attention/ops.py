"""Jit wrapper: flash attention with XLA fallback for odd shapes."""
from __future__ import annotations

from .kernel import flash_attention
from .ref import attention_ref


def attention(q, k, v, causal=True, window=0, softcap=None, scale=None, use_pallas=True, interpret=True):
    Sq, Sk, D = q.shape[1], k.shape[1], q.shape[-1]
    blockable = Sq % min(128, Sq) == 0 and Sk % min(128, Sk) == 0
    if use_pallas and blockable and q.shape[2] % k.shape[2] == 0:
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale, interpret=interpret
        )
    return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap, scale=scale)
