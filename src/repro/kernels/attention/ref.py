"""Pure-jnp oracle for flash attention: GQA + causal + sliding window +
logit softcap (the gemma2/hymba/phi4 attention flavours)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, causal=True, window=0, softcap=None, scale=None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hk, D); Hq % Hk == 0.

    window > 0 limits attention to the last ``window`` keys (inclusive of
    self). Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, Sq, Hk, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (prefill/full)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)
