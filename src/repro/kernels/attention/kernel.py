"""Pallas TPU flash attention (forward).

Block-wise online softmax: grid (B, Hq, Sq/bq); each step streams the KV
sequence in ``bk``-sized VMEM blocks, keeping running (max, sum, acc) in
registers. GQA maps query head h to KV head h // (Hq//Hk) in the BlockSpec
index map (no KV replication in HBM). Causal + sliding-window blocks are
*skipped*, not masked — the sparsity becomes wall-clock, which is exactly
the gemma2 local-layer win. Logit softcap (gemma2) applied in-block.

VMEM budget per step: q (bq, D) + k/v (bk, D) each + acc (bq, D) fp32 —
with bq=bk=512, D=256: ~1.8 MB, comfortably inside the ~16 MB VMEM.
MXU alignment: choose bq/bk multiples of 128 and D in {64,128,256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import load_block

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, Sk, causal, window, softcap, scale, q_offset):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
    D = q.shape[-1]

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq) + q_offset  # global key-aligned positions

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, D), jnp.float32)

    n_kb = Sk // bk
    # block range: causal => kv blocks beyond the last query are skipped;
    # window => kv blocks older than (min q_pos - window) are skipped.
    hi = n_kb if not causal else jnp.minimum(n_kb, (qi * bq + bq - 1 + q_offset) // bk + 1)
    lo = 0
    if window and window > 0:
        lo = jnp.maximum(0, (qi * bq + q_offset - window + 1) // bk)

    def body(kb, carry):
        m, l, acc = carry
        # int indices can't mix with pl.ds in this jax version's NDIndexer;
        # _compat.load_block loads them as size-1 dynamic slices and drops them
        k = load_block(k_ref, 0, pl.ds(kb * bk, bk), 0, slice(None)).astype(jnp.float32)
        v = load_block(v_ref, 0, pl.ds(kb * bk, bk), 0, slice(None)).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window and window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale", "bq", "bk", "interpret")
)
def flash_attention(
    q,
    k,
    v,
    causal: bool = True,
    window: int = 0,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hk, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale_v = scale if scale is not None else float(1.0 / D**0.5)
    q_offset = Sk - Sq  # align query block positions with absolute key ids

    kernel = functools.partial(
        _flash_kernel,
        bq=bq,
        bk=bk,
        Sk=Sk,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale_v,
        q_offset=q_offset,
    )
    grid = (B, Hq, Sq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, Sk, 1, D), lambda b, h, i: (b, 0, h // G, 0)),
            pl.BlockSpec((1, Sk, 1, D), lambda b, h, i: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
