# Pallas TPU kernels for the perf-critical compute layers:
#   deconv    — the paper's hardware-aware transposed conv (phase-decomposed)
#   attention — flash attention (GQA/causal/window/softcap)
#   ssd       — Mamba-2 chunked state-space scan
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper
# with XLA fallback), ref.py (pure-jnp oracle); validated in interpret mode.
from .deconv.ops import deconv2d
from .attention.ops import attention as flash_attention_op
from .ssd.ops import ssd as ssd_op
