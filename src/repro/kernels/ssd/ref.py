"""Oracle for the SSD kernel: the chunked pure-jnp implementation in
repro.nn.ssm (itself verified against the naive recurrence in tests)."""
from repro.nn.ssm import ssd_chunked as ssd_ref  # noqa: F401
