"""Jit wrapper: Pallas SSD with jnp fallback for non-chunk-multiple seqs."""
from __future__ import annotations

from .kernel import ssd_pallas
from .ref import ssd_ref


def ssd(x, dt, A, B, C, chunk: int = 128, use_pallas: bool = True, interpret: bool = True):
    s = x.shape[1]
    if use_pallas and s % min(chunk, s) == 0:
        return ssd_pallas(x, dt, A, B, C, chunk=min(chunk, s), interpret=interpret)
    return ssd_ref(x, dt, A, B, C, chunk=chunk)
