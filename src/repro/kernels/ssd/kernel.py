"""Pallas TPU kernel: Mamba-2 SSD chunked scan (forward).

Grid (B, H, S/chunk) with the chunk axis 'arbitrary' (sequential): the
inter-chunk SSM state (P, N) lives in a VMEM scratch ref that persists
across grid steps — the standard Pallas-TPU carry idiom. Per chunk the
work is dense MXU matmuls (CB^T scores, masked-decay apply, state
update), i.e. the SSD duality's matmul-rich form; nothing is recurrent at
the element level, matching how the original Triton kernel restructures
the scan for tensor cores — re-expressed here for MXU tiles.

B/C are per-group: the BlockSpec index map sends head h to group
h // (H/G), so grouped B/C are never materialized per-head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import load_block


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, chunk, P, N):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros((P, N), jnp.float32)

    # singleton grid axes via the shared jax-0.4.37 int-index workaround
    x = load_block(x_ref, 0, slice(None), 0, slice(None)).astype(jnp.float32)  # (L, P)
    dt = load_block(dt_ref, 0, slice(None), 0).astype(jnp.float32)  # (L,)
    a = a_ref[0].astype(jnp.float32)  # scalar (per head)
    bmat = load_block(b_ref, 0, slice(None), 0, slice(None)).astype(jnp.float32)  # (L, N)
    cmat = load_block(c_ref, 0, slice(None), 0, slice(None)).astype(jnp.float32)  # (L, N)

    dA = dt * a  # (L,)
    dA_cum = jnp.cumsum(dA)  # (L,)

    # intra-chunk: scores (L, L) = C B^T ⊙ decay(L), lower-triangular
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    seg = dA_cum[:, None] - dA_cum[None, :]  # decay from j..i (i >= j)
    li = jax.lax.iota(jnp.int32, chunk)
    causal = li[:, None] >= li[None, :]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    xw = x * dt[:, None]  # dt-weighted inputs
    y_intra = jax.lax.dot_general(scores * L, xw, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # inter-chunk: y += (C . h_prev) * exp(dA_cum)
    h_prev = state_ref[...]  # (P, N)
    y_inter = jax.lax.dot_general(cmat, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(dA_cum)[:, None]

    o_ref[0, :, 0, :] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update: h = h * exp(sum dA) + sum_l exp(dA_cum[-1]-dA_cum[l]) dt_l x_l B_l^T
    decay_states = jnp.exp(dA_cum[-1] - dA_cum)  # (L,)
    xw_dec = xw * decay_states[:, None]  # (L, P)
    delta = jax.lax.dot_general(xw_dec, bmat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = h_prev * jnp.exp(dA_cum[-1]) + delta


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, B, C, chunk: int = 128, interpret: bool = True):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, g, n) -> y like x."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    rep = h // g
    grid = (b, h, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, P=p, N=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
        if not interpret
        else None,
        interpret=interpret,
    )(x, dt, A, B, C)
