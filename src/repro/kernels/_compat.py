"""Shared Pallas compatibility helpers for the kernel packages.

jax 0.4.37's ``NDIndexer`` rejects integer indices mixed with ``pl.ds``
dynamic slices in one ``pl.load`` — the idiom every blocked kernel wants
for "this singleton grid axis, that dynamic block". ``load_block`` is the
one shared workaround: integer indices are loaded as size-1 dynamic
slices and the singleton axes dropped after the load, which lowers to the
same memory traffic. Originally worked around inline in
``attention/kernel.py``; extracted here so new kernels can't silently
copy a broken raw mix.
"""
from __future__ import annotations

from jax.experimental import pallas as pl


def load_block(ref, *index):
    """``pl.load(ref, index)`` that accepts int indices beside ``pl.ds``.

    ``index`` elements may be python/traced ints (the axis is loaded as a
    size-1 dynamic slice and squeezed from the result), ``pl.ds(...)``
    slices, or plain ``slice`` objects (kept as-is). Returns the loaded
    array with every int-indexed axis dropped.
    """
    idx, keep = [], []
    for i in index:
        if isinstance(i, (slice, pl.Slice)):
            idx.append(i)
            keep.append(slice(None))
        else:  # int index: size-1 dynamic slice, squeezed after the load
            idx.append(pl.ds(i, 1))
            keep.append(0)
    return pl.load(ref, tuple(idx))[tuple(keep)]
