"""jit wrappers for the fused serving blocks, with reference fallback.

The Pallas kernels compute norm statistics per sample (grid (B,)) — exact
for instance/group norm at any batch and for batch norm at B == 1. A
B > 1 batch-norm call (merged micro-batches never hit this: only
batch-independent models merge) falls back to the jnp reference, which is
still one fused jit region under XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import conv_block_pallas, deconv_block_pallas, sppf_pyramid_pallas
from .ref import conv_block_ref, deconv_block_ref


def _affine(x, b, gamma, beta, cout):
    f32 = jnp.float32
    b = jnp.zeros((cout,), f32) if b is None else b
    gamma = jnp.ones((cout,), f32) if gamma is None else gamma
    beta = jnp.zeros((cout,), f32) if beta is None else beta
    return b, gamma, beta


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "norm", "groups", "act", "eps", "interpret")
)
def conv_block(
    x,
    w,
    b=None,
    gamma=None,
    beta=None,
    stride: int = 1,
    padding: int = 0,
    norm: str = "batch",
    groups: int = 1,
    act: str = "silu",
    eps: float = 1e-5,
    interpret: bool = True,
):
    """Fused conv(+bias)+norm+act: (B, H, W, Cin) -> (B, Ho, Wo, Cout)."""
    b, gamma, beta = _affine(x, b, gamma, beta, w.shape[-1])
    if norm == "batch" and x.shape[0] > 1:
        return conv_block_ref(
            x, w, b, gamma, beta, stride=stride, padding=padding, norm=norm,
            groups=groups, act=act, eps=eps,
        )
    return conv_block_pallas(
        x, w, b, gamma, beta, stride=stride, padding=padding, norm=norm,
        groups=groups, act=act, eps=eps, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("norm", "groups", "act", "eps", "interpret"))
def deconv_block(
    x,
    w,
    b=None,
    gamma=None,
    beta=None,
    norm: str = "batch",
    groups: int = 1,
    act: str = "relu",
    eps: float = 1e-5,
    interpret: bool = True,
):
    """Fused k=4/s=2 deconv + crop (+bias) + norm + act: -> (B, 2H, 2W, Cout)."""
    b, gamma, beta = _affine(x, b, gamma, beta, w.shape[-1])
    if norm == "batch" and x.shape[0] > 1:
        return deconv_block_ref(x, w, b, gamma, beta, norm=norm, groups=groups, act=act, eps=eps)
    return deconv_block_pallas(
        x, w, b, gamma, beta, norm=norm, groups=groups, act=act, eps=eps, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("window", "reps", "interpret"))
def sppf_pyramid(x, window: int = 5, reps: int = 3, interpret: bool = True):
    """Fused SPPF pool pyramid + concat: (B, H, W, C) -> (B, H, W, (reps+1)*C).

    Max/concat only — exact at any batch, no reference fallback needed."""
    return sppf_pyramid_pallas(x, window=window, reps=reps, interpret=interpret)
