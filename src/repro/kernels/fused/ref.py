"""Pure-jnp oracles for the fused serving blocks — the exact op sequence
the XLA (unfused) stage callables run, composed from the same nn-layer
math (`Conv2D`/`ConvTranspose2D` + `BatchNorm2D`-family stats + act)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

DN = ("NHWC", "HWIO", "NHWC")


def _act(y, act):
    if act == "relu":
        return jax.nn.relu(y)
    if act == "lrelu":
        return jax.nn.leaky_relu(y, 0.2)
    if act == "silu":
        return jax.nn.silu(y)
    if act == "tanh":
        return jnp.tanh(y)
    return y


def _norm(y, gamma, beta, *, norm, groups, eps):
    """Batch-statistics norm over the batch (batch), per-sample (instance),
    or per-sample grouped channels (group) — fp32 in, fp32 out."""
    if norm == "none":
        return y
    if norm == "batch":
        mean = jnp.mean(y, axis=(0, 1, 2), keepdims=True)
        var = jnp.var(y, axis=(0, 1, 2), keepdims=True)
        return (y - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
    if norm == "instance":
        mean = jnp.mean(y, axis=(1, 2), keepdims=True)
        var = jnp.var(y, axis=(1, 2), keepdims=True)
        return (y - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
    if norm == "group":
        B, H, W, C = y.shape
        yg = y.reshape(B, H, W, groups, C // groups)
        mean = jnp.mean(yg, axis=(1, 2, 4), keepdims=True)
        var = jnp.var(yg, axis=(1, 2, 4), keepdims=True)
        return ((yg - mean) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C) * gamma + beta
    raise ValueError(f"unknown norm {norm!r}")


def conv_block_ref(
    x, w, b, gamma, beta, stride=1, padding=0, norm="batch", groups=1, act="silu", eps=1e-5
):
    y = jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=DN,
    )
    y = y.astype(jnp.float32) + b.astype(jnp.float32)
    y = _norm(y, gamma.astype(jnp.float32), beta.astype(jnp.float32), norm=norm, groups=groups, eps=eps)
    return _act(y, act).astype(x.dtype)


def sppf_pyramid_ref(x, window=5, reps=3):
    """SPPF tail oracle: the exact op sequence the unfused stage callables
    run — ``reps`` cascaded stride-1/same-padded max pools (reduce_window
    with a -inf identity, as ``nn.max_pool``) concatenated with the input
    along channels."""
    pad = window // 2
    outs = [x]
    for _ in range(reps):
        outs.append(
            jax.lax.reduce_window(
                outs[-1],
                -jnp.inf,
                jax.lax.max,
                (1, window, window, 1),
                (1, 1, 1, 1),
                [(0, 0), (pad, pad), (pad, pad), (0, 0)],
            )
        )
    return jnp.concatenate(outs, axis=-1)


def deconv_block_ref(x, w, b, gamma, beta, norm="batch", groups=1, act="relu", eps=1e-5):
    """k=4/stride=2 VALID transposed conv + border crop (torch padding=1)
    + bias + norm + act — the Pix2Pix up-block sequence."""
    y = jax.lax.conv_transpose(
        x, w.astype(x.dtype), strides=(2, 2), padding="VALID", dimension_numbers=DN
    )
    y = y[:, 1:-1, 1:-1, :]
    y = y.astype(jnp.float32) + b.astype(jnp.float32)
    y = _norm(y, gamma.astype(jnp.float32), beta.astype(jnp.float32), norm=norm, groups=groups, eps=eps)
    return _act(y, act).astype(x.dtype)
