"""Pallas TPU kernels: fused conv/deconv + norm + activation serving blocks.

The serving hot path runs `conv -> norm -> act` (Pix2Pix down blocks, every
YOLO fused conv block) and `deconv -> crop -> norm -> act` (Pix2Pix up
blocks) as separate XLA ops: each stage round-trips the activation through
HBM. These kernels fuse a whole block into one pallas_call — the conv is
tap-decomposed into k*k dense (Cin x Cout) GEMMs (pure MXU work, same
idiom as the phase-decomposed deconv), the norm statistics and the
activation are applied in-register, and only the block's final output is
written back.

Grid is (B,): one sample per step, whole spatial extent in VMEM (serving
shapes: <= 64x64x64 fp32 ~ 1 MB, comfortably inside ~16 MB). Per-sample
statistics make the fused norm exact for instance/group norm at any batch
and for batch norm at B == 1 — the serving case (frames are single
samples; only batch-independent models merge micro-batches). The ops
wrapper falls back to the reference for B > 1 batch norm.

The deconv kernel reuses the phase-matmul decomposition from
``kernels.deconv`` (k=4, stride=2; torch padding=1 — i.e. the paper's
crop — folded into the phase arithmetic, so deconv+crop is one kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import load_block
from ..deconv.kernel import _phase_matmuls

ACTS = ("none", "relu", "lrelu", "silu", "tanh")
NORMS = ("none", "batch", "instance", "group")


def _norm_act(y, gamma, beta, *, norm, groups, act, eps):
    """Per-sample norm + activation on a (H, W, C) fp32 tile."""
    if norm in ("batch", "instance"):
        # batch stats at B==1 == instance stats; mirrors BatchNorm2D math
        mean = jnp.mean(y, axis=(0, 1), keepdims=True)
        var = jnp.var(y, axis=(0, 1), keepdims=True)
        y = (y - mean) * jax.lax.rsqrt(var + eps)
        y = y * gamma + beta
    elif norm == "group":
        H, W, C = y.shape
        yg = y.reshape(H, W, groups, C // groups)
        mean = jnp.mean(yg, axis=(0, 1, 3), keepdims=True)
        var = jnp.var(yg, axis=(0, 1, 3), keepdims=True)
        y = ((yg - mean) * jax.lax.rsqrt(var + eps)).reshape(H, W, C)
        y = y * gamma + beta
    if act == "relu":
        y = jax.nn.relu(y)
    elif act == "lrelu":
        y = jax.nn.leaky_relu(y, 0.2)
    elif act == "silu":
        y = jax.nn.silu(y)
    elif act == "tanh":
        y = jnp.tanh(y)
    return y


def _conv_block_kernel(
    x_ref, w_ref, b_ref, g_ref, bt_ref, o_ref, *, k, stride, pad, Ho, Wo, norm, groups, act, eps
):
    # singleton batch axis via the shared jax-0.4.37 int-index workaround
    x = load_block(x_ref, 0, slice(None), slice(None), slice(None)).astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    w = w_ref[...].astype(jnp.float32)  # (k, k, Cin, Cout)
    cin, cout = w.shape[2], w.shape[3]
    acc = jnp.zeros((Ho * Wo, cout), jnp.float32)
    # tap decomposition: k*k strided windows, each a dense (Cin x Cout) GEMM
    for ki in range(k):
        for kj in range(k):
            win = jax.lax.slice(
                x,
                (ki, kj, 0),
                (ki + stride * (Ho - 1) + 1, kj + stride * (Wo - 1) + 1, cin),
                (stride, stride, 1),
            )
            acc = acc + jax.lax.dot_general(
                win.reshape(Ho * Wo, cin),
                w[ki, kj],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    y = acc.reshape(Ho, Wo, cout) + b_ref[...].astype(jnp.float32)
    y = _norm_act(y, g_ref[...].astype(jnp.float32), bt_ref[...].astype(jnp.float32),
                  norm=norm, groups=groups, act=act, eps=eps)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "norm", "groups", "act", "eps", "interpret")
)
def conv_block_pallas(
    x,
    w,
    b,
    gamma,
    beta,
    stride: int = 1,
    padding: int = 0,
    norm: str = "batch",
    groups: int = 1,
    act: str = "silu",
    eps: float = 1e-5,
    interpret: bool = True,
):
    """Fused conv(+bias) + norm + act. x: (B, H, W, Cin) -> (B, Ho, Wo, Cout).

    ``b``/``gamma``/``beta``: (Cout,) conv bias and norm affine (pass zeros/
    ones to disable). Norm statistics are per-sample — exact for instance/
    group norm, and for batch norm only at B == 1 (the ops wrapper guards).
    """
    B, H, W, Cin = x.shape
    k = w.shape[0]
    Cout = w.shape[-1]
    Ho = (H + 2 * padding - k) // stride + 1
    Wo = (W + 2 * padding - k) // stride + 1
    assert norm in NORMS and act in ACTS, (norm, act)
    kernel = functools.partial(
        _conv_block_kernel,
        k=k, stride=stride, pad=padding, Ho=Ho, Wo=Wo,
        norm=norm, groups=groups, act=act, eps=eps,
    )
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, Cin), lambda bi: (bi, 0, 0, 0)),
            pl.BlockSpec((k, k, Cin, Cout), lambda bi: (0, 0, 0, 0)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Ho, Wo, Cout), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Cout), x.dtype),
        interpret=interpret,
    )(x, w, b, gamma, beta)


def _sppf_kernel(x_ref, o_ref, *, H, W, C, window, reps):
    """SPPF pool pyramid: ``reps`` cascaded stride-1 max pools on one
    sample, concatenated with the input along channels — all in VMEM, one
    write of the (H, W, (reps+1)*C) result. Each pool is window*window
    static slices reduced by max (-inf halo), so padded positions can
    never win: bit-exact vs the reduce_window reference at any dtype."""
    x = load_block(x_ref, 0, slice(None), slice(None), slice(None))  # (H, W, C)
    pad = window // 2
    neg = jnp.asarray(-jnp.inf, x.dtype)
    outs = [x]
    cur = x
    for _ in range(reps):
        xp = jnp.pad(cur, ((pad, pad), (pad, pad), (0, 0)), constant_values=neg)
        m = None
        for ki in range(window):
            for kj in range(window):
                win = jax.lax.slice(xp, (ki, kj, 0), (ki + H, kj + W, C))
                m = win if m is None else jnp.maximum(m, win)
        cur = m
        outs.append(cur)
    o_ref[0] = jnp.concatenate(outs, axis=-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "reps", "interpret"))
def sppf_pyramid_pallas(x, window: int = 5, reps: int = 3, interpret: bool = True):
    """Fused SPPF tail: (B, H, W, C) -> (B, H, W, (reps+1)*C) — the
    concat of the input with ``reps`` cascaded stride-1/same max pools
    (YOLOv8: 5x5, reps=3). Pure max/concat, so no per-sample-statistics
    caveat: exact at any batch."""
    B, H, W, C = x.shape
    kernel = functools.partial(_sppf_kernel, H=H, W=W, C=C, window=window, reps=reps)
    Cout = (reps + 1) * C
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, W, C), lambda bi: (bi, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, H, W, Cout), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cout), x.dtype),
        interpret=interpret,
    )(x)


def _deconv_block_kernel(x_ref, w_ref, b_ref, g_ref, bt_ref, o_ref, *, H, W, norm, groups, act, eps):
    x_0 = load_block(x_ref, 0, slice(None), slice(None), slice(None))  # (H, W, Cin)
    # whole sample per grid step: the +-1 row halos are plain shifts
    x_m1 = jnp.concatenate([jnp.zeros_like(x_0[:1]), x_0[:-1]], axis=0)
    x_p1 = jnp.concatenate([x_0[1:], jnp.zeros_like(x_0[:1])], axis=0)
    tile = _phase_matmuls(x_m1, x_0, x_p1, w_ref[...], H, W)  # (H, 2, W, 2, Cout)
    y = tile.reshape(2 * H, 2 * W, -1) + b_ref[...].astype(jnp.float32)
    y = _norm_act(y, g_ref[...].astype(jnp.float32), bt_ref[...].astype(jnp.float32),
                  norm=norm, groups=groups, act=act, eps=eps)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("norm", "groups", "act", "eps", "interpret"))
def deconv_block_pallas(
    x,
    w,
    b,
    gamma,
    beta,
    norm: str = "batch",
    groups: int = 1,
    act: str = "relu",
    eps: float = 1e-5,
    interpret: bool = True,
):
    """Fused k=4/stride=2/torch-padding-1 deconv (crop folded) + norm + act.

    x: (B, H, W, Cin) -> (B, 2H, 2W, Cout); weights (4, 4, Cin, Cout).
    Same per-sample-statistics caveat as ``conv_block_pallas``.
    """
    B, H, W, Cin = x.shape
    assert w.shape[:2] == (4, 4), "phase decomposition is specialized to k=4"
    Cout = w.shape[-1]
    assert norm in NORMS and act in ACTS, (norm, act)
    kernel = functools.partial(
        _deconv_block_kernel, H=H, W=W, norm=norm, groups=groups, act=act, eps=eps
    )
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, Cin), lambda bi: (bi, 0, 0, 0)),
            pl.BlockSpec((4, 4, Cin, Cout), lambda bi: (0, 0, 0, 0)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
            pl.BlockSpec((Cout,), lambda bi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 2 * H, 2 * W, Cout), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 2 * H, 2 * W, Cout), x.dtype),
        interpret=interpret,
    )(x, w, b, gamma, beta)
