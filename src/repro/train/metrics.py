"""Image-quality metrics from the paper (§III.B eq. 1-3) + detection IoU."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mse(original, generated):
    """Paper eq. (1). Images in [0, 255] convention for Table II parity."""
    o = original.astype(jnp.float32)
    g = generated.astype(jnp.float32)
    return jnp.mean(jnp.square(o - g), axis=(-3, -2, -1))


def psnr(original, generated, max_val: float = 255.0):
    """Paper eq. (2): 10 log10((L-1)^2 / MSE)."""
    m = mse(original, generated)
    return 10.0 * jnp.log10(jnp.square(max_val) / jnp.maximum(m, 1e-12))


def _gaussian_kernel(size: int = 11, sigma: float = 1.5):
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-0.5 * jnp.square(x / sigma))
    g = g / jnp.sum(g)
    return jnp.outer(g, g)


def ssim(original, generated, max_val: float = 255.0, size: int = 11, sigma: float = 1.5):
    """Paper eq. (3), standard Gaussian-window SSIM, averaged over channels.

    Inputs (B, H, W, C) in [0, max_val]."""
    k1, k2 = 0.01, 0.03
    c1, c2 = (k1 * max_val) ** 2, (k2 * max_val) ** 2
    kern = _gaussian_kernel(size, sigma)[..., None, None]  # (s,s,1,1)

    def filt(img):
        B, H, W, C = img.shape
        x = jnp.moveaxis(img, -1, 1).reshape(B * C, H, W, 1)
        y = jax.lax.conv_general_dilated(
            x, kern, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return y.reshape(B, C, y.shape[1], y.shape[2]).transpose(0, 2, 3, 1)

    o = original.astype(jnp.float32)
    g = generated.astype(jnp.float32)
    mu_o, mu_g = filt(o), filt(g)
    var_o = filt(o * o) - mu_o**2
    var_g = filt(g * g) - mu_g**2
    cov = filt(o * g) - mu_o * mu_g
    s = ((2 * mu_o * mu_g + c1) * (2 * cov + c2)) / (
        (mu_o**2 + mu_g**2 + c1) * (var_o + var_g + c2)
    )
    return jnp.mean(s, axis=(-3, -2, -1))


def to_uint8_range(x):
    """[-1, 1] tanh output -> [0, 255]."""
    return (jnp.clip(x, -1.0, 1.0) + 1.0) * 127.5


def box_iou(a, b):
    """a, b: (..., 4) as (x1, y1, x2, y2)."""
    x1 = jnp.maximum(a[..., 0], b[..., 0])
    y1 = jnp.maximum(a[..., 1], b[..., 1])
    x2 = jnp.minimum(a[..., 2], b[..., 2])
    y2 = jnp.minimum(a[..., 3], b[..., 3])
    inter = jnp.maximum(x2 - x1, 0) * jnp.maximum(y2 - y1, 0)
    area_a = jnp.maximum(a[..., 2] - a[..., 0], 0) * jnp.maximum(a[..., 3] - a[..., 1], 0)
    area_b = jnp.maximum(b[..., 2] - b[..., 0], 0) * jnp.maximum(b[..., 3] - b[..., 1], 0)
    return inter / jnp.maximum(area_a + area_b - inter, 1e-9)
