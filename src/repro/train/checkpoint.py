"""Fault-tolerant checkpointing (no orbax in this environment).

Format: one zstd-compressed msgpack file per host process per step,
``<dir>/step_<N>/shard_<proc>.ckpt`` + an atomically-renamed ``MANIFEST``
committing the step. Properties needed at cluster scale:

* **atomic commit** — a step is visible only after its MANIFEST rename;
  a crash mid-write leaves the previous checkpoint intact.
* **async save** — serialization happens on a writer thread after
  ``jax.device_get`` (off the training critical path).
* **keep-k GC** — bounded disk usage.
* **elastic restore** — arrays are loaded host-side and re-placed with
  *new* shardings, so a checkpoint written on one mesh restores onto a
  differently-sized mesh (elastic scaling / failure recovery).
* **integrity** — per-leaf checksums; a corrupt newest checkpoint falls
  back to the previous one.
"""
from __future__ import annotations

import os
import queue
import shutil
import struct
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # compression is optional — fall back to uncompressed payloads
    import zstandard
except ImportError:  # pragma: no cover - exercised on zstd-less containers
    zstandard = None

HAVE_ZSTD = zstandard is not None

MANIFEST = "MANIFEST"

# shard header: <Q raw_len><B codec><payload>. Legacy shards (zstd-only
# format) lack the codec byte; their payload always starts with the zstd
# magic 0x28, which no codec id uses, so readers can tell them apart.
CODEC_RAW = 0
CODEC_ZSTD = 1
_ZSTD_MAGIC_BYTE = 0x28


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _pack_array(a: np.ndarray) -> dict:
    if a.dtype == jnp.bfloat16:
        data = a.view(np.uint16).tobytes()
        dtype = "bfloat16"
    else:
        data = a.tobytes()
        dtype = a.dtype.str
    return {
        "dtype": dtype,
        "shape": list(a.shape),
        "crc": zlib.crc32(data),
        "data": data,
    }


def _unpack_array(d: dict) -> np.ndarray:
    data = d["data"]
    if zlib.crc32(data) != d["crc"]:
        raise IOError("checkpoint leaf checksum mismatch")
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(data, np.uint16).reshape(d["shape"]).view(jnp.bfloat16)
    else:
        a = np.frombuffer(data, np.dtype(d["dtype"])).reshape(d["shape"])
    return a


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None, process_index: int = 0, n_processes: int = 1):
    """Synchronous save. Call on already-device_get'd host data for async."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    payload = {
        "step": step,
        "extra": extra or {},
        "arrays": {k: _pack_array(v) for k, v in flat.items()},
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if HAVE_ZSTD:
        codec, data = CODEC_ZSTD, zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        codec, data = CODEC_RAW, raw
    shard = os.path.join(step_dir, f"shard_{process_index:05d}.ckpt")
    tmp = shard + ".tmp"
    with open(tmp, "wb") as f:
        f.write(struct.pack("<QB", len(raw), codec))
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, shard)
    # commit: manifest names the step (process 0 only on multihost)
    if process_index == 0:
        mtmp = os.path.join(ckpt_dir, MANIFEST + ".tmp")
        with open(mtmp, "w") as f:
            f.write(f"{step}\n{n_processes}\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(ckpt_dir, MANIFEST))
    return shard


def _load_shard(path: str) -> dict:
    with open(path, "rb") as f:
        rawlen = struct.unpack("<Q", f.read(8))[0]
        head = f.read(1)
        body = f.read()
    if not head:
        raise IOError(f"truncated checkpoint shard {path}")
    codec = head[0]
    if codec == _ZSTD_MAGIC_BYTE:  # legacy shard: payload starts right here
        codec, body = CODEC_ZSTD, head + body
    if codec == CODEC_RAW:
        raw = body
    elif codec == CODEC_ZSTD:
        if not HAVE_ZSTD:
            raise IOError(f"{path} is zstd-compressed but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(body, max_output_size=rawlen)
    else:
        raise IOError(f"unknown checkpoint codec {codec} in {path}")
    if len(raw) != rawlen:
        raise IOError(f"checkpoint payload length mismatch in {path}")
    return msgpack.unpackb(raw, raw=False)


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    mpath = os.path.join(ckpt_dir, MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            return int(f.readline())
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    template: Any,
    step: int | None = None,
    shardings: Any = None,
    process_index: int = 0,
):
    """Restore into the structure of ``template``. ``shardings`` (matching
    pytree of jax.sharding.Sharding or None) re-places arrays — possibly on
    a different mesh than the one that wrote the checkpoint (elastic).
    Falls back to the previous step if the newest shard is corrupt."""
    candidates = [step] if step is not None else list(reversed(available_steps(ckpt_dir)))
    last_err = None
    for s in candidates:
        shard = os.path.join(ckpt_dir, f"step_{s:010d}", f"shard_{process_index:05d}.ckpt")
        try:
            payload = _load_shard(shard)
            arrays = {k: _unpack_array(v) for k, v in payload["arrays"].items()}
            leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
            treedef = jax.tree.structure(template)
            out = []
            for path, leaf in leaves_paths:
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
                if key not in arrays:
                    raise KeyError(f"checkpoint missing leaf {key}")
                a = arrays[key]
                want_shape = tuple(leaf.shape)
                if tuple(a.shape) != want_shape:
                    raise ValueError(f"shape mismatch for {key}: {a.shape} vs {want_shape}")
                out.append(a)
            tree = jax.tree.unflatten(treedef, out)
            if shardings is not None:
                tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
            else:
                tree = jax.tree.map(jnp.asarray, tree)
            return tree, payload["step"], payload.get("extra", {})
        except Exception as e:  # corrupt/partial -> try older
            last_err = e
            continue
    raise FileNotFoundError(f"no restorable checkpoint in {ckpt_dir}: {last_err}")


def gc_checkpoints(ckpt_dir: str, keep: int = 3):
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background writer thread; the train loop only pays device_get."""

    def __init__(self, ckpt_dir: str, keep: int = 3, process_index: int = 0):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.process_index = process_index
        self.q: queue.Queue = queue.Queue(maxsize=2)
        self.errors: list[Exception] = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self.q.get()
            try:
                if item is None:
                    return
                step, tree, extra = item
                save_checkpoint(self.ckpt_dir, step, tree, extra, self.process_index)
                gc_checkpoints(self.ckpt_dir, self.keep)
            except Exception as e:  # pragma: no cover
                self.errors.append(e)
            finally:
                self.q.task_done()

    def save(self, step: int, tree: Any, extra: dict | None = None):
        host_tree = jax.device_get(tree)  # synchronous copy; write is async
        self.q.put((step, host_tree, extra))

    def wait(self):
        """Block until all queued saves are durable; surface writer errors."""
        self.q.join()
        if self.errors:
            raise self.errors[0]

    def close(self):
        self.wait()
        self.q.put(None)
        self._thread.join(timeout=10)
