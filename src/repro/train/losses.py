"""Losses: LM cross-entropy, Pix2Pix GAN objectives, simplified detection."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) (any float dtype), labels (B,S) int.

    Sharding-friendly: the gold logit is extracted with an iota compare +
    masked reduce (fuses into the reduction and partitions over a sharded
    vocab dim) rather than take_along_axis (which makes GSPMD all-gather
    the vocab axis). Accumulation in fp32 without materializing an fp32
    copy of the logits."""
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], shifted, 0.0), axis=-1)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(head_fn, params, hidden, labels, mask=None, chunk: int = 512):
    """Fused LM-head + loss over sequence chunks: the (B, S, V) logits are
    never materialized — each (B, chunk, V) block is computed, reduced to
    per-token NLL, and rematerialized in backward (jax.checkpoint).

    head_fn(params, h) -> logits for a hidden chunk h (B, c, d)."""
    B, S = labels.shape
    if S % chunk or S <= chunk:
        return cross_entropy(head_fn(params, hidden), labels, mask)
    nc = S // chunk

    def body(i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = head_fn(params, h)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        shifted = (logits - m).astype(jnp.float32)
        logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == lb[..., None], shifted, 0.0), axis=-1)
        return logz - gold  # (B, chunk)

    nll = jax.lax.map(jax.checkpoint(body), jnp.arange(nc, dtype=jnp.int32))
    nll = jnp.moveaxis(nll, 0, 1).reshape(B, S)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def bce_with_logits(logits, targets):
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * targets + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def pix2pix_g_loss(disc_fake_logits, fake, real, lambda_l1: float = 100.0):
    """Generator loss: BCE(D(x, G(x)), 1) + lambda * L1(G(x), y) (paper §V.A.1)."""
    adv = bce_with_logits(disc_fake_logits, jnp.ones_like(disc_fake_logits))
    l1 = jnp.mean(jnp.abs(fake.astype(jnp.float32) - real.astype(jnp.float32)))
    return adv + lambda_l1 * l1, {"g_adv": adv, "g_l1": l1}


def pix2pix_d_loss(disc_real_logits, disc_fake_logits):
    real = bce_with_logits(disc_real_logits, jnp.ones_like(disc_real_logits))
    fake = bce_with_logits(disc_fake_logits, jnp.zeros_like(disc_fake_logits))
    return real + fake, {"d_real": real, "d_fake": fake}


def yolo_loss(preds: dict, targets: dict, n_classes: int, reg_max: int = 16):
    """Simplified anchor-free detection loss on grid-assigned targets.

    targets per scale: {"cls": (B,H,W) int (-1 = background),
                        "box": (B,H,W,4) normalized l,t,r,b distances}.
    BCE on class logits + DFL-style CE on the discretized box distances
    for positive cells. (The paper consumes only detector throughput; this
    loss exists so the end-to-end training driver is runnable.)
    """
    total = jnp.zeros((), jnp.float32)
    n_pos_total = jnp.zeros((), jnp.float32)
    for scale in ("p3", "p4", "p5"):
        p = preds[scale].astype(jnp.float32)
        box_logits = p[..., : 4 * reg_max]
        cls_logits = p[..., 4 * reg_max :]
        t = targets[scale]
        pos = (t["cls"] >= 0).astype(jnp.float32)
        onehot = jax.nn.one_hot(jnp.maximum(t["cls"], 0), n_classes) * pos[..., None]
        cls_bce = jnp.maximum(cls_logits, 0) - cls_logits * onehot + jnp.log1p(
            jnp.exp(-jnp.abs(cls_logits))
        )
        total = total + jnp.sum(cls_bce) / cls_bce.size
        # DFL: each of 4 sides as distribution over reg_max bins
        B, H, W, _ = box_logits.shape
        bl = box_logits.reshape(B, H, W, 4, reg_max)
        tgt = jnp.clip(t["box"] * (reg_max - 1), 0, reg_max - 1)
        lo = jnp.floor(tgt).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, reg_max - 1)
        w_hi = tgt - lo
        logp = jax.nn.log_softmax(bl, axis=-1)
        nll = -(
            (1 - w_hi) * jnp.take_along_axis(logp, lo[..., None], -1)[..., 0]
            + w_hi * jnp.take_along_axis(logp, hi[..., None], -1)[..., 0]
        )
        total = total + jnp.sum(nll * pos[..., None]) / jnp.maximum(jnp.sum(pos) * 4, 1.0)
        n_pos_total = n_pos_total + jnp.sum(pos)
    return total, {"n_pos": n_pos_total}
