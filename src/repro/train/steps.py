"""Train / serve step factories.

These produce the pure functions that ``jax.jit`` lowers — the same
functions are used by the real training loop, the examples, the smoke
tests, and the multi-pod dry-run (on ShapeDtypeStructs).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .losses import chunked_cross_entropy, cross_entropy, pix2pix_d_loss, pix2pix_g_loss, yolo_loss


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def make_lm_train_step(
    model, optimizer, aux_weight: float = 0.01, n_micro: int = 1, loss_chunk: int = 512
):
    """batch = {"tokens": (B,S), "labels": (B,S), optional "mask", "positions",
    "extra_embeds", "embed_positions"(VLM), "frames"(whisper)}.

    ``loss_chunk`` fuses the LM head with the loss over sequence chunks so
    (B, S, vocab) logits are never materialized. ``n_micro > 1`` enables
    microbatched gradient accumulation (lax.scan): activation working set
    shrinks by n_micro; weight-grad reductions stay sharded."""

    def loss_fn(params, batch):
        kwargs = {}
        for k in ("positions", "extra_embeds", "embed_positions"):
            if k in batch:
                kwargs[k] = batch[k]
        if "frames" in batch:
            hidden, aux = model(params, batch["frames"], batch["tokens"], return_hidden=True)
        else:
            hidden, aux = model(params, batch["tokens"], return_hidden=True, **kwargs)
        ce = chunked_cross_entropy(
            model.head, params, hidden, batch["labels"], batch.get("mask"), chunk=loss_chunk
        )
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    def grad_fn(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # lax.scan accumulation: the loop carry serializes microbatches so
        # peak memory is ONE microbatch (an unrolled python loop lets the
        # scheduler hoist all forwards before the backwards — measured 9x
        # peak memory). NOTE: XLA cost_analysis counts the while body once;
        # the dry-run analysis scales in-loop flops/bytes by n_micro.
        # sharding-preserving split: reshape (B,...) -> (B/n, n, ...) keeps
        # dim0 block-local per device, then moveaxis so scan slices dim0.
        # A direct (n, B/n, ...) reshape regroups rows ACROSS devices and
        # makes GSPMD all-gather every microbatch.
        split = jax.tree.map(
            lambda x: jnp.moveaxis(
                x.reshape(x.shape[0] // n_micro, n_micro, *x.shape[1:]), 1, 0
            ),
            batch,
        )

        def body(acc, mb):
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = (
                acc[0] + loss,
                jax.tree.map(lambda a, b: a + b, acc[1], parts),
                jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc[2], grads),
            )
            return acc, None

        zero_parts = {"ce": jnp.zeros((), jnp.float32), "aux": jnp.zeros((), jnp.float32)}
        zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, parts, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_parts, zero_grads), split
        )
        inv = 1.0 / n_micro
        return (loss * inv, jax.tree.map(lambda x: x * inv, parts)), jax.tree.map(
            lambda g: g * inv, grads
        )

    def train_step(params, opt_state, batch):
        (loss, parts), grads = grad_fn(params, batch)
        params, opt_state, opt_info = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **parts, **opt_info}
        return params, opt_state, metrics

    return train_step


def make_lm_decode_step(model):
    """One serving decode step: (params, token, caches, t) -> (logits, caches)."""

    def decode_step(params, token, caches, t):
        return model.decode_step(params, token, caches, t)

    return decode_step


def make_lm_prefill(model):
    def prefill(params, tokens):
        return model.prefill(params, tokens)

    return prefill


def greedy_generate(model, params, prompt, steps: int, max_len: int, cache_dtype=jnp.bfloat16):
    """Reference sampling loop (prefill + greedy decode)."""
    B, S = prompt.shape
    caches = model.init_caches(B, max_len, dtype=cache_dtype)
    logits = None
    tok = prompt[:, :1]
    outs = []
    for t in range(S + steps - 1):
        logits, caches = model.decode_step(params, tok, caches, t)
        if t + 1 < S:
            tok = prompt[:, t + 1 : t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
            outs.append(tok)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Pix2Pix GAN
# ---------------------------------------------------------------------------


def make_pix2pix_train_step(model, g_opt, d_opt, lambda_l1: float = 100.0):
    """params = {"generator": ..., "discriminator": ...};
    opt_state = {"g": ..., "d": ...}; batch = {"src": CT, "dst": MRI} in [-1,1]."""

    def g_loss_fn(g_params, d_params, batch, rng):
        fake = model.generate({"generator": g_params}, batch["src"], rng=rng, train=True)
        d_fake = model.discriminate({"discriminator": d_params}, batch["src"], fake)
        loss, parts = pix2pix_g_loss(d_fake, fake, batch["dst"], lambda_l1)
        return loss, (parts, fake)

    def d_loss_fn(d_params, batch, fake):
        d_real = model.discriminate({"discriminator": d_params}, batch["src"], batch["dst"])
        d_fake = model.discriminate({"discriminator": d_params}, batch["src"], jax.lax.stop_gradient(fake))
        return pix2pix_d_loss(d_real, d_fake)

    def train_step(params, opt_state, batch, rng):
        (g_loss, (g_parts, fake)), g_grads = jax.value_and_grad(g_loss_fn, has_aux=True)(
            params["generator"], params["discriminator"], batch, rng
        )
        (d_loss, d_parts), d_grads = jax.value_and_grad(d_loss_fn, has_aux=True)(
            params["discriminator"], batch, fake
        )
        new_g, g_state, g_info = g_opt.update(g_grads, opt_state["g"], params["generator"])
        new_d, d_state, d_info = d_opt.update(d_grads, opt_state["d"], params["discriminator"])
        params = {"generator": new_g, "discriminator": new_d}
        opt_state = {"g": g_state, "d": d_state}
        metrics = {"g_loss": g_loss, "d_loss": d_loss, **g_parts, **d_parts}
        return params, opt_state, metrics

    return train_step


def make_pix2pix_infer(model):
    def infer(params, src):
        return model.generate(params, src, train=False)

    return infer


# ---------------------------------------------------------------------------
# YOLOv8
# ---------------------------------------------------------------------------


def make_yolo_train_step(model, optimizer):
    cfg = model.cfg

    def loss_fn(params, batch):
        preds = model(params, batch["image"])
        loss, parts = yolo_loss(preds, batch["targets"], cfg.n_classes, cfg.reg_max)
        return loss, parts

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_info = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **opt_info}

    return train_step
