"""Generic fault-tolerant training loop.

Features needed at 1000-node scale, all exercised by tests:
* auto-resume from the newest intact checkpoint (atomic manifest),
* async checkpointing off the critical path,
* straggler watchdog: per-step wall time vs. an EMA; slow steps are
  logged and counted (on a real cluster this signal feeds the restart /
  re-shard supervisor in ``launch.supervisor``),
* crash recovery: any exception flushes a final checkpoint before
  re-raising, so the supervisor restarts from the last good step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax

from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than factor*EMA => straggler event
    ema_decay: float = 0.9


@dataclasses.dataclass
class LoopState:
    params: Any
    opt_state: Any
    step: int = 0
    history: list[dict] = dataclasses.field(default_factory=list)
    straggler_events: list[tuple[int, float, float]] = dataclasses.field(default_factory=list)


def run_train_loop(
    train_step: Callable,
    params: Any,
    opt_state: Any,
    data_iter: Iterator,
    cfg: LoopConfig,
    rng: jax.Array | None = None,
    resume: bool = True,
    log_fn: Callable[[str], None] = print,
    shardings: Any = None,
) -> LoopState:
    state = LoopState(params=params, opt_state=opt_state)
    ckptr = None
    if cfg.ckpt_dir:
        ckptr = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        if resume and latest_step(cfg.ckpt_dir) is not None:
            tree, step, extra = restore_checkpoint(
                cfg.ckpt_dir,
                {"params": params, "opt_state": opt_state},
                shardings=shardings,
            )
            state.params, state.opt_state = tree["params"], tree["opt_state"]
            state.step = step
            log_fn(f"[loop] resumed from step {step}")

    ema = None
    try:
        while state.step < cfg.total_steps:
            batch = next(data_iter)
            t0 = time.perf_counter()
            if rng is not None:
                step_rng = jax.random.fold_in(rng, state.step)
                out = train_step(state.params, state.opt_state, batch, step_rng)
            else:
                out = train_step(state.params, state.opt_state, batch)
            state.params, state.opt_state, metrics = out
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            dt = time.perf_counter() - t0
            state.step += 1

            if ema is None:
                ema = dt
            else:
                if dt > cfg.straggler_factor * ema:
                    state.straggler_events.append((state.step, dt, ema))
                    log_fn(f"[loop] STRAGGLER step {state.step}: {dt*1e3:.1f}ms vs EMA {ema*1e3:.1f}ms")
                ema = cfg.ema_decay * ema + (1 - cfg.ema_decay) * dt

            if state.step % cfg.log_every == 0 or state.step == cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                state.history.append({"step": state.step, "time": dt, **m})
                log_fn(f"[loop] step {state.step}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items()))

            if ckptr and state.step % cfg.ckpt_every == 0:
                ckptr.save(state.step, {"params": state.params, "opt_state": state.opt_state})
    except Exception:
        if ckptr:  # flush a rescue checkpoint so the supervisor can resume
            try:
                ckptr.save(state.step, {"params": state.params, "opt_state": state.opt_state})
                ckptr.wait()
            except Exception:
                pass
        raise
    finally:
        if ckptr:
            ckptr.save(state.step, {"params": state.params, "opt_state": state.opt_state})
            ckptr.close()
    return state
