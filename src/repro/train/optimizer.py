"""Optimizers from scratch (no optax in this environment).

AdamW with fp32 moments, global-norm clipping, and schedule support.
States are plain pytrees -> checkpointable/shardable like params
(moments inherit each param's logical sharding axes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_lr(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return f


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Schedule | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float | None = 1.0
    # keep an fp32 master copy in the optimizer state so params (and hence
    # FSDP all-gathers / grad reduce-scatters) can live in bf16 — halves
    # the dominant collective traffic of FSDP training.
    master_weights: bool = False

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.master_weights:
            st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return st

    def abstract_state(self, abstract_params):
        sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        st = {
            "m": jax.tree.map(sds, abstract_params),
            "v": jax.tree.map(sds, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if self.master_weights:
            st["master"] = jax.tree.map(sds, abstract_params)
        return st

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.zeros((), jnp.float32)
        if self.grad_clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip_norm)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v, master=None):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            ref = master if master is not None else p.astype(jnp.float32)
            if self.weight_decay:
                delta = delta + self.weight_decay * ref
            new_ref = ref - lr * delta
            return new_ref.astype(p.dtype), m, v, new_ref

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_ma = jax.tree.leaves(state["master"]) if self.master_weights else [None] * len(flat_p)
        out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "step": step}
        if self.master_weights:
            new_state["master"] = jax.tree.unflatten(treedef, [o[3] for o in out])
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass(frozen=True)
class Adam(AdamW):
    """Adam = AdamW with zero decoupled weight decay (pix2pix uses
    Adam(2e-4, b1=0.5) per the paper's reference implementation)."""

    weight_decay: float = 0.0


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Schedule | float = 0.01
    momentum: float = 0.9

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def init(self, params):
        return {
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def abstract_state(self, abstract_params):
        return {
            "mom": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)

        def upd(p, g, m):
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, treedef = jax.tree.flatten(params)
        out = [
            upd(p, g, m)
            for p, g, m in zip(flat_p, jax.tree.leaves(grads), jax.tree.leaves(state["mom"]))
        ]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, {"mom": new_m, "step": step}, {"lr": lr}
