from .optimizer import Adam, AdamW, SGD, constant_lr, warmup_cosine, global_norm
from .losses import bce_with_logits, cross_entropy, pix2pix_d_loss, pix2pix_g_loss, yolo_loss
from .metrics import box_iou, mse, psnr, ssim, to_uint8_range
from .steps import (
    greedy_generate,
    make_lm_decode_step,
    make_lm_prefill,
    make_lm_train_step,
    make_pix2pix_infer,
    make_pix2pix_train_step,
    make_yolo_train_step,
)
from .checkpoint import (
    AsyncCheckpointer,
    available_steps,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .loop import LoopConfig, LoopState, run_train_loop
