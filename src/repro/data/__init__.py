from .synthetic import (
    PhantomConfig,
    detection_batches,
    grid_targets,
    make_phantom_pair,
    phantom_batches,
    token_batches,
)
from .loader import FailingIterator, Prefetcher, shard_batch
