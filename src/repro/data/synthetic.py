"""Synthetic data: paired CT/MRI brain phantoms + lesion boxes + LM tokens.

The paper's datasets ([28] paired CT/MRI, [35] stroke detection) are not
available offline; these generators produce *structured* phantoms with a
deterministic CT<->MRI intensity relationship so that the full training /
evaluation / metric pipeline is executable and the Table II *trends*
(cropping/conv variants vs original) are measurable.

Geometry per sample: an elliptical skull ring, 3-6 soft-tissue ellipses,
ventricle pair, and (with probability ``lesion_p``) a bright lesion blob.
CT mapping: bone bright, tissue flat, lesion faint. MRI mapping: bone
dark, tissue textured by class, lesion bright — i.e. the translation task
carries real information.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class PhantomConfig:
    img_size: int = 256
    lesion_p: float = 0.7
    n_tissue: tuple[int, int] = (3, 6)
    noise: float = 0.02


def _ellipse_mask(h, w, cy, cx, ry, rx, theta, yy, xx):
    ct, st = np.cos(theta), np.sin(theta)
    y = yy - cy
    x = xx - cx
    u = (ct * x + st * y) / rx
    v = (-st * x + ct * y) / ry
    return (u * u + v * v) <= 1.0


def make_phantom_pair(rng: np.random.Generator, cfg: PhantomConfig):
    """Returns (ct, mri, boxes, labels): images (H, W, 1) in [-1, 1];
    boxes (x1,y1,x2,y2) normalized; labels int (0 = lesion)."""
    s = cfg.img_size
    yy, xx = np.mgrid[0:s, 0:s].astype(np.float32)
    ct = np.full((s, s), -1.0, np.float32)
    mri = np.full((s, s), -1.0, np.float32)

    cy, cx = s / 2 + rng.uniform(-8, 8), s / 2 + rng.uniform(-8, 8)
    ry, rx = s * rng.uniform(0.36, 0.44), s * rng.uniform(0.30, 0.38)
    theta = rng.uniform(-0.3, 0.3)
    skull_outer = _ellipse_mask(s, s, cy, cx, ry, rx, theta, yy, xx)
    skull_inner = _ellipse_mask(s, s, cy, cx, ry * 0.92, rx * 0.92, theta, yy, xx)
    brain = skull_inner
    ring = skull_outer & ~skull_inner
    # CT: bone very bright, brain mildly uniform
    ct[ring] = 0.95
    ct[brain] = -0.1
    # MRI: bone dark, brain bright-ish grey
    mri[ring] = -0.85
    mri[brain] = 0.15

    n_tis = rng.integers(cfg.n_tissue[0], cfg.n_tissue[1] + 1)
    for i in range(n_tis):
        tcy = cy + rng.uniform(-0.5, 0.5) * ry
        tcx = cx + rng.uniform(-0.5, 0.5) * rx
        tr = rng.uniform(0.08, 0.22) * min(ry, rx)
        m = _ellipse_mask(s, s, tcy, tcx, tr, tr * rng.uniform(0.6, 1.4), rng.uniform(0, np.pi), yy, xx) & brain
        cls = rng.integers(0, 3)
        ct[m] = ct[m] + [0.05, 0.12, -0.05][cls]
        mri[m] = mri[m] + [0.45, -0.25, 0.3][cls]  # tissue contrast lives in MRI

    # ventricles
    for sgn in (-1, 1):
        m = _ellipse_mask(s, s, cy, cx + sgn * 0.18 * rx, ry * 0.22, rx * 0.1, theta + sgn * 0.5, yy, xx) & brain
        ct[m] = -0.25
        mri[m] = -0.55

    boxes, labels = [], []
    if rng.uniform() < cfg.lesion_p:
        lcy = cy + rng.uniform(-0.45, 0.45) * ry
        lcx = cx + rng.uniform(-0.45, 0.45) * rx
        lr = rng.uniform(0.05, 0.12) * min(ry, rx)
        lrx = lr * rng.uniform(0.7, 1.3)
        m = _ellipse_mask(s, s, lcy, lcx, lr, lrx, rng.uniform(0, np.pi), yy, xx) & brain
        ct[m] = 0.35  # hyperdense on CT (hemorrhagic stroke)
        mri[m] = 0.9
        if m.any():
            ys, xs = np.where(m)
            boxes.append([xs.min() / s, ys.min() / s, (xs.max() + 1) / s, (ys.max() + 1) / s])
            labels.append(0)

    noise = rng.normal(0, cfg.noise, (2, s, s)).astype(np.float32)
    ct = np.clip(ct + noise[0], -1, 1)[..., None]
    mri = np.clip(mri + noise[1], -1, 1)[..., None]
    return ct, mri, np.array(boxes, np.float32).reshape(-1, 4), np.array(labels, np.int32)


def phantom_batches(
    batch: int, cfg: PhantomConfig = PhantomConfig(), seed: int = 0, channels: int = 3
) -> Iterator[dict]:
    """Infinite iterator of {"src": CT, "dst": MRI} batches (NHWC, [-1,1])."""
    rng = np.random.default_rng(seed)
    while True:
        cts, mris = [], []
        for _ in range(batch):
            ct, mri, _, _ = make_phantom_pair(rng, cfg)
            cts.append(np.repeat(ct, channels, axis=-1))
            mris.append(np.repeat(mri, channels, axis=-1))
        yield {"src": np.stack(cts), "dst": np.stack(mris)}


def grid_targets(boxes, labels, img_size: int, strides=(8, 16, 32), n_classes: int = 2):
    """Assign boxes to center cells per FPN scale (simplified TAL)."""
    out = {}
    for name, st in zip(("p3", "p4", "p5"), strides):
        g = img_size // st
        cls = np.full((g, g), -1, np.int32)
        box = np.zeros((g, g, 4), np.float32)
        for b, l in zip(boxes, labels):
            cx, cy = (b[0] + b[2]) / 2 * g, (b[1] + b[3]) / 2 * g
            ix, iy = int(np.clip(cx, 0, g - 1)), int(np.clip(cy, 0, g - 1))
            cls[iy, ix] = l
            # l, t, r, b distances normalized to [0,1] by scale extent
            box[iy, ix] = np.clip(
                [cx - b[0] * g, cy - b[1] * g, b[2] * g - cx, b[3] * g - cy], 0, g
            ) / g
        out[name] = {"cls": cls, "box": box}
    return out


def detection_batches(
    batch: int, cfg: PhantomConfig = PhantomConfig(), seed: int = 0, n_classes: int = 2
) -> Iterator[dict]:
    """Infinite iterator of {"image", "targets"} for the YOLO driver."""
    rng = np.random.default_rng(seed)
    while True:
        imgs, tgts = [], []
        for _ in range(batch):
            ct, _, boxes, labels = make_phantom_pair(rng, cfg)
            imgs.append(np.repeat(ct, 3, axis=-1))
            tgts.append(grid_targets(boxes, labels, cfg.img_size, n_classes=n_classes))
        targets = {
            k: {
                f: np.stack([t[k][f] for t in tgts])
                for f in ("cls", "box")
            }
            for k in ("p3", "p4", "p5")
        }
        yield {"image": np.stack(imgs), "targets": targets}


def token_batches(
    batch: int, seq_len: int, vocab: int, seed: int = 0, order: int = 2
) -> Iterator[dict]:
    """Synthetic LM stream with learnable structure: a random order-2
    Markov chain over a vocab subset (so loss decreases measurably)."""
    rng = np.random.default_rng(seed)
    sub = min(vocab, 64)
    trans = rng.integers(0, sub, size=(sub, sub, 2))  # 2 likely successors

    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        state = rng.integers(0, sub, size=(batch, 2))
        for t in range(seq_len + 1):
            choice = rng.integers(0, 2, size=batch)
            explore = rng.uniform(size=batch) < 0.05
            nxt = trans[state[:, 0], state[:, 1], choice]
            nxt = np.where(explore, rng.integers(0, sub, size=batch), nxt)
            toks[:, t] = nxt
            state = np.stack([state[:, 1], nxt], axis=1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
