"""Prefetching, device-placing data loader."""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax
import numpy as np


def shard_batch(batch: dict, sharding=None) -> dict:
    """Place a host batch on devices (with a NamedSharding when given)."""
    if sharding is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches ahead (overlap host
    data generation with device compute)."""

    def __init__(self, it: Iterator, depth: int = 2, transform: Callable | None = None):
        self.it = it
        self.transform = transform or (lambda x: x)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err: Exception | None = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(self.transform(item))
        except Exception as e:
            self.err = e
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            if self.err:
                raise self.err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


class FailingIterator:
    """Test utility: raises after ``fail_at`` batches (node-failure drill)."""

    def __init__(self, it: Iterator, fail_at: int):
        self.it, self.fail_at, self.count = it, fail_at, 0

    def __iter__(self):
        return self

    def __next__(self):
        if self.count == self.fail_at:
            raise RuntimeError(f"injected data failure at batch {self.count}")
        self.count += 1
        return next(self.it)
