"""Multi-model multi-engine schedules (the paper's §IV + §VI).

Three scheduling modes, exactly as evaluated by the paper:

* ``standalone``      — one model on one engine, illegal layers falling
                        back to the peer (Fig. 8/9/10).
* ``naive``           — model A whole on the constrained engine, model B
                        whole on the flexible engine (client-server
                        scheme, Fig. 11/12).
* ``haxconn``         — HaX-CoNN-style swap schedule: each model is split
                        at one partition point; the two instances run
                        counter-phased across both engines so busy times
                        balance (Tables III–VI). The two partition points
                        are found by exact search over all O(L_A * L_B)
                        candidates against the cost model — the two-engine
                        specialization of HaX-CoNN's SAT formulation,
                        solved optimally.

Every search takes a ``CostProvider`` (default: the analytic roofline),
so the same planners run against XLA-measured per-layer costs — the
HaX-CoNN observation that measured costs, not analytic ones, are what
make engine-allocation decisions transfer to hardware.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .cost_model import (
    ANALYTIC,
    CostProvider,
    SegmentCost,
    balanced_partition_point,
    graph_time,
    partition_boundary_bytes,
    segment_cost,
    transfer_time,
)
from .graph import LayerGraph
from .plan_ir import PlanIR, make_plan_ir


@dataclasses.dataclass
class EngineLoad:
    busy: float = 0.0  # productive compute time per cycle
    stall: float = 0.0  # waiting on peer fallback / transfers

    @property
    def fps(self):
        total = self.busy + self.stall
        return 1.0 / total if total > 0 else math.inf


@dataclasses.dataclass
class Schedule:
    kind: str
    models: tuple[str, ...]
    engines: tuple[str, ...]
    cycle_time: float  # steady-state seconds per frame (per model instance)
    loads: dict[str, EngineLoad]
    partitions: dict[str, tuple[int, int]] | None = None  # model -> (to_peer, back)
    notes: list[str] = dataclasses.field(default_factory=list)
    segments: list[tuple] = dataclasses.field(default_factory=list)  # (engine, label, dur)
    # the typed segment-level plan the serve stack consumes (every
    # scheduler emits one; None only for hand-built Schedule objects)
    ir: PlanIR | None = None

    @property
    def aggregate_fps(self):
        return len(self.models) / self.cycle_time if self.cycle_time > 0 else math.inf

    def engine_fps(self, name):
        return self.loads[name].fps

    def idle_fraction(self, name):
        l = self.loads[name]
        return 1.0 - l.busy / self.cycle_time if self.cycle_time else 0.0

    def ascii_timeline(self, width: int = 72) -> str:
        """Nsight-style textual timing diagram of one steady-state cycle."""
        lines = [f"cycle = {self.cycle_time*1e3:.2f} ms  ({self.aggregate_fps:.1f} FPS aggregate)"]
        scale = width / self.cycle_time if self.cycle_time else 0
        for eng in self.engines:
            segs = [(lbl, dur) for e, lbl, dur in self.segments if e == eng]
            bar, legend = "", []
            for lbl, dur in segs:
                n = max(1, int(dur * scale))
                ch = lbl[0].upper()
                bar += ch * n
                legend.append(f"{lbl}={dur*1e3:.2f}ms")
            bar = bar[:width].ljust(width, ".")
            lines.append(f"{eng:>9} |{bar}|")
            lines.append(f"{'':>9}  {' '.join(legend)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# standalone (Fig. 8/9/10)
# ---------------------------------------------------------------------------


def standalone_schedule(
    graph: LayerGraph, engine, peer, allow_fallback=True, provider: CostProvider | None = None
) -> Schedule:
    c = graph_time(graph, engine, peer, allow_fallback=allow_fallback, provider=provider)
    loads = {
        engine.name: EngineLoad(busy=c.engine_busy, stall=c.peer_busy + c.transfer),
        peer.name: EngineLoad(busy=c.peer_busy, stall=0.0),
    }
    segs = [(engine.name, "compute", c.engine_busy)]
    if c.peer_busy:
        segs += [(engine.name, "stall", c.peer_busy + c.transfer), (peer.name, "fallback", c.peer_busy)]
    sched = Schedule(
        kind="standalone",
        models=(graph.model_name,),
        engines=(engine.name, peer.name),
        cycle_time=c.elapsed,
        loads=loads,
        segments=segs,
        notes=[f"fallback_runs={c.n_fallback_runs}"],
        ir=make_plan_ir(
            (graph.model_name,),
            (engine.name, peer.name),
            [[(0, 0, len(graph), c.elapsed)]],
            expected_cycle=c.elapsed,
            cost_provider=(provider or ANALYTIC).name,
            kind="standalone",
            graphs=(graph,),
        ),
    )
    return sched


def peer_utilization(graph: LayerGraph, engine, peer, provider: CostProvider | None = None) -> float:
    """Fraction of the frame time the *peer* is busy serving fallbacks —
    the paper's Fig. 10 'GPU utilization of the DLA-assigned model'."""
    c = graph_time(graph, engine, peer, provider=provider)
    return c.peer_busy / c.elapsed if c.elapsed else 0.0


# ---------------------------------------------------------------------------
# naive concurrent (client-server scheme, Fig. 11/12)
# ---------------------------------------------------------------------------


def naive_schedule(
    graph_a: LayerGraph, graph_b: LayerGraph, constrained, flexible, provider: CostProvider | None = None
) -> Schedule:
    """A runs whole on the constrained engine (DLA), B whole on the flexible
    one (GPU). A's fallbacks preempt the GPU and stretch both periods."""
    ca = graph_time(graph_a, constrained, flexible, provider=provider)
    tb = graph_time(graph_b, flexible, flexible, allow_fallback=False, provider=provider).engine_busy
    # GPU serves B plus A's fallback work each A-frame; A-frames take at
    # least ca.elapsed, so the steady-state GPU period per B frame:
    gpu_period = tb + ca.peer_busy * min(1.0, (tb + ca.peer_busy) / max(ca.elapsed, 1e-12))
    dla_period = max(ca.elapsed, 0.0)
    loads = {
        flexible.name: EngineLoad(busy=tb, stall=gpu_period - tb),
        constrained.name: EngineLoad(busy=ca.engine_busy, stall=dla_period - ca.engine_busy),
    }
    return Schedule(
        kind="naive",
        models=(graph_a.model_name, graph_b.model_name),
        engines=(constrained.name, flexible.name),
        cycle_time=max(gpu_period, dla_period),
        loads=loads,
        segments=[
            (constrained.name, "a_compute", ca.engine_busy),
            (constrained.name, "stall", ca.peer_busy + ca.transfer),
            (flexible.name, "b_compute", tb),
            (flexible.name, "fallback", ca.peer_busy),
        ],
        notes=[f"A fallback runs={ca.n_fallback_runs}"],
        ir=make_plan_ir(
            (graph_a.model_name, graph_b.model_name),
            (constrained.name, flexible.name),
            [[(0, 0, len(graph_a), ca.elapsed)], [(1, 0, len(graph_b), tb)]],
            expected_cycle=max(gpu_period, dla_period),
            cost_provider=(provider or ANALYTIC).name,
            kind="naive",
            graphs=(graph_a, graph_b),
        ),
    )


# ---------------------------------------------------------------------------
# HaX-CoNN swap schedule (Tables III-VI)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HaxConnResult:
    schedule: Schedule
    p_a: int  # A: [0, p_a) on constrained engine, [p_a, L) on flexible
    p_b: int  # B: [0, p_b) on flexible engine,  [p_b, L) on constrained
    phase: dict[str, float]

    @property
    def ir(self) -> PlanIR:
        return self.schedule.ir


def _candidate_points(graph: LayerGraph, stride: int = 1):
    """Legal partition points: every interior point on plain graphs, only
    stage-callable boundaries on expanded (fine-grained) graphs — the
    legality mask lives on the metas (``LayerGraph.cut_points``). The
    stride knob thins the legal set to keep the beam tractable."""
    return graph.cut_points(stride)


def _evaluate_pair(graph_a, graph_b, pa, pb, constrained, flexible, allow_fallback, provider=None):
    la, lb = len(graph_a), len(graph_b)
    ca1 = segment_cost(graph_a, 0, pa, constrained, flexible, allow_fallback, provider=provider)
    ca2 = segment_cost(graph_a, pa, la, flexible, flexible, False, provider=provider)
    xa = transfer_time(partition_boundary_bytes(graph_a, pa), constrained)
    cb1 = segment_cost(graph_b, 0, pb, flexible, flexible, False, provider=provider)
    cb2 = segment_cost(graph_b, pb, lb, constrained, flexible, allow_fallback, provider=provider)
    xb = transfer_time(partition_boundary_bytes(graph_b, pb), flexible)
    t_con = ca1.elapsed + cb2.elapsed + xa + xb
    t_flex = cb1.elapsed + ca2.elapsed + ca1.peer_busy + cb2.peer_busy
    return ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex


def haxconn_schedule(
    graph_a: LayerGraph,
    graph_b: LayerGraph,
    constrained,
    flexible,
    allow_fallback: bool = True,
    stride: int = 1,
    fixed: tuple[int, int] | None = None,
    provider: CostProvider | None = None,
) -> HaxConnResult:
    """Exact search for the partition pair minimizing steady-state cycle time
    (or evaluation at a caller-``fixed`` (pa, pb) — e.g. the paper's
    Table III/V points).

    Steady state (double buffered): per cycle the constrained engine runs
    A[0:pa) of frame t and B[pb:) of frame t-1; the flexible engine runs
    B[0:pb) of frame t and A[pa:) of frame t-1. Cycle = max(engine periods)
    + partition transfers. Fallback inside a constrained segment steals
    flexible-engine time and stalls the constrained engine (original,
    non-surgered models) — exactly why the paper's hardware-aware variants
    double DLA throughput here.
    """
    best = None
    la, lb = len(graph_a), len(graph_b)
    cand_a = [fixed[0]] if fixed else _candidate_points(graph_a, stride)
    cand_b = [fixed[1]] if fixed else _candidate_points(graph_b, stride)
    for pa in cand_a:
        for pb in cand_b:
            ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex = _evaluate_pair(
                graph_a, graph_b, pa, pb, constrained, flexible, allow_fallback, provider
            )
            cycle = max(t_con, t_flex)
            idle = abs(t_con - t_flex)
            key = (cycle, idle)
            if best is None or key < best[0]:
                best = (key, pa, pb, ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex)
    (_, pa, pb, ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex) = best
    cycle = max(t_con, t_flex)
    loads = {
        constrained.name: EngineLoad(
            busy=ca1.engine_busy + cb2.engine_busy, stall=cycle - (ca1.engine_busy + cb2.engine_busy)
        ),
        flexible.name: EngineLoad(
            busy=cb1.engine_busy + ca2.engine_busy + ca1.peer_busy + cb2.peer_busy,
            stall=cycle - (cb1.engine_busy + ca2.engine_busy + ca1.peer_busy + cb2.peer_busy),
        ),
    }
    sched = Schedule(
        kind="haxconn",
        models=(graph_a.model_name, graph_b.model_name),
        engines=(constrained.name, flexible.name),
        cycle_time=cycle,
        loads=loads,
        partitions={graph_a.model_name: (pa, la), graph_b.model_name: (pb, lb)},
        segments=[
            (constrained.name, "a1", ca1.elapsed),
            (constrained.name, "xfer", xa + xb),
            (constrained.name, "b2", cb2.elapsed),
            (flexible.name, "b1", cb1.elapsed),
            (flexible.name, "a2", ca2.elapsed),
            (flexible.name, "fallback", ca1.peer_busy + cb2.peer_busy),
        ],
        notes=[
            f"A: constrained[0:{pa}) flexible[{pa}:{la})",
            f"B: flexible[0:{pb}) constrained[{pb}:{lb})",
            f"fallback_runs={ca1.n_fallback_runs + cb2.n_fallback_runs}",
        ],
        ir=make_plan_ir(
            (graph_a.model_name, graph_b.model_name),
            (constrained.name, flexible.name),
            [
                [(0, 0, pa, ca1.elapsed), (1, pa, la, ca2.elapsed)],
                [(1, 0, pb, cb1.elapsed), (0, pb, lb, cb2.elapsed)],
            ],
            expected_cycle=cycle,
            cost_provider=(provider or ANALYTIC).name,
            search="fixed" if fixed else "exhaustive",
            kind="haxconn",
            graphs=(graph_a, graph_b),
        ),
    )
    return HaxConnResult(sched, pa, pb, {"constrained": t_con, "flexible": t_flex})


# ---------------------------------------------------------------------------
# N-model generalization (multi-stream serving planner)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelRoute:
    """Per-model execution route: ordered (engine_index, lo, hi) segments
    covering [0, L). Model i's pair under E engines is
    (i % E, (i+1) % E) — the counter-phased assignment that reduces to the
    HaX-CoNN swap schedule at N=2, E=2."""

    model: str
    partition: int
    segments: list[tuple[int, int, int]]  # (engine_index, lo, hi)


@dataclasses.dataclass
class NModelPlan:
    schedule: Schedule
    routes: list[ModelRoute]
    partitions: list[int]
    engine_times: dict[str, float]  # steady-state per-cycle occupancy
    flex_index: int  # engine absorbing fallback work
    cost_provider: str = "analytic"  # which CostProvider scored this plan
    search: str = "exhaustive"  # exhaustive | beam | descent | fixed
    ir: PlanIR | None = None  # the typed plan the serve stack consumes

    @property
    def cycle_time(self) -> float:
        return self.schedule.cycle_time


def _flex_engine_index(engines) -> int:
    """The fallback target: fewest constraints, ties to the last engine
    (callers conventionally list constrained engines first)."""
    return min(range(len(engines)), key=lambda i: (len(engines[i].constraints), -i))


def _model_pair(i: int, n_engines: int) -> tuple[int, int]:
    return i % n_engines, (i + 1) % n_engines


def _make_model_cost_fn(graphs, engines, allow_fallback, flex_idx, provider=None):
    """Memoized per-(model, partition) segment costs: a search trial changes
    one model's point, so the other models' costs recur."""
    cache: dict[tuple[int, int], tuple] = {}
    E = len(engines)
    flex = engines[flex_idx]

    def cost(i: int, p: int):
        key = (i, p)
        if key not in cache:
            g = graphs[i]
            e1, e2 = _model_pair(i, E)
            c1 = segment_cost(g, 0, p, engines[e1], flex, allow_fallback and e1 != flex_idx, provider=provider)
            c2 = segment_cost(g, p, len(g), engines[e2], flex, allow_fallback and e2 != flex_idx, provider=provider)
            x = transfer_time(partition_boundary_bytes(g, p), engines[e1]) if e1 != e2 else 0.0
            cache[key] = (e1, e2, c1, c2, x)
        return cache[key]

    return cost


def _evaluate_vector(graphs, engines, pvec, allow_fallback, flex_idx, cost_fn=None):
    """Steady-state per-engine occupancy for one partition vector.

    Accumulation mirrors ``_evaluate_pair`` term-for-term (segment elapsed
    first, then partition transfers, then fallback steals) so that at
    N=2/E=2 the floating-point cycle time is bit-identical to
    ``haxconn_schedule`` and the argmin selects the same partitions.
    """
    if cost_fn is None:
        cost_fn = _make_model_cost_fn(graphs, engines, allow_fallback, flex_idx)
    E = len(engines)
    t = [0.0] * E  # occupancy (compute + transfers + stalls charged here)
    busy = [0.0] * E  # productive compute only
    per_model = []
    for i, p in enumerate(pvec):
        e1, e2, c1, c2, x = cost_fn(i, p)
        t[e1] += c1.elapsed
        t[e2] += c2.elapsed
        busy[e1] += c1.engine_busy
        busy[e2] += c2.engine_busy
        per_model.append((e1, e2, c1, c2, x))
    for e1, e2, c1, c2, x in per_model:
        if e1 != e2:
            # the engine pair's shared link serializes on its first engine
            t[min(e1, e2)] += x
    for e1, e2, c1, c2, x in per_model:
        t[flex_idx] += c1.peer_busy
        t[flex_idx] += c2.peer_busy
        busy[flex_idx] += c1.peer_busy + c2.peer_busy
    cycle = max(t)
    spread = cycle - min(t)
    return (cycle, spread), t, busy, per_model


def _candidate_deltas(cands, cost_fn, n_engines, flex_idx):
    """Per-model candidate engine-occupancy contribution vectors.

    Candidates whose *raw cost components* are identical to an earlier
    candidate's are dropped (per-model cost monotonicity makes long flat
    plateaus — e.g. zero-flop crop layers — common): identical components
    accumulate identically in ``_evaluate_vector``'s fixed summation
    order, so the earlier point ties every completion exactly and
    precedes it in product order — the pruning never changes the argmin.
    (Keying on the raw components rather than the summed delta matters:
    equal float *sums* do not imply equal canonical keys.)
    """
    deltas = []
    for i, cl in enumerate(cands):
        seen, lst = set(), []
        for ci, p in enumerate(cl):
            e1, e2, c1, c2, x = cost_fn(i, p)
            raw = (c1.elapsed, c2.elapsed, x, c1.peer_busy, c2.peer_busy)
            if raw in seen:
                continue
            seen.add(raw)
            d = [0.0] * n_engines
            d[e1] += c1.elapsed
            d[e2] += c2.elapsed
            if e1 != e2:
                d[min(e1, e2)] += x
            d[flex_idx] += c1.peer_busy + c2.peer_busy
            lst.append((ci, p, tuple(d)))
        deltas.append(lst)
    return deltas


def _beam_search(cands, cost_fn, n_engines, flex_idx, key_of, beam_width):
    """Beam search over partition vectors.

    States carry the partial per-engine occupancy (monotonically growing —
    every candidate contribution is nonnegative, so a partial cycle lower-
    bounds every completion) and the tuple of candidate indices, which is
    exactly the vector's rank in ``itertools.product`` order. When the beam
    never truncates, the surviving set *is* the full product and the final
    argmin (canonical key, then product order) is bit-identical to the
    exhaustive search.
    """
    deltas = _candidate_deltas(cands, cost_fn, n_engines, flex_idx)
    # Lookahead for the truncation ordering: each unplaced model must add at
    # least its elementwise-min contribution to every engine, so ranking
    # partial states by max(occupancy + suffix_min) compares lower bounds on
    # their completions instead of raw (counter-phase-biased) partial cycles.
    suffix_min = [(0.0,) * n_engines]
    for lst in reversed(deltas):
        m = tuple(min(d[e] for _, _, d in lst) for e in range(n_engines))
        suffix_min.append(tuple(a + b for a, b in zip(suffix_min[-1], m)))
    suffix_min.reverse()
    beam = [((), (), (0.0,) * n_engines)]  # (idx_tuple, pvec, occupancy)
    for level, lst in enumerate(deltas):
        nxt = [
            (idx + (ci,), pvec + (p,), tuple(o + dd for o, dd in zip(occ, d)))
            for idx, pvec, occ in beam
            for ci, p, d in lst
        ]
        if len(nxt) > beam_width:
            rest = suffix_min[level + 1]

            def rank(s):
                bound = [o + r for o, r in zip(s[2], rest)]
                return (max(bound), max(bound) - min(bound), s[0])

            nxt.sort(key=rank)
            nxt = nxt[:beam_width]
        beam = nxt
    _, best_pvec, _ = min(beam, key=lambda s: (key_of(s[1]), s[0]))
    return best_pvec, key_of(best_pvec)


def _coordinate_descent(start_pvec, cands, key_of, rounds):
    """Sweep every model's candidate list holding the others fixed, until a
    fixed point — used as the legacy search mode and as the cheap local
    polish after beam search (strict improvement only, so it can never
    leave a beam optimum for a tie)."""
    best_pvec, best_key = tuple(start_pvec), key_of(tuple(start_pvec))
    for _ in range(rounds):
        improved = False
        for i in range(len(cands)):
            for p in cands[i]:
                trial = list(best_pvec)
                trial[i] = p
                k = key_of(tuple(trial))
                if k < best_key:
                    best_key, best_pvec = k, tuple(trial)
                    improved = True
        if not improved:
            break
    return best_pvec, best_key


def nmodel_schedule(
    graphs: list[LayerGraph],
    engines,
    allow_fallback: bool = True,
    stride: int = 1,
    fixed: tuple[int, ...] | None = None,
    exhaustive_limit: int = 20000,
    descent_rounds: int = 8,
    provider: CostProvider | None = None,
    search: str = "auto",
    beam_width: int = 64,
) -> NModelPlan:
    """Plan N staged models over E engines, one partition point per model.

    ``search`` modes:

    * ``"auto"``       — exhaustive over the Cartesian product of candidate
                         points when it is small (this covers N=2, where the
                         result is provably identical to ``haxconn_schedule``),
                         else beam search.
    * ``"exhaustive"`` — force the full product scan.
    * ``"beam"``       — beam search over partition vectors (width
                         ``beam_width``), pruning identical-contribution
                         candidates, followed by a coordinate-descent
                         polish from the beam's best vector. The legacy
                         balanced warm start is kept as a restart seed, so
                         the beam planner is structurally never worse than
                         the old coordinate descent.
    * ``"descent"``    — the legacy coordinate descent from a cost-balanced
                         start (kept as a comparison baseline).

    Plans record which provider scored them (``plan.cost_provider``) and
    which search produced them (``plan.search``).
    """
    graphs, engines = list(graphs), list(engines)
    if not graphs:
        raise ValueError("nmodel_schedule needs at least one model graph")
    if not engines:
        raise ValueError("nmodel_schedule needs at least one engine")
    if search not in ("auto", "exhaustive", "beam", "descent"):
        raise ValueError(f"unknown search mode {search!r}")
    if provider is None:
        provider = ANALYTIC
    flex_idx = _flex_engine_index(engines)
    if fixed is not None:
        cands = [[p] for p in fixed]
    else:
        cands = [_candidate_points(g, stride) for g in graphs]
    for i, c in enumerate(cands):
        if not c:
            raise ValueError(f"model {graphs[i].model_name} has no interior partition point")

    cost_fn = _make_model_cost_fn(graphs, engines, allow_fallback, flex_idx, provider)

    key_cache: dict[tuple, tuple] = {}

    def key_of(pvec):
        pvec = tuple(pvec)
        if pvec not in key_cache:
            key_cache[pvec] = _evaluate_vector(graphs, engines, pvec, allow_fallback, flex_idx, cost_fn)[0]
        return key_cache[pvec]

    n_candidates = math.prod(len(c) for c in cands)
    if fixed is not None:
        mode = "fixed"
    elif search == "auto":
        mode = "exhaustive" if n_candidates <= exhaustive_limit else "beam"
    else:
        mode = search
    if mode in ("exhaustive", "fixed"):
        best_key, best_pvec = None, None
        for pvec in itertools.product(*cands):
            k = key_of(pvec)
            if best_key is None or k < best_key:
                best_key, best_pvec = k, pvec
    else:
        balanced = [
            balanced_partition_point(
                g,
                engines[_model_pair(i, len(engines))[0]],
                engines[_model_pair(i, len(engines))[1]],
                cands[i],
                provider=provider,
            )
            for i, g in enumerate(graphs)
        ]
        if mode == "beam":
            best_pvec, best_key = _beam_search(cands, cost_fn, len(engines), flex_idx, key_of, beam_width)
            best_pvec, best_key = _coordinate_descent(best_pvec, cands, key_of, descent_rounds)
            restart = _coordinate_descent(balanced, cands, key_of, descent_rounds)
            if restart[1] < best_key:
                best_pvec, best_key = restart
        else:  # descent
            best_pvec, best_key = _coordinate_descent(balanced, cands, key_of, descent_rounds)

    (cycle, _), t, busy, per_model = _evaluate_vector(
        graphs, engines, best_pvec, allow_fallback, flex_idx, cost_fn
    )
    loads = {e.name: EngineLoad(busy=b, stall=cycle - b) for e, b in zip(engines, busy)}
    routes, segments, notes, ir_spans = [], [], [], []
    n_fallback = 0
    for i, (g, p) in enumerate(zip(graphs, best_pvec)):
        e1, e2, c1, c2, x = per_model[i]
        label = chr(ord("a") + i % 26)
        routes.append(
            ModelRoute(
                model=g.model_name,
                partition=p,
                segments=[(e1, 0, p), (e2, p, len(g))],
            )
        )
        ir_spans.append([(e1, 0, p, c1.elapsed), (e2, p, len(g), c2.elapsed)])
        segments.append((engines[e1].name, f"{label}1", c1.elapsed))
        if x:
            segments.append((engines[min(e1, e2)].name, "xfer", x))
        segments.append((engines[e2].name, f"{label}2", c2.elapsed))
        if c1.peer_busy + c2.peer_busy:
            segments.append((engines[flex_idx].name, "fallback", c1.peer_busy + c2.peer_busy))
        n_fallback += c1.n_fallback_runs + c2.n_fallback_runs
        notes.append(
            f"{g.model_name}: {engines[e1].name}[0:{p}) {engines[e2].name}[{p}:{len(g)})"
        )
    notes.append(f"fallback_runs={n_fallback}")
    notes.append(f"search={mode} cost={provider.name}")
    ir = make_plan_ir(
        tuple(g.model_name for g in graphs),
        tuple(e.name for e in engines),
        ir_spans,
        expected_cycle=cycle,
        cost_provider=provider.name,
        search=mode,
        kind="nmodel",
        graphs=graphs,
    )
    sched = Schedule(
        kind="nmodel",
        models=tuple(g.model_name for g in graphs),
        engines=tuple(e.name for e in engines),
        cycle_time=cycle,
        loads=loads,
        # instance-indexed keys: the same graph may be scheduled N times
        # with different partition points
        partitions={
            f"{i}:{g.model_name}": (p, len(g)) for i, (g, p) in enumerate(zip(graphs, best_pvec))
        },
        segments=segments,
        notes=notes,
        ir=ir,
    )
    return NModelPlan(
        schedule=sched,
        routes=routes,
        partitions=list(best_pvec),
        engine_times={e.name: ti for e, ti in zip(engines, t)},
        flex_index=flex_idx,
        cost_provider=provider.name,
        search=mode,
        ir=ir,
    )
