"""Multi-model multi-engine schedules (the paper's §IV + §VI).

Three scheduling modes, exactly as evaluated by the paper:

* ``standalone``      — one model on one engine, illegal layers falling
                        back to the peer (Fig. 8/9/10).
* ``naive``           — model A whole on the constrained engine, model B
                        whole on the flexible engine (client-server
                        scheme, Fig. 11/12).
* ``haxconn``         — HaX-CoNN-style swap schedule: each model is split
                        at one partition point; the two instances run
                        counter-phased across both engines so busy times
                        balance (Tables III–VI). The two partition points
                        are found by exact search over all O(L_A * L_B)
                        candidates against the cost model — the two-engine
                        specialization of HaX-CoNN's SAT formulation,
                        solved optimally.

Every search takes a ``CostProvider`` (default: the analytic roofline),
so the same planners run against XLA-measured per-layer costs — the
HaX-CoNN observation that measured costs, not analytic ones, are what
make engine-allocation decisions transfer to hardware.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .cost_model import (
    ANALYTIC,
    CostProvider,
    SegmentCost,
    SegmentCostCache,
    balanced_partition_point,
    graph_time,
    partition_boundary_bytes,
    segment_cost,
    transfer_time,
)
from .graph import LayerGraph
from .plan_ir import PlanIR, make_plan_ir


@dataclasses.dataclass
class EngineLoad:
    busy: float = 0.0  # productive compute time per cycle
    stall: float = 0.0  # waiting on peer fallback / transfers

    @property
    def fps(self):
        total = self.busy + self.stall
        return 1.0 / total if total > 0 else math.inf


@dataclasses.dataclass
class Schedule:
    kind: str
    models: tuple[str, ...]
    engines: tuple[str, ...]
    cycle_time: float  # steady-state seconds per frame (per model instance)
    loads: dict[str, EngineLoad]
    partitions: dict[str, tuple[int, int]] | None = None  # model -> (to_peer, back)
    notes: list[str] = dataclasses.field(default_factory=list)
    segments: list[tuple] = dataclasses.field(default_factory=list)  # (engine, label, dur)
    # the typed segment-level plan the serve stack consumes (every
    # scheduler emits one; None only for hand-built Schedule objects)
    ir: PlanIR | None = None

    @property
    def aggregate_fps(self):
        return len(self.models) / self.cycle_time if self.cycle_time > 0 else math.inf

    def engine_fps(self, name):
        return self.loads[name].fps

    def idle_fraction(self, name):
        l = self.loads[name]
        return 1.0 - l.busy / self.cycle_time if self.cycle_time else 0.0

    def ascii_timeline(self, width: int = 72) -> str:
        """Nsight-style textual timing diagram of one steady-state cycle."""
        lines = [f"cycle = {self.cycle_time*1e3:.2f} ms  ({self.aggregate_fps:.1f} FPS aggregate)"]
        scale = width / self.cycle_time if self.cycle_time else 0
        for eng in self.engines:
            segs = [(lbl, dur) for e, lbl, dur in self.segments if e == eng]
            bar, legend = "", []
            for lbl, dur in segs:
                n = max(1, int(dur * scale))
                ch = lbl[0].upper()
                bar += ch * n
                legend.append(f"{lbl}={dur*1e3:.2f}ms")
            bar = bar[:width].ljust(width, ".")
            lines.append(f"{eng:>9} |{bar}|")
            lines.append(f"{'':>9}  {' '.join(legend)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# standalone (Fig. 8/9/10)
# ---------------------------------------------------------------------------


def _standalone_schedule_impl(
    graph: LayerGraph, engine, peer, allow_fallback=True, provider: CostProvider | None = None
) -> Schedule:
    c = graph_time(graph, engine, peer, allow_fallback=allow_fallback, provider=provider)
    loads = {
        engine.name: EngineLoad(busy=c.engine_busy, stall=c.peer_busy + c.transfer),
        peer.name: EngineLoad(busy=c.peer_busy, stall=0.0),
    }
    segs = [(engine.name, "compute", c.engine_busy)]
    if c.peer_busy:
        segs += [(engine.name, "stall", c.peer_busy + c.transfer), (peer.name, "fallback", c.peer_busy)]
    sched = Schedule(
        kind="standalone",
        models=(graph.model_name,),
        engines=(engine.name, peer.name),
        cycle_time=c.elapsed,
        loads=loads,
        segments=segs,
        notes=[f"fallback_runs={c.n_fallback_runs}"],
        ir=make_plan_ir(
            (graph.model_name,),
            (engine.name, peer.name),
            [[(0, 0, len(graph), c.elapsed)]],
            expected_cycle=c.elapsed,
            cost_provider=(provider or ANALYTIC).name,
            kind="standalone",
            graphs=(graph,),
        ),
    )
    return sched


def peer_utilization(graph: LayerGraph, engine, peer, provider: CostProvider | None = None) -> float:
    """Fraction of the frame time the *peer* is busy serving fallbacks —
    the paper's Fig. 10 'GPU utilization of the DLA-assigned model'."""
    c = graph_time(graph, engine, peer, provider=provider)
    return c.peer_busy / c.elapsed if c.elapsed else 0.0


# ---------------------------------------------------------------------------
# naive concurrent (client-server scheme, Fig. 11/12)
# ---------------------------------------------------------------------------


def _naive_schedule_impl(
    graph_a: LayerGraph, graph_b: LayerGraph, constrained, flexible, provider: CostProvider | None = None
) -> Schedule:
    """A runs whole on the constrained engine (DLA), B whole on the flexible
    one (GPU). A's fallbacks preempt the GPU and stretch both periods."""
    ca = graph_time(graph_a, constrained, flexible, provider=provider)
    tb = graph_time(graph_b, flexible, flexible, allow_fallback=False, provider=provider).engine_busy
    # GPU serves B plus A's fallback work each A-frame; A-frames take at
    # least ca.elapsed, so the steady-state GPU period per B frame:
    gpu_period = tb + ca.peer_busy * min(1.0, (tb + ca.peer_busy) / max(ca.elapsed, 1e-12))
    dla_period = max(ca.elapsed, 0.0)
    loads = {
        flexible.name: EngineLoad(busy=tb, stall=gpu_period - tb),
        constrained.name: EngineLoad(busy=ca.engine_busy, stall=dla_period - ca.engine_busy),
    }
    return Schedule(
        kind="naive",
        models=(graph_a.model_name, graph_b.model_name),
        engines=(constrained.name, flexible.name),
        cycle_time=max(gpu_period, dla_period),
        loads=loads,
        segments=[
            (constrained.name, "a_compute", ca.engine_busy),
            (constrained.name, "stall", ca.peer_busy + ca.transfer),
            (flexible.name, "b_compute", tb),
            (flexible.name, "fallback", ca.peer_busy),
        ],
        notes=[f"A fallback runs={ca.n_fallback_runs}"],
        ir=make_plan_ir(
            (graph_a.model_name, graph_b.model_name),
            (constrained.name, flexible.name),
            [[(0, 0, len(graph_a), ca.elapsed)], [(1, 0, len(graph_b), tb)]],
            expected_cycle=max(gpu_period, dla_period),
            cost_provider=(provider or ANALYTIC).name,
            kind="naive",
            graphs=(graph_a, graph_b),
        ),
    )


# ---------------------------------------------------------------------------
# HaX-CoNN swap schedule (Tables III-VI)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HaxConnResult:
    schedule: Schedule
    p_a: int  # A: [0, p_a) on constrained engine, [p_a, L) on flexible
    p_b: int  # B: [0, p_b) on flexible engine,  [p_b, L) on constrained
    phase: dict[str, float]

    @property
    def ir(self) -> PlanIR:
        return self.schedule.ir


def _candidate_points(graph: LayerGraph, stride: int = 1):
    """Legal partition points: every interior point on plain graphs, only
    stage-callable boundaries on expanded (fine-grained) graphs — the
    legality mask lives on the metas (``LayerGraph.cut_points``). The
    stride knob thins the legal set to keep the beam tractable."""
    return graph.cut_points(stride)


def _evaluate_pair(graph_a, graph_b, pa, pb, constrained, flexible, allow_fallback, provider=None):
    la, lb = len(graph_a), len(graph_b)
    ca1 = segment_cost(graph_a, 0, pa, constrained, flexible, allow_fallback, provider=provider)
    ca2 = segment_cost(graph_a, pa, la, flexible, flexible, False, provider=provider)
    xa = transfer_time(partition_boundary_bytes(graph_a, pa), constrained)
    cb1 = segment_cost(graph_b, 0, pb, flexible, flexible, False, provider=provider)
    cb2 = segment_cost(graph_b, pb, lb, constrained, flexible, allow_fallback, provider=provider)
    xb = transfer_time(partition_boundary_bytes(graph_b, pb), flexible)
    t_con = ca1.elapsed + cb2.elapsed + xa + xb
    t_flex = cb1.elapsed + ca2.elapsed + ca1.peer_busy + cb2.peer_busy
    return ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex


def _haxconn_schedule_impl(
    graph_a: LayerGraph,
    graph_b: LayerGraph,
    constrained,
    flexible,
    allow_fallback: bool = True,
    stride: int = 1,
    fixed: tuple[int, int] | None = None,
    provider: CostProvider | None = None,
) -> HaxConnResult:
    """Exact search for the partition pair minimizing steady-state cycle time
    (or evaluation at a caller-``fixed`` (pa, pb) — e.g. the paper's
    Table III/V points).

    Steady state (double buffered): per cycle the constrained engine runs
    A[0:pa) of frame t and B[pb:) of frame t-1; the flexible engine runs
    B[0:pb) of frame t and A[pa:) of frame t-1. Cycle = max(engine periods)
    + partition transfers. Fallback inside a constrained segment steals
    flexible-engine time and stalls the constrained engine (original,
    non-surgered models) — exactly why the paper's hardware-aware variants
    double DLA throughput here.
    """
    best = None
    la, lb = len(graph_a), len(graph_b)
    cand_a = [fixed[0]] if fixed else _candidate_points(graph_a, stride)
    cand_b = [fixed[1]] if fixed else _candidate_points(graph_b, stride)
    for pa in cand_a:
        for pb in cand_b:
            ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex = _evaluate_pair(
                graph_a, graph_b, pa, pb, constrained, flexible, allow_fallback, provider
            )
            cycle = max(t_con, t_flex)
            idle = abs(t_con - t_flex)
            key = (cycle, idle)
            if best is None or key < best[0]:
                best = (key, pa, pb, ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex)
    (_, pa, pb, ca1, ca2, cb1, cb2, xa, xb, t_con, t_flex) = best
    cycle = max(t_con, t_flex)
    loads = {
        constrained.name: EngineLoad(
            busy=ca1.engine_busy + cb2.engine_busy, stall=cycle - (ca1.engine_busy + cb2.engine_busy)
        ),
        flexible.name: EngineLoad(
            busy=cb1.engine_busy + ca2.engine_busy + ca1.peer_busy + cb2.peer_busy,
            stall=cycle - (cb1.engine_busy + ca2.engine_busy + ca1.peer_busy + cb2.peer_busy),
        ),
    }
    sched = Schedule(
        kind="haxconn",
        models=(graph_a.model_name, graph_b.model_name),
        engines=(constrained.name, flexible.name),
        cycle_time=cycle,
        loads=loads,
        partitions={graph_a.model_name: (pa, la), graph_b.model_name: (pb, lb)},
        segments=[
            (constrained.name, "a1", ca1.elapsed),
            (constrained.name, "xfer", xa + xb),
            (constrained.name, "b2", cb2.elapsed),
            (flexible.name, "b1", cb1.elapsed),
            (flexible.name, "a2", ca2.elapsed),
            (flexible.name, "fallback", ca1.peer_busy + cb2.peer_busy),
        ],
        notes=[
            f"A: constrained[0:{pa}) flexible[{pa}:{la})",
            f"B: flexible[0:{pb}) constrained[{pb}:{lb})",
            f"fallback_runs={ca1.n_fallback_runs + cb2.n_fallback_runs}",
        ],
        ir=make_plan_ir(
            (graph_a.model_name, graph_b.model_name),
            (constrained.name, flexible.name),
            [
                [(0, 0, pa, ca1.elapsed), (1, pa, la, ca2.elapsed)],
                [(1, 0, pb, cb1.elapsed), (0, pb, lb, cb2.elapsed)],
            ],
            expected_cycle=cycle,
            cost_provider=(provider or ANALYTIC).name,
            search="fixed" if fixed else "exhaustive",
            kind="haxconn",
            graphs=(graph_a, graph_b),
        ),
    )
    return HaxConnResult(sched, pa, pb, {"constrained": t_con, "flexible": t_flex})


# ---------------------------------------------------------------------------
# N-model generalization (multi-stream serving planner)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """A candidate per-model route: strictly increasing interior ``cuts``
    plus the engine index of each resulting segment
    (``len(engines) == len(cuts) + 1``).

    The single-cut specialization ``RouteSpec((p,), (i % E, (i+1) % E))``
    is exactly the legacy counter-phased pair that reduces to the
    HaX-CoNN swap schedule at N=2, E=2; multi-cut routes ping-pong a
    model across the engines at up to ``max_cuts`` boundaries."""

    cuts: tuple[int, ...]
    engines: tuple[int, ...]

    def __post_init__(self):
        if len(self.engines) != len(self.cuts) + 1:
            raise ValueError(
                f"route with {len(self.cuts)} cuts needs {len(self.cuts) + 1} "
                f"segment engines, got {len(self.engines)}"
            )
        if any(b <= a for a, b in zip(self.cuts, self.cuts[1:])):
            raise ValueError(f"route cuts must be strictly increasing, got {self.cuts}")

    @property
    def n_cuts(self) -> int:
        return len(self.cuts)

    def segments(self, n_layers: int) -> list[tuple[int, int, int]]:
        """The (engine_index, lo, hi) segment list this route induces."""
        bounds = (0,) + self.cuts + (n_layers,)
        return [(e, bounds[j], bounds[j + 1]) for j, e in enumerate(self.engines)]


def _as_route_spec(entry, i: int, n_engines: int) -> RouteSpec:
    """Normalize a ``fixed=`` entry: a bare int is the legacy single cut
    with the counter-phased engine pair; ``(cuts, engines)`` tuples and
    ``RouteSpec``s pass through (validated)."""
    if isinstance(entry, RouteSpec):
        spec = entry
    elif isinstance(entry, int):
        spec = RouteSpec((entry,), _model_pair(i, n_engines))
    else:
        cuts, engines = entry
        spec = RouteSpec(tuple(int(c) for c in cuts), tuple(int(e) for e in engines))
    if any(not 0 <= e < n_engines for e in spec.engines):
        raise ValueError(f"route {spec} binds an unknown engine (E={n_engines})")
    return spec


@dataclasses.dataclass
class ModelRoute:
    """Per-model execution route: ordered (engine_index, lo, hi) segments
    covering [0, L). ``partition`` is the first cut (the legacy planner's
    single partition point); ``cuts`` records the full k-cut vector."""

    model: str
    partition: int
    segments: list[tuple[int, int, int]]  # (engine_index, lo, hi)
    cuts: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.cuts is None:
            self.cuts = tuple(hi for _, _, hi in self.segments[:-1])


@dataclasses.dataclass
class NModelPlan:
    schedule: Schedule
    routes: list[ModelRoute]
    partitions: list[int]  # first cut per model (legacy single-point view)
    engine_times: dict[str, float]  # steady-state per-cycle occupancy
    flex_index: int  # engine absorbing fallback work
    cost_provider: str = "analytic"  # which CostProvider scored this plan
    search: str = "exhaustive"  # exhaustive | beam | descent | fixed
    ir: PlanIR | None = None  # the typed plan the serve stack consumes
    cuts: list[tuple[int, ...]] = dataclasses.field(default_factory=list)  # full k-cut vectors
    max_cuts: int = 1  # the cut budget the search ran with
    batch: int = 1  # effective admission batch the routes were scored at

    @property
    def cycle_time(self) -> float:
        return self.schedule.cycle_time


def _flex_engine_index(engines) -> int:
    """The fallback target: fewest constraints, ties to the last engine
    (callers conventionally list constrained engines first)."""
    return min(range(len(engines)), key=lambda i: (len(engines[i].constraints), -i))


def _model_pair(i: int, n_engines: int) -> tuple[int, int]:
    return i % n_engines, (i + 1) % n_engines


@dataclasses.dataclass(frozen=True)
class RouteCost:
    """Cost decomposition of one candidate route on its graph."""

    segs: tuple  # ((engine_index, SegmentCost), ...) in route order
    xfers: tuple  # ((charged_engine_index, seconds), ...) per engine-changing cut
    fallback: float  # total peer-steal time charged to the flex engine

    @property
    def makespan(self) -> float:
        """The model's serialized frame time under this route — the cheap
        per-model score used to rank candidates when the multi-cut set
        must be capped (``route_limit``)."""
        return sum(c.elapsed for _, c in self.segs) + sum(x for _, x in self.xfers)

    @property
    def n_fallback_runs(self) -> int:
        return sum(c.n_fallback_runs for _, c in self.segs)


class _RouteCoster:
    """Route costing over a shared ``SegmentCostCache``.

    Two memo levels: per-(model, span, engine) segment costs (shared by
    every route that places that span there) and per-(model, route)
    assembled ``RouteCost``s. Segment/transfer terms are produced by the
    exact calls the legacy single-cut ``cost_fn`` made, so single-cut
    route costs are bit-identical to the old (e1, e2, c1, c2, x) tuples.
    """

    def __init__(self, graphs, engines, allow_fallback, flex_idx, provider=None, impl="xla",
                 batch=1):
        self.graphs = graphs
        self.engines = engines
        self.allow_fallback = allow_fallback
        self.flex_idx = flex_idx
        self.impl_mode = impl
        self.batch = max(int(batch), 1)  # effective admission batch the DP scores at
        self.cache = SegmentCostCache(provider)
        self._routes: dict[tuple[int, RouteSpec], RouteCost] = {}
        # per-(model, span, engine) winning implementation under "auto"
        self._impl_choice: dict[tuple[int, int, int, int], str] = {}

    def _seg_impl(self, i: int, lo: int, hi: int, e: int, impl: str) -> SegmentCost:
        return self.cache.segment(
            i,
            self.graphs[i],
            lo,
            hi,
            self.engines[e],
            self.engines[self.flex_idx],
            self.allow_fallback and e != self.flex_idx,
            impl,
            self.batch,
        )

    def seg(self, i: int, lo: int, hi: int, e: int) -> SegmentCost:
        if self.impl_mode == "pallas":
            return self._seg_impl(i, lo, hi, e, "pallas_fused")
        c_xla = self._seg_impl(i, lo, hi, e, "xla")
        if self.impl_mode == "xla":
            return c_xla
        # "auto": per-segment argmin over implementations. The fused
        # variant wins only when it dominates component-wise (elapsed AND
        # peer-steal no worse, elapsed strictly better) — every occupancy
        # term in _evaluate_routes is then <= its xla counterpart, so the
        # impl-aware plan cost is structurally never worse than xla-only.
        c_pal = self._seg_impl(i, lo, hi, e, "pallas_fused")
        if c_pal.elapsed < c_xla.elapsed and c_pal.peer_busy <= c_xla.peer_busy:
            self._impl_choice[(i, lo, hi, e)] = "pallas_fused"
            return c_pal
        return c_xla

    def chosen(self, i: int, lo: int, hi: int, e: int) -> str:
        """The implementation bound to one segment under the coster's mode."""
        if self.impl_mode == "pallas":
            return "pallas_fused"
        return self._impl_choice.get((i, lo, hi, e), "xla")

    def xfer(self, i: int, p: int, e_prev: int) -> float:
        return self.cache.transfer(i, self.graphs[i], p, self.engines[e_prev], self.batch)

    def route(self, i: int, spec: RouteSpec) -> RouteCost:
        key = (i, spec)
        rc = self._routes.get(key)
        if rc is None:
            bounds = (0,) + spec.cuts + (len(self.graphs[i]),)
            segs = []
            for j, e in enumerate(spec.engines):
                segs.append((e, self.seg(i, bounds[j], bounds[j + 1], e)))
            xfers = []
            for j, p in enumerate(spec.cuts):
                ep, en = spec.engines[j], spec.engines[j + 1]
                if ep != en:
                    # the engine pair's shared link serializes on its first engine
                    xfers.append((min(ep, en), self.xfer(i, p, ep)))
            fb = 0.0
            for _, c in segs:
                fb += c.peer_busy
            rc = RouteCost(tuple(segs), tuple(xfers), fb)
            self._routes[key] = rc
        return rc


def _evaluate_routes(n_engines, route_vec, flex_idx, coster: _RouteCoster):
    """Steady-state per-engine occupancy for one vector of routes.

    Accumulation mirrors ``_evaluate_pair`` term-for-term (segment elapsed
    first, then partition transfers, then fallback steals — in route
    order within each model, model order across models) so that at
    N=2/E=2 with single-cut routes the floating-point cycle time is
    bit-identical to ``haxconn_schedule`` and the argmin selects the same
    partitions; k-segment routes simply contribute more terms to the
    same three passes."""
    t = [0.0] * n_engines  # occupancy (compute + transfers + stalls charged here)
    busy = [0.0] * n_engines  # productive compute only
    per_model = []
    for i, spec in enumerate(route_vec):
        rc = coster.route(i, spec)
        for e, c in rc.segs:
            t[e] += c.elapsed
            busy[e] += c.engine_busy
        per_model.append(rc)
    for rc in per_model:
        for ce, x in rc.xfers:
            t[ce] += x
    for rc in per_model:
        for _, c in rc.segs:
            t[flex_idx] += c.peer_busy
        busy[flex_idx] += rc.fallback
    cycle = max(t)
    spread = cycle - min(t)
    return (cycle, spread), t, busy, per_model


def _candidate_deltas(cands, coster, n_engines, flex_idx):
    """Per-model candidate engine-occupancy contribution vectors.

    Candidates whose *raw cost components* (and engine bindings) are
    identical to an earlier candidate's are dropped (per-model cost
    monotonicity makes long flat plateaus — e.g. zero-flop crop layers —
    common): identical components accumulate identically in
    ``_evaluate_routes``'s fixed summation order, so the earlier route
    ties every completion exactly and precedes it in product order — the
    pruning never changes the argmin. (Keying on the raw components
    rather than the summed delta matters: equal float *sums* do not imply
    equal canonical keys.)
    """
    deltas = []
    for i, cl in enumerate(cands):
        seen, lst = set(), []
        for ci, spec in enumerate(cl):
            rc = coster.route(i, spec)
            raw = (
                spec.engines,
                tuple((c.elapsed, c.peer_busy) for _, c in rc.segs),
                rc.xfers,
            )
            if raw in seen:
                continue
            seen.add(raw)
            d = [0.0] * n_engines
            for e, c in rc.segs:
                d[e] += c.elapsed
            for ce, x in rc.xfers:
                d[ce] += x
            d[flex_idx] += rc.fallback
            lst.append((ci, spec, tuple(d)))
        deltas.append(lst)
    return deltas


def _beam_search(cands, coster, n_engines, flex_idx, key_of, beam_width):
    """Beam search over route vectors.

    States carry the partial per-engine occupancy (monotonically growing —
    every candidate contribution is nonnegative, so a partial cycle lower-
    bounds every completion) and the tuple of candidate indices, which is
    exactly the vector's rank in ``itertools.product`` order. When the beam
    never truncates, the surviving set *is* the full product and the final
    argmin (canonical key, then product order) is bit-identical to the
    exhaustive search.
    """
    deltas = _candidate_deltas(cands, coster, n_engines, flex_idx)
    # Lookahead for the truncation ordering: each unplaced model must add at
    # least its elementwise-min contribution to every engine, so ranking
    # partial states by max(occupancy + suffix_min) compares lower bounds on
    # their completions instead of raw (counter-phase-biased) partial cycles.
    suffix_min = [(0.0,) * n_engines]
    for lst in reversed(deltas):
        m = tuple(min(d[e] for _, _, d in lst) for e in range(n_engines))
        suffix_min.append(tuple(a + b for a, b in zip(suffix_min[-1], m)))
    suffix_min.reverse()
    beam = [((), (), (0.0,) * n_engines)]  # (idx_tuple, pvec, occupancy)
    for level, lst in enumerate(deltas):
        nxt = [
            (idx + (ci,), pvec + (p,), tuple(o + dd for o, dd in zip(occ, d)))
            for idx, pvec, occ in beam
            for ci, p, d in lst
        ]
        if len(nxt) > beam_width:
            rest = suffix_min[level + 1]

            def rank(s):
                bound = [o + r for o, r in zip(s[2], rest)]
                return (max(bound), max(bound) - min(bound), s[0])

            nxt.sort(key=rank)
            nxt = nxt[:beam_width]
        beam = nxt
    _, best_pvec, _ = min(beam, key=lambda s: (key_of(s[1]), s[0]))
    return best_pvec, key_of(best_pvec)


def _coordinate_descent(start_pvec, cands, key_of, rounds):
    """Sweep every model's candidate list holding the others fixed, until a
    fixed point — used as the legacy search mode and as the cheap local
    polish after beam search (strict improvement only, so it can never
    leave a beam optimum for a tie)."""
    best_pvec, best_key = tuple(start_pvec), key_of(tuple(start_pvec))
    for _ in range(rounds):
        improved = False
        for i in range(len(cands)):
            for p in cands[i]:
                trial = list(best_pvec)
                trial[i] = p
                k = key_of(tuple(trial))
                if k < best_key:
                    best_key, best_pvec = k, tuple(trial)
                    improved = True
        if not improved:
            break
    return best_pvec, best_key


def _dp_engine_assignments(coster: _RouteCoster, i: int, cuts: tuple[int, ...]) -> list[tuple[int, ...]]:
    """Per-model DP over engine assignments for a fixed cut vector.

    State = the engine running the current segment; value = the model's
    serialized makespan so far (segment elapsed + engine-switch
    transfers, the same terms ``RouteCost.makespan`` sums). Consecutive
    segments must change engines — a same-engine cut is equivalent to the
    route with that cut removed, which is already a candidate at k-1 cuts.
    Returns the argmin path ending on *each* engine, best first: at E=2
    that is exactly both alternating ping-pong sequences; at E>2 it is a
    diversity-preserving set of E assignments whose cross-model balance
    the outer vector search arbitrates via the occupancy deltas.
    """
    E = len(coster.engines)
    n = len(coster.graphs[i])
    bounds = (0,) + cuts + (n,)
    dp = {e: (coster.seg(i, bounds[0], bounds[1], e).elapsed, (e,)) for e in range(E)}
    for j in range(1, len(bounds) - 1):
        lo, hi = bounds[j], bounds[j + 1]
        nxt = {}
        for e in range(E):
            seg_t = coster.seg(i, lo, hi, e).elapsed
            best = None
            for ep, (tot, path) in dp.items():
                if ep == e:
                    continue
                cand = tot + coster.xfer(i, bounds[j], ep) + seg_t
                if best is None or cand < best[0] or (cand == best[0] and path < best[1]):
                    best = (cand, path)
            if best is not None:
                nxt[e] = (best[0], best[1] + (e,))
        dp = nxt
    return [path for _, path in sorted(dp.values())]


def _route_candidates(
    coster: _RouteCoster, i: int, pts, max_cuts: int, route_limit: int
) -> tuple[list[RouteSpec], bool]:
    """Candidate routes for model ``i``: the legacy single-cut candidates
    first (in cut-point order — the prefix the ``max_cuts=1`` pin and the
    never-worse restart rely on), then, per extra cut count k, every
    k-subset of the legal points with its DP engine assignments. When a
    k-level exceeds ``route_limit`` the cap is *balance-aware*: candidates
    are grouped by engine signature (first/last segment engines — the
    counter-phase classes the outer vector search balances across models),
    each group is ranked by per-model makespan, and the groups are
    interleaved round-robin up to the limit. A pure makespan sort would
    keep route_limit near-identical routes that all start on the fastest
    engine and starve the search of counter-phased partners; the
    interleave keeps the cheapest routes of *every* phase class (stable
    order throughout, so ties stay deterministic). Returns
    (candidates, capped)."""
    E = len(coster.engines)
    e1, e2 = _model_pair(i, E)
    cands = [RouteSpec((p,), (e1, e2)) for p in pts]
    capped = False
    if max_cuts <= 1 or E < 2:
        return cands, capped
    for k in range(2, max_cuts + 1):
        level = [
            RouteSpec(cuts, engs)
            for cuts in itertools.combinations(pts, k)
            for engs in _dp_engine_assignments(coster, i, cuts)
        ]
        if route_limit and len(level) > route_limit:
            groups: dict[tuple[int, int], list[RouteSpec]] = {}
            for r in sorted(level, key=lambda r: coster.route(i, r).makespan):
                groups.setdefault((r.engines[0], r.engines[-1]), []).append(r)
            ordered = [g for _, g in sorted(groups.items())]
            level, rank = [], 0
            while len(level) < route_limit:
                took = False
                for g in ordered:
                    if rank < len(g):
                        level.append(g[rank])
                        took = True
                        if len(level) >= route_limit:
                            break
                if not took:
                    break
                rank += 1
            capped = True
        cands.extend(level)
    return cands, capped


def _run_search(cands, balanced, mode, coster, n_engines, flex_idx, key_of, beam_width, descent_rounds):
    """One search over the given candidate lists — the exact legacy
    control flow (exhaustive product scan / beam + descent polish +
    balanced restart / descent-only), factored out so the multi-cut
    planner can run it on both the single-cut prefix and the full
    candidate space."""
    if mode in ("exhaustive", "fixed"):
        best_key, best_vec = None, None
        for vec in itertools.product(*cands):
            k = key_of(vec)
            if best_key is None or k < best_key:
                best_key, best_vec = k, vec
        return best_vec, best_key
    if mode == "beam":
        best_vec, best_key = _beam_search(cands, coster, n_engines, flex_idx, key_of, beam_width)
        best_vec, best_key = _coordinate_descent(best_vec, cands, key_of, descent_rounds)
        restart = _coordinate_descent(balanced, cands, key_of, descent_rounds)
        if restart[1] < best_key:
            best_vec, best_key = restart
        return best_vec, best_key
    # descent
    return _coordinate_descent(balanced, cands, key_of, descent_rounds)


def _nmodel_schedule_impl(
    graphs: list[LayerGraph],
    engines,
    allow_fallback: bool = True,
    stride: int = 1,
    fixed=None,
    exhaustive_limit: int = 20000,
    descent_rounds: int = 8,
    provider: CostProvider | None = None,
    search: str = "auto",
    beam_width: int = 64,
    max_cuts: int = 1,
    route_limit: int = 512,
    impl: str = "xla",
    batch: int = 1,
) -> NModelPlan:
    """Plan N staged models over E engines, up to ``max_cuts`` partition
    points per model.

    Each model's route is a sequence of ``(span, engine)`` segments drawn
    from its legal ``cut_points(stride)``: single-cut candidates keep the
    legacy counter-phased engine pair; multi-cut candidates take every
    k-subset of the points with engine assignments from a per-model DP
    (``_dp_engine_assignments``). ``max_cuts=1`` is bit-identical to the
    historical single-point planner (and, at N=2, to
    ``haxconn_schedule``); at ``max_cuts>1`` the search additionally
    polishes the best single-cut vector inside the multi-cut space, so
    the plan cost is structurally never worse than ``max_cuts=1``.

    ``search`` modes:

    * ``"auto"``       — exhaustive over the Cartesian product of candidate
                         routes when it is small (this covers N=2 single-cut,
                         where the result is provably identical to
                         ``haxconn_schedule``), else beam search.
    * ``"exhaustive"`` — force the full product scan.
    * ``"beam"``       — beam search over route vectors (width
                         ``beam_width``), pruning identical-contribution
                         candidates, followed by a coordinate-descent
                         polish from the beam's best vector. The legacy
                         balanced warm start is kept as a restart seed, so
                         the beam planner is structurally never worse than
                         the old coordinate descent.
    * ``"descent"``    — the legacy coordinate descent from a cost-balanced
                         start (kept as a comparison baseline).

    ``fixed`` pins routes instead of searching: a sequence whose entries
    are an ``int`` (legacy single cut with the counter-phased pair), a
    ``(cuts, engines)`` tuple / ``RouteSpec`` (a full multi-cut route —
    how the re-planner re-scores an incumbent plan), or ``None`` (leave
    that model free — the partial-re-plan path searches one model while
    holding the rest).

    Plans record which provider scored them (``plan.cost_provider``),
    which search produced them (``plan.search``), and the full cut
    vectors (``plan.cuts``; ``plan.partitions`` stays the first-cut view).

    ``impl`` adds implementation choice as a planning dimension beside
    the engine binding: ``"xla"`` (default — bit-identical to the
    historical planner), ``"pallas"`` (force the fused conv/deconv+
    norm+act kernels on every segment), or ``"auto"`` (per-segment argmin
    over both implementations; the winning variant is recorded on each
    emitted ``PlanSegment.impl`` and the plan cost is structurally never
    worse than ``impl="xla"``).
    """
    graphs, engines = list(graphs), list(engines)
    if not graphs:
        raise ValueError("nmodel_schedule needs at least one model graph")
    if not engines:
        raise ValueError("nmodel_schedule needs at least one engine")
    if search not in ("auto", "exhaustive", "beam", "descent"):
        raise ValueError(f"unknown search mode {search!r}")
    if max_cuts < 1:
        raise ValueError(f"max_cuts must be >= 1, got {max_cuts}")
    if impl not in ("xla", "auto", "pallas"):
        raise ValueError(f"unknown impl mode {impl!r} (expected xla | auto | pallas)")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if provider is None:
        provider = ANALYTIC
    E = len(engines)
    flex_idx = _flex_engine_index(engines)
    coster = _RouteCoster(
        graphs, engines, allow_fallback, flex_idx, provider, impl=impl, batch=batch
    )

    pinned: list[RouteSpec | None] = [None] * len(graphs)
    if fixed is not None:
        if len(fixed) != len(graphs):
            raise ValueError(f"fixed pins {len(fixed)} models but {len(graphs)} graphs given")
        pinned = [None if f is None else _as_route_spec(f, i, E) for i, f in enumerate(fixed)]
    all_pinned = fixed is not None and all(p is not None for p in pinned)

    pts_all, cands, n_single, capped = [], [], [], False
    for i, g in enumerate(graphs):
        if pinned[i] is not None:
            pts_all.append([])
            cands.append([pinned[i]])
            n_single.append(1)
            continue
        pts = _candidate_points(g, stride)
        if not pts:
            raise ValueError(f"model {g.model_name} has no interior partition point")
        cl, cp = _route_candidates(coster, i, pts, max_cuts, route_limit)
        pts_all.append(pts)
        cands.append(cl)
        n_single.append(len(pts))
        capped = capped or cp

    key_cache: dict[tuple, tuple] = {}

    def key_of(vec):
        vec = tuple(vec)
        if vec not in key_cache:
            key_cache[vec] = _evaluate_routes(E, vec, flex_idx, coster)[0]
        return key_cache[vec]

    def pick_mode(lists):
        if all_pinned:
            return "fixed"
        if search == "auto":
            n = math.prod(len(c) for c in lists)
            return "exhaustive" if n <= exhaustive_limit else "beam"
        return search

    balanced = [
        pinned[i]
        if pinned[i] is not None
        else RouteSpec(
            (
                balanced_partition_point(
                    g,
                    engines[_model_pair(i, E)[0]],
                    engines[_model_pair(i, E)[1]],
                    pts_all[i],
                    provider=provider,
                ),
            ),
            _model_pair(i, E),
        )
        for i, g in enumerate(graphs)
    ]

    # single-cut pass: exactly the legacy search over the single-cut
    # candidate prefix — at max_cuts=1 this IS the result (bit-identical
    # to the historical planner); at max_cuts>1 it seeds the never-worse
    # guarantee below
    cands1 = [cl[:n] for cl, n in zip(cands, n_single)]
    mode1 = pick_mode(cands1)
    best_vec, best_key = _run_search(
        cands1, balanced, mode1, coster, E, flex_idx, key_of, beam_width, descent_rounds
    )
    mode = mode1
    if max_cuts > 1 and not all_pinned:
        mode = pick_mode(cands)
        multi_vec, multi_key = _run_search(
            cands, balanced, mode, coster, E, flex_idx, key_of, beam_width, descent_rounds
        )
        # polish the single-cut optimum inside the multi-cut space: the
        # result can only improve on it, so max_cuts=k is structurally
        # never worse than max_cuts=1 even when the beam truncates
        best_vec, best_key = _coordinate_descent(best_vec, cands, key_of, descent_rounds)
        if multi_key < best_key:
            best_vec, best_key = multi_vec, multi_key

    (cycle, _), t, busy, per_model = _evaluate_routes(E, best_vec, flex_idx, coster)
    loads = {e.name: EngineLoad(busy=b, stall=cycle - b) for e, b in zip(engines, busy)}
    routes, segments, notes, ir_spans = [], [], [], []
    n_fallback = 0
    for i, (g, spec) in enumerate(zip(graphs, best_vec)):
        rc = per_model[i]
        label = chr(ord("a") + i % 26)
        seg_list = spec.segments(len(g))
        routes.append(
            ModelRoute(
                model=g.model_name,
                partition=spec.cuts[0] if spec.cuts else len(g),
                segments=seg_list,
                cuts=spec.cuts,
            )
        )
        ir_spans.append(
            [
                (e, lo, hi, c.elapsed, coster.chosen(i, lo, hi, e))
                for (e, lo, hi), (_, c) in zip(seg_list, rc.segs)
            ]
        )
        xi = 0
        for j, ((e, lo, hi), (_, c)) in enumerate(zip(seg_list, rc.segs)):
            segments.append((engines[e].name, f"{label}{j + 1}", c.elapsed))
            if j < len(spec.cuts) and spec.engines[j] != spec.engines[j + 1]:
                ce, x = rc.xfers[xi]
                xi += 1
                if x:
                    segments.append((engines[ce].name, "xfer", x))
        if rc.fallback:
            segments.append((engines[flex_idx].name, "fallback", rc.fallback))
        n_fallback += rc.n_fallback_runs
        notes.append(
            f"{g.model_name}: "
            + " ".join(f"{engines[e].name}[{lo}:{hi})" for e, lo, hi in seg_list)
        )
    notes.append(f"fallback_runs={n_fallback}")
    notes.append(f"search={mode} cost={provider.name}")
    if batch > 1:
        notes.append(f"batch={batch} (per-frame amortized costs)")
    if max_cuts > 1:
        notes.append(f"max_cuts={max_cuts}" + (" (route candidates capped)" if capped else ""))
    if impl != "xla":
        n_pallas = sum(
            1 for spans in ir_spans for sp in spans if sp[4] == "pallas_fused"
        )
        notes.append(f"impl={impl} ({n_pallas} pallas_fused segments)")
    ir = make_plan_ir(
        tuple(g.model_name for g in graphs),
        tuple(e.name for e in engines),
        ir_spans,
        expected_cycle=cycle,
        cost_provider=provider.name,
        search=mode,
        kind="nmodel",
        graphs=graphs,
        cut_budget=max_cuts,
        impl_mode=impl,
        batch=batch,
    )
    sched = Schedule(
        kind="nmodel",
        models=tuple(g.model_name for g in graphs),
        engines=tuple(e.name for e in engines),
        cycle_time=cycle,
        loads=loads,
        # instance-indexed keys: the same graph may be scheduled N times
        # with different partition points
        partitions={
            f"{i}:{g.model_name}": tuple(spec.cuts) + (len(g),)
            for i, (g, spec) in enumerate(zip(graphs, best_vec))
        },
        segments=segments,
        notes=notes,
        ir=ir,
    )
    return NModelPlan(
        schedule=sched,
        routes=routes,
        partitions=[spec.cuts[0] if spec.cuts else len(g) for spec, g in zip(best_vec, graphs)],
        engine_times={e.name: ti for e, ti in zip(engines, t)},
        flex_index=flex_idx,
        cost_provider=provider.name,
        search=mode,
        ir=ir,
        cuts=[tuple(spec.cuts) for spec in best_vec],
        max_cuts=max_cuts,
        batch=batch,
    )


# ---------------------------------------------------------------------------
# legacy entry points — thin deprecated wrappers over the impls above
# ---------------------------------------------------------------------------


def _deprecated_entry(impl, name: str):
    """Wrap a scheduler impl with a DeprecationWarning pointing at the
    unified ``repro.core.plan()`` API. The wrapper is pass-through — same
    arguments, same return object — so pinned outputs stay bit-identical
    to the pre-``plan()`` entry points."""
    import functools
    import warnings

    @functools.wraps(impl)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"{name} is deprecated; use repro.core.plan(..., kind=...) — it returns "
            "the PlanIR the serve stack consumes (the legacy result's .ir)",
            DeprecationWarning,
            stacklevel=2,
        )
        return impl(*args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = name
    return wrapper


standalone_schedule = _deprecated_entry(_standalone_schedule_impl, "standalone_schedule")
naive_schedule = _deprecated_entry(_naive_schedule_impl, "naive_schedule")
haxconn_schedule = _deprecated_entry(_haxconn_schedule_impl, "haxconn_schedule")
nmodel_schedule = _deprecated_entry(_nmodel_schedule_impl, "nmodel_schedule")
