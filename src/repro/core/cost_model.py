"""Roofline cost model over layer graphs + engine specs.

Per-layer time on an engine is the roofline max(flops/peak, bytes/bw);
"inefficient" (but legal) layers pay a derate. Transfers between engines
cost boundary_bytes / link_bw plus a fixed switch overhead — this is what
makes fallback expensive and what the HaX-CoNN balance search trades off.

The same estimates can be *profiled* instead of analytic: see
``core.profiler`` which re-derives flops/bytes from XLA's
``compiled.cost_analysis()`` per layer (the trtexec analogue).
"""
from __future__ import annotations

import dataclasses

from .constraints import Violation
from .graph import LayerGraph, LayerMeta

SWITCH_OVERHEAD = 25e-6  # s; engine handoff latency (DeepStream/TensorRT-like)
INEFFICIENT_DERATE = 0.5  # achieved fraction of engine flops on mis-aligned layers


def layer_time(l: LayerMeta, engine) -> float:
    flops = engine.flops
    for v in engine.supports(l):
        if v.severity == "inefficient":
            flops = flops * INEFFICIENT_DERATE
    t_c = l.flops / flops if flops else 0.0
    t_m = l.bytes_accessed / engine.hbm_bw
    return max(t_c, t_m)


def transfer_time(nbytes: float, engine) -> float:
    return nbytes / engine.link_bw + SWITCH_OVERHEAD


def is_illegal(l: LayerMeta, engine) -> bool:
    return any(v.severity == "illegal" for v in engine.supports(l))


@dataclasses.dataclass
class SegmentCost:
    """Cost of running graph[lo:hi] 'assigned' to ``engine`` with illegal
    layers falling back to ``peer`` (paper's Jetson semantics)."""

    lo: int
    hi: int
    engine_busy: float  # time the assigned engine computes
    peer_busy: float  # time stolen from the peer by fallback
    transfer: float  # engine<->peer handoff time (incl. switch overhead)
    n_fallback_runs: int
    elapsed: float  # wall time of the segment (serialized fallback)

    @property
    def has_fallback(self):
        return self.n_fallback_runs > 0


def segment_cost(graph: LayerGraph, lo: int, hi: int, engine, peer, allow_fallback=True) -> SegmentCost:
    engine_busy = peer_busy = transfer = 0.0
    runs = 0
    prev_illegal = False
    for i in range(lo, hi):
        l = graph[i]
        ill = allow_fallback and is_illegal(l, engine)
        if ill:
            peer_busy += layer_time(l, peer)
            if not prev_illegal:
                runs += 1
                # hand the activation to the peer...
                prev_bytes = graph[i - 1].boundary_bytes if i > lo else l.boundary_bytes
                transfer += transfer_time(prev_bytes, engine)
        else:
            engine_busy += layer_time(l, engine)
            if prev_illegal:
                # ...and back
                transfer += transfer_time(graph[i - 1].boundary_bytes, engine)
        prev_illegal = ill
    if prev_illegal:
        transfer += transfer_time(graph[hi - 1].boundary_bytes, engine)
    return SegmentCost(
        lo=lo,
        hi=hi,
        engine_busy=engine_busy,
        peer_busy=peer_busy,
        transfer=transfer,
        n_fallback_runs=runs,
        elapsed=engine_busy + peer_busy + transfer,
    )


def graph_time(graph: LayerGraph, engine, peer=None, allow_fallback=True) -> SegmentCost:
    peer = peer or engine
    return segment_cost(graph, 0, len(graph), engine, peer, allow_fallback=allow_fallback)


def partition_boundary_bytes(graph: LayerGraph, p: int) -> float:
    """Bytes crossing a partition placed after layer p-1."""
    if p <= 0 or p >= len(graph):
        return 0.0
    return graph[p - 1].boundary_bytes


def balanced_partition_point(graph: LayerGraph, head_engine, tail_engine, candidates=None) -> int:
    """Partition point that best balances head time on ``head_engine``
    against tail time on ``tail_engine`` — the warm start for the N-model
    planner's coordinate descent (and a decent heuristic on its own)."""
    cands = list(candidates) if candidates is not None else list(range(1, len(graph)))
    if not cands:
        raise ValueError(f"{graph.model_name}: no interior partition point")
    prefix = [0.0]
    for l in graph:
        prefix.append(prefix[-1] + layer_time(l, head_engine))
    suffix = [0.0]
    for l in reversed(list(graph)):
        suffix.append(suffix[-1] + layer_time(l, tail_engine))
    suffix.reverse()
    return min(cands, key=lambda p: abs(prefix[p] - suffix[p]))
