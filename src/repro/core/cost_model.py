"""Cost providers + roofline cost model over layer graphs and engine specs.

Per-layer time on an engine is the roofline max(flops/peak, bytes/bw);
"inefficient" (but legal) layers pay a derate. Transfers between engines
cost boundary_bytes / link_bw plus a fixed switch overhead — this is what
makes fallback expensive and what the HaX-CoNN balance search trades off.

Where the flop/byte numbers come from is pluggable (the ``CostProvider``
protocol): ``AnalyticCost`` uses the LayerMeta estimates as-built,
``MeasuredCost`` re-derives them from XLA's ``compiled.cost_analysis()``
per layer (the trtexec analogue, see ``core.profiler``) and caches the
resulting per-(layer, engine, dtype) timings to a JSON file so repeated
planning runs do not re-lower, and ``BlendedCost`` takes measured numbers
where a measurement exists and falls back to analytic elsewhere. The
scheduler and the partition heuristics consume only the provider
interface, so one flag switches the whole plan->execute pipeline from
paper-mode analytic planning to hardware-measured planning.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading

from .constraints import Violation
from .graph import LayerGraph, LayerMeta

SWITCH_OVERHEAD = 25e-6  # s; engine handoff latency (DeepStream/TensorRT-like)
INEFFICIENT_DERATE = 0.5  # achieved fraction of engine flops on mis-aligned layers
BATCH_FIXED_FRAC = 0.25  # fraction of per-frame time that is batch-amortizable


def batch_amortization(batch: int) -> float:
    """Per-frame time multiplier at effective batch ``batch``.

    Models the fixed per-dispatch cost (kernel launch, weight traffic,
    host sync) that a batched executable pays once instead of per frame:
    ``amort(1) == 1.0`` exactly — batch-1 plans are bit-identical to the
    pre-batching planner — and the curve decays toward
    ``1 - BATCH_FIXED_FRAC`` as the bucket grows. ``MeasuredCost``
    replaces this analytic curve with real per-bucket lowerings; this is
    the fallback shape for analytic planning and unmeasured layers."""
    b = max(int(batch), 1)
    return 1.0 - BATCH_FIXED_FRAC * (1.0 - 1.0 / b)


def _effective_flops(l: LayerMeta, engine) -> float:
    """Engine flops achievable on this layer: derated once when any
    'inefficient' violation applies. The derate is deliberately NOT
    compounded per violation — hierarchical metas report one violation
    per mis-aligned primitive, and compounding would derate a composite
    by 0.5^k instead of the 0.5 a mis-aligned block actually costs."""
    if any(v.severity == "inefficient" for v in engine.supports(l)):
        return engine.flops * INEFFICIENT_DERATE
    return engine.flops


def _roofline(flops: float, bytes_accessed: float, l: LayerMeta, engine) -> float:
    eff = _effective_flops(l, engine)
    t_c = flops / eff if eff else 0.0
    t_m = bytes_accessed / engine.hbm_bw
    return max(t_c, t_m)


def layer_time(l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
    """Analytic roofline layer time (the historical default path).

    ``impl="pallas_fused"`` costs marked fused blocks (``attrs["fuse"]``
    on the lead layer) with their fused analytic totals — one HBM round
    trip for the whole block — and their folded members at zero; layers
    without a variant keep the per-layer roofline. ``batch`` > 1 returns
    the *per-frame* time at that effective batch (see
    ``batch_amortization``); batch=1 is the historical value exactly."""
    amort = batch_amortization(batch)
    if impl != "xla":
        fu = l.attrs.get("fuse")
        if fu is not None:
            return _roofline(fu["flops"], fu["bytes"], l, engine) * amort
        if "fused_into" in l.attrs:
            return 0.0
        if l.sublayers:
            return sum(layer_time(p, engine, impl, batch) for p in l.sublayers)
    return _roofline(l.flops, l.bytes_accessed, l, engine) * amort


def transfer_time(nbytes: float, engine) -> float:
    return nbytes / engine.link_bw + SWITCH_OVERHEAD


def is_illegal(l: LayerMeta, engine) -> bool:
    return any(v.severity == "illegal" for v in engine.supports(l))


# ---------------------------------------------------------------------------
# Cost providers
# ---------------------------------------------------------------------------


class CostProvider:
    """Source of per-layer timings for the planner.

    Subclasses override ``layer_time``; ``available`` reports whether the
    provider has a *measured* (non-analytic) number for a layer, which is
    what ``BlendedCost`` keys its fallback on.
    """

    name = "base"

    def layer_time(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
        raise NotImplementedError

    def available(self, l: LayerMeta, impl: str = "xla") -> bool:
        return False

    def describe(self) -> str:
        return self.name


class AnalyticCost(CostProvider):
    """Roofline over the LayerMeta's analytic flop/byte estimates."""

    name = "analytic"

    def layer_time(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
        return layer_time(l, engine, impl, batch)


ANALYTIC = AnalyticCost()


class MeasuredCost(CostProvider):
    """Roofline over XLA-measured flop/byte counts per layer.

    Conv/deconv layers are lowered individually on ShapeDtypeStructs and
    their ``cost_analysis()`` numbers replace the analytic estimates;
    pointwise/norm/concat-style kinds go through a generic elementwise
    lowering (``profiler._elementwise_cost``), so every segment of the
    serving graphs is covered by a measurement. Composite graph-level
    kinds (c2f, sppf, head, ...) are costed by *expansion*: when the meta
    carries a primitive decomposition (``LayerMeta.sublayers``), its time
    is the sum of the measured primitive times — ``coverage()`` reaches
    1.0 on the YOLO graph. Composites without a decomposition keep the
    analytic numbers; ``available`` reports which. The derived
    per-(layer, engine, dtype) timing is cached in memory and, when
    ``cache_path`` is given, persisted as JSON so later runs (and other
    processes) skip the lowering entirely.
    """

    name = "measured"
    _MEASURABLE = ("conv", "deconv")
    # elementwise kinds measured via the generic lowering in core.profiler
    # (kept as a literal so importing cost_model does not pull in jax)
    _ELEMENTWISE = ("act", "tanh", "bn", "norm", "concat", "crop", "pad", "pool", "dropout", "add")

    def __init__(self, cache_path: str | None = None, dtype: str = "bfloat16"):
        self.cache_path = cache_path
        self.dtype = dtype
        self._cache: dict[str, float] = {}
        self.measure_count = 0  # lowerings performed by this instance
        self.hits = 0
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                payload = json.load(f)
            if payload.get("dtype", dtype) != dtype:
                raise ValueError(
                    f"{cache_path}: cached dtype {payload.get('dtype')!r} != requested {dtype!r}"
                )
            self._cache = dict(payload.get("entries", {}))

    def available(self, l: LayerMeta, impl: str = "xla") -> bool:
        if l.sublayers:
            # composite graph-level kinds (c2f/sppf/head/...) are costed by
            # expansion: measurable iff every primitive in their
            # decomposition is
            return all(self.available(p, impl) for p in l.sublayers)
        if impl != "xla":
            if "fused_into" in l.attrs:
                return True  # cost folds into the group's lead layer
            if "fuse" in l.attrs:
                return l.attrs.get("groups", 1) == 1
        if l.kind in self._MEASURABLE:
            return l.attrs.get("groups", 1) == 1
        return l.kind in self._ELEMENTWISE

    def coverage(self, graph: LayerGraph, impl: str = "xla") -> float:
        """Fraction of a graph's layers served by a measurement (composites
        count as covered when their whole decomposition is)."""
        return sum(self.available(l, impl) for l in graph) / max(len(graph), 1)

    def coverage_report(self, graph: LayerGraph, impls=("xla", "pallas_fused")) -> dict:
        """Per-implementation coverage with the uncovered layer names —
        the gaps a calibration run must fill before ``--impl auto``
        planning is fully measured on this graph."""
        report = {}
        for impl in impls:
            missing = [l.name for l in graph if not self.available(l, impl)]
            report[impl] = {
                "coverage": self.coverage(graph, impl),
                "missing": missing,
            }
        return report

    def _key(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> str:
        shape = "x".join(str(d) for d in l.in_shape)
        a = l.attrs
        sig = f"k{a.get('kernel', 1)}s{a.get('stride', 1)}p{a.get('padding', 0)}"
        base = f"{l.kind}|{shape}|{sig}|c{l.out_shape[-1]}|{engine.name}|{self.dtype}"
        if impl != "xla":
            base = f"{base}|{impl}"
        # per-bucket entries form the amortization curve in the JSON cache;
        # batch=1 keys stay byte-identical to the pre-batching format
        return base if batch == 1 else f"{base}|b{batch}"

    @staticmethod
    def _batched_shape(in_shape, batch: int) -> tuple:
        shape = tuple(in_shape)
        if batch == 1 or not shape:
            return shape
        return (shape[0] * batch,) + shape[1:]

    def _measure(self, l: LayerMeta, batch: int = 1) -> tuple[float, float]:
        from .profiler import _conv_cost, _elementwise_cost

        self.measure_count += 1
        shape = self._batched_shape(l.in_shape, batch)
        if l.kind in self._MEASURABLE:
            flops, bytes_ = _conv_cost(
                shape,
                l.attrs.get("kernel", 1),
                l.attrs.get("stride", 1),
                l.attrs.get("padding", 0),
                l.out_shape[-1],
                l.kind == "deconv",
                self.dtype,
            )
        else:
            flops, bytes_ = _elementwise_cost(l.kind, shape, self.dtype)
        # per-frame numbers at this bucket: weight traffic is counted once
        # by cost_analysis, so dividing by batch yields a real (sub-linear)
        # amortization curve rather than the analytic approximation
        return flops / batch, bytes_ / batch

    def _measure_fused(self, l: LayerMeta, fu: dict, batch: int = 1) -> tuple[float, float]:
        from .profiler import _fused_cost, _sppf_cost

        self.measure_count += 1
        shape = self._batched_shape(l.in_shape, batch)
        if fu.get("kind") == "pool":
            # SPPF pool pyramid + concat fused into one region
            flops, bytes_ = _sppf_cost(shape, fu.get("window", 5), fu.get("span", 3), self.dtype)
        else:
            flops, bytes_ = _fused_cost(
                shape,
                l.attrs.get("kernel", 1),
                l.attrs.get("stride", 1),
                l.attrs.get("padding", 0),
                l.out_shape[-1],
                fu.get("kind", l.kind) == "deconv",
                fu.get("norm", "none"),
                fu.get("act", "none"),
                self.dtype,
            )
        return flops / batch, bytes_ / batch

    def layer_time(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
        batch = max(int(batch), 1)
        if not self.available(l, impl):
            return layer_time(l, engine, impl, batch)
        if l.sublayers:
            return sum(self.layer_time(p, engine, impl, batch) for p in l.sublayers)
        if impl != "xla":
            if "fused_into" in l.attrs:
                return 0.0
            fu = l.attrs.get("fuse")
            if fu is not None:
                key = self._key(l, engine, impl, batch)
                if key in self._cache:
                    self.hits += 1
                    return self._cache[key]
                flops, bytes_ = self._measure_fused(l, fu, batch)
                t = _roofline(flops or fu["flops"], bytes_ or fu["bytes"], l, engine)
                self._cache[key] = t
                return t
        key = self._key(l, engine, batch=batch)
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        flops, bytes_ = self._measure(l, batch)
        t = _roofline(flops or l.flops, bytes_ or l.bytes_accessed, l, engine)
        self._cache[key] = t
        return t

    def save(self, path: str | None = None) -> str:
        path = path or self.cache_path
        if not path:
            raise ValueError("MeasuredCost has no cache_path to save to")
        payload = {"version": 1, "dtype": self.dtype, "entries": self._cache}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
        return path

    @property
    def cache_size(self) -> int:
        return len(self._cache)


class BlendedCost(CostProvider):
    """Measured where a measurement exists, analytic everywhere else."""

    name = "blended"

    def __init__(self, measured: MeasuredCost | None = None, analytic: CostProvider | None = None):
        self.measured = measured or MeasuredCost()
        self.analytic = analytic or ANALYTIC

    def available(self, l: LayerMeta, impl: str = "xla") -> bool:
        return self.measured.available(l, impl)

    def layer_time(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
        if self.measured.available(l, impl):
            return self.measured.layer_time(l, engine, impl, batch)
        return self.analytic.layer_time(l, engine, impl, batch)

    def save(self, path: str | None = None) -> str:
        return self.measured.save(path)


class OnlineCost(CostProvider):
    """Live-calibrated costs: a base provider scaled by a decayed weighted
    ratio of observed vs expected per-segment wall time, one per engine.

    The serving executor reports ``(engine, observed_wall_s, expected_s)``
    per profiled segment (``expected_s`` always in *base-provider* units,
    re-derived from the graphs — never from a previously-scaled plan, so
    the calibration is a fixed base->wall mapping that survives plan
    hot-swaps). The scale is ``EMA(observed) / EMA(expected)`` rather
    than ``EMA(observed/expected)``: numerator and denominator decay
    together, so a sample's influence is proportional to its expected
    magnitude — near-empty spans whose wall is pure host overhead (ratios
    in the thousands) cannot swing the calibration, while heavyweight
    segments dominate it. ``layer_time`` then returns ``base *
    scale(engine)``: the planner ranks engines by what they actually
    deliver right now, which is exactly the signal the re-planner needs
    when thermal state or co-located load skews one engine. On this CPU
    container the scales double as the analytic-units -> wall-clock
    calibration.

    One instance may be shared by every replica of a serving fleet: the
    drain is thread-safe (an ``RLock`` guards the EMA state), so all
    replicas' ``SegmentObservation``s fold into a single fleet-wide
    calibration store keyed per (engine, impl).
    """

    name = "online"

    def __init__(self, base: CostProvider | None = None, alpha: float = 0.35):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"EMA alpha must be in (0, 1], got {alpha}")
        self.base = base or ANALYTIC
        self.alpha = alpha
        self._num: dict[str, float] = {}  # decayed observed-wall sum
        self._den: dict[str, float] = {}  # decayed expected sum
        self._lock = threading.RLock()  # fleet replicas drain concurrently
        self.observations = 0

    def observe(self, engine_name: str, observed_s: float, expected_s: float):
        """Fold one (observed wall, expected base-units) sample."""
        if observed_s <= 0.0 or expected_s <= 0.0:
            return
        a = self.alpha
        with self._lock:
            if engine_name not in self._num:
                self._num[engine_name] = observed_s
                self._den[engine_name] = expected_s
            else:
                self._num[engine_name] = (1.0 - a) * self._num[engine_name] + a * observed_s
                self._den[engine_name] = (1.0 - a) * self._den[engine_name] + a * expected_s
            self.observations += 1

    def scale(self, engine_name: str) -> float:
        with self._lock:
            den = self._den.get(engine_name, 0.0)
            return self._num[engine_name] / den if den > 0 else 1.0

    def scale_for(self, engine_name: str, impl: str = "xla", batch: int = 1) -> float:
        """Per-(engine, impl, bucket) calibration: non-xla implementations
        get their own drift channel (``"engine|impl"`` keys, fed by the
        executor when a segment ran that variant), and batched segments get
        per-bucket channels (``"...|b{bucket}"``) — the observed-vs-expected
        ratio at each bucket is its own calibration, so a mis-modelled
        amortization curve surfaces as bucket-channel drift. Fallback
        ladder: exact (impl, bucket) -> (engine, bucket) -> impl -> plain
        engine scale."""
        base = f"{engine_name}|{impl}" if impl != "xla" else engine_name
        if batch > 1:
            for key in (f"{base}|b{batch}", f"{engine_name}|b{batch}"):
                if key in self._num:
                    return self.scale(key)
        if impl != "xla" and base in self._num:
            return self.scale(base)
        return self.scale(engine_name)

    def calibrated(self, engine_names) -> bool:
        return all(e in self._num for e in engine_names)

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            return {name: self.scale(name) for name in list(self._num)}

    # -- fleet-wide calibration sync (see serve.multiproc) -------------------

    def state(self) -> dict[str, dict[str, float]]:
        """The raw per-key EMA sums, JSON-able: ``{key: {num, den}}``.
        The sums — not the ratios — are the sync currency: merging them
        keeps each contributor's weight proportional to its decayed
        expected magnitude (the same weighted-ratio idiom ``observe``
        applies to individual samples)."""
        with self._lock:
            return {k: {"num": self._num[k], "den": self._den[k]} for k in self._num}

    def load_state(self, state: dict) -> "OnlineCost":
        """Replace the per-key EMA sums with a (merged) ``state()`` dict.
        Non-positive entries are skipped — a broadcast can never wipe a
        key into an invalid scale. Returns self."""
        with self._lock:
            for name, st in state.items():
                num, den = float(st["num"]), float(st["den"])
                if num <= 0.0 or den <= 0.0:
                    continue
                self._num[name] = num
                self._den[name] = den
        return self

    def layer_time(self, l: LayerMeta, engine, impl: str = "xla", batch: int = 1) -> float:
        return self.base.layer_time(l, engine, impl, batch) * self.scale_for(
            engine.name, impl, batch
        )

    def available(self, l: LayerMeta, impl: str = "xla") -> bool:
        return self.base.available(l, impl)

    def describe(self) -> str:
        scales = ", ".join(f"{k}x{v:.3g}" for k, v in sorted(self.snapshot().items()))
        return f"online({self.base.name}; {scales or 'uncalibrated'})"

    def save(self, path: str | None = None) -> str:
        """Persist the wrapped provider's timing cache (measured/blended
        bases feed the JSON cache; analytic has nothing to save)."""
        if hasattr(self.base, "save"):
            return self.base.save(path)
        raise ValueError(f"OnlineCost over {self.base.name!r} has no timing cache to save")

    # -- calibration persistence (warm-start across process restarts) -------

    def save_calibration(self, path: str) -> str:
        """Write the learned per-engine EMA state to JSON. The decayed
        (observed, expected) sums are stored — not just their ratio — so a
        restarted process resumes the EMA with the same sample weighting
        it shut down with.

        The write is atomic for *concurrent* writers: each write goes to
        a uniquely-named temp file in the target directory, then
        ``os.replace``s into place. A fixed ``path + ".tmp"`` would let
        two fleet workers checkpointing at once interleave writes into
        the same temp file and publish a corrupt mix; with unique temps
        the last replace wins and every published file is complete."""
        payload = {
            "version": 1,
            "alpha": self.alpha,
            "base": self.base.name,
            "engines": self.state(),
        }
        target = os.path.abspath(path)
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(target) + ".", suffix=".tmp", dir=os.path.dirname(target)
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, target)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def load_calibration(self, path: str) -> "OnlineCost":
        """Warm-start the per-engine scales from a ``save_calibration``
        JSON. Returns self; raises on version/shape mismatch and when the
        calibration was learned over a *different base provider* — scales
        are EMA(wall)/EMA(base-units), so analytic-base scales are
        meaningless to a measured-base calibrator and vice versa."""
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != 1:
            raise ValueError(f"{path}: unsupported calibration version {payload.get('version')!r}")
        saved_base = payload.get("base", self.base.name)
        if saved_base != self.base.name:
            raise ValueError(
                f"{path}: calibration was learned over base provider {saved_base!r} "
                f"but this OnlineCost wraps {self.base.name!r} — the scales are in "
                "different units; re-calibrate instead of warm-starting"
            )
        with self._lock:
            for name, st in payload.get("engines", {}).items():
                num, den = float(st["num"]), float(st["den"])
                if num <= 0 or den <= 0:
                    raise ValueError(f"{path}: non-positive EMA state for engine {name!r}")
                self._num[name] = num
                self._den[name] = den
        return self


def make_cost_provider(
    name: str,
    cache_path: str | None = None,
    dtype: str = "bfloat16",
    calibration_path: str | None = None,
) -> CostProvider:
    """Factory behind every ``--cost {analytic,measured,blended,online}``
    flag. ``online`` wraps the blended (measured-with-analytic-fallback)
    provider in the live EMA calibrator the re-planning runtime feeds;
    ``calibration_path`` (when the file exists) warm-starts its per-engine
    scales from a previous process's ``save_calibration`` JSON."""
    if name == "analytic":
        return ANALYTIC
    if name == "measured":
        return MeasuredCost(cache_path=cache_path, dtype=dtype)
    if name == "blended":
        return BlendedCost(MeasuredCost(cache_path=cache_path, dtype=dtype))
    if name == "online":
        online = OnlineCost(BlendedCost(MeasuredCost(cache_path=cache_path, dtype=dtype)))
        if calibration_path and os.path.exists(calibration_path):
            online.load_calibration(calibration_path)
        return online
    raise ValueError(f"unknown cost provider {name!r} (want analytic|measured|blended|online)")


# ---------------------------------------------------------------------------
# Segment / graph costing (provider-parameterized)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentCost:
    """Cost of running graph[lo:hi] 'assigned' to ``engine`` with illegal
    layers falling back to ``peer`` (paper's Jetson semantics)."""

    lo: int
    hi: int
    engine_busy: float  # time the assigned engine computes
    peer_busy: float  # time stolen from the peer by fallback
    transfer: float  # engine<->peer handoff time (incl. switch overhead)
    n_fallback_runs: int
    elapsed: float  # wall time of the segment (serialized fallback)

    @property
    def has_fallback(self):
        return self.n_fallback_runs > 0


def _effective_impls(graph: LayerGraph, lo: int, hi: int, impl: str) -> list[str] | None:
    """Per-layer implementation actually run by segment [lo, hi) under
    ``impl`` — mirrors ``StagedModel.segment_ops``: a fused group only
    switches when it lies entirely inside the segment, so blocks split by
    the segment boundary are costed (and executed) as xla. Composite
    layers keep ``impl``: their fused groups live inside the node, so a
    segment containing the node contains every group (the provider
    recurses into the decomposition)."""
    if impl == "xla":
        return None
    eff = [impl] * (hi - lo)
    for i, l in enumerate(graph):
        fu = l.attrs.get("fuse")
        if fu is None:
            continue
        a, b = i, i + fu["span"]
        if a >= lo and b <= hi:
            continue  # fully contained: the group runs fused
        for j in range(max(a, lo), min(b, hi)):
            eff[j - lo] = "xla"
    return eff


def segment_cost(
    graph: LayerGraph,
    lo: int,
    hi: int,
    engine,
    peer,
    allow_fallback=True,
    provider: CostProvider | None = None,
    impl: str = "xla",
    batch: int = 1,
) -> SegmentCost:
    """Per-frame segment cost at effective batch ``batch``: layer times
    are the provider's per-frame amortized numbers and each handoff moves
    the whole bucket's activations once (``bytes * batch`` through the
    link, one SWITCH_OVERHEAD) divided back per frame — so batching
    amortizes the fixed engine-switch latency exactly where the serving
    executor does. batch=1 reproduces the historical costs bit-for-bit."""
    if provider is None:
        provider = ANALYTIC
    batch = max(int(batch), 1)
    eff = _effective_impls(graph, lo, hi, impl)

    def xfer(nbytes: float) -> float:
        return transfer_time(nbytes * batch, engine) / batch

    engine_busy = peer_busy = transfer = 0.0
    runs = 0
    prev_illegal = False
    for i in range(lo, hi):
        l = graph[i]
        li = "xla" if eff is None else eff[i - lo]
        ill = allow_fallback and is_illegal(l, engine)
        if ill:
            peer_busy += provider.layer_time(l, peer, li, batch)
            if not prev_illegal:
                runs += 1
                # hand the activation to the peer...
                prev_bytes = graph[i - 1].boundary_bytes if i > lo else l.boundary_bytes
                transfer += xfer(prev_bytes)
        else:
            engine_busy += provider.layer_time(l, engine, li, batch)
            if prev_illegal:
                # ...and back
                transfer += xfer(graph[i - 1].boundary_bytes)
        prev_illegal = ill
    if prev_illegal:
        transfer += xfer(graph[hi - 1].boundary_bytes)
    return SegmentCost(
        lo=lo,
        hi=hi,
        engine_busy=engine_busy,
        peer_busy=peer_busy,
        transfer=transfer,
        n_fallback_runs=runs,
        elapsed=engine_busy + peer_busy + transfer,
    )


def graph_time(
    graph: LayerGraph,
    engine,
    peer=None,
    allow_fallback=True,
    provider: CostProvider | None = None,
    impl: str = "xla",
    batch: int = 1,
) -> SegmentCost:
    peer = peer or engine
    return segment_cost(
        graph, 0, len(graph), engine, peer,
        allow_fallback=allow_fallback, provider=provider, impl=impl, batch=batch,
    )


class SegmentCostCache:
    """Memoized ``segment_cost``/``transfer_time`` keyed on spans.

    The multi-cut planner evaluates the same (model, span, engine)
    segment under thousands of candidate routes — any two routes sharing
    a cut share the span on one side of it — so the planner's inner loop
    is one dict lookup per segment instead of an O(span) re-walk. Keys
    are (model_index, lo, hi, engine.name, allow_fallback); the provider
    is fixed per cache (a re-plan under refreshed OnlineCost scales
    builds a fresh cache, so stale timings can never leak into a plan).
    """

    def __init__(self, provider: CostProvider | None = None):
        self.provider = provider or ANALYTIC
        self._segments: dict[tuple, SegmentCost] = {}
        self._transfers: dict[tuple, float] = {}

    def segment(
        self, mi: int, graph: LayerGraph, lo: int, hi: int, engine, peer, allow_fallback,
        impl: str = "xla", batch: int = 1,
    ) -> SegmentCost:
        key = (mi, lo, hi, engine.name, allow_fallback, impl, batch)
        c = self._segments.get(key)
        if c is None:
            c = segment_cost(
                graph, lo, hi, engine, peer, allow_fallback,
                provider=self.provider, impl=impl, batch=batch,
            )
            self._segments[key] = c
        return c

    def transfer(self, mi: int, graph: LayerGraph, p: int, engine, batch: int = 1) -> float:
        key = (mi, p, engine.name, batch)
        x = self._transfers.get(key)
        if x is None:
            # whole bucket crosses once, amortized back per frame
            x = transfer_time(partition_boundary_bytes(graph, p) * batch, engine) / batch
            self._transfers[key] = x
        return x


def partition_boundary_bytes(graph: LayerGraph, p: int) -> float:
    """Bytes crossing a partition placed after layer p-1."""
    if p <= 0 or p >= len(graph):
        return 0.0
    return graph[p - 1].boundary_bytes


def balanced_partition_point(
    graph: LayerGraph, head_engine, tail_engine, candidates=None, provider: CostProvider | None = None
) -> int:
    """Partition point that best balances head time on ``head_engine``
    against tail time on ``tail_engine`` — the warm start for the N-model
    planner's local searches (and a decent heuristic on its own)."""
    if provider is None:
        provider = ANALYTIC
    cands = list(candidates) if candidates is not None else graph.cut_points()
    if not cands:
        raise ValueError(f"{graph.model_name}: no interior partition point")
    prefix = [0.0]
    for l in graph:
        prefix.append(prefix[-1] + provider.layer_time(l, head_engine))
    suffix = [0.0]
    for l in reversed(list(graph)):
        suffix.append(suffix[-1] + provider.layer_time(l, tail_engine))
    suffix.reverse()
    return min(cands, key=lambda p: abs(prefix[p] - suffix[p]))
