# The paper's primary contribution: engine-aware multi-model scheduling.
from .api import plan
from .graph import LayerGraph, LayerMeta, conv_meta, pointwise_meta
from .engine import (
    EngineSpec,
    jetson_orin_engines,
    tpu_submesh_engines,
    TPU_V5E_BF16_FLOPS,
    TPU_V5E_HBM_BW,
    TPU_V5E_ICI_BW,
)
from .constraints import (
    DLA_ANALOGUE_CONSTRAINTS,
    TPU_SMALL_CONSTRAINTS,
    DeconvPaddingZero,
    DtypeConstraint,
    KernelSizeRange,
    LaneAlignment,
    StaticShapesOnly,
    Violation,
    check_graph,
)
from .surgery import RULES, SurgeryReport, apply_surgery, substitute_pix2pix
from .cost_model import (
    ANALYTIC,
    AnalyticCost,
    BlendedCost,
    CostProvider,
    MeasuredCost,
    OnlineCost,
    SegmentCostCache,
    balanced_partition_point,
    graph_time,
    layer_time,
    make_cost_provider,
    segment_cost,
    transfer_time,
)
from .plan_ir import PlanIR, PlanSegment, ir_from_routes, make_plan_ir, translate_ir
from .scheduler import (
    HaxConnResult,
    ModelRoute,
    NModelPlan,
    RouteSpec,
    Schedule,
    haxconn_schedule,
    naive_schedule,
    nmodel_schedule,
    peer_utilization,
    standalone_schedule,
)
from .pipeline import StagedModel, TwoModelPipeline, pix2pix_staged, yolo_staged
