"""Per-layer dry-run profiler — the trtexec analogue.

Re-derives ``LayerMeta.flops`` / ``bytes_accessed`` from XLA's
``compiled.cost_analysis()`` by lowering each compute layer individually
on ShapeDtypeStructs (no allocation). The scheduler can then run against
*compiler-measured* costs instead of analytic estimates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import LayerGraph


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: newer
    releases return a one-element list of dicts, older ones the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


@functools.lru_cache(maxsize=512)
def _conv_cost(in_shape, kernel, stride, padding, c_out, transposed, dtype_str):
    dtype = jnp.dtype(dtype_str)
    x = jax.ShapeDtypeStruct(in_shape, dtype)
    w = jax.ShapeDtypeStruct((kernel, kernel, in_shape[-1], c_out), dtype)

    if transposed:

        def f(x, w):
            y = jax.lax.conv_transpose(
                x, w, strides=(stride, stride), padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
            if padding:
                y = y[:, padding:-padding, padding:-padding, :]
            return y

    else:

        def f(x, w):
            pad = [(padding, padding), (padding, padding)] if padding else "VALID"
            return jax.lax.conv_general_dilated(
                x, w, (stride, stride), pad, dimension_numbers=("NHWC", "HWIO", "NHWC")
            )

    compiled = jax.jit(f).lower(x, w).compile()
    ca = cost_analysis_dict(compiled)
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def profile_graph(graph: LayerGraph, dtype=jnp.bfloat16) -> LayerGraph:
    """Return a copy of ``graph`` with XLA-measured flops/bytes on conv and
    deconv layers (other kinds keep analytic estimates)."""
    out = []
    for l in graph:
        if l.kind in ("conv", "deconv"):
            flops, bytes_ = _conv_cost(
                tuple(l.in_shape),
                l.attrs.get("kernel", 1),
                l.attrs.get("stride", 1),
                l.attrs.get("padding", 0),
                l.out_shape[-1],
                l.kind == "deconv",
                jnp.dtype(dtype).name,
            )
            nl = l.clone(flops=flops or l.flops, bytes_accessed=bytes_ or l.bytes_accessed)
        else:
            nl = l.clone()
        out.append(nl)
    return LayerGraph(graph.model_name + "[profiled]", out).renumber()
