"""Per-layer dry-run profiler — the trtexec analogue.

Re-derives ``LayerMeta.flops`` / ``bytes_accessed`` from XLA's
``compiled.cost_analysis()`` by lowering each compute layer individually
on ShapeDtypeStructs (no allocation). The scheduler can then run against
*compiler-measured* costs instead of analytic estimates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .graph import LayerGraph


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: newer
    releases return a one-element list of dicts, older ones the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _elementwise_fn(kind: str):
    """Representative lowering per non-conv layer kind. All of these are
    memory-bound elementwise/shuffle ops, so one op per kind is enough for
    XLA's byte/flop accounting to replace the analytic estimate."""
    if kind in ("act",):
        return lambda x: jax.nn.leaky_relu(x, 0.2)
    if kind in ("tanh",):
        return jnp.tanh
    if kind in ("bn", "norm"):
        # inference-time normalization is a per-channel affine
        def bn(x):
            g = jnp.ones((x.shape[-1],), x.dtype)
            b = jnp.zeros((x.shape[-1],), x.dtype)
            return x * g + b

        return bn
    if kind == "concat":
        # the graph meta's shape is the concatenated result; lower the
        # concat of its two halves along the channel axis
        def cat(x):
            h = x.shape[-1] // 2
            return jnp.concatenate([x[..., :h], x[..., h or 1 :]], axis=-1)

        return cat
    if kind in ("crop", "pad"):
        def crop(x):
            if x.ndim >= 3 and x.shape[1] > 2 and x.shape[2] > 2:
                return x[:, 1:-1, 1:-1, ...]
            return x * jnp.asarray(1.0, x.dtype)

        return crop
    if kind == "pool":
        def pool(x):
            return jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 1, 1, 1), "SAME"
            )

        return pool
    if kind == "dropout":
        return lambda x: x * jnp.asarray(1.0, x.dtype)  # inference passthrough
    if kind == "add":
        return lambda x: x + x  # residual merge (two reads, one write)
    return None


ELEMENTWISE_KINDS = ("act", "tanh", "bn", "norm", "concat", "crop", "pad", "pool", "dropout", "add")


@functools.lru_cache(maxsize=2048)
def _elementwise_cost(kind, in_shape, dtype_str):
    """XLA-measured (flops, bytes) for one elementwise-ish layer. Returns
    transcendentals folded into flops (tanh etc. count there)."""
    fn = _elementwise_fn(kind)
    if fn is None:
        return 0.0, 0.0
    x = jax.ShapeDtypeStruct(tuple(in_shape), jnp.dtype(dtype_str))
    compiled = jax.jit(fn).lower(x).compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0)) + float(ca.get("transcendentals", 0.0))
    return flops, float(ca.get("bytes accessed", 0.0))


@functools.lru_cache(maxsize=512)
def _conv_cost(in_shape, kernel, stride, padding, c_out, transposed, dtype_str):
    dtype = jnp.dtype(dtype_str)
    x = jax.ShapeDtypeStruct(in_shape, dtype)
    w = jax.ShapeDtypeStruct((kernel, kernel, in_shape[-1], c_out), dtype)

    if transposed:

        def f(x, w):
            y = jax.lax.conv_transpose(
                x, w, strides=(stride, stride), padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
            if padding:
                y = y[:, padding:-padding, padding:-padding, :]
            return y

    else:

        def f(x, w):
            pad = [(padding, padding), (padding, padding)] if padding else "VALID"
            return jax.lax.conv_general_dilated(
                x, w, (stride, stride), pad, dimension_numbers=("NHWC", "HWIO", "NHWC")
            )

    compiled = jax.jit(f).lower(x, w).compile()
    ca = cost_analysis_dict(compiled)
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


@functools.lru_cache(maxsize=512)
def _fused_cost(in_shape, kernel, stride, padding, c_out, transposed, norm, act, dtype_str):
    """XLA-measured (flops, bytes) for one fused conv/deconv+norm+act
    block lowered as a SINGLE jit region: the compiler fuses the epilogue,
    so ``bytes accessed`` counts the block's input, output, and params
    once — the honest cost of the Pallas fused kernel, directly comparable
    against the sum of the per-layer ``_conv_cost``/``_elementwise_cost``
    lowerings the xla implementation pays (which round-trip every
    intermediate through HBM)."""
    dtype = jnp.dtype(dtype_str)
    x = jax.ShapeDtypeStruct(in_shape, dtype)
    w = jax.ShapeDtypeStruct((kernel, kernel, in_shape[-1], c_out), dtype)
    v = jax.ShapeDtypeStruct((c_out,), jnp.float32)

    def f(x, w, gamma, beta):
        if transposed:
            y = jax.lax.conv_transpose(
                x, w, strides=(stride, stride), padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            if padding:
                y = y[:, padding:-padding, padding:-padding, :]
        else:
            pad = [(padding, padding), (padding, padding)] if padding else "VALID"
            y = jax.lax.conv_general_dilated(
                x, w, (stride, stride), pad, dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
        y = y.astype(jnp.float32)
        if norm != "none":
            # inference-time normalization is a per-channel affine (same
            # stand-in _elementwise_cost uses for the unfused bn layer)
            y = y * gamma + beta
        if act == "relu":
            y = jax.nn.relu(y)
        elif act == "lrelu":
            y = jax.nn.leaky_relu(y, 0.2)
        elif act == "silu":
            y = jax.nn.silu(y)
        elif act == "tanh":
            y = jnp.tanh(y)
        return y.astype(dtype)

    compiled = jax.jit(f).lower(x, w, v, v).compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0)) + float(ca.get("transcendentals", 0.0))
    return flops, float(ca.get("bytes accessed", 0.0))


@functools.lru_cache(maxsize=128)
def _sppf_cost(in_shape, window, reps, dtype_str):
    """XLA-measured (flops, bytes) for the SPPF pool pyramid + concat
    lowered as a SINGLE jit region: ``reps`` cascaded stride-1 max pools
    whose intermediates feed both the next pool and the final concat.
    Fused, the input is read once and only the 4C concat is written —
    the honest cost of the Pallas ``sppf_pyramid`` kernel, comparable
    against the sum of the per-pool ``_elementwise_cost`` lowerings the
    xla implementation pays."""
    dtype = jnp.dtype(dtype_str)
    x = jax.ShapeDtypeStruct(tuple(in_shape), dtype)
    pad = window // 2

    def f(x):
        outs = [x]
        for _ in range(reps):
            outs.append(
                jax.lax.reduce_window(
                    outs[-1],
                    -jnp.inf,
                    jax.lax.max,
                    (1, window, window, 1),
                    (1, 1, 1, 1),
                    [(0, 0), (pad, pad), (pad, pad), (0, 0)],
                )
            )
        return jnp.concatenate(outs, axis=-1)

    compiled = jax.jit(f).lower(x).compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0)) + float(ca.get("transcendentals", 0.0))
    return flops, float(ca.get("bytes accessed", 0.0))


def _profile_layer(l, dtype_name: str):
    """Measured clone of one meta. Composites are profiled through their
    primitive decomposition and their totals become the measured sums, so
    profiling a coarse hierarchical graph and profiling its expansion
    agree layer-for-layer."""
    if l.sublayers:
        subs = [_profile_layer(p, dtype_name) for p in l.sublayers]
        return l.clone(
            sublayers=subs,
            flops=sum(p.flops for p in subs),
            bytes_accessed=sum(p.bytes_accessed for p in subs),
        )
    if l.kind in ("conv", "deconv"):
        flops, bytes_ = _conv_cost(
            tuple(l.in_shape),
            l.attrs.get("kernel", 1),
            l.attrs.get("stride", 1),
            l.attrs.get("padding", 0),
            l.out_shape[-1],
            l.kind == "deconv",
            dtype_name,
        )
        return l.clone(flops=flops or l.flops, bytes_accessed=bytes_ or l.bytes_accessed)
    if l.kind in ELEMENTWISE_KINDS:
        flops, bytes_ = _elementwise_cost(l.kind, tuple(l.in_shape), dtype_name)
        return l.clone(flops=flops or l.flops, bytes_accessed=bytes_ or l.bytes_accessed)
    return l.clone()


def profile_graph(graph: LayerGraph, dtype=jnp.bfloat16) -> LayerGraph:
    """Return a copy of ``graph`` with XLA-measured flops/bytes on conv,
    deconv, and elementwise (pointwise/norm/concat/...) layers; composite
    kinds (c2f, sppf, head, ...) are measured through their primitive
    decomposition (undecomposed composites keep analytic estimates).
    Works on coarse and expanded graphs alike."""
    name = jnp.dtype(dtype).name
    out = [_profile_layer(l, name) for l in graph]
    return LayerGraph(graph.model_name + "[profiled]", out).renumber()
