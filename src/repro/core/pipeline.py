"""Concurrent two-model pipeline executor (the paper's DeepStream analogue).

A ``StagedModel`` wraps per-layer executable ops aligned with the model's
``LayerGraph``. ``TwoModelPipeline`` executes a HaX-CoNN swap schedule in
steady state with double buffering:

  tick t:  E_con runs A[0:pa) of frame t      E_flex runs B[0:pb) of frame t
           E_con runs B[pb:)  of frame t-1    E_flex runs A[pa:)  of frame t-1

On real hardware the two engines are disjoint device sets and the four
segment calls are dispatched asynchronously (JAX's async dispatch overlaps
them); on this CPU container they serialize but remain functionally
identical, which is what the correctness tests pin down. ``place_fn``
hooks engine-boundary transfers (``jax.device_put`` to a submesh on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from .graph import LayerGraph
from .plan_ir import PlanIR


@dataclasses.dataclass
class StagedModel:
    name: str
    ops: list[tuple[str, Callable]]  # (name, fn(params, state) -> state)
    params: Any
    graph: LayerGraph
    init_state: Callable[[Any], dict]
    finalize: Callable[[dict], Any]
    # per-frame outputs independent of batch companions (instance/group
    # norm) — the precondition for merge_batches micro-batching
    batch_independent: bool = False
    # layer span [lo, hi) each op covers when the graph is finer than the
    # op list (expanded graphs: one op per *stage callable*, several
    # primitive layers per op). None = ops align 1:1 with graph layers.
    op_spans: list[tuple[int, int]] | None = None
    # named implementation variants: impl -> same-length op list (e.g.
    # "pallas_fused" with each fused block collapsed onto its lead op), and
    # the op-index groups [a, b) that must be substituted atomically — a
    # group only switches impl when a segment contains it entirely, so cut
    # points interior to a fused block keep the reference ops
    variant_ops: dict[str, list[tuple[str, Callable]]] | None = None
    variant_groups: list[tuple[int, int]] | None = None

    def __post_init__(self):
        for impl, vops in (self.variant_ops or {}).items():
            assert len(vops) == len(self.ops), (
                f"{self.name}: variant {impl!r} has {len(vops)} ops, expected {len(self.ops)}"
            )
        if self.op_spans is None:
            assert len(self.ops) == len(self.graph), (
                f"{self.name}: ops ({len(self.ops)}) must align with layer graph ({len(self.graph)})"
            )
        else:
            assert len(self.op_spans) == len(self.ops), (
                f"{self.name}: {len(self.op_spans)} op spans for {len(self.ops)} ops"
            )
            pos = 0
            for lo, hi in self.op_spans:
                assert lo == pos and hi > lo, f"{self.name}: op spans must partition the graph"
                pos = hi
            assert pos == len(self.graph), (
                f"{self.name}: op spans cover [0,{pos}) but the graph has {len(self.graph)} layers"
            )
            self._op_start = {lo: i for i, (lo, _) in enumerate(self.op_spans)}
            self._op_end = {hi: i + 1 for i, (_, hi) in enumerate(self.op_spans)}

    @property
    def n_layers(self) -> int:
        """Layer count of the planning graph — the unit PlanIR spans use."""
        return len(self.graph)

    def op_range(self, lo, hi) -> tuple[int, int]:
        """Map a layer span [lo, hi) to the op range that executes it.

        With ``op_spans`` the span must start and end on stage-callable
        boundaries — exactly the cuts ``LayerGraph.cut_points`` declares
        legal; anything else raises."""
        if self.op_spans is None:
            return lo, hi
        try:
            return self._op_start[lo], self._op_end[hi]
        except KeyError:
            raise ValueError(
                f"{self.name}: layer span [{lo},{hi}) does not align with stage boundaries"
            ) from None

    def run_segment(self, state, lo, hi, impl: str = "xla"):
        return self.segment_fn(lo, hi, impl)(self.params, state)

    def segment_ops(self, lo, hi, impl: str = "xla"):
        """The (name, fn) ops executing layers ``[lo, hi)`` under ``impl``.

        Variant substitution is per fused group and only where the group's
        op span [a, b) lies entirely inside the segment; everything else —
        including blocks split by the segment boundary — stays ``xla``."""
        olo, ohi = self.op_range(lo, hi)
        ops = list(self.ops[olo:ohi])
        vops = (self.variant_ops or {}).get(impl)
        if impl != "xla" and vops is not None:
            for a, b in self.variant_groups or []:
                if a >= olo and b <= ohi:
                    ops[a - olo : b - olo] = vops[a:b]
        return ops

    def segment_fn(self, lo, hi, impl: str = "xla"):
        """Pure ``(params, state) -> state`` over the ops executing layers
        ``[lo, hi)`` — the form ``jax.jit`` (with state-buffer donation)
        accepts."""
        ops = self.segment_ops(lo, hi, impl)

        def f(params, state):
            for _, fn in ops:
                state = fn(params, state)
            return state

        return f

    def jitted_segment_fn(self, lo, hi, donate: bool = False, impl: str = "xla"):
        """Fused one-executable form of ``segment_fn``, cached on the model
        so every executor over the same route shares the compilation."""
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        key = (lo, hi, donate, impl)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                self.segment_fn(lo, hi, impl), donate_argnums=(1,) if donate else ()
            )
        return self._jit_cache[key]

    def check_route(self, spans) -> None:
        """Validate that an arbitrary span list tiles [0, n_layers) on
        stage-executable boundaries — the staging precondition for a
        k-segment route. Raises ``ValueError`` with the offending span
        otherwise (gaps, overlaps, short coverage, or a cut inside a
        fused stage callable)."""
        pos = 0
        for lo, hi in spans:
            if lo != pos or hi <= lo:
                raise ValueError(
                    f"{self.name}: route spans must tile the graph contiguously; "
                    f"got [{lo},{hi}) at layer {pos}"
                )
            self.op_range(lo, hi)  # stage-boundary legality
            pos = hi
        if pos != self.n_layers:
            raise ValueError(
                f"{self.name}: route covers [0,{pos}) but the model has {self.n_layers} layers"
            )

    def run_route(self, x, spans):
        """Execute an arbitrary (validated) multi-segment route eagerly —
        the per-model reference the multi-cut equivalence tests pin
        against ``run_all``."""
        self.check_route(spans)
        state = self.init_state(x)
        for lo, hi in spans:
            state = self.run_segment(state, lo, hi)
        return self.finalize(state)

    def run_all(self, x):
        return self.finalize(self.run_segment(self.init_state(x), 0, self.n_layers))


def stage_ops_from_graph(
    graph: LayerGraph, impl: str = "xla"
) -> tuple[list[tuple[str, Callable]], list[tuple[int, int]]]:
    """Fine-grained (op, span) lists from a coarse graph whose metas carry
    ``attrs["stages"]`` callables — one executable op per stage, spanning
    that stage's primitive layers in the *expanded* graph. ``impl`` picks
    a registered stage-callable variant where one exists."""
    from ..models.yolov8 import node_stages

    ops, spans, pos = [], [], 0
    for l in graph:
        if not l.attrs.get("stages"):
            raise ValueError(f"{l.name}: no stage callables; cannot stage at fine granularity")
        for sname, nprims, fn in node_stages(l, impl):
            ops.append((sname, fn))
            spans.append((pos, pos + nprims))
            pos += nprims
    return ops, spans


def fuse_groups_of(graph: LayerGraph) -> list[tuple[int, int]]:
    """Layer-index spans of the graph's marked fused blocks
    (``attrs["fuse"]`` on the lead layer — see the model layer_graphs)."""
    return [
        (i, i + l.attrs["fuse"]["span"]) for i, l in enumerate(graph) if "fuse" in l.attrs
    ]


def pix2pix_staged(cfg, params, batch_dtype=None, granularity: str = "coarse") -> StagedModel:
    from ..models.pix2pix import Pix2PixGenerator, generator_ops

    gen = Pix2PixGenerator(cfg)
    graph = gen.layer_graph()
    groups = fuse_groups_of(graph)  # ops align 1:1 with (primitive) layers
    if granularity == "fine":
        # the pix graph is already primitive-only; the expanded view keeps
        # the coarse index map so plans annotate coarse spans uniformly
        graph = graph.expand()
    return StagedModel(
        name=f"pix2pix[{cfg.deconv_mode}]",
        ops=generator_ops(cfg),
        params=params["generator"] if "generator" in params else params,
        graph=graph,
        init_state=lambda x: {"x": x.astype(cfg.act_dtype), "skips": []},
        finalize=lambda s: s["x"],
        batch_independent=cfg.batch_independent,
        variant_ops={"pallas_fused": generator_ops(cfg, impl="pallas_fused")},
        variant_groups=groups,
    )


def yolo_staged(cfg, params, granularity: str = "coarse") -> StagedModel:
    """YOLO staged model at ``coarse`` (one op per composite node) or
    ``fine`` granularity (expanded primitive graph, one op per sub-block
    stage callable — cuts inside ``c2f``/``sppf``/``head`` become
    executable)."""
    from ..models.yolov8 import YOLOv8

    if granularity not in ("coarse", "fine"):
        raise ValueError(f"granularity must be 'coarse' or 'fine', got {granularity!r}")
    m = YOLOv8(cfg)
    coarse = m.layer_graph()
    if granularity == "fine":
        ops, spans = stage_ops_from_graph(coarse)
        vops, _ = stage_ops_from_graph(coarse, impl="pallas_fused")
        graph, op_spans = coarse.expand(), spans
        # fused blocks whose variant spans multiple stage ops (the SPPF
        # pool pyramid: three pool stages -> one kernel) must switch impl
        # atomically; every other op switches individually, as before —
        # ConvBlock fuse groups live inside a single stage callable
        multi = []
        for glo, ghi in fuse_groups_of(graph):
            a = max(i for i, (lo, _hi) in enumerate(spans) if lo <= glo)
            b = min(i + 1 for i, (_lo, hi) in enumerate(spans) if hi >= ghi)
            if b - a > 1:
                multi.append((a, b))
        covered = {i for a, b in multi for i in range(a, b)}
        groups = sorted(multi + [(i, i + 1) for i in range(len(ops)) if i not in covered])
    else:
        ops, graph, op_spans = m.staged_ops(coarse), coarse, None
        vops = m.staged_ops(coarse, impl="pallas_fused")
        # every op is stage-atomic (a coarse node's fused blocks live
        # wholly inside its one stage callable), so groups are single ops
        groups = [(i, i + 1) for i in range(len(ops))]
    return StagedModel(
        name=cfg.name,
        ops=ops,
        params=params,
        graph=graph,
        init_state=lambda x: {"x": x.astype(cfg.act_dtype)},
        finalize=lambda s: {"p3": s["o3"], "p4": s["o4"], "p5": s["o5"]},
        op_spans=op_spans,
        variant_ops={"pallas_fused": vops},
        variant_groups=groups,
    )


@dataclasses.dataclass
class TickLog:
    tick: int
    engine: str
    work: str


class TwoModelPipeline:
    """Steady-state double-buffered execution of a HaX-CoNN schedule.

    Thin wrapper over the generic ``serve.StreamExecutor``: the two-model
    swap schedule is expressed as two counter-phased routes (A: con then
    flex, B: flex then con) with one stream per model, which the executor
    runs tick-for-tick as the original phase-1/phase-2 loop did.
    """

    def __init__(
        self,
        model_a: StagedModel,
        model_b: StagedModel,
        plan,
        place_con: Callable | None = None,
        place_flex: Callable | None = None,
    ):
        self.a, self.b = model_a, model_b
        # accept the unified entry point's PlanIR or a legacy HaxConnResult
        ir = plan if isinstance(plan, PlanIR) else plan.ir
        self.pa, self.pb = ir.partitions
        self.plan = ir
        self.place_con = place_con or (lambda x: x)
        self.place_flex = place_flex or (lambda x: x)
        self.log: list[TickLog] = []

    def run_stream(self, frames_a, frames_b):
        """frames_*: lists of model inputs (equal length). Returns
        (outputs_a, outputs_b) in input order + populates ``self.log``."""
        from ..serve.executor import StreamExecutor  # lazy: serve imports this module
        from ..serve.streams import StreamSpec
        from .plan_ir import make_plan_ir

        assert len(frames_a) == len(frames_b)
        la, lb = self.a.n_layers, self.b.n_layers
        # the scheduler's typed IR drives the executor; rebuild it from the
        # (possibly caller-overridden) partition points
        ir = self.plan
        if ir is None or ir.partitions != [self.pa, self.pb]:
            ir = make_plan_ir(
                (self.a.name, self.b.name),
                ("con", "flex"),
                [[(0, 0, self.pa), (1, self.pa, la)], [(1, 0, self.pb), (0, self.pb, lb)]],
                kind="haxconn",
            )
        ex = StreamExecutor(
            [self.a, self.b],
            ir,
            [StreamSpec("A", 0), StreamSpec("B", 1)],
            max_queue=max(1, len(frames_a)),
            place_fns=[self.place_con, self.place_flex],
            engine_names=["con", "flex"],
            model_labels=["A", "B"],
            # the two-model pipeline is the paper-faithful correctness
            # harness: keep the eager op sequence (bit-exact vs run_all)
            jit_segments=False,
        )
        for fa, fb in zip(frames_a, frames_b):
            ok = ex.submit(0, fa) and ex.submit(1, fb)
            if not ok:
                raise RuntimeError("pipeline frame queue refused a frame (depth mis-sized)")
        outs = ex.run_until_drained()
        self.log = ex.log
        return outs["A"], outs["B"]


def submesh_placers(mesh_devices, n_con: int):
    """Split a flat device list into (constrained, flexible) placement fns."""
    con, flex = list(mesh_devices[:n_con]), list(mesh_devices[n_con:])

    def place(devs):
        def f(state):
            return jax.tree.map(lambda x: jax.device_put(x, devs[0]), state)

        return f

    return place(con or flex), place(flex or con)
