"""Concurrent two-model pipeline executor (the paper's DeepStream analogue).

A ``StagedModel`` wraps per-layer executable ops aligned with the model's
``LayerGraph``. ``TwoModelPipeline`` executes a HaX-CoNN swap schedule in
steady state with double buffering:

  tick t:  E_con runs A[0:pa) of frame t      E_flex runs B[0:pb) of frame t
           E_con runs B[pb:)  of frame t-1    E_flex runs A[pa:)  of frame t-1

On real hardware the two engines are disjoint device sets and the four
segment calls are dispatched asynchronously (JAX's async dispatch overlaps
them); on this CPU container they serialize but remain functionally
identical, which is what the correctness tests pin down. ``place_fn``
hooks engine-boundary transfers (``jax.device_put`` to a submesh on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from .graph import LayerGraph
from .scheduler import HaxConnResult


@dataclasses.dataclass
class StagedModel:
    name: str
    ops: list[tuple[str, Callable]]  # (name, fn(params, state) -> state)
    params: Any
    graph: LayerGraph
    init_state: Callable[[Any], dict]
    finalize: Callable[[dict], Any]
    # per-frame outputs independent of batch companions (instance/group
    # norm) — the precondition for merge_batches micro-batching
    batch_independent: bool = False

    def __post_init__(self):
        assert len(self.ops) == len(self.graph), (
            f"{self.name}: ops ({len(self.ops)}) must align with layer graph ({len(self.graph)})"
        )

    def run_segment(self, state, lo, hi):
        return self.segment_fn(lo, hi)(self.params, state)

    def segment_fn(self, lo, hi):
        """Pure ``(params, state) -> state`` over ``ops[lo:hi)`` — the form
        ``jax.jit`` (with state-buffer donation) accepts."""

        def f(params, state):
            for _, fn in self.ops[lo:hi]:
                state = fn(params, state)
            return state

        return f

    def jitted_segment_fn(self, lo, hi, donate: bool = False):
        """Fused one-executable form of ``segment_fn``, cached on the model
        so every executor over the same route shares the compilation."""
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        key = (lo, hi, donate)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                self.segment_fn(lo, hi), donate_argnums=(1,) if donate else ()
            )
        return self._jit_cache[key]

    def run_all(self, x):
        return self.finalize(self.run_segment(self.init_state(x), 0, len(self.ops)))


def pix2pix_staged(cfg, params, batch_dtype=None) -> StagedModel:
    from ..models.pix2pix import Pix2PixGenerator, generator_ops

    gen = Pix2PixGenerator(cfg)
    return StagedModel(
        name=f"pix2pix[{cfg.deconv_mode}]",
        ops=generator_ops(cfg),
        params=params["generator"] if "generator" in params else params,
        graph=gen.layer_graph(),
        init_state=lambda x: {"x": x.astype(cfg.act_dtype), "skips": []},
        finalize=lambda s: s["x"],
        batch_independent=cfg.batch_independent,
    )


def yolo_staged(cfg, params) -> StagedModel:
    from ..models.yolov8 import YOLOv8

    m = YOLOv8(cfg)
    return StagedModel(
        name=cfg.name,
        ops=m.staged_ops(),
        params=params,
        graph=m.layer_graph(),
        init_state=lambda x: {"x": x.astype(cfg.act_dtype)},
        finalize=lambda s: {"p3": s["o3"], "p4": s["o4"], "p5": s["o5"]},
    )


@dataclasses.dataclass
class TickLog:
    tick: int
    engine: str
    work: str


class TwoModelPipeline:
    """Steady-state double-buffered execution of a HaX-CoNN schedule.

    Thin wrapper over the generic ``serve.StreamExecutor``: the two-model
    swap schedule is expressed as two counter-phased routes (A: con then
    flex, B: flex then con) with one stream per model, which the executor
    runs tick-for-tick as the original phase-1/phase-2 loop did.
    """

    def __init__(
        self,
        model_a: StagedModel,
        model_b: StagedModel,
        plan: HaxConnResult,
        place_con: Callable | None = None,
        place_flex: Callable | None = None,
    ):
        self.a, self.b = model_a, model_b
        self.pa, self.pb = plan.p_a, plan.p_b
        self.plan = plan
        self.place_con = place_con or (lambda x: x)
        self.place_flex = place_flex or (lambda x: x)
        self.log: list[TickLog] = []

    def run_stream(self, frames_a, frames_b):
        """frames_*: lists of model inputs (equal length). Returns
        (outputs_a, outputs_b) in input order + populates ``self.log``."""
        from ..serve.executor import StreamExecutor  # lazy: serve imports this module
        from ..serve.streams import StreamSpec
        from .plan_ir import make_plan_ir

        assert len(frames_a) == len(frames_b)
        la, lb = len(self.a.ops), len(self.b.ops)
        # the scheduler's typed IR drives the executor; rebuild it from the
        # (possibly caller-overridden) partition points
        ir = self.plan.ir
        if ir is None or ir.partitions != [self.pa, self.pb]:
            ir = make_plan_ir(
                (self.a.name, self.b.name),
                ("con", "flex"),
                [[(0, 0, self.pa), (1, self.pa, la)], [(1, 0, self.pb), (0, self.pb, lb)]],
                kind="haxconn",
            )
        ex = StreamExecutor(
            [self.a, self.b],
            ir,
            [StreamSpec("A", 0), StreamSpec("B", 1)],
            max_queue=max(1, len(frames_a)),
            place_fns=[self.place_con, self.place_flex],
            engine_names=["con", "flex"],
            model_labels=["A", "B"],
            # the two-model pipeline is the paper-faithful correctness
            # harness: keep the eager op sequence (bit-exact vs run_all)
            jit_segments=False,
        )
        for fa, fb in zip(frames_a, frames_b):
            ok = ex.submit(0, fa) and ex.submit(1, fb)
            if not ok:
                raise RuntimeError("pipeline frame queue refused a frame (depth mis-sized)")
        outs = ex.run_until_drained()
        self.log = ex.log
        return outs["A"], outs["B"]


def submesh_placers(mesh_devices, n_con: int):
    """Split a flat device list into (constrained, flexible) placement fns."""
    con, flex = list(mesh_devices[:n_con]), list(mesh_devices[n_con:])

    def place(devs):
        def f(state):
            return jax.tree.map(lambda x: jax.device_put(x, devs[0]), state)

        return f

    return place(con or flex), place(flex or con)
