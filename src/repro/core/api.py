"""The unified planning entry point: ``repro.core.plan``.

Five PRs of organic growth left four scheduler entry points
(``nmodel_schedule``, ``haxconn_schedule``, ``standalone_schedule``,
``naive_schedule``), each returning a different result type, while the
serve stack consumes exactly one contract — the typed ``PlanIR``.
``plan()`` collapses them: one call signature, one return type, with the
legacy searches kept verbatim underneath so outputs are bit-identical to
the old entry points on the same inputs.

``kind`` selects the scheduling mode (``"nmodel"`` is the general
multi-stream planner and the default; ``"haxconn"``/``"standalone"``/
``"naive"`` are the paper's two-model comparison schedules).
``granularity="fine"`` expands coarse graphs to their primitive
decompositions before planning — cuts inside composite blocks become
legal at stage-callable boundaries. ``max_cuts="auto"`` raises the
per-model cut budget until the planned cycle stops improving (the
carry-over planner polish): budget k is structurally never worse than
k-1, so the loop stops at the first budget that buys nothing.
"""
from __future__ import annotations

from .cost_model import CostProvider, make_cost_provider
from .graph import ExpandedGraph, LayerGraph
from .plan_ir import PlanIR

# Budget ceiling for max_cuts="auto": each extra cut multiplies the
# candidate space, and past a handful of ping-pong boundaries the
# transfer cost dominates any balance gain on every graph we plan.
AUTO_CUTS_CEILING = 4
# Relative cycle improvement a bigger budget must buy to keep escalating.
AUTO_CUTS_RTOL = 1e-6

_KINDS = ("nmodel", "haxconn", "standalone", "naive")


def _as_graph(g) -> LayerGraph:
    """Accept a ``LayerGraph`` or anything carrying one (``StagedModel``)."""
    if isinstance(g, LayerGraph):
        return g
    inner = getattr(g, "graph", None)
    if isinstance(inner, LayerGraph):
        return inner
    raise TypeError(f"expected a LayerGraph or StagedModel, got {type(g).__name__}")


def plan(
    graphs,
    engines,
    *,
    kind: str = "nmodel",
    search: str = "auto",
    granularity: str = "coarse",
    max_cuts: int | str = 1,
    cost: str | CostProvider | None = None,
    allow_fallback: bool = True,
    stride: int = 1,
    fixed=None,
    beam_width: int = 64,
    route_limit: int = 512,
    exhaustive_limit: int = 20000,
    descent_rounds: int = 8,
    impl: str = "xla",
    batch: int = 1,
) -> PlanIR:
    """Plan ``graphs`` over ``engines``; returns the typed ``PlanIR``.

    ``graphs`` is a sequence of ``LayerGraph``s (or ``StagedModel``s — the
    graph is taken); a single graph may be passed bare for
    ``kind="standalone"``. ``engines`` follows the legacy conventions:
    constrained engines first (``nmodel``'s fallback flows to the least
    constrained one; ``haxconn``/``naive`` read ``(constrained,
    flexible)``; ``standalone`` reads ``(engine, peer)``).

    ``cost`` is a ``CostProvider`` or a ``make_cost_provider`` name
    (``analytic``/``measured``/``blended``); ``fixed`` pins routes instead
    of searching (the ``nmodel_schedule`` forms: ints, ``(cuts,
    engines)`` tuples, ``RouteSpec``s, or ``None`` holes; an ``(pa, pb)``
    pair for ``haxconn``). ``max_cuts="auto"`` searches budgets
    1..``AUTO_CUTS_CEILING`` and keeps the first whose successor no
    longer improves the planned cycle (``PlanIR.cut_budget`` records the
    chosen budget). Outputs are bit-identical to the legacy entry points
    at the same settings — ``plan(...)`` is ``<legacy>(...).ir``.

    ``impl`` selects the implementation-planning mode (``nmodel`` only):
    ``"xla"`` forces the per-op lowering everywhere (the default, and the
    historical behaviour), ``"pallas"`` forces the fused serving kernels,
    ``"auto"`` lets the route search pick the argmin implementation per
    segment (recorded on each ``PlanSegment.impl``).

    ``batch`` scores every route at that effective admission batch
    (``nmodel`` only): per-frame amortized layer and transfer costs, the
    knob the serving re-planner turns when the coalescer's observed
    bucket shifts. ``batch=1`` is bit-identical to the historical plans.
    """
    from . import scheduler as _sched

    if kind not in _KINDS:
        raise ValueError(f"unknown plan kind {kind!r}; expected one of {_KINDS}")
    if granularity not in ("coarse", "fine"):
        raise ValueError(f"granularity must be 'coarse' or 'fine', got {granularity!r}")
    if impl not in ("xla", "auto", "pallas"):
        raise ValueError(f"unknown impl mode {impl!r} (expected xla | auto | pallas)")
    if impl != "xla" and kind != "nmodel":
        raise ValueError(f"impl={impl!r} needs kind='nmodel' (got kind={kind!r})")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if batch > 1 and kind != "nmodel":
        raise ValueError(f"batch={batch} needs kind='nmodel' (got kind={kind!r})")
    if isinstance(graphs, (LayerGraph,)) or hasattr(graphs, "graph"):
        graphs = [graphs]
    gs = [_as_graph(g) for g in graphs]
    if granularity == "fine":
        gs = [g if isinstance(g, ExpandedGraph) else g.expand() for g in gs]
    provider = None
    if cost is not None:
        provider = cost if isinstance(cost, CostProvider) else make_cost_provider(cost)
    engines = list(engines)

    if kind == "standalone":
        if len(gs) != 1:
            raise ValueError(f"kind='standalone' plans one graph, got {len(gs)}")
        if len(engines) != 2:
            raise ValueError("kind='standalone' needs (engine, peer)")
        return _sched._standalone_schedule_impl(
            gs[0], engines[0], engines[1], allow_fallback=allow_fallback, provider=provider
        ).ir
    if kind == "naive":
        if len(gs) != 2 or len(engines) != 2:
            raise ValueError("kind='naive' plans two graphs over (constrained, flexible)")
        return _sched._naive_schedule_impl(
            gs[0], gs[1], engines[0], engines[1], provider=provider
        ).ir
    if kind == "haxconn":
        if len(gs) != 2 or len(engines) != 2:
            raise ValueError("kind='haxconn' plans two graphs over (constrained, flexible)")
        return _sched._haxconn_schedule_impl(
            gs[0],
            gs[1],
            engines[0],
            engines[1],
            allow_fallback=allow_fallback,
            stride=stride,
            fixed=fixed,
            provider=provider,
        ).ir

    def _nmodel(budget: int) -> PlanIR:
        return _sched._nmodel_schedule_impl(
            gs,
            engines,
            allow_fallback=allow_fallback,
            stride=stride,
            fixed=fixed,
            exhaustive_limit=exhaustive_limit,
            descent_rounds=descent_rounds,
            provider=provider,
            search=search,
            beam_width=beam_width,
            max_cuts=budget,
            route_limit=route_limit,
            impl=impl,
            batch=batch,
        ).ir

    if max_cuts == "auto":
        # Escalate the cut budget until the planned cycle stops improving.
        # Budget k+1 is structurally never worse than k (the k-budget
        # optimum is polished inside the larger space), so the first
        # budget whose successor buys nothing is the stopping point.
        best = _nmodel(1)
        for k in range(2, AUTO_CUTS_CEILING + 1):
            cand = _nmodel(k)
            if cand.expected_cycle < best.expected_cycle * (1.0 - AUTO_CUTS_RTOL):
                best = cand
            else:
                break
        return best
    if not isinstance(max_cuts, int):
        raise ValueError(f"max_cuts must be an int or 'auto', got {max_cuts!r}")
    return _nmodel(max_cuts)
