"""Per-engine capability constraints.

Mirrors the DLA restrictions the paper works around (§III.A.2, [26]):
  * only FP16/INT8 dtypes                  -> DtypeConstraint
  * deconvolution padding must be zero     -> DeconvPaddingZero
  * kernel sizes must be in [1, 32]        -> KernelSizeRange
  * no dynamic tensor shapes ([9]-[11])    -> StaticShapesOnly
plus TPU-flavoured rules used by the submesh engines:
  * channel counts should align to the 128-lane MXU -> LaneAlignment
    (severity "inefficient": legal but costed with an efficiency penalty)

A violated "illegal" constraint forces *fallback*: the layer must execute
on the peer engine, splitting the segment and paying two transfers — the
exact Jetson semantics the paper eliminates via surgery.
"""
from __future__ import annotations

import dataclasses

from .graph import LayerMeta

COMPUTE_KINDS = ("conv", "deconv", "matmul", "attn", "moe", "ssd", "c2f", "head", "sppf")


@dataclasses.dataclass(frozen=True)
class Violation:
    layer: str
    constraint: str
    reason: str
    severity: str = "illegal"  # "illegal" | "inefficient"


@dataclasses.dataclass(frozen=True)
class DtypeConstraint:
    allowed: tuple[str, ...] = ("bf16", "int8")

    def check(self, l: LayerMeta):
        dt = l.attrs.get("dtype", "bf16")
        if dt not in self.allowed:
            return Violation(l.name, "dtype", f"dtype {dt} not in {self.allowed}")
        return None


@dataclasses.dataclass(frozen=True)
class DeconvPaddingZero:
    def check(self, l: LayerMeta):
        if l.kind == "deconv" and l.attrs.get("padding", 0) != 0:
            return Violation(
                l.name, "deconv_padding", "deconvolution padding must be zero on this engine"
            )
        return None


@dataclasses.dataclass(frozen=True)
class KernelSizeRange:
    lo: int = 1
    hi: int = 32

    def check(self, l: LayerMeta):
        if l.kind in ("conv", "deconv"):
            k = l.attrs.get("kernel", 1)
            if not (self.lo <= k <= self.hi):
                return Violation(l.name, "kernel_size", f"kernel {k} outside [{self.lo},{self.hi}]")
        return None


@dataclasses.dataclass(frozen=True)
class StaticShapesOnly:
    def check(self, l: LayerMeta):
        if l.attrs.get("dynamic_shape", False):
            return Violation(l.name, "dynamic_shape", "dynamic tensor shapes unsupported")
        return None


@dataclasses.dataclass(frozen=True)
class GroupedDeconvUnsupported:
    def check(self, l: LayerMeta):
        if l.kind == "deconv" and l.attrs.get("groups", 1) != 1:
            return Violation(l.name, "grouped_deconv", "grouped deconvolution unsupported")
        return None


@dataclasses.dataclass(frozen=True)
class LaneAlignment:
    """TPU MXU lane alignment: channel dims should be multiples of ``lanes``."""

    lanes: int = 128

    def check(self, l: LayerMeta):
        if l.kind in COMPUTE_KINDS and len(l.out_shape) >= 1:
            c = l.out_shape[-1]
            if c >= self.lanes and c % self.lanes:
                return Violation(
                    l.name,
                    "lane_alignment",
                    f"channels {c} not a multiple of {self.lanes} lanes",
                    severity="inefficient",
                )
        return None


DLA_ANALOGUE_CONSTRAINTS = (
    DtypeConstraint(),
    DeconvPaddingZero(),
    KernelSizeRange(1, 32),
    StaticShapesOnly(),
    GroupedDeconvUnsupported(),
)

TPU_SMALL_CONSTRAINTS = DLA_ANALOGUE_CONSTRAINTS + (LaneAlignment(128),)


def check_graph(graph, engine):
    """Per-layer violations for a graph on an engine.

    Returns {layer_idx: [Violation, ...]} containing only layers with
    >=1 "illegal" violation (inefficiencies are reported separately).
    """
    illegal, inefficient = {}, {}
    for l in graph:
        vs = engine.supports(l)
        ill = [v for v in vs if v.severity == "illegal"]
        ine = [v for v in vs if v.severity == "inefficient"]
        if ill:
            illegal[l.idx] = ill
        if ine:
            inefficient[l.idx] = ine
    return illegal, inefficient
