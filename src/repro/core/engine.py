"""Compute-engine abstraction.

The paper schedules across the Jetson's GPU and DLA. On a TPU pod the
same role is played by *disjoint submeshes* with different sizes and (to
model the DLA's restricted op set) different capability constraints. The
cost model and the HaX-CoNN scheduler consume only this abstraction, so
the identical machinery drives:

  * the faithful Jetson reproduction (calibrated GPU/DLA engine specs),
  * TPU submesh co-serving (two models sharing one pod),
  * and prefill/decode-style disaggregation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

# ---- hardware constants -------------------------------------------------------
# TPU v5e (target hardware for the framework):
TPU_V5E_BF16_FLOPS = 197e12  # per chip
TPU_V5E_HBM_BW = 819e9  # bytes/s per chip
TPU_V5E_ICI_BW = 50e9  # bytes/s per link (~4 links/chip on a 2D torus)

# Jetson AGX Orin engine efficiencies, calibrated so that the cost model
# lands on the paper's measured standalone throughputs (Table IV context:
# Pix2Pix G is ~12.1 GFLOP/frame at 256x256; GPU ~172 FPS, balanced DLA
# ~148 FPS). These are *effective* (achieved) rates, not peaks.
JETSON_ORIN_GPU_FLOPS = 2.1e12
JETSON_ORIN_GPU_BW = 204.8e9
JETSON_ORIN_DLA_FLOPS = 1.85e12
JETSON_ORIN_DLA_BW = 102.4e9
JETSON_XFER_BW = 32e9  # engine<->engine via shared DRAM


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    name: str
    n_chips: int
    peak_flops: float  # total achievable FLOP/s for the engine
    hbm_bw: float  # total bytes/s
    link_bw: float  # bytes/s to the peer engine
    constraints: tuple[Any, ...] = ()
    efficiency: float = 1.0  # multiplier on peak_flops (achievable utilization)
    # Optional concrete ``jax.Device`` this spec executes on. Excluded from
    # eq/hash: binding is a *placement* decision, so a bound slice plans
    # identically to the abstract specs it was derived from.
    device: Any = dataclasses.field(default=None, compare=False)

    @property
    def flops(self):
        return self.peak_flops * self.efficiency

    def bound(self, device) -> "EngineSpec":
        """This spec bound to a concrete ``jax.Device`` placement target."""
        return dataclasses.replace(self, device=device)

    def supports(self, layer) -> list:
        """Return the list of violated constraints for a layer (empty = legal).

        Composite metas (hierarchical graphs) are checked through their
        primitive decomposition too: a ``c2f`` block containing one
        illegal primitive is illegal as a whole at coarse granularity —
        the planner must expand it to route around the primitive.

        The result is memoized per (layer object, engine): the multi-cut
        planner calls this on every layer of every candidate span, and
        walking a composite's decomposition each time dominated planning
        profiles. Keying on the object identity is sound because graph
        rewrites (surgery, expansion) ``clone()`` metas rather than
        mutating them in place; the cached entry pins the layer so a
        recycled ``id`` can never alias a dead one. Callers must treat
        the returned list as read-only."""
        cache = self.__dict__.get("_supports_cache")
        if cache is None:
            cache = {}
            # frozen dataclass: the cache is identity-keyed scratch state,
            # not part of the spec's value (hash/eq are unaffected)
            object.__setattr__(self, "_supports_cache", cache)
        hit = cache.get(id(layer))
        if hit is not None and hit[0] is layer:
            return hit[1]
        out = []
        for c in self.constraints:
            v = c.check(layer)
            if v is not None:
                out.append(v)
        for sub in getattr(layer, "sublayers", None) or ():
            out.extend(self.supports(sub))
        cache[id(layer)] = (layer, out)
        return out


def jetson_orin_engines(constraints_dla=(), constraints_gpu=()):
    gpu = EngineSpec(
        "GPU", 1, JETSON_ORIN_GPU_FLOPS, JETSON_ORIN_GPU_BW, JETSON_XFER_BW, tuple(constraints_gpu)
    )
    dla = EngineSpec(
        "DLA", 1, JETSON_ORIN_DLA_FLOPS, JETSON_ORIN_DLA_BW, JETSON_XFER_BW, tuple(constraints_dla)
    )
    return gpu, dla


def tpu_submesh_engines(
    n_big: int = 192,
    n_small: int = 64,
    constraints_small=(),
    efficiency: float = 0.6,
):
    """Split one 256-chip pod into a flexible 'GPU-analogue' submesh and a
    constrained 'DLA-analogue' submesh for concurrent multi-model serving."""
    big = EngineSpec(
        "TPU-BIG",
        n_big,
        n_big * TPU_V5E_BF16_FLOPS,
        n_big * TPU_V5E_HBM_BW,
        TPU_V5E_ICI_BW * min(n_big, n_small),
        (),
        efficiency,
    )
    small = EngineSpec(
        "TPU-SMALL",
        n_small,
        n_small * TPU_V5E_BF16_FLOPS,
        n_small * TPU_V5E_HBM_BW,
        TPU_V5E_ICI_BW * min(n_big, n_small),
        tuple(constraints_small),
        efficiency,
    )
    return big, small


class DevicePool:
    """Discovered ``jax.Device``s sliced into per-replica engine groups.

    The fleet (``repro.serve.fleet``) replicates the planned pipeline R
    times; each replica gets a slice of the pool and an engine tuple
    bound to that slice. On multi-device hosts the slices are disjoint
    (``D // R`` devices each, round-robin reuse once R exceeds D); on
    1-device hosts — CPU CI — every replica binds the virtual 2-engine
    GPU/DLA pair to the single device, so the whole fleet still runs.
    Placement is exposed as per-engine ``place_fns`` (``jax.device_put``
    closures) in the shape ``StreamExecutor`` consumes; on a 1-device
    pool they collapse to identity so the hot path pays nothing.
    """

    def __init__(self, engines, devices=None):
        if devices is None:
            import jax

            devices = list(jax.devices())
        if not devices:
            raise ValueError("DevicePool needs at least one device")
        self.devices = list(devices)
        self.engines = tuple(engines)
        if not self.engines:
            raise ValueError("DevicePool needs at least one engine spec")

    @classmethod
    def discover(cls, engines=None, constraints_dla=(), constraints_gpu=()):
        """Pool over ``jax.devices()``; defaults to the Jetson-analogue
        (DLA, GPU) virtual pair in planning order when no specs are given."""
        if engines is None:
            gpu, dla = jetson_orin_engines(constraints_dla, constraints_gpu)
            engines = (dla, gpu)
        return cls(engines)

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def replica_devices(self, replica: int, n_replicas: int) -> list:
        """The device slice backing one replica (wraps when R > D)."""
        if replica < 0 or replica >= n_replicas:
            raise ValueError(f"replica {replica} out of range for {n_replicas}")
        per = max(1, len(self.devices) // max(1, n_replicas))
        return [self.devices[(replica * per + j) % len(self.devices)] for j in range(per)]

    def worker_pool(self, worker: int, n_workers: int) -> "DevicePool":
        """A sub-pool over one worker *process*'s device slice.

        The multi-process fleet (``repro.serve.multiproc``) spawns R
        workers; each builds its replica group over the devices visible
        to *its* process. Slicing reuses the replica round-robin (wraps
        when R exceeds D), so a worker's pool is just this pool narrowed
        to its share — on 1-device hosts every worker sees the single
        device and placement stays identity."""
        return DevicePool(self.engines, devices=self.replica_devices(worker, n_workers))

    def engine_slice(self, replica: int, n_replicas: int) -> tuple[EngineSpec, ...]:
        """The pool's engine specs bound to this replica's devices."""
        devs = self.replica_devices(replica, n_replicas)
        return tuple(e.bound(devs[i % len(devs)]) for i, e in enumerate(self.engines))

    def place_fns(self, replica: int, n_replicas: int) -> list:
        """Per-engine state-placement closures for ``StreamExecutor``."""
        if len(self.devices) == 1:
            # single-device host: device_put would be a no-op round trip
            return [lambda state: state for _ in self.engines]
        import jax

        fns = []
        for e in self.engine_slice(replica, n_replicas):
            dev = e.device
            fns.append(
                lambda state, dev=dev: jax.tree.map(lambda x: jax.device_put(x, dev), state)
            )
        return fns
