"""Hardware-aware model surgery (§V.A.2 of the paper).

Rewrites engine-illegal layers into engine-legal equivalents at the
layer-graph level, and exposes the corresponding model-config rewrite for
Pix2Pix. The two paper-endorsed substitutions preserve or improve
accuracy (Table II); the four rejected alternatives are kept for the
ablation benchmark (the paper reports they "negatively impact accuracy").
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .constraints import Violation
from .graph import LayerGraph, LayerMeta, conv_meta, pointwise_meta


@dataclasses.dataclass(frozen=True)
class SurgeryRule:
    name: str
    quality: str  # "endorsed" | "rejected" (paper's verdict)
    matches: Callable[[LayerMeta, Violation], bool]
    apply: Callable[[LayerMeta], list[LayerMeta]]


def _match_deconv_padding(l: LayerMeta, v: Violation) -> bool:
    return l.kind == "deconv" and v.constraint == "deconv_padding"


def _deconv_nopad(l: LayerMeta) -> LayerMeta:
    """The same deconv with padding=0; output grows by 2*padding each dim."""
    B, h, w, c_in = l.in_shape
    c_out = l.out_shape[-1]
    k, s, p = l.attrs["kernel"], l.attrs["stride"], l.attrs["padding"]
    return conv_meta(l.idx, l.name, B, h, w, c_in, c_out, k, s, 0, transposed=True)


def _apply_cropping(l: LayerMeta) -> list[LayerMeta]:
    d = _deconv_nopad(l)
    p = l.attrs["padding"]
    crop = pointwise_meta(l.idx, l.name + ".crop", "crop", l.out_shape, flops_per_elem=0.0)
    crop.attrs = {"crop": p}
    crop.in_shape = d.out_shape
    return [d, crop]


def _apply_conv(l: LayerMeta) -> list[LayerMeta]:
    d = _deconv_nopad(l)
    B, h, w, c = d.out_shape
    # 3x3 VALID conv trims one row/col per border (paper eq. 8/9) iff padding==1
    conv = conv_meta(l.idx, l.name + ".conv", B, h, w, c, c, 3, 1, 0)
    return [d, conv]


def _apply_avg_pool(l: LayerMeta) -> list[LayerMeta]:
    d = _deconv_nopad(l)
    B, h, w, c = d.out_shape
    pool = pointwise_meta(l.idx, l.name + ".avgpool", "pool", (B, h - 2, w - 2, c), flops_per_elem=9.0)
    pool.in_shape = d.out_shape
    pool.attrs = {"window": 3, "stride": 1}
    return [d, pool]


def _apply_max_pool(l: LayerMeta) -> list[LayerMeta]:
    out = _apply_avg_pool(l)
    out[1].name = out[1].name.replace("avgpool", "maxpool")
    return out


def _apply_reduced_kernel(l: LayerMeta) -> list[LayerMeta]:
    """Reduce deconv kernel to 2 (stride 2, pad 0): out = 2*in exactly, but
    the receptive field shrinks — the paper found this hurts accuracy."""
    B, h, w, c_in = l.in_shape
    c_out = l.out_shape[-1]
    return [conv_meta(l.idx, l.name + ".k2", B, h, w, c_in, c_out, 2, 2, 0, transposed=True)]


def _apply_fused_crop(l: LayerMeta) -> list[LayerMeta]:
    """Beyond-paper (TPU-native): ONE kernel-backed op — the phase-
    decomposed deconv with the crop folded into output indexing
    (repro.kernels.deconv). vs 'cropping': removes the crop layer's full
    (B, 2H, 2W, C) read+write AND the border compute the crop discards.
    Illegal on the literal Jetson DLA (fixed-function); legal on the TPU
    submesh engines where we control the kernel."""
    B, h, w, c_in = l.in_shape
    c_out = l.out_shape[-1]
    k, s, p = l.attrs["kernel"], l.attrs["stride"], l.attrs["padding"]
    fused = conv_meta(l.idx, l.name + ".fused", B, h, w, c_in, c_out, k, s, p, transposed=True)
    fused.kind = "deconv_fused"
    # phase decomposition computes only surviving outputs: scale flops by
    # the kept-area fraction ((2h-2p)/2h)^2 relative to the pad-free op
    keep = ((s * h - 2 * p) / (s * (h - 1) + k - 2 * p)) ** 2 if h > 1 else 1.0
    nopad_flops = 2.0 * B * h * w * c_in * k * k * c_out
    fused.flops = nopad_flops * keep
    return [fused]


RULE_CROPPING = SurgeryRule("cropping", "endorsed", _match_deconv_padding, _apply_cropping)
RULE_CONV = SurgeryRule("conv", "endorsed", _match_deconv_padding, _apply_conv)
RULE_FUSED_CROP = SurgeryRule("fused_crop", "endorsed", _match_deconv_padding, _apply_fused_crop)
RULE_AVG_POOL = SurgeryRule("avg_pool", "rejected", _match_deconv_padding, _apply_avg_pool)
RULE_MAX_POOL = SurgeryRule("max_pool", "rejected", _match_deconv_padding, _apply_max_pool)
RULE_REDUCED_KERNEL = SurgeryRule(
    "reduced_kernel", "rejected", _match_deconv_padding, _apply_reduced_kernel
)

RULES = {
    r.name: r
    for r in (
        RULE_CROPPING,
        RULE_CONV,
        RULE_FUSED_CROP,
        RULE_AVG_POOL,
        RULE_MAX_POOL,
        RULE_REDUCED_KERNEL,
    )
}


@dataclasses.dataclass
class SurgeryReport:
    rule: str
    replaced: list[str]
    param_delta: int
    layer_delta: int
    remaining_illegal: list[str]


def apply_surgery(graph: LayerGraph, engine, rule_name: str = "cropping"):
    """Rewrite every layer of ``graph`` that is illegal on ``engine`` using
    ``rule``. Returns (new_graph, SurgeryReport).

    Hierarchical graphs are rewritten at primitive granularity: when any
    node carries a composite decomposition, the pass runs on the expanded
    (primitive-only) graph — surgery rules match primitives, never
    composite kinds, so an illegal primitive buried inside a composite is
    only reachable there."""
    if any(l.is_composite for l in graph):
        graph = graph.expand()
    rule = RULES[rule_name]
    new_layers: list[LayerMeta] = []
    replaced = []
    p_before = graph.total_params()
    for l in graph:
        vs = [v for v in engine.supports(l) if v.severity == "illegal"]
        applicable = [v for v in vs if rule.matches(l, v)]
        if applicable:
            new_layers.extend(rule.apply(l))
            replaced.append(l.name)
        else:
            new_layers.append(l.clone())
    g = LayerGraph(f"{graph.model_name}->{rule_name}", new_layers).renumber()
    remaining = [
        l.name for l in g if any(v.severity == "illegal" for v in engine.supports(l))
    ]
    return g, SurgeryReport(
        rule=rule_name,
        replaced=replaced,
        param_delta=g.total_params() - p_before,
        layer_delta=len(g) - len(graph),
        remaining_illegal=remaining,
    )


def substitute_pix2pix(cfg, rule_name: str):
    """Model-level rewrite: returns a Pix2PixConfig in the requested mode.

    The weights of 'padded' and 'cropping' variants are interchangeable
    (identical pytrees, identical function); 'conv' adds 3x3 conv params.
    """
    mode = {"cropping": "cropping", "conv": "conv"}[rule_name]
    return dataclasses.replace(cfg, deconv_mode=mode)
