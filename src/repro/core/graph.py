"""Layer-graph representation consumed by the engine-constraint checker,
the surgery pass, and the HaX-CoNN scheduler.

A ``LayerGraph`` is a linear sequence of ``LayerMeta`` nodes (the paper
schedules at layer-sequence granularity; skip connections are captured as
extra tensor traffic on the node, which is what matters for transfer
costing at partition points).

The graph is *hierarchical*: a node may carry ``sublayers`` — a
primitive-only decomposition of a composite block (YOLO ``c2f``/``sppf``/
``head``). ``expand()``/``flatten()`` produce an ``ExpandedGraph`` whose
nodes are all primitives, with an index map back to the coarse nodes, so
the planner can place cuts *inside* composites and the measured-cost
provider can measure them. Cut legality lives on the metas
(``attrs["cut_after"]``): a partition after layer ``p-1`` is legal only
where the model exposes an executable stage boundary — interior
primitives of one fused stage callable (e.g. the conv inside a
conv+bn+silu block) refuse cuts. ``cut_points()`` is the single source
of candidate partition points for every scheduler.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


@dataclasses.dataclass
class LayerMeta:
    idx: int
    name: str
    kind: str  # conv | deconv | crop | bn | act | add | pool | pad | concat | tanh | dropout | matmul | attn | moe | ssd | norm | embed | c2f | sppf | head | other
    in_shape: tuple[int, ...]
    out_shape: tuple[int, ...]
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    flops: float = 0.0
    bytes_accessed: float = 0.0
    params: int = 0
    # bytes that must move to the next layer if a partition is placed after
    # this node (activation + any live skip tensors)
    boundary_bytes: float = 0.0
    # primitive decomposition of a composite node (None = already primitive).
    # Composite flop/byte/param totals are the sums over the decomposition,
    # so expansion conserves them exactly.
    sublayers: list["LayerMeta"] | None = None

    @property
    def is_composite(self) -> bool:
        return bool(self.sublayers)

    @property
    def cut_after(self) -> bool:
        """Whether a partition directly after this layer is executable."""
        return bool(self.attrs.get("cut_after", True))

    def primitives(self) -> list["LayerMeta"]:
        """The recursive primitive-only decomposition ([self] if primitive)."""
        if not self.sublayers:
            return [self]
        return [p for sub in self.sublayers for p in sub.primitives()]

    def clone(self, **kw):
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d["attrs"] = dict(self.attrs)
        if self.sublayers is not None:
            d["sublayers"] = [s.clone() for s in self.sublayers]
        d.update(kw)
        return LayerMeta(**d)


@dataclasses.dataclass
class LayerGraph:
    model_name: str
    layers: list[LayerMeta]

    def __len__(self):
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    def __getitem__(self, i):
        return self.layers[i]

    def total_flops(self):
        return sum(l.flops for l in self.layers)

    def total_bytes(self):
        return sum(l.bytes_accessed for l in self.layers)

    def total_params(self):
        return sum(l.params for l in self.layers)

    def renumber(self):
        for i, l in enumerate(self.layers):
            l.idx = i
        return self

    def cut_points(self, stride: int = 1) -> list[int]:
        """Legal interior partition points, optionally strided.

        A point ``p`` (cut after layer ``p-1``) is legal when the layer
        before it allows cuts (``cut_after``); on expanded graphs that is
        exactly the set of stage-callable boundaries. ``stride > 1`` keeps
        every stride-th legal point — the knob that keeps the beam search
        tractable on fine-grained graphs.
        """
        pts = [p for p in range(1, len(self.layers)) if self.layers[p - 1].cut_after]
        return pts[::stride] if stride > 1 else pts

    def expand(self) -> "ExpandedGraph":
        """Primitive-only view of this graph with an index map back to it.

        Each composite node is replaced by its (recursively flattened)
        primitive decomposition; primitive nodes pass through. The last
        primitive of every coarse node always permits a cut — the coarse
        partition points remain a subset of the expanded ones.
        """
        fine: list[LayerMeta] = []
        coarse_of: list[int] = []
        spans: list[tuple[int, int]] = []
        for ci, l in enumerate(self.layers):
            lo = len(fine)
            for p in l.primitives():
                c = p.clone()
                c.sublayers = None
                fine.append(c)
                coarse_of.append(ci)
            fine[-1].attrs["cut_after"] = True
            spans.append((lo, len(fine)))
        g = ExpandedGraph(
            model_name=f"{self.model_name}[expanded]",
            layers=fine,
            coarse=self,
            coarse_of=tuple(coarse_of),
            spans=tuple(spans),
        )
        return g.renumber()

    def flatten(self) -> "ExpandedGraph":
        """Alias for :meth:`expand` (the decomposition is stored flat, so
        one expansion is already primitive-only)."""
        return self.expand()


@dataclasses.dataclass
class ExpandedGraph(LayerGraph):
    """A primitive-only ``LayerGraph`` remembering its coarse origin.

    ``coarse_of[i]`` is the coarse node that produced fine layer ``i``;
    ``spans[c]`` is the fine half-open span of coarse node ``c``. The two
    maps let planners report fine cuts in coarse terms (PlanIR coarse
    spans) and translate coarse plans onto the fine graph for
    like-for-like comparison.
    """

    coarse: LayerGraph | None = None
    coarse_of: tuple[int, ...] = ()
    spans: tuple[tuple[int, int], ...] = ()

    def fine_cut(self, coarse_p: int) -> int:
        """Expanded index of a coarse partition point (cut after coarse
        node ``coarse_p - 1``)."""
        if coarse_p <= 0:
            return 0
        return self.spans[coarse_p - 1][1]

    def coarse_span(self, lo: int, hi: int) -> tuple[int, int]:
        """Smallest coarse span [clo, chi) covering fine span [lo, hi)."""
        if hi <= lo:
            raise ValueError(f"empty fine span [{lo},{hi})")
        return (self.coarse_of[lo], self.coarse_of[hi - 1] + 1)

    def coarse_cut(self, fine_p: int) -> int | None:
        """Coarse partition point whose expansion boundary is ``fine_p`` —
        the inverse of :meth:`fine_cut` — or None when the fine cut falls
        strictly inside a coarse node (not expressible coarsely)."""
        if fine_p <= 0:
            return 0
        if fine_p >= len(self.layers):
            return len(self.spans)
        for c, (_, hi) in enumerate(self.spans):
            if hi == fine_p:
                return c + 1
            if hi > fine_p:
                return None
        return None


def _size(shape):
    return math.prod(shape)


def conv_meta(
    idx,
    name,
    B,
    h_in,
    w_in,
    c_in,
    c_out,
    kernel,
    stride,
    padding,
    dtype_bytes=2,
    transposed=False,
    groups=1,
):
    """LayerMeta for a (transposed) convolution with analytic flops/bytes."""
    if transposed:
        h_out = stride * (h_in - 1) + kernel - 2 * padding
        w_out = stride * (w_in - 1) + kernel - 2 * padding
        flops = 2.0 * B * h_in * w_in * c_in * kernel * kernel * c_out / groups
    else:
        h_out = (h_in + 2 * padding - kernel) // stride + 1
        w_out = (w_in + 2 * padding - kernel) // stride + 1
        flops = 2.0 * B * h_out * w_out * c_out * kernel * kernel * c_in / groups
    params = kernel * kernel * (c_in // groups) * c_out + c_out
    in_shape = (B, h_in, w_in, c_in)
    out_shape = (B, h_out, w_out, c_out)
    bytes_accessed = dtype_bytes * (_size(in_shape) + _size(out_shape)) + 4 * params
    return LayerMeta(
        idx=idx,
        name=name,
        kind="deconv" if transposed else "conv",
        in_shape=in_shape,
        out_shape=out_shape,
        attrs={"kernel": kernel, "stride": stride, "padding": padding, "groups": groups},
        flops=flops,
        bytes_accessed=bytes_accessed,
        params=params,
        boundary_bytes=dtype_bytes * _size(out_shape),
    )


def pointwise_meta(idx, name, kind, shape, dtype_bytes=2, flops_per_elem=1.0, params=0):
    n = _size(shape)
    return LayerMeta(
        idx=idx,
        name=name,
        kind=kind,
        in_shape=shape,
        out_shape=shape,
        flops=flops_per_elem * n,
        bytes_accessed=dtype_bytes * 2 * n + 4 * params,
        params=params,
        boundary_bytes=dtype_bytes * n,
    )


def reshape_meta(idx, name, kind, in_shape, out_shape, dtype_bytes=2):
    return LayerMeta(
        idx=idx,
        name=name,
        kind=kind,
        in_shape=in_shape,
        out_shape=out_shape,
        flops=0.0,
        bytes_accessed=dtype_bytes * (_size(in_shape) + _size(out_shape)),
        boundary_bytes=dtype_bytes * _size(out_shape),
    )
