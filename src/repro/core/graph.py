"""Layer-graph representation consumed by the engine-constraint checker,
the surgery pass, and the HaX-CoNN scheduler.

A ``LayerGraph`` is a linear sequence of ``LayerMeta`` nodes (the paper
schedules at layer-sequence granularity; skip connections are captured as
extra tensor traffic on the node, which is what matters for transfer
costing at partition points).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any


@dataclasses.dataclass
class LayerMeta:
    idx: int
    name: str
    kind: str  # conv | deconv | crop | bn | act | pool | pad | concat | tanh | dropout | matmul | attn | moe | ssd | norm | embed | other
    in_shape: tuple[int, ...]
    out_shape: tuple[int, ...]
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    flops: float = 0.0
    bytes_accessed: float = 0.0
    params: int = 0
    # bytes that must move to the next layer if a partition is placed after
    # this node (activation + any live skip tensors)
    boundary_bytes: float = 0.0

    def clone(self, **kw):
        d = dataclasses.asdict(self)
        d.update(kw)
        return LayerMeta(**d)


@dataclasses.dataclass
class LayerGraph:
    model_name: str
    layers: list[LayerMeta]

    def __len__(self):
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    def __getitem__(self, i):
        return self.layers[i]

    def total_flops(self):
        return sum(l.flops for l in self.layers)

    def total_bytes(self):
        return sum(l.bytes_accessed for l in self.layers)

    def total_params(self):
        return sum(l.params for l in self.layers)

    def renumber(self):
        for i, l in enumerate(self.layers):
            l.idx = i
        return self


def _size(shape):
    return math.prod(shape)


def conv_meta(
    idx,
    name,
    B,
    h_in,
    w_in,
    c_in,
    c_out,
    kernel,
    stride,
    padding,
    dtype_bytes=2,
    transposed=False,
    groups=1,
):
    """LayerMeta for a (transposed) convolution with analytic flops/bytes."""
    if transposed:
        h_out = stride * (h_in - 1) + kernel - 2 * padding
        w_out = stride * (w_in - 1) + kernel - 2 * padding
        flops = 2.0 * B * h_in * w_in * c_in * kernel * kernel * c_out / groups
    else:
        h_out = (h_in + 2 * padding - kernel) // stride + 1
        w_out = (w_in + 2 * padding - kernel) // stride + 1
        flops = 2.0 * B * h_out * w_out * c_out * kernel * kernel * c_in / groups
    params = kernel * kernel * (c_in // groups) * c_out + c_out
    in_shape = (B, h_in, w_in, c_in)
    out_shape = (B, h_out, w_out, c_out)
    bytes_accessed = dtype_bytes * (_size(in_shape) + _size(out_shape)) + 4 * params
    return LayerMeta(
        idx=idx,
        name=name,
        kind="deconv" if transposed else "conv",
        in_shape=in_shape,
        out_shape=out_shape,
        attrs={"kernel": kernel, "stride": stride, "padding": padding, "groups": groups},
        flops=flops,
        bytes_accessed=bytes_accessed,
        params=params,
        boundary_bytes=dtype_bytes * _size(out_shape),
    )


def pointwise_meta(idx, name, kind, shape, dtype_bytes=2, flops_per_elem=1.0, params=0):
    n = _size(shape)
    return LayerMeta(
        idx=idx,
        name=name,
        kind=kind,
        in_shape=shape,
        out_shape=shape,
        flops=flops_per_elem * n,
        bytes_accessed=dtype_bytes * 2 * n + 4 * params,
        params=params,
        boundary_bytes=dtype_bytes * n,
    )


def reshape_meta(idx, name, kind, in_shape, out_shape, dtype_bytes=2):
    return LayerMeta(
        idx=idx,
        name=name,
        kind=kind,
        in_shape=in_shape,
        out_shape=out_shape,
        flops=0.0,
        bytes_accessed=dtype_bytes * (_size(in_shape) + _size(out_shape)),
        boundary_bytes=dtype_bytes * _size(out_shape),
    )
