"""Segment-level plan IR — the single contract between planners and the
serve stack.

Every scheduler (``haxconn_schedule``, ``nmodel_schedule``, standalone /
naive) emits a typed ``PlanIR``: per model, an ordered tuple of
``PlanSegment``s (layer span, engine binding, expected cost under the
provider that scored the plan). The executor consumes *only* this IR —
it never reaches into scheduler-internal dicts or ``StagedModel``
structure — which is what makes live plan hot-swap possible: a new IR
with the same (models, layer counts) signature can replace the running
one at a frame boundary, and in-flight frames finish on a snapshot of
the segments they were admitted under.

``expected_cost`` is recorded in the *scoring provider's* units (the
analytic roofline's seconds, or calibrated wall seconds when an
``OnlineCost`` provider scored the plan). The re-planning runtime never
compares observations against these numbers directly — it re-derives
base-unit expectations from the graphs — so swapping between plans
scored by different providers cannot skew the drift detector.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class PlanSegment:
    """One contiguous layer span of one model bound to one engine.

    ``lo``/``hi`` index the plan's graph — *expanded* (primitive) indices
    for fine-granularity plans. ``coarse_lo``/``coarse_hi`` then record
    the smallest coarse-node span covering it (-1/-1 when the plan was
    made on a coarse graph and the two index spaces coincide), so reports
    and operators can read fine cuts in model-block terms.
    """

    model_index: int
    stage: int  # position in the model's route
    engine: int  # engine index into PlanIR.engine_names
    lo: int
    hi: int  # layer span [lo, hi)
    expected_cost: float = 0.0  # scoring-provider seconds for this span
    coarse_lo: int = -1  # coarse-node span covering [lo, hi); -1 = n/a
    coarse_hi: int = -1
    # implementation variant the span is staged with: "xla" (per-op
    # lowering) or "pallas_fused" (fused conv/deconv+norm+act kernels for
    # the fuse groups fully inside the span; boundary-split groups run xla
    # regardless — staging and costing share that containment rule)
    impl: str = "xla"

    @property
    def span(self) -> tuple[int, int]:
        return (self.lo, self.hi)

    @property
    def coarse_span(self) -> tuple[int, int] | None:
        if self.coarse_lo < 0:
            return None
        return (self.coarse_lo, self.coarse_hi)

    def describe(self, engine_names: Sequence[str] | None = None) -> str:
        eng = engine_names[self.engine] if engine_names else f"E{self.engine}"
        base = f"m{self.model_index}[{self.lo}:{self.hi})@{eng}"
        if self.coarse_lo >= 0:
            base += f"~c[{self.coarse_lo}:{self.coarse_hi})"
        if self.impl != "xla":
            base += f"+{self.impl}"
        return base


@dataclasses.dataclass(frozen=True)
class PlanIR:
    """Typed segment-level plan: what runs where, and what it should cost."""

    models: tuple[str, ...]
    engine_names: tuple[str, ...]
    segments: tuple[tuple[PlanSegment, ...], ...]  # per model, route order
    expected_cycle: float = 0.0  # scoring-provider steady-state cycle
    cost_provider: str = "analytic"
    search: str = "none"
    kind: str = "manual"  # haxconn | nmodel | standalone | naive | manual
    revision: int = 0  # bumped on every hot-swap
    # the cut budget the search ran with (0 = unrecorded — legacy plans /
    # hand-built IRs fall back to the realized cut count). Distinct from
    # cut_counts: a max_cuts=2 search whose optimum is single-cut still
    # carries budget 2, so a re-planner inheriting the incumbent's
    # granularity keeps the full search space.
    cut_budget: int = 0
    # implementation-selection mode the search ran with: "xla" (force the
    # per-op lowering everywhere), "pallas" (force the fused kernels where
    # a span contains fuse groups), or "auto" (per-segment argmin over
    # both — structurally never worse than "xla"). Re-planners inherit it.
    impl_mode: str = "xla"
    # effective admission batch the routes were scored at (continuous
    # batching: the coalescer's steady-state bucket). 1 = per-frame costs.
    batch: int = 1

    def __post_init__(self):
        if len(self.segments) != len(self.models):
            raise ValueError(
                f"plan has {len(self.models)} models but {len(self.segments)} segment routes"
            )
        for mi, segs in enumerate(self.segments):
            if not segs:
                raise ValueError(f"model {mi} ({self.models[mi]}) has an empty route")
            prev = segs[0].lo
            if segs[0].lo != 0:
                raise ValueError(f"model {mi} route starts at {segs[0].lo}, not 0")
            for si, s in enumerate(segs):
                if s.model_index != mi or s.stage != si:
                    raise ValueError(f"segment {s} mis-indexed at route position ({mi}, {si})")
                if s.lo != prev:
                    raise ValueError(f"model {mi} route is not contiguous at layer {s.lo}")
                if s.hi <= s.lo:
                    raise ValueError(f"model {mi} has an empty/reversed span [{s.lo},{s.hi})")
                if not 0 <= s.engine < len(self.engine_names):
                    raise ValueError(f"segment {s} binds unknown engine {s.engine}")
                prev = s.hi

    # -- introspection -------------------------------------------------------

    @property
    def n_models(self) -> int:
        return len(self.models)

    @property
    def n_engines(self) -> int:
        return len(self.engine_names)

    @property
    def n_layers(self) -> tuple[int, ...]:
        return tuple(segs[-1].hi for segs in self.segments)

    @property
    def partitions(self) -> list[int]:
        """First-stage boundary per model (the planner's partition point)."""
        return [segs[0].hi for segs in self.segments]

    @property
    def cuts(self) -> tuple[tuple[int, ...], ...]:
        """Full per-model cut vectors (interior segment boundaries)."""
        return tuple(tuple(s.hi for s in segs[:-1]) for segs in self.segments)

    @property
    def cut_counts(self) -> tuple[int, ...]:
        """Cuts per model route — the plan's multi-cut metadata."""
        return tuple(len(segs) - 1 for segs in self.segments)

    @property
    def max_cuts(self) -> int:
        """The plan's cut budget: the recorded search budget when the
        emitting scheduler set one, else the realized cut count (1 floor,
        so a re-planner inheriting the incumbent's granularity never
        degenerates to uncuttable single-segment planning)."""
        return self.cut_budget or max(1, max(self.cut_counts))

    def impl_bindings(self) -> tuple[tuple[str, ...], ...]:
        """Per-model implementation bindings in route order — the hot-swap
        comparison key beside the engine/cut structure (two plans with the
        same spans but different impls are different plans)."""
        return tuple(tuple(s.impl for s in segs) for segs in self.segments)

    def route_specs(self) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
        """Per-model ``(cuts, engines)`` pairs — the scheduler's ``fixed=``
        form, used to re-score or pin an incumbent plan route-for-route."""
        return [
            (tuple(s.hi for s in segs[:-1]), tuple(s.engine for s in segs))
            for segs in self.segments
        ]

    def route(self, model_index: int) -> tuple[PlanSegment, ...]:
        return self.segments[model_index]

    def engine_spans(self, engine: int) -> list[PlanSegment]:
        return [s for segs in self.segments for s in segs if s.engine == engine]

    def validate_against(self, n_layers: Sequence[int]):
        """Check the IR covers exactly the given per-model layer counts —
        the executor's admission contract (and the hot-swap precondition)."""
        if len(n_layers) != len(self.models):
            raise ValueError(f"plan has {len(self.models)} models, executor has {len(n_layers)}")
        for mi, (segs, n) in enumerate(zip(self.segments, n_layers)):
            if segs[-1].hi != n:
                raise ValueError(
                    f"model {mi} ({self.models[mi]}): plan covers [0,{segs[-1].hi}) "
                    f"but the staged model has {n} ops"
                )

    def with_revision(self, revision: int) -> "PlanIR":
        return dataclasses.replace(self, revision=revision)

    def describe(self) -> str:
        head = (
            f"PlanIR[{self.kind}] rev={self.revision} cycle={self.expected_cycle * 1e3:.3f}ms "
            f"cost={self.cost_provider} search={self.search} cuts={list(self.cut_counts)}"
        )
        if self.impl_mode != "xla":
            head += f" impl={self.impl_mode}"
        lines = [head]
        for mi, segs in enumerate(self.segments):
            spans = " -> ".join(
                f"{self.engine_names[s.engine]}[{s.lo}:{s.hi})"
                + (f"+{s.impl}" if s.impl != "xla" else "")
                for s in segs
            )
            lines.append(f"  {self.models[mi]}: {spans}")
        return "\n".join(lines)

    # -- serialization -------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": 1,
                "models": list(self.models),
                "engine_names": list(self.engine_names),
                "segments": [
                    [
                        {
                            "engine": s.engine,
                            "lo": s.lo,
                            "hi": s.hi,
                            "expected_cost": s.expected_cost,
                            "coarse_lo": s.coarse_lo,
                            "coarse_hi": s.coarse_hi,
                            "impl": s.impl,
                        }
                        for s in segs
                    ]
                    for segs in self.segments
                ],
                "expected_cycle": self.expected_cycle,
                "cost_provider": self.cost_provider,
                "search": self.search,
                "kind": self.kind,
                "revision": self.revision,
                "cut_budget": self.cut_budget,
                "impl_mode": self.impl_mode,
                "batch": self.batch,
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "PlanIR":
        d = json.loads(text)
        segments = tuple(
            tuple(
                PlanSegment(
                    model_index=mi,
                    stage=si,
                    engine=int(s["engine"]),
                    lo=int(s["lo"]),
                    hi=int(s["hi"]),
                    expected_cost=float(s.get("expected_cost", 0.0)),
                    coarse_lo=int(s.get("coarse_lo", -1)),
                    coarse_hi=int(s.get("coarse_hi", -1)),
                    impl=s.get("impl", "xla"),
                )
                for si, s in enumerate(segs)
            )
            for mi, segs in enumerate(d["segments"])
        )
        return cls(
            models=tuple(d["models"]),
            engine_names=tuple(d["engine_names"]),
            segments=segments,
            expected_cycle=float(d.get("expected_cycle", 0.0)),
            cost_provider=d.get("cost_provider", "analytic"),
            search=d.get("search", "none"),
            kind=d.get("kind", "manual"),
            revision=int(d.get("revision", 0)),
            cut_budget=int(d.get("cut_budget", 0)),
            impl_mode=d.get("impl_mode", "xla"),
            batch=int(d.get("batch", 1)),
        )


def make_plan_ir(
    model_names: Sequence[str],
    engine_names: Sequence[str],
    spans: Sequence[Sequence[tuple[int, int, int, float] | tuple[int, int, int]]],
    expected_cycle: float = 0.0,
    cost_provider: str = "analytic",
    search: str = "none",
    kind: str = "manual",
    graphs: Sequence | None = None,
    cut_budget: int = 0,
    impl_mode: str = "xla",
    batch: int = 1,
) -> PlanIR:
    """Build a PlanIR from per-model ``(engine, lo, hi[, expected_cost[,
    impl]])`` span lists — the one constructor every scheduler emit path
    goes through. When ``graphs`` carries expanded graphs (anything
    exposing ``coarse_span``), each segment is annotated with the
    coarse-node span its fine span covers."""

    def _coarse(mi, lo, hi):
        g = graphs[mi] if graphs is not None and mi < len(graphs) else None
        if g is None or not hasattr(g, "coarse_span"):
            return -1, -1
        return g.coarse_span(lo, hi)

    def _segment(mi, si, sp):
        lo, hi = int(sp[1]), int(sp[2])
        clo, chi = _coarse(mi, lo, hi)
        return PlanSegment(
            model_index=mi,
            stage=si,
            engine=int(sp[0]),
            lo=lo,
            hi=hi,
            expected_cost=float(sp[3]) if len(sp) > 3 else 0.0,
            coarse_lo=clo,
            coarse_hi=chi,
            impl=sp[4] if len(sp) > 4 else "xla",
        )

    segments = tuple(
        tuple(_segment(mi, si, sp) for si, sp in enumerate(model_spans))
        for mi, model_spans in enumerate(spans)
    )
    return PlanIR(
        models=tuple(model_names),
        engine_names=tuple(engine_names),
        segments=segments,
        expected_cycle=expected_cycle,
        cost_provider=cost_provider,
        search=search,
        kind=kind,
        cut_budget=cut_budget,
        impl_mode=impl_mode,
        batch=batch,
    )


def translate_ir(ir: PlanIR, graphs) -> PlanIR:
    """Re-index a coarse-granularity plan onto expanded graphs.

    Each segment's coarse span [lo, hi) becomes the fine span
    ``[fine_cut(lo), fine_cut(hi))`` of the matching ``ExpandedGraph`` —
    the staging-compatible form when the executor's models were staged at
    fine granularity but the plan was made on the coarse graphs (the
    cheap-planning / escalate-on-drift deployment). Expected costs carry
    over unchanged: they remain in the scoring provider's coarse units,
    which the re-planning runtime never compares against directly."""
    spans = [
        [(s.engine, g.fine_cut(s.lo), g.fine_cut(s.hi), s.expected_cost, s.impl) for s in segs]
        for segs, g in zip(ir.segments, graphs)
    ]
    return make_plan_ir(
        ir.models,
        ir.engine_names,
        spans,
        expected_cycle=ir.expected_cycle,
        cost_provider=ir.cost_provider,
        search=ir.search,
        kind=ir.kind,
        graphs=graphs,
        cut_budget=ir.cut_budget,
        impl_mode=ir.impl_mode,
        batch=ir.batch,
    )


def ir_from_routes(routes, model_names=None, engine_names=None, kind: str = "manual") -> PlanIR:
    """Adapt legacy ``ModelRoute`` lists (scheduler-dict era) to the IR.

    Kept so executor call sites that hand-build routes keep working; new
    code should consume a scheduler's ``.ir`` directly.
    """
    names = list(model_names) if model_names else [getattr(r, "model", f"m{i}") for i, r in enumerate(routes)]
    n_engines = max(e for r in routes for e, _, _ in r.segments) + 1
    engines = list(engine_names) if engine_names else [f"E{i}" for i in range(n_engines)]
    return make_plan_ir(
        names,
        engines,
        [[(e, lo, hi) for e, lo, hi in r.segments] for r in routes],
        kind=kind,
    )
