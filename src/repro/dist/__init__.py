# Distribution layer: sharding rules + compressed data-parallel gradients.
from .compress import make_compressed_dp_grad_fn, zeros_like_error
from .sharding import (
    TrainShardings,
    batch_sharding,
    default_rules,
    opt_state_shardings,
    spec_for_axes,
    spec_for_axes_shaped,
    train_shardings,
    tree_shardings,
    tree_shardings_shaped,
)
