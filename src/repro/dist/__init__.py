# Distribution layer: sharding rules + compressed data-parallel gradients.
from .compress import make_compressed_dp_grad_fn, zeros_like_error
from .sharding import (
    batch_sharding,
    default_rules,
    spec_for_axes,
    spec_for_axes_shaped,
    tree_shardings,
    tree_shardings_shaped,
)
