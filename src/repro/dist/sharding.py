"""Axis-name -> mesh-axis sharding rules (the levanter-style mapping).

Params carry logical axis names (``Module.axes()``: "embed", "mlp",
"heads", "vocab", ...). A *rule table* maps each name to the mesh axes it
may shard over; ``spec_for_axes`` applies the table left-to-right, never
reusing a mesh axis within one param, and ``_fit_spec`` drops proposed
axes that do not divide the actual dimension (kv-head dims of size 1 on a
16-way model axis, ragged vocab remainders, ...). Everything downstream
consumes plain ``NamedSharding``s, so this works on any jax new enough to
have them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical param axes that carry the bulk of the bytes: shard these over
# tensor-parallel + data (fsdp) mesh axes when model sharding is on
_BIG_AXES = ("mlp", "vocab")
# axes sharded over the tensor-parallel mesh axis only
_MODEL_AXES = ("heads", "expert", "conv_out")
_MODEL_MESH_NAMES = ("model", "tensor")


def default_rules(shard_model: bool, mesh_axes: tuple[str, ...]) -> dict[str, tuple[str, ...] | None]:
    """Rule table for a mesh. Mesh axes named 'model'/'tensor' are
    tensor-parallel; everything else ('data', 'pod', 'fsdp', ...) is
    data-like. Unlisted logical axes replicate."""
    model = tuple(a for a in mesh_axes if a in _MODEL_MESH_NAMES)
    data = tuple(a for a in mesh_axes if a not in _MODEL_MESH_NAMES)
    rules: dict[str, tuple[str, ...] | None] = {"batch": data or None}
    if shard_model:
        for name in _BIG_AXES:
            rules[name] = model + data
        for name in _MODEL_AXES:
            rules[name] = model or None
    return rules


def spec_for_axes(axes, rules) -> P:
    """PartitionSpec for one param's logical axes; a mesh axis is consumed
    by the first logical axis that claims it."""
    used: set[str] = set()
    out = []
    for a in axes or ():
        m = rules.get(a) if a is not None else None
        if m is None:
            out.append(None)
            continue
        m = (m,) if isinstance(m, str) else tuple(m)
        m = tuple(x for x in m if x not in used)
        used.update(m)
        out.append(m if len(m) > 1 else (m[0] if m else None))
    return P(*out)


def _fit_spec(spec, shape, mesh):
    """Drop proposed mesh axes that do not divide the dimension they
    shard (trailing-first), so every sharding is actually placeable."""
    sizes = dict(mesh.shape)
    out = []
    for entry, dim in zip(tuple(spec), shape):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        while names and dim % math.prod(sizes[n] for n in names) != 0:
            names = names[:-1]
        out.append(names if len(names) > 1 else (names[0] if names else None))
    out += [None] * (len(shape) - len(out))
    return tuple(out)


def spec_for_axes_shaped(axes, shape, mesh, rules) -> P:
    return P(*_fit_spec(tuple(spec_for_axes(axes, rules)), shape, mesh))


def _is_axes_leaf(x) -> bool:
    return x is None or isinstance(x, tuple)


def tree_shardings(mesh, axes_tree, rules):
    """NamedSharding per param from logical axes alone (no divisibility
    fitting — prefer ``tree_shardings_shaped``)."""
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, spec_for_axes(ax, rules)), axes_tree, is_leaf=_is_axes_leaf
    )


def tree_shardings_shaped(mesh, axes_tree, shapes_tree, rules):
    """NamedSharding per param, divisibility-fitted against the leaf
    shapes (``jax.ShapeDtypeStruct`` or arrays)."""
    return jax.tree.map(
        lambda ax, sd: NamedSharding(mesh, spec_for_axes_shaped(ax, tuple(sd.shape), mesh, rules)),
        axes_tree,
        shapes_tree,
        is_leaf=_is_axes_leaf,
    )


def batch_sharding(mesh, batch_size: int, rules) -> NamedSharding:
    """Sharding for a batch leaf: leading dim over the data-like axes."""
    fitted = _fit_spec((rules.get("batch"),), (batch_size,), mesh)
    return NamedSharding(mesh, P(*fitted))


def opt_state_shardings(mesh, params_shardings, opt_state):
    """Shardings for an optimizer-state pytree, derived from the param
    shardings: any sub-tree structurally identical to the params (AdamW's
    moments, master weights, ...) inherits them; everything else (step
    counters, scalars) replicates. No hand-rolled ``{"m": psh, ...}``."""
    replicated = NamedSharding(mesh, P())
    pstruct = jax.tree.structure(params_shardings)

    def branch(sub):
        if jax.tree.structure(sub) == pstruct:
            return params_shardings
        return jax.tree.map(lambda _: replicated, sub)

    if isinstance(opt_state, dict):
        return {k: branch(v) for k, v in opt_state.items()}
    return branch(opt_state)


@dataclasses.dataclass(frozen=True)
class TrainShardings:
    """The full sharding plumbing of one train launch."""

    params: Any
    opt_state: Any
    batch: NamedSharding
    rules: dict


def train_shardings(mesh, axes_tree, abstract_params, opt_state, batch_size: int, rules=None):
    """One-call config plumbing for a sharded train launch: divisibility-
    fitted param shardings (``tree_shardings_shaped``), structurally
    derived optimizer-state shardings, and the batch sharding — explicit
    ``NamedSharding``s only, so this works on every jax new enough to
    have them (no mesh context manager required)."""
    rules = rules if rules is not None else default_rules(True, mesh.axis_names)
    psh = tree_shardings_shaped(mesh, axes_tree, abstract_params, rules)
    return TrainShardings(
        params=psh,
        opt_state=opt_state_shardings(mesh, psh, opt_state),
        batch=batch_sharding(mesh, batch_size, rules),
        rules=rules,
    )
