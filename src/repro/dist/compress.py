"""Compressed data-parallel gradients with error feedback.

Pod-scale all-reduce bandwidth is the scaling wall; the standard remedy
is low-bit gradient exchange with per-tensor scales plus error feedback
so the quantization residual re-enters the next step instead of being
lost (1-bit Adam / PowerSGD lineage). ``make_compressed_dp_grad_fn``
wraps a loss into a grad fn that (1) shards the batch over the data-like
mesh axes, (2) adds the carried residual, (3) fake-quantizes to ``bits``
with a per-tensor max scale (what the wire format would carry), and
(4) returns the dequantized gradient + the new residual, split over
``n_chunks`` carriers (one per pod in the hierarchical reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def zeros_like_error(params, n_chunks: int):
    """Fresh error-feedback state: one residual carrier per chunk/pod."""
    return jax.tree.map(lambda x: jnp.zeros((n_chunks,) + x.shape, jnp.float32), params)


def make_compressed_dp_grad_fn(loss_fn, mesh, batch_spec: P, bits: int = 8):
    """Returns ``grad_fn(params, batch, err) -> (grad, new_err)``.

    ``batch_spec``'s first entry names the mesh axes the batch dim shards
    over (e.g. ``P(("pod", "data"))``). The dequantized gradient stays
    within scale/2 of the true gradient per element (scale = max|g|/
    (2^(bits-1)-1)); the residual is carried in ``new_err``.
    """
    levels = float(2 ** (bits - 1) - 1)
    batch_axes = tuple(batch_spec)[0] if len(tuple(batch_spec)) else None

    def _shard_batch(x):
        spec = P(*((batch_axes,) + (None,) * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def grad_fn(params, batch, err):
        if mesh is not None and batch_axes is not None:
            batch = jax.tree.map(_shard_batch, batch)
        g = jax.grad(loss_fn)(params, batch)
        g_leaves, treedef = jax.tree.flatten(g)
        e_leaves = treedef.flatten_up_to(err)
        out_g, out_e = [], []
        for gi, ei in zip(g_leaves, e_leaves):
            total = gi.astype(jnp.float32) + ei.sum(axis=0)
            scale = jnp.maximum(jnp.max(jnp.abs(total)) / levels, 1e-20)
            deq = jnp.round(total / scale) * scale  # fake-quantized exchange
            resid = (total - deq) / ei.shape[0]
            out_g.append(deq.astype(gi.dtype))
            out_e.append(jnp.broadcast_to(resid[None], ei.shape).astype(ei.dtype))
        return treedef.unflatten(out_g), treedef.unflatten(out_e)

    return grad_fn
