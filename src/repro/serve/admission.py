"""SLO-aware admission control: graceful degradation under overload.

A closed-loop server blocks its producer when queues fill; an open-loop
one cannot — frames keep arriving. Queuing them unboundedly preserves
throughput on paper while every frame blows its deadline (the classic
goodput collapse). The admission controller instead degrades in
escalating order as queue pressure rises:

1. **shed resolution** (``pressure >= shed_resolution_at``) — the frame
   is admitted through ``degrade_frame`` (by default a spatial subsample
   by ``resolution_factor``), trading fidelity for per-frame compute.
   Only applied to models whose ``resolution_flexible`` flag is set —
   shape-specialized models pass through untouched (the decision is
   still recorded, so reports show the controller's intent).
2. **shed staging** (``pressure >= shed_route_at``) — the frame runs the
   *degraded route*: the whole model as one coarse segment on the engine
   already carrying most of its planned work. No pipeline hand-offs, no
   inter-engine transfers, minimum per-frame service time — the
   coarse-granularity fallback of the plan it degrades from.
3. **drop lowest priority** (queue full) — the newest frame of the
   lowest-priority (highest-tier) nonempty queue of the same model is
   evicted to make room for a strictly higher-priority arrival;
   arrivals that outrank nothing are dropped themselves.

Pressure is the model's aggregate queue fill fraction
(``StreamExecutor.queue_pressure``). Every decision is recorded in
``serve.metrics`` per stream and per tier, so reports expose
goodput-under-SLO next to shed/drop counts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

ADMIT = "admit"
SHED_RES = "shed_res"
SHED_ROUTE = "shed_route"
DROP = "drop"


def subsample_frame(frame, factor: int):
    """Default resolution shed: stride-subsample the spatial axes of an
    NHWC frame (rank >= 3; leading batch and trailing channel axes kept)."""
    ndim = getattr(frame, "ndim", 0)
    if ndim < 3:
        return frame
    idx = [slice(None)] * ndim
    for ax in range(1, ndim - 1):
        idx[ax] = slice(None, None, factor)
    return frame[tuple(idx)]


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Thresholds of the escalating degradation ladder.

    Pressures are queue fill fractions in [0, 1]; each level activates at
    its threshold and stays active above it (``shed_route_at`` implies
    resolution shedding too when the model allows it).
    """

    shed_resolution_at: float = 0.5
    shed_route_at: float = 0.75
    # Pressure above which arrivals that are not of the model's
    # highest-priority tier are dropped outright — queueing them would
    # spend the high-priority streams' deadline budget on work that will
    # miss its own deadline anyway.
    drop_at: float = 0.9
    resolution_factor: int = 2
    enabled: bool = True
    # Replaces the default subsampler when set: (frame) -> degraded frame.
    degrade_frame: Callable | None = None

    def __post_init__(self):
        if not 0.0 < self.shed_resolution_at <= self.shed_route_at <= self.drop_at:
            raise ValueError("need 0 < shed_resolution_at <= shed_route_at <= drop_at")
        if self.resolution_factor < 1:
            raise ValueError("resolution_factor must be >= 1")

    def decide(self, pressure: float) -> tuple[str, int]:
        """(decision, degrade level) for one arrival at this pressure.
        Level 0 = admit untouched, 1 = shed resolution, 2 = shed staging."""
        if not self.enabled:
            return ADMIT, 0
        if pressure >= self.shed_route_at:
            return SHED_ROUTE, 2
        if pressure >= self.shed_resolution_at:
            return SHED_RES, 1
        return ADMIT, 0

    def degrade(self, frame):
        if self.degrade_frame is not None:
            return self.degrade_frame(frame)
        return subsample_frame(frame, self.resolution_factor)
