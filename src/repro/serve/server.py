"""Multi-stream serving front end: request queue -> stream assignment ->
executor -> metrics.

``MultiStreamServer`` owns the planned ``StreamExecutor`` plus a global
request queue. Requests name a *model* (not a stream); the server assigns
each to the least-loaded stream bound to that model, pumps the executor
when queues back up, and folds completions into per-stream latency /
throughput metrics. This is the CPU-container stand-in for the paper's
DeepStream app: the same code drives TPU submeshes when the staged
models' ``place_fns`` put segments on real device subsets.

Pass a ``serve.Replanner`` to close the online re-planning loop: the
server wires it into the executor (profiled ticks feed the ``OnlineCost``
EMA, the drift detector hot-swaps plans at frame boundaries) and folds
its state — per-engine scales, drift, swap events — into ``report()``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

from ..core.pipeline import StagedModel
from ..core.plan_ir import PlanIR
from ..core.scheduler import NModelPlan
from .admission import ADMIT, DROP, AdmissionConfig
from .batching import BatchConfig
from .executor import StreamExecutor
from .metrics import ServeMetrics, segment_summary
from .replanner import Replanner
from .streams import StreamSpec


@dataclasses.dataclass
class Request:
    model_index: int
    frame: Any


class MultiStreamServer:
    def __init__(
        self,
        models: list[StagedModel],
        plan: PlanIR | NModelPlan | list,
        streams: list[StreamSpec],
        max_queue: int = 4,
        microbatch: int = 1,
        merge_batches: bool | list[bool] = False,
        place_fns=None,
        dispatch: str = "overlapped",
        jit_segments: bool = True,
        replanner: Replanner | None = None,
        admission: AdmissionConfig | None = None,
        resolution_flexible: bool | list[bool] = False,
        batching: BatchConfig | None = None,
    ):
        self.executor = StreamExecutor(
            models,
            plan,
            streams,
            max_queue=max_queue,
            microbatch=microbatch,
            merge_batches=merge_batches,
            place_fns=place_fns,
            dispatch=dispatch,
            jit_segments=jit_segments,
            batching=batching,
        )
        self.replanner = replanner
        self.metrics = ServeMetrics(
            [s.name for s in streams], slos={s.name: s.slo for s in streams if s.slo is not None}
        )
        if replanner is not None:
            replanner.attach(self.executor)
            # close the SLO feedback loop: sustained deadline misses are a
            # re-plan trigger alongside queue growth and cost drift
            replanner.slo_miss_fn = self.metrics.recent_slo_miss_rate
        self.admission = admission
        if isinstance(resolution_flexible, bool):
            self.resolution_flexible = [resolution_flexible] * len(models)
        else:
            self.resolution_flexible = list(resolution_flexible)
        self._backlog: deque[Request] = deque()
        self._recorded = 0
        self._recorded_ticks = 0
        self._t0: float | None = None

    # -- request intake -----------------------------------------------------

    def submit(self, model_index: int, frame: Any):
        """Enqueue one frame for a model; assignment + execution happen in
        ``pump``/``drain``. Starts the wall clock on first submission."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._backlog.append(Request(model_index, frame))

    def _least_loaded_stream(self, model_index: int) -> int:
        ex = self.executor
        best, best_depth = -1, None
        for si, s in enumerate(ex.streams):
            if s.model_index != model_index:
                continue
            depth = len(ex.queues[si])
            if best_depth is None or depth < best_depth:
                best, best_depth = si, depth
        if best < 0:
            raise ValueError(f"no stream serves model index {model_index}")
        return best

    # -- open-loop intake ---------------------------------------------------

    def offer(self, target: int | str, frame: Any) -> str:
        """Open-loop admission: take one arriving frame *now*, without
        blocking and without backlogging — the open-loop counterpart of
        ``submit``/``pump``. ``target`` is a model index (assigned to its
        least-loaded stream) or a stream name.

        The admission controller reads the model's queue pressure and
        degrades in escalating order: shed resolution, shed staging, and —
        past ``drop_at`` — drop arrivals whose priority tier is not the
        highest contending one (their queued service time would come out
        of the high-priority streams' deadline budget). A full queue
        drops the arrival regardless of tier (it is the newest frame of
        its own stream). Returns the recorded decision (``admission``
        module constants)."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        ex = self.executor
        si = self._least_loaded_stream(target) if isinstance(target, int) else ex._stream_index(target)
        spec = ex.streams[si]
        self.metrics.record_arrival(spec.name)
        decision, level = ADMIT, 0
        if self.admission is not None:
            pressure = ex.queue_pressure(spec.model_index)
            decision, level = self.admission.decide(pressure)
            if (
                self.admission.enabled
                and pressure >= self.admission.drop_at
                and spec.tier > self._min_tier(spec.model_index)
            ):
                self.metrics.record_admission(spec.name, DROP)
                return DROP
        if level >= 1 and not self.resolution_flexible[spec.model_index]:
            # shape-specialized model: record the shed intent but keep the
            # frame intact (level 2 still reroutes; level 1 becomes a no-op)
            degraded_frame = frame
        elif level >= 1:
            degraded_frame = self.admission.degrade(frame)
        else:
            degraded_frame = frame
        if not ex.submit(si, degraded_frame, degrade=level):
            self.metrics.record_admission(spec.name, DROP)
            return DROP
        self.metrics.record_admission(spec.name, decision)
        return decision

    def _min_tier(self, model_index: int) -> int:
        """Highest priority (lowest tier number) among the model's streams."""
        return min(
            (s.tier for s in self.executor.streams if s.model_index == model_index), default=0
        )

    def tick(self):
        """One executor tick + metrics fold — the open-loop driver's unit
        of service (it never blocks on admission the way ``pump`` does)."""
        self.executor.tick()
        self._fold_completions()

    def finish(self):
        """Fold any unrecorded completions/ticks (end-of-run bookkeeping)."""
        self._fold_completions()

    def reset_metrics(self):
        """Start a fresh measurement window: discard recorded metrics and
        the wall clock, keep the executor's compiled/warmed state and plan.
        The warm-then-measure idiom for benches — warmup frames (compiles,
        cache fills) should not pollute goodput-under-SLO numbers."""
        ex = self.executor
        self._fold_completions()  # drop anything pending into the old window
        self._recorded = len(ex.completions)
        self._recorded_ticks = len(ex.tick_stats)
        self.metrics = ServeMetrics(
            [s.name for s in ex.streams],
            slos={s.name: s.slo for s in ex.streams if s.slo is not None},
        )
        if self.replanner is not None:
            self.replanner.slo_miss_fn = self.metrics.recent_slo_miss_rate
        self._t0 = None

    # -- closed-loop intake -------------------------------------------------

    def pump(self):
        """Move backlog into stream queues, ticking the executor whenever
        the chosen queue pushes back; then fold new completions."""
        while self._backlog:
            req = self._backlog[0]
            si = self._least_loaded_stream(req.model_index)
            if self.executor.submit(si, req.frame):
                self._backlog.popleft()
            else:
                self.executor.tick()  # backpressure: make room before retrying
        self._fold_completions()

    def drain(self):
        self.pump()
        self.executor.run_until_drained()
        self._fold_completions()
        return self.executor.outputs

    def _fold_completions(self):
        for c in self.executor.completions[self._recorded :]:
            self.metrics.record(
                c.stream, c.latency_s, degrade=c.degrade, batch=c.batch, held=c.held
            )
        self._recorded = len(self.executor.completions)
        for t in self.executor.tick_stats[self._recorded_ticks :]:
            self.metrics.record_tick(t)
        self._recorded_ticks = len(self.executor.tick_stats)

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        wall = (time.perf_counter() - self._t0) if self._t0 is not None else 0.0
        rep = self.metrics.report(wall)
        rep["dispatch"] = self.executor.dispatch
        rep["plan_revision"] = self.executor.plan_revision
        if self.replanner is not None:
            rep["replan"] = self.replanner.summary()
            rep["segments"] = segment_summary(self.executor.segment_obs)
        return rep
