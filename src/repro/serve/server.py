"""Multi-stream serving front end: request queue -> stream assignment ->
executor -> metrics.

``MultiStreamServer`` owns the planned ``StreamExecutor`` plus a global
request queue. Requests name a *model* (not a stream); the server assigns
each to the least-loaded stream bound to that model, pumps the executor
when queues back up, and folds completions into per-stream latency /
throughput metrics. This is the CPU-container stand-in for the paper's
DeepStream app: the same code drives TPU submeshes when the staged
models' ``place_fns`` put segments on real device subsets.

Pass a ``serve.Replanner`` to close the online re-planning loop: the
server wires it into the executor (profiled ticks feed the ``OnlineCost``
EMA, the drift detector hot-swaps plans at frame boundaries) and folds
its state — per-engine scales, drift, swap events — into ``report()``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

from ..core.pipeline import StagedModel
from ..core.plan_ir import PlanIR
from ..core.scheduler import NModelPlan
from .executor import StreamExecutor
from .metrics import ServeMetrics, segment_summary
from .replanner import Replanner
from .streams import StreamSpec


@dataclasses.dataclass
class Request:
    model_index: int
    frame: Any


class MultiStreamServer:
    def __init__(
        self,
        models: list[StagedModel],
        plan: PlanIR | NModelPlan | list,
        streams: list[StreamSpec],
        max_queue: int = 4,
        microbatch: int = 1,
        merge_batches: bool | list[bool] = False,
        place_fns=None,
        dispatch: str = "overlapped",
        jit_segments: bool = True,
        replanner: Replanner | None = None,
    ):
        self.executor = StreamExecutor(
            models,
            plan,
            streams,
            max_queue=max_queue,
            microbatch=microbatch,
            merge_batches=merge_batches,
            place_fns=place_fns,
            dispatch=dispatch,
            jit_segments=jit_segments,
        )
        self.replanner = replanner
        if replanner is not None:
            replanner.attach(self.executor)
        self.metrics = ServeMetrics([s.name for s in streams])
        self._backlog: deque[Request] = deque()
        self._recorded = 0
        self._recorded_ticks = 0
        self._t0: float | None = None

    # -- request intake -----------------------------------------------------

    def submit(self, model_index: int, frame: Any):
        """Enqueue one frame for a model; assignment + execution happen in
        ``pump``/``drain``. Starts the wall clock on first submission."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._backlog.append(Request(model_index, frame))

    def _least_loaded_stream(self, model_index: int) -> int:
        ex = self.executor
        best, best_depth = -1, None
        for si, s in enumerate(ex.streams):
            if s.model_index != model_index:
                continue
            depth = len(ex.queues[si])
            if best_depth is None or depth < best_depth:
                best, best_depth = si, depth
        if best < 0:
            raise ValueError(f"no stream serves model index {model_index}")
        return best

    def pump(self):
        """Move backlog into stream queues, ticking the executor whenever
        the chosen queue pushes back; then fold new completions."""
        while self._backlog:
            req = self._backlog[0]
            si = self._least_loaded_stream(req.model_index)
            if self.executor.submit(si, req.frame):
                self._backlog.popleft()
            else:
                self.executor.tick()  # backpressure: make room before retrying
        self._fold_completions()

    def drain(self):
        self.pump()
        self.executor.run_until_drained()
        self._fold_completions()
        return self.executor.outputs

    def _fold_completions(self):
        for c in self.executor.completions[self._recorded :]:
            self.metrics.record(c.stream, c.latency_s)
        self._recorded = len(self.executor.completions)
        for t in self.executor.tick_stats[self._recorded_ticks :]:
            self.metrics.record_tick(t)
        self._recorded_ticks = len(self.executor.tick_stats)

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        wall = (time.perf_counter() - self._t0) if self._t0 is not None else 0.0
        rep = self.metrics.report(wall)
        rep["dispatch"] = self.executor.dispatch
        rep["plan_revision"] = self.executor.plan_revision
        if self.replanner is not None:
            rep["replan"] = self.replanner.summary()
            rep["segments"] = segment_summary(self.executor.segment_obs)
        return rep
