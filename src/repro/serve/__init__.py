# Multi-stream serving: N staged models over E engines with K frame streams,
# planned through the segment-level PlanIR and re-planned live by the
# drift-watching Replanner.
from .demo import build_pix_yolo_serving, build_replanner, merge_flags_for
from .executor import Completion, Flight, SegmentObservation, StreamExecutor, SwapEvent
from .metrics import (
    ServeMetrics,
    StreamMetrics,
    SwapStall,
    TickStats,
    overlap_summary,
    percentile,
    segment_summary,
    swap_stall_summary,
)
from .replanner import ReplanConfig, ReplanEvent, Replanner
from .server import MultiStreamServer, Request
from .streams import FrameQueue, StreamSpec
