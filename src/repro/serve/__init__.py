# Multi-stream serving: N staged models over E engines with K frame streams.
from .demo import build_pix_yolo_serving, merge_flags_for
from .executor import Completion, Flight, StreamExecutor
from .metrics import ServeMetrics, StreamMetrics, TickStats, overlap_summary, percentile
from .server import MultiStreamServer, Request
from .streams import FrameQueue, StreamSpec
