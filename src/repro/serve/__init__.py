# Multi-stream serving: N staged models over E engines with K frame streams,
# planned through the segment-level PlanIR and re-planned live by the
# drift-watching Replanner. `build_server` is the one-call facade; the
# open-loop pieces (traffic, SLOs, admission) live in .traffic/.admission.
from .admission import ADMIT, DROP, SHED_RES, SHED_ROUTE, AdmissionConfig, subsample_frame
from .batching import BatchConfig, bucket_for
from .demo import build_pix_yolo_serving, build_replanner, merge_flags_for
from .executor import Completion, Flight, SegmentObservation, StreamExecutor, SwapEvent
from .facade import ServerBundle, build_server
from .fleet import FleetRouter, FleetServer, LocalReplica
from .metrics import (
    ServeMetrics,
    StreamMetrics,
    SwapStall,
    TickStats,
    TierMetrics,
    engine_wait_summary,
    fleet_report,
    merge_metrics,
    metrics_from_payload,
    overlap_summary,
    percentile,
    router_imbalance,
    segment_summary,
    swap_stall_summary,
)
from .multiproc import (
    ProcFleetServer,
    RemoteReplica,
    ShmRing,
    WorkerDied,
    WorkerError,
    WorkerTimeout,
    merge_calibration,
)
from .replanner import ReplanConfig, ReplanEvent, Replanner
from .server import MultiStreamServer, Request
from .streams import FrameQueue, StreamSpec
from .traffic import (
    SLOPolicy,
    TrafficConfig,
    arrival_times,
    merged_arrivals,
    run_open_loop,
)
