# Multi-stream serving: N staged models over E engines with K frame streams.
from .demo import build_pix_yolo_serving
from .executor import Completion, Flight, StreamExecutor
from .metrics import ServeMetrics, StreamMetrics, percentile
from .server import MultiStreamServer, Request
from .streams import FrameQueue, StreamSpec
