"""Deadline-aware continuous batching configuration.

``BatchConfig`` is the one knob bundle behind the serving stack's
cross-stream coalescer (``StreamExecutor._admit``): ``max_batch`` bounds
the bucket ladder (powers of two, the shapes the executor pre-compiles
batched executables for), ``hold_ms`` caps how long a partial bucket may
wait for co-riders, and ``min_slack_factor`` is the deadline-safety
margin — a frame only waits when its SLO slack exceeds that multiple of
the expected batched service time plus the hold window, so batching
never converts a meetable deadline into a miss. ``max_batch=1`` (the
default) disables coalescing entirely and the executor is bit-identical
to the pre-batching behaviour.
"""
from __future__ import annotations

import dataclasses


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest power-of-two bucket >= ``n``, capped at ``max_batch``."""
    n = max(int(n), 1)
    b = 1
    while b < n and b < max_batch:
        b <<= 1
    return min(b, max_batch) if n <= max_batch else max_batch


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    """Continuous-batching policy for the serving executor.

    * ``max_batch`` — largest coalesced flight (1 disables batching).
    * ``hold_ms`` — longest a partial bucket may hold for more frames.
    * ``min_slack_factor`` — a member may only hold when its SLO slack
      exceeds ``min_slack_factor * expected_batched_service + hold``.
    """

    max_batch: int = 1
    hold_ms: float = 0.0
    min_slack_factor: float = 1.5

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.hold_ms < 0:
            raise ValueError(f"hold_ms must be >= 0, got {self.hold_ms}")
        if self.min_slack_factor < 0:
            raise ValueError(
                f"min_slack_factor must be >= 0, got {self.min_slack_factor}"
            )

    @property
    def enabled(self) -> bool:
        return self.max_batch > 1

    @property
    def hold_s(self) -> float:
        return self.hold_ms * 1e-3

    @property
    def buckets(self) -> tuple[int, ...]:
        """The bucket ladder: powers of two up to ``max_batch`` (always
        including ``max_batch`` itself so every admissible group has an
        exact executable)."""
        out = []
        b = 1
        while b < self.max_batch:
            out.append(b)
            b <<= 1
        out.append(self.max_batch)
        return tuple(out)

    def bucket_for(self, n: int) -> int:
        return bucket_for(n, self.max_batch)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict | None) -> "BatchConfig":
        return cls(**d) if d else cls()
