"""Generic tick-based multi-stream executor.

Generalizes the two-model HaX-CoNN swap pipeline: N staged models, each
with a planner-assigned route of (engine, lo, hi) segments, fed by K
bounded per-stream frame queues. One *tick* is one steady-state cycle:

  * every in-flight frame advances exactly one route segment (deepest
    stage first — the double-buffered counter-phase), then
  * each model admits up to ``microbatch`` queued frames (round-robin
    over its streams) into stage 0.

With N=2 and one stream per model this reproduces ``TwoModelPipeline``'s
schedule tick-for-tick (pinned by test). On real hardware the per-engine
segment calls dispatch asynchronously; on CPU they serialize but stay
functionally identical — single-frame flights run the exact same op
sequence as ``StagedModel.run_all``, so outputs are bit-exact.

Micro-batching (``microbatch > 1``) admits up to that many same-model
frames per tick so an engine runs one model's segment back-to-back for
the whole group (one engine switch per group — what micro-batching buys
on real hardware) while keeping every frame's math unchanged. With
``merge_batches=True`` the group is additionally concatenated along the
leading axis and the route runs once for the merged state; outputs are
sliced back per frame. Only enable merging for batch-independent models —
Pix2Pix's ``BatchNorm2D`` takes statistics over the batch axis, so
merging changes its outputs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.pipeline import StagedModel, TickLog
from ..core.scheduler import ModelRoute, NModelPlan
from .streams import FrameQueue, StreamSpec


@dataclasses.dataclass
class FlightMember:
    stream_index: int
    frame_id: int
    size: int  # leading-axis extent of this frame in the (possibly merged) state
    t_submit: float
    tick_submit: int


@dataclasses.dataclass
class Flight:
    model_index: int
    members: list[FlightMember]
    state: Any
    stage: int  # segments already executed


@dataclasses.dataclass
class Completion:
    stream: str
    frame_id: int
    output: Any
    tick_submit: int
    tick_done: int
    latency_s: float  # wall-clock submit -> completion


class StreamExecutor:
    """Drives N staged models over their planned routes for K streams."""

    def __init__(
        self,
        models: list[StagedModel],
        routes: list[ModelRoute] | NModelPlan,
        streams: list[StreamSpec],
        max_queue: int = 8,
        microbatch: int = 1,
        merge_batches: bool = False,
        place_fns: list[Callable] | None = None,
        engine_names: list[str] | None = None,
        model_labels: list[str] | None = None,
    ):
        if isinstance(routes, NModelPlan):
            if engine_names is None:
                engine_names = list(routes.schedule.engines)
            routes = routes.routes
        if len(models) != len(routes):
            raise ValueError(f"{len(models)} models but {len(routes)} routes")
        for m, r in zip(models, routes):
            hi = 0
            for _, lo, seg_hi in r.segments:
                if lo != hi:
                    raise ValueError(f"route for {m.name} is not contiguous at {lo}")
                hi = seg_hi
            if hi != len(m.ops):
                raise ValueError(f"route for {m.name} covers [0,{hi}) but model has {len(m.ops)} ops")
        for s in streams:
            if not 0 <= s.model_index < len(models):
                raise ValueError(f"stream {s.name} references unknown model {s.model_index}")
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        self.models = models
        self.routes = routes
        self.streams = streams
        self.microbatch = microbatch
        self.merge_batches = merge_batches
        n_engines = max(e for r in routes for e, _, _ in r.segments) + 1
        self.place_fns = place_fns or [lambda x: x] * n_engines
        self.engine_names = engine_names or [f"E{i}" for i in range(n_engines)]
        self.model_labels = model_labels or [m.name for m in models]
        self.queues = [FrameQueue(max_queue) for _ in streams]
        self.in_flight: list[Flight] = []
        self.completions: list[Completion] = []
        self.outputs: dict[str, list] = {s.name: [] for s in streams}
        self.log: list[TickLog] = []
        self.tick_count = 0
        self._frame_ids = [0] * len(streams)
        self._rr = [0] * len(models)  # round-robin cursor per model
        self._streams_of = [
            [i for i, s in enumerate(streams) if s.model_index == m] for m in range(len(models))
        ]
        self._max_stages = max(len(r.segments) for r in routes)

    # -- submission ---------------------------------------------------------

    def submit(self, stream: int | str, frame: Any) -> bool:
        """Queue a frame on a stream; False = queue full (backpressure)."""
        si = stream if isinstance(stream, int) else self._stream_index(stream)
        fid = self._frame_ids[si]
        if not self.queues[si].push((fid, frame, time.perf_counter())):
            return False
        self._frame_ids[si] += 1
        return True

    def _stream_index(self, name: str) -> int:
        for i, s in enumerate(self.streams):
            if s.name == name:
                return i
        raise KeyError(f"unknown stream {name!r}")

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues) + sum(len(f.members) for f in self.in_flight)

    # -- execution ----------------------------------------------------------

    def _run_segment(self, flight: Flight):
        model = self.models[flight.model_index]
        eng, lo, hi = self.routes[flight.model_index].segments[flight.stage]
        state = self.place_fns[eng](flight.state)
        flight.state = model.run_segment(state, lo, hi)
        flight.stage += 1
        ids = ",".join(str(m.frame_id) for m in flight.members)
        self.log.append(
            TickLog(
                self.tick_count,
                self.engine_names[eng],
                f"{self.model_labels[flight.model_index]}[{lo}:{hi})#f{ids}",
            )
        )

    def _complete(self, flight: Flight):
        model = self.models[flight.model_index]
        out = model.finalize(flight.state)
        now = time.perf_counter()
        if len(flight.members) == 1:
            sliced = [out]
        else:
            off, sliced = 0, []
            for m in flight.members:
                o = off
                sliced.append(jax.tree.map(lambda a, o=o, n=m.size: a[o : o + n], out))
                off += m.size
        for m, o in zip(flight.members, sliced):
            name = self.streams[m.stream_index].name
            self.outputs[name].append(o)
            self.completions.append(
                Completion(
                    stream=name,
                    frame_id=m.frame_id,
                    output=o,
                    tick_submit=m.tick_submit,
                    tick_done=self.tick_count,
                    latency_s=now - m.t_submit,
                )
            )

    def _admit(self, mi: int):
        model = self.models[mi]
        stream_idxs = self._streams_of[mi]
        if not stream_idxs:
            return
        picked: list[tuple[int, int, Any, float]] = []
        n = len(stream_idxs)
        start = self._rr[mi]
        for k in range(n):
            if len(picked) >= self.microbatch:
                break
            si = stream_idxs[(start + k) % n]
            if len(self.queues[si]):
                fid, frame, t_sub = self.queues[si].pop()
                picked.append((si, fid, frame, t_sub))
        if not picked:
            return
        self._rr[mi] = (start + len(picked)) % n
        members, states = [], []
        for si, fid, frame, t_sub in picked:
            size = int(frame.shape[0]) if hasattr(frame, "shape") and frame.shape else 1
            members.append(FlightMember(si, fid, size, t_sub, self.tick_count))
            states.append(model.init_state(frame))
        if self.merge_batches and len(states) > 1:
            merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *states)
            flights = [Flight(model_index=mi, members=members, state=merged, stage=0)]
        else:
            flights = [
                Flight(model_index=mi, members=[m], state=s, stage=0)
                for m, s in zip(members, states)
            ]
        for flight in flights:
            self._run_segment(flight)
            if flight.stage == len(self.routes[mi].segments):
                self._complete(flight)
            else:
                self.in_flight.append(flight)

    def tick(self):
        """One steady-state cycle: advance every in-flight frame one
        segment (deepest first), then admit new frames into stage 0."""
        for stage in range(self._max_stages - 1, 0, -1):
            for mi in range(len(self.models)):
                for flight in [
                    f for f in self.in_flight if f.model_index == mi and f.stage == stage
                ]:
                    self._run_segment(flight)
                    if flight.stage == len(self.routes[mi].segments):
                        self._complete(flight)
                        self.in_flight.remove(flight)
        for mi in range(len(self.models)):
            self._admit(mi)
        self.tick_count += 1

    def run_until_drained(self, max_ticks: int = 100000):
        while self.pending:
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"executor did not drain within {max_ticks} ticks")
            self.tick()
        return self.outputs
