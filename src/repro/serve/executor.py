"""Generic tick-based multi-stream executor with overlapped dispatch and
live plan hot-swap.

Generalizes the two-model HaX-CoNN swap pipeline: N staged models, each
with a planner-assigned route of ``PlanSegment``s (layer span + engine
binding), fed by K bounded per-stream frame queues. The executor consumes
*only* the typed ``core.plan_ir.PlanIR`` — scheduler results
(``NModelPlan``, ``HaxConnResult``) and legacy ``ModelRoute`` lists are
normalized to an IR at construction, and nothing downstream reaches into
scheduler internals. Plan spans are *layer* indices: on fine-granularity
(expanded-graph) models the ``StagedModel`` maps each span to its
sub-block stage executables (``op_spans``), so cuts inside composite
blocks stage and run exactly like coarse cuts — spans that don't land on
stage boundaries are rejected at staging time. One *tick* is one
steady-state cycle in two phases:

  * **issue** — every in-flight frame advances exactly one route segment
    (deepest stage first — the double-buffered counter-phase), then each
    model admits up to ``microbatch`` queued frames (round-robin over its
    streams) into stage 0. In the default ``dispatch="overlapped"`` mode
    the segment computations are only *dispatched* (JAX async dispatch):
    the host keeps issuing the other engines' segments while earlier ones
    compute, so counter-phased engines genuinely overlap. With
    ``jit_segments=True`` (the default) each (model, span) segment is
    additionally fused into one jitted executable — one dispatch per
    engine call instead of one per op — with the state buffers donated on
    backends that support donation, so a segment writes in place. XLA
    fusion may flip low-order bits vs the eager op sequence; pass
    ``jit_segments=False`` for the bit-exact-vs-``run_all`` baseline.
  * **resolve** — frames whose route finished are completed: the host
    blocks on the finalized outputs (the only synchronization point of
    the tick), slices merged groups apart, and stamps latencies.

**Plan hot-swap** (the online re-planning runtime): ``swap_plan(new_ir)``
replaces the active plan at a frame boundary — between ticks, or at the
end of the tick that called it. Each flight snapshots its route at
admission, so in-flight frames finish on the plan they started under
while new admissions take the new routes: zero dropped frames, no
ordering change, and (routes being a pure re-orchestration of the same
op sequence) outputs equal to an unswapped run. ``prepare_plan(new_ir)``
pre-executes the new plan's segment executables on zero-filled states of
the shapes seen so far — the double-buffered staged-weights warmup that
keeps compilation off the hot path before the swap.

**Per-segment observation**: with ``profile_every=k``, every k-th tick is
a *profiled* tick — each segment call is individually synchronized and
its wall time recorded as a ``SegmentObservation`` (and pushed to the
``on_segment`` callback). That is the live cost feedback the
``serve.replanner`` folds into its ``OnlineCost`` EMA; non-profiled ticks
keep full overlap. ``segment_delay_fn`` injects an extra per-segment cost
on its engine (perturbation harness for the recovery benchmark): stalls
accrue per engine and the tick pays the slowest engine's total once,
overlapped with the async compute — a slowed *parallel* engine looks
exactly like this — while profiled observations report the engine-virtual
wall (compute + stall) so the drift detector sees the slowdown.

``dispatch="serialized"`` instead synchronizes after *every* segment
call — the pre-overlap behaviour kept as the measurable baseline. Both
modes run the exact same op sequence per frame as ``StagedModel.run_all``.
Per-tick host wall/blocked time is recorded in ``tick_stats`` (see
``metrics.TickStats.overlap_efficiency``).

Micro-batching (``microbatch > 1``) admits up to that many same-model
frames per tick; with ``merge_batches`` the group is concatenated along
the leading axis and the route runs once for the merged state (only for
batch-independent models — see ``Pix2PixConfig(norm="instance")``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.pipeline import StagedModel, TickLog
from ..core.plan_ir import PlanIR, PlanSegment, ir_from_routes
from ..core.scheduler import NModelPlan
from .batching import BatchConfig
from .metrics import TickStats
from .streams import FrameQueue, StreamSpec


@dataclasses.dataclass
class FlightMember:
    stream_index: int
    frame_id: int
    size: int  # leading-axis extent of this frame in the (possibly merged) state
    t_submit: float
    tick_submit: int
    degrade: int = 0  # admission degrade level (0 none, 1 resolution, 2 route)


@dataclasses.dataclass
class Flight:
    model_index: int
    members: list[FlightMember]
    state: Any
    stage: int  # segments already executed
    route: tuple[PlanSegment, ...]  # snapshot of the plan at admission
    revision: int  # plan revision the flight was admitted under
    degrade: int = 0  # level 2 flights run the degraded (single-segment) route
    valid: int = 0  # real frames in the (possibly padded) state; 0 = all
    bucket: int = 0  # padded leading-axis extent (the compiled bucket); 0 = valid
    held: bool = False  # the coalescer delayed this flight waiting for co-riders
    t_issue: float = 0.0  # admission wall clock (feeds the service-time EMA)


@dataclasses.dataclass
class Completion:
    stream: str
    frame_id: int
    output: Any
    tick_submit: int
    tick_done: int
    latency_s: float  # wall-clock submit -> completion
    degrade: int = 0  # admission degrade level the frame ran under
    batch: int = 1  # real frames in the flight this frame rode in (occupancy)
    held: bool = False  # the flight was held by the coalescer before running


@dataclasses.dataclass(frozen=True)
class SegmentObservation:
    """One profiled segment execution — the executor's live cost signal."""

    tick: int
    model_index: int
    stage: int
    engine: int
    lo: int
    hi: int
    wall_s: float  # dispatch + sync wall time of this segment call
    batch: int  # leading-axis frames in the flight (merged groups > 1)
    revision: int  # plan revision the segment ran under
    impl: str = "xla"  # implementation variant the segment ran with
    bucket: int = 0  # padded bucket the segment executed at (0 = batch)


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    tick: int
    revision: int
    partitions: tuple[int, ...]  # first cut per model (legacy view)
    expected_cycle: float
    cuts: tuple[tuple[int, ...], ...] = ()  # full k-cut vectors per model


def _leading(state) -> int:
    """Leading-axis extent of a state pytree (the executed batch bucket)."""
    leaves = jax.tree.leaves(state)
    if not leaves:
        return 1
    shape = jnp.shape(leaves[0]) if not hasattr(leaves[0], "shape") else leaves[0].shape
    return int(shape[0]) if shape else 1


def _as_plan_ir(plan, engine_names=None) -> PlanIR:
    """Normalize every accepted plan form to the IR contract."""
    if isinstance(plan, PlanIR):
        return plan
    if isinstance(plan, NModelPlan):
        return plan.ir
    if hasattr(plan, "ir") and isinstance(getattr(plan, "ir"), PlanIR):
        return plan.ir  # HaxConnResult / Schedule
    return ir_from_routes(plan, engine_names=engine_names)


class StreamExecutor:
    """Drives N staged models over their planned routes for K streams."""

    def __init__(
        self,
        models: list[StagedModel],
        plan: PlanIR | NModelPlan | list,
        streams: list[StreamSpec],
        max_queue: int = 8,
        microbatch: int = 1,
        merge_batches: bool | list[bool] = False,
        place_fns: list[Callable] | None = None,
        engine_names: list[str] | None = None,
        model_labels: list[str] | None = None,
        dispatch: str = "overlapped",
        jit_segments: bool = True,
        profile_every: int = 0,
        on_segment: Callable[[SegmentObservation], None] | None = None,
        segment_delay_fn: Callable[[PlanSegment], float] | None = None,
        batching: BatchConfig | None = None,
    ):
        ir = _as_plan_ir(plan, engine_names)
        if len(models) != ir.n_models:
            raise ValueError(f"{len(models)} models but plan routes {ir.n_models}")
        ir.validate_against([m.n_layers for m in models])
        self._check_span_staging(ir, models)
        for s in streams:
            if not 0 <= s.model_index < len(models):
                raise ValueError(f"stream {s.name} references unknown model {s.model_index}")
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        if dispatch not in ("overlapped", "serialized"):
            raise ValueError(f"dispatch must be 'overlapped' or 'serialized', got {dispatch!r}")
        if profile_every < 0:
            raise ValueError("profile_every must be >= 0 (0 = no segment profiling)")
        self.models = models
        self.plan = ir
        self.streams = streams
        self.microbatch = microbatch
        self.dispatch = dispatch
        if isinstance(merge_batches, bool):
            self.merge_batches = [merge_batches] * len(models)
        else:
            if len(merge_batches) != len(models):
                raise ValueError(f"{len(merge_batches)} merge flags but {len(models)} models")
            self.merge_batches = list(merge_batches)
        n_engines = ir.n_engines
        self.place_fns = place_fns or [lambda x: x] * n_engines
        self.engine_names = list(engine_names) if engine_names else list(ir.engine_names)
        self.model_labels = model_labels or [m.name for m in models]
        self.queues = [FrameQueue(max_queue) for _ in streams]
        self.in_flight: list[Flight] = []
        self.completions: list[Completion] = []
        self.outputs: dict[str, list] = {s.name: [] for s in streams}
        self.log: list[TickLog] = []
        self.tick_stats: list[TickStats] = []
        self.tick_count = 0
        self._frame_ids = [0] * len(streams)
        self._rr = [0] * len(models)  # round-robin cursor per model
        self._streams_of = [
            [i for i, s in enumerate(streams) if s.model_index == m] for m in range(len(models))
        ]
        self._blocked_s = 0.0  # block_until_ready time inside the current tick
        self._segments_issued = 0
        # live cost feedback + re-planning hooks
        self.profile_every = profile_every
        self.on_segment = on_segment
        self.on_tick: Callable[["StreamExecutor"], None] | None = None
        self.segment_delay_fn = segment_delay_fn
        self._tick_delay: dict[int, float] = {}  # engine -> accrued stall this tick
        self.segment_obs: list[SegmentObservation] = []
        self.swap_events: list[SwapEvent] = []
        self._profiling_tick = False
        # stage-0 state structs seen per model (for prepare_plan warmups)
        self._state_structs: dict[int, list] = {m: [] for m in range(len(models))}
        self.jit_segments = jit_segments
        # donation needs backend support; the CPU client ignores donated
        # buffers (and warns), so only donate segment state buffers off-CPU
        self._donate = jax.default_backend() not in ("cpu",)
        # keyed by (model, lo, hi, impl, bucket): hot-swapped plans whose
        # spans (and implementation bindings) coincide with an old plan's
        # reuse the same (possibly compiled) runner; the bucket key gives
        # every batch size its own warmed executable so steady-state
        # batched serving never recompiles
        self._seg_fns: dict[tuple[int, int, int, str, int], Callable] = {}
        # degraded single-segment routes, keyed (model, plan revision)
        self._degraded_routes: dict[tuple[int, int], tuple[PlanSegment, ...]] = {}
        # per-model stream admission order: strictly tier-first (round-robin
        # within a tier); identical to plain round-robin when no stream
        # carries an SLO, so closed-loop behaviour is unchanged
        self._tiers = [s.tier for s in streams]
        # continuous batching (coalescer) state
        self.batching = batching or BatchConfig()
        self._hold_since: dict[int, float] = {}  # model -> wall clock hold start
        self._held_pending: set[int] = set()  # models with a hold in progress
        # observed admission->completion service time EMA per (model, bucket):
        # the self-calibrating "expected batched segment time" the hold
        # decision compares slack against
        self._svc_ema: dict[tuple[int, int], float] = {}
        # per-engine host-time breakdown for the current tick (satellite
        # diagnostic): engine index -> [issue_s, transfer_s, resolve_s]
        self._wait_acc: dict[int, list[float]] = {}

    # -- submission ---------------------------------------------------------

    def submit(self, stream: int | str, frame: Any, degrade: int = 0) -> bool:
        """Queue a frame on a stream; False = queue full (backpressure).

        ``degrade`` is the admission controller's degrade level: level-1
        frames were resolution-shed upstream (they only opt out of merge
        batching — their shapes differ), level-2 frames run the degraded
        single-segment route instead of the plan's."""
        si = stream if isinstance(stream, int) else self._stream_index(stream)
        fid = self._frame_ids[si]
        if not self.queues[si].push((fid, frame, time.perf_counter(), degrade)):
            return False
        self._frame_ids[si] += 1
        return True

    def _stream_index(self, name: str) -> int:
        for i, s in enumerate(self.streams):
            if s.name == name:
                return i
        raise KeyError(f"unknown stream {name!r}")

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues) + sum(len(f.members) for f in self.in_flight)

    def queue_pressure(self, model_index: int | None = None) -> float:
        """Aggregate queue fill fraction in [0, 1] — the admission
        controller's and re-planner's load signal. Restricted to one
        model's streams when ``model_index`` is given."""
        qs = [
            q
            for si, q in enumerate(self.queues)
            if model_index is None or self.streams[si].model_index == model_index
        ]
        cap = sum(q.maxdepth for q in qs)
        return sum(len(q) for q in qs) / cap if cap else 0.0

    # -- plan hot-swap ------------------------------------------------------

    @property
    def plan_revision(self) -> int:
        return self.plan.revision

    @staticmethod
    def _check_span_staging(ir: PlanIR, models):
        """Reject plans whose spans can't stage before any frame runs:
        on fine-granularity models every route segment — however many
        cuts the plan takes — must start and end on stage-callable
        boundaries (``StagedModel.check_route``)."""
        for mi, segs in enumerate(ir.segments):
            models[mi].check_route([(s.lo, s.hi) for s in segs])

    def swap_plan(self, new_ir: PlanIR) -> int:
        """Install a new plan at the next frame boundary (new admissions).

        In-flight frames keep their admission-time route snapshots, so the
        swap drops nothing and changes no frame's op sequence — only where
        future segments run. Returns the new plan revision.
        """
        if tuple(new_ir.models) != tuple(self.plan.models):
            raise ValueError(
                f"swap changes the model set {self.plan.models} -> {new_ir.models}"
            )
        if new_ir.n_engines > len(self.place_fns):
            raise ValueError(
                f"swap needs {new_ir.n_engines} engines but executor has {len(self.place_fns)}"
            )
        new_ir.validate_against([m.n_layers for m in self.models])
        self._check_span_staging(new_ir, self.models)
        rev = self.plan.revision + 1
        self.plan = new_ir.with_revision(rev)
        self.swap_events.append(
            SwapEvent(
                tick=self.tick_count,
                revision=rev,
                partitions=tuple(new_ir.partitions),
                expected_cycle=new_ir.expected_cycle,
                cuts=new_ir.cuts,
            )
        )
        self.log.append(TickLog(self.tick_count, "*", f"swap->rev{rev} cuts={list(new_ir.cuts)}"))
        return rev

    def prepare_plan(self, new_ir: PlanIR) -> int:
        """Warm the new plan's segment executables off the hot path.

        For every stage-0 state shape seen so far, abstractly threads the
        state through the new routes and runs each segment once on zeros —
        seeding the jit caches (double-buffered executables: the old
        plan's stay valid for in-flight frames). Returns the number of
        segment executions warmed; silently skips models that have not
        seen a frame yet.
        """
        new_ir.validate_against([m.n_layers for m in self.models])
        self._check_span_staging(new_ir, self.models)
        warmed = 0
        for mi, segs in enumerate(new_ir.segments):
            model = self.models[mi]
            for _, struct in self._state_structs[mi]:
                for bstruct in self._warm_structs(mi, struct):
                    bucket = _leading(bstruct)
                    state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), bstruct)
                    for seg in segs:
                        impl = getattr(seg, "impl", "xla")
                        key = (mi, seg.lo, seg.hi, impl, bucket)
                        if key not in self._seg_fns:
                            self._seg_fns[key] = self._make_runner(mi, seg.lo, seg.hi, impl)
                        state = self._seg_fns[key](model.params, state)
                        warmed += 1
                    jax.block_until_ready(state)
        return warmed

    def _warm_structs(self, mi: int, struct):
        """The state structs a plan warmup must compile for: the seen
        struct itself plus — for models the coalescer may batch — every
        bucket-scaled variant of its single-frame shapes, so a plan swap
        lands with all bucket executables warm and steady-state batched
        serving never compiles on the hot path."""
        out = [struct]
        bc = self.batching
        if bc.enabled and self.merge_batches[mi] and _leading(struct) == 1:
            for b in bc.buckets:
                if b == 1:
                    continue
                out.append(
                    jax.tree.map(
                        lambda s, b=b: jax.ShapeDtypeStruct((b,) + tuple(s.shape[1:]), s.dtype),
                        struct,
                    )
                )
        return out

    # -- execution ----------------------------------------------------------

    def _block(self, x, engine: int | None = None):
        """block_until_ready with the wait charged to this tick's stats
        (and, when ``engine`` is given, to that engine's resolve-wait in
        the per-engine breakdown)."""
        t0 = time.perf_counter()
        x = jax.block_until_ready(x)
        dt = time.perf_counter() - t0
        self._blocked_s += dt
        if engine is not None:
            self._charge_wait(engine, 2, dt)
        return x

    def _charge_wait(self, engine: int, slot: int, dt: float):
        """Accrue host time to one engine's (issue, transfer, resolve)
        breakdown for the current tick."""
        acc = self._wait_acc.get(engine)
        if acc is None:
            acc = self._wait_acc[engine] = [0.0, 0.0, 0.0]
        acc[slot] += dt

    def _make_runner(self, mi: int, lo: int, hi: int, impl: str = "xla") -> Callable:
        model = self.models[mi]
        if self.jit_segments:
            # cached on the model: executors over the same span share one
            # compiled executable per (segment, impl, shape)
            return model.jitted_segment_fn(lo, hi, donate=self._donate, impl=impl)
        return model.segment_fn(lo, hi, impl=impl)

    def _degraded_route(self, mi: int) -> tuple[PlanSegment, ...]:
        """The model's shed-staging route: the whole layer span as one
        coarse segment on the engine already carrying most of its planned
        work (fewest hand-offs, no inter-engine transfers — the minimum
        service-time fallback admission control escalates to). Always
        stage-legal: [0, n_layers) starts and ends on stage boundaries."""
        key = (mi, self.plan.revision)
        route = self._degraded_routes.get(key)
        if route is None:
            segs = self.plan.route(mi)
            load: dict[int, float] = {}
            for s in segs:
                load[s.engine] = load.get(s.engine, 0.0) + s.expected_cost
            eng = max(load, key=lambda e: (load[e], -e))
            route = (
                PlanSegment(
                    model_index=mi,
                    stage=0,
                    engine=eng,
                    lo=0,
                    hi=segs[-1].hi,
                    expected_cost=sum(s.expected_cost for s in segs),
                ),
            )
            self._degraded_routes[key] = route
        return route

    def _segment_runner(self, mi: int, seg: PlanSegment, bucket: int = 1) -> Callable:
        impl = getattr(seg, "impl", "xla")
        key = (mi, seg.lo, seg.hi, impl, bucket)
        fn = self._seg_fns.get(key)
        if fn is None:
            fn = self._make_runner(mi, seg.lo, seg.hi, impl)
            self._seg_fns[key] = fn
        return fn

    def _run_segment(self, flight: Flight):
        """Issue one route segment for a flight. In overlapped mode this
        only dispatches the computation (async); serialized mode waits for
        it. Profiled ticks synchronize per segment to stamp a wall-time
        observation (the live cost feedback)."""
        seg = flight.route[flight.stage]
        eng = seg.engine
        t0 = time.perf_counter()
        state = self.place_fns[eng](flight.state)
        t1 = time.perf_counter()
        self._charge_wait(eng, 1, t1 - t0)
        bucket = flight.bucket or flight.valid or _leading(state)
        flight.state = self._segment_runner(flight.model_index, seg, bucket)(
            self.models[flight.model_index].params, state
        )
        self._charge_wait(eng, 0, time.perf_counter() - t1)
        d = 0.0
        if self.segment_delay_fn is not None:
            d = self.segment_delay_fn(seg)
            if d > 0:
                # simulated engine slowdown: engines stall concurrently on
                # real hardware, so the stall accrues to this engine's
                # per-tick total (paid as max over engines at tick end)
                # instead of sleeping inline, which would serialize
                # stalls that genuinely overlap
                self._tick_delay[eng] = self._tick_delay.get(eng, 0.0) + d
        flight.stage += 1
        self._segments_issued += 1
        ids = ",".join(str(m.frame_id) for m in flight.members)
        self.log.append(
            TickLog(
                self.tick_count,
                self.engine_names[eng],
                f"{self.model_labels[flight.model_index]}[{seg.lo}:{seg.hi})#f{ids}",
            )
        )
        if self._profiling_tick:
            self._block(flight.state, engine=eng)
            obs = SegmentObservation(
                tick=self.tick_count,
                model_index=flight.model_index,
                stage=seg.stage,
                engine=eng,
                lo=seg.lo,
                hi=seg.hi,
                # the engine-virtual wall: what this span costs on its
                # (possibly slowed) engine
                wall_s=time.perf_counter() - t0 + d,
                batch=sum(m.size for m in flight.members),
                revision=flight.revision,
                impl=getattr(seg, "impl", "xla"),
                bucket=bucket,
            )
            self.segment_obs.append(obs)
            if self.on_segment is not None:
                self.on_segment(obs)
        elif self.dispatch == "serialized":
            self._block(flight.state, engine=eng)

    def _complete(self, flight: Flight):
        model = self.models[flight.model_index]
        last_eng = flight.route[-1].engine if flight.route else None
        out = self._block(model.finalize(flight.state), engine=last_eng)
        now = time.perf_counter()
        valid = flight.valid or sum(m.size for m in flight.members)
        if flight.t_issue:
            # fold this flight's admission->completion wall into the
            # per-(model, bucket) service EMA the coalescer's hold
            # decision consults
            key = (flight.model_index, flight.bucket or valid)
            svc = now - flight.t_issue
            prev = self._svc_ema.get(key)
            self._svc_ema[key] = svc if prev is None else 0.7 * prev + 0.3 * svc
        if len(flight.members) == 1 and not (flight.bucket and flight.bucket > valid):
            sliced = [out]
        else:
            # padded lanes (bucket > valid) fall off here: member slices
            # only ever index [0, valid), so the zero-filled pad rows are
            # never observable in any completion — bit-exactness vs
            # per-frame execution is a slicing invariant, not a masking op
            off, sliced = 0, []
            for m in flight.members:
                o = off
                sliced.append(jax.tree.map(lambda a, o=o, n=m.size: a[o : o + n], out))
                off += m.size
        for m, o in zip(flight.members, sliced):
            name = self.streams[m.stream_index].name
            self.outputs[name].append(o)
            self.completions.append(
                Completion(
                    stream=name,
                    frame_id=m.frame_id,
                    output=o,
                    tick_submit=m.tick_submit,
                    tick_done=self.tick_count,
                    latency_s=now - m.t_submit,
                    degrade=m.degrade,
                    batch=valid,
                    held=flight.held,
                )
            )

    def _note_state_struct(self, mi: int, state):
        struct = jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), state)
        flat, treedef = jax.tree.flatten(struct)
        key = (treedef, tuple((s.shape, s.dtype) for s in flat))
        known = self._state_structs[mi]
        if key not in [k for k, _ in known]:
            known.append((key, struct))

    def expected_service(self, mi: int, bucket: int) -> float:
        """Observed admission->completion wall EMA for (model, bucket) —
        the coalescer's self-calibrating estimate of what riding a batch
        of that size costs. Falls back to the largest smaller bucket seen
        (batched service is monotone-ish in bucket), 0.0 before any
        observation (hold decisions then bound only by the hold window)."""
        t = self._svc_ema.get((mi, bucket))
        if t is not None:
            return t
        seen = [b for (m, b), _ in self._svc_ema.items() if m == mi and b < bucket]
        return self._svc_ema[(mi, max(seen))] if seen else 0.0

    def _should_hold(self, mi: int, cands: list[tuple[int, tuple]], now: float) -> bool:
        """The slack-driven hold decision for a partial bucket: wait for
        co-riders only when *every* waiting member's SLO slack clears the
        expected batched service time (scaled by ``min_slack_factor``)
        plus the full hold window — so a hold can never turn a meetable
        deadline into a miss — and the hold window has not expired. Any
        degraded candidate or an empty window admits immediately (under
        queue pressure the caller has already filled the bucket, so high
        load never holds and batching never costs goodput)."""
        bc = self.batching
        if bc.hold_s <= 0.0:
            return False
        started = self._hold_since.get(mi)
        if started is not None and now - started >= bc.hold_s:
            return False  # window expired: admit what we have
        if any(item[3] > 0 for _, item in cands):
            return False  # degraded frames never wait on a merge they can't join
        total = sum(
            int(item[1].shape[0]) if hasattr(item[1], "shape") and item[1].shape else 1
            for _, item in cands
        )
        t_b = self.expected_service(mi, bc.bucket_for(total))
        floor = bc.min_slack_factor * t_b + bc.hold_s
        for si, item in cands:
            slo = self.streams[si].slo
            if slo is None:
                continue
            slack = slo.deadline_s - (now - item[2])
            if slack <= floor:
                return False
        return True

    def _admit(self, mi: int) -> list[Flight]:
        """Admit queued frames for model ``mi`` into stage 0 of the
        *current* plan; returns the flights that already finished their
        route (single-segment models). Streams are drained strictly
        tier-first (SLO priority); within a tier the oldest waiting head
        goes first (age tiebreak — a stream can no longer lose the
        microbatch cut forever to rotation phasing), falling back to
        round-robin order on equal ages. With no SLOs attached every tier
        is 0 and fresh frames tie, so closed-loop behaviour is unchanged.

        With an enabled ``BatchConfig`` and a batch-independent model
        (``merge_batches``), admission becomes the cross-stream
        coalescer: up to ``max_batch`` clean frames from any of the
        model's streams merge into one flight, padded to the power-of-two
        bucket; a partial bucket may *hold* (frames stay queued) while
        every member's slack allows it — see ``_should_hold``."""
        model = self.models[mi]
        stream_idxs = self._streams_of[mi]
        if not stream_idxs:
            return []
        bc = self.batching
        coalesce = bc.enabled and self.merge_batches[mi]
        cap = bc.max_batch if coalesce else self.microbatch
        n = len(stream_idxs)
        start = self._rr[mi]
        rotated = [stream_idxs[(start + k) % n] for k in range(n)]
        now = time.perf_counter()

        def head_age(si: int) -> float:
            q = self.queues[si]
            return now - q.peek()[2] if len(q) else -1.0

        # stable: (tier, oldest-head-first), rr order breaking exact ties
        rotated.sort(key=lambda si: (self._tiers[si], -head_age(si)))
        # candidate collection peeks without popping: a held bucket's
        # frames must stay queued (and keep aging) until admission.
        # Coalescing drains multiple frames per stream (greedy bucket
        # fill under queue pressure); classic admission keeps the one-
        # frame-per-stream round-robin cut.
        cands: list[tuple[int, tuple]] = []
        if coalesce:
            pos = {si: 0 for si in rotated}
            progress = True
            while len(cands) < cap and progress:
                progress = False
                for si in rotated:
                    if len(cands) >= cap:
                        break
                    if pos[si] < len(self.queues[si]):
                        cands.append((si, self.queues[si].peek(pos[si])))
                        pos[si] += 1
                        progress = True
        else:
            for si in rotated:
                if len(cands) >= cap:
                    break
                if len(self.queues[si]):
                    cands.append((si, self.queues[si].peek()))
        if not cands:
            return []
        held = mi in self._held_pending
        if coalesce and len(cands) < cap and self._should_hold(mi, cands, now):
            if mi not in self._hold_since:
                self._hold_since[mi] = now
            self._held_pending.add(mi)
            return []
        self._hold_since.pop(mi, None)
        self._held_pending.discard(mi)
        picked: list[tuple[int, int, Any, float, int]] = []
        for si, _ in cands:
            fid, frame, t_sub, degrade = self.queues[si].pop()
            picked.append((si, fid, frame, t_sub, degrade))
        self._rr[mi] = (start + len(picked)) % n
        members, states = [], []
        for si, fid, frame, t_sub, degrade in picked:
            size = int(frame.shape[0]) if hasattr(frame, "shape") and frame.shape else 1
            members.append(FlightMember(si, fid, size, t_sub, self.tick_count, degrade=degrade))
            states.append(model.init_state(frame))
        route = self.plan.route(mi)
        rev = self.plan.revision
        # Degraded frames never merge: level-1 frames have shed shapes,
        # level-2 frames run the degraded route, both incompatible with a
        # concatenated full-route group.
        clean = [(m, s) for m, s in zip(members, states) if m.degrade == 0]
        shed = [(m, s) for m, s in zip(members, states) if m.degrade > 0]
        if self.merge_batches[mi] and len(clean) > 1:
            merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *(s for _, s in clean))
            total = sum(m.size for m, _ in clean)
            bucket = bc.bucket_for(total) if coalesce else total
            if bucket > total:
                # pad to the compiled bucket with zero lanes; _complete
                # slices members out of [0, total) so the pads are never
                # observable (bit-exact vs per-frame execution)
                merged = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [a, jnp.zeros((bucket - total,) + a.shape[1:], a.dtype)], axis=0
                    ),
                    merged,
                )
            flights = [
                Flight(
                    model_index=mi,
                    members=[m for m, _ in clean],
                    state=merged,
                    stage=0,
                    route=route,
                    revision=rev,
                    valid=total,
                    bucket=bucket,
                    held=held,
                )
            ]
        else:
            flights = [
                Flight(
                    model_index=mi,
                    members=[m],
                    state=s,
                    stage=0,
                    route=route,
                    revision=rev,
                    valid=m.size,
                    bucket=m.size,
                    held=held and m.degrade == 0,
                )
                for m, s in clean
            ]
        for m, s in shed:
            flights.append(
                Flight(
                    model_index=mi,
                    members=[m],
                    state=s,
                    stage=0,
                    route=self._degraded_route(mi) if m.degrade >= 2 else route,
                    revision=rev,
                    degrade=m.degrade,
                    valid=m.size,
                    bucket=m.size,
                )
            )
        for flight in flights:
            self._note_state_struct(mi, flight.state)
            flight.t_issue = time.perf_counter()
        done = []
        for flight in flights:
            self._run_segment(flight)
            if flight.stage == len(flight.route):
                done.append(flight)
            else:
                self.in_flight.append(flight)
        return done

    def tick(self):
        """One steady-state cycle. Issue phase: advance every in-flight
        frame one segment (deepest first), then admit new frames into
        stage 0 — all dispatched without waiting in overlapped mode.
        Resolve phase: block on (only) the frames whose route finished."""
        t_start = time.perf_counter()
        self._blocked_s = 0.0
        self._segments_issued = 0
        self._wait_acc = {}
        self._profiling_tick = self.profile_every > 0 and self.tick_count % self.profile_every == 0
        if self._profiling_tick and self.in_flight:
            # drain the async dispatch queue before timing anything: without
            # this barrier the first profiled segment absorbs the previous
            # tick's in-flight work and its wall time is attributed to the
            # wrong (model, engine, span) — poisoning the cost calibration
            for f in self.in_flight:
                last = f.route[min(f.stage, len(f.route) - 1)].engine if f.route else None
                self._block(f.state, engine=last)
        done: list[Flight] = []
        # deepest stage first; route lengths may differ across plan
        # revisions, so the depth bound comes from the live flights
        max_stages = max((len(f.route) for f in self.in_flight), default=1)
        for stage in range(max_stages - 1, 0, -1):
            for mi in range(len(self.models)):
                for flight in [
                    f for f in self.in_flight if f.model_index == mi and f.stage == stage
                ]:
                    self._run_segment(flight)
                    if flight.stage == len(flight.route):
                        done.append(flight)
                        self.in_flight.remove(flight)
        for mi in range(len(self.models)):
            done.extend(self._admit(mi))
        if self._tick_delay:
            # pay the slowest engine's accrued stall once per tick, before
            # resolving: concurrent engines' stalls overlap each other and
            # the still-async dispatched compute
            time.sleep(max(self._tick_delay.values()))
            self._tick_delay.clear()
        for flight in done:
            self._complete(flight)
        self.tick_stats.append(
            TickStats(
                tick=self.tick_count,
                wall_s=time.perf_counter() - t_start,
                blocked_s=self._blocked_s,
                segments=self._segments_issued,
                engine_wait={
                    self.engine_names[e]: tuple(acc) for e, acc in self._wait_acc.items()
                }
                or None,
            )
        )
        self.tick_count += 1
        if self.on_tick is not None:
            # frame boundary: the replanner's chance to observe drift and
            # hot-swap before the next admission
            self.on_tick(self)

    def run_until_drained(self, max_ticks: int = 100000):
        while self.pending:
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"executor did not drain within {max_ticks} ticks")
            self.tick()
        return self.outputs

    def overlap_efficiency(self) -> float:
        """Aggregate fraction of tick time the host was not blocked."""
        from .metrics import overlap_summary

        return overlap_summary(self.tick_stats)["overlap_efficiency"]
