"""Generic tick-based multi-stream executor with overlapped dispatch.

Generalizes the two-model HaX-CoNN swap pipeline: N staged models, each
with a planner-assigned route of (engine, lo, hi) segments, fed by K
bounded per-stream frame queues. One *tick* is one steady-state cycle in
two phases:

  * **issue** — every in-flight frame advances exactly one route segment
    (deepest stage first — the double-buffered counter-phase), then each
    model admits up to ``microbatch`` queued frames (round-robin over its
    streams) into stage 0. In the default ``dispatch="overlapped"`` mode
    the segment computations are only *dispatched* (JAX async dispatch):
    the host keeps issuing the other engines' segments while earlier ones
    compute, so counter-phased engines genuinely overlap. With
    ``jit_segments=True`` each (model, stage) segment is additionally
    fused into one jitted executable — one dispatch per engine call
    instead of one per op — with the state buffers donated on backends
    that support donation (shapes permitting), so a segment writes in
    place.
  * **resolve** — frames whose route finished are completed: the host
    blocks on the finalized outputs (the only synchronization point of
    the tick), slices merged groups apart, and stamps latencies.

``dispatch="serialized"`` instead synchronizes after *every* segment
call — each engine call completes before the next is issued, the
pre-overlap behaviour kept as the measurable baseline. Both modes run
the exact same op sequence per frame as ``StagedModel.run_all``, so
outputs are bit-exact vs the monolithic models and identical across
modes (pinned by test). Per-tick host wall/blocked time is recorded in
``tick_stats`` (see ``metrics.TickStats.overlap_efficiency``).

Micro-batching (``microbatch > 1``) admits up to that many same-model
frames per tick so an engine runs one model's segment back-to-back for
the whole group (one engine switch per group — what micro-batching buys
on real hardware) while keeping every frame's math unchanged. With
``merge_batches`` (a bool for all models or one flag per model) the
group is additionally concatenated along the leading axis and the route
runs once for the merged state; outputs are sliced back per frame. Only
enable merging for batch-independent models — Pix2Pix's ``BatchNorm2D``
takes statistics over the batch axis, so merging changes its outputs
(use ``Pix2PixConfig(norm="instance")`` for a batch-independent variant).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.pipeline import StagedModel, TickLog
from ..core.scheduler import ModelRoute, NModelPlan
from .metrics import TickStats
from .streams import FrameQueue, StreamSpec


@dataclasses.dataclass
class FlightMember:
    stream_index: int
    frame_id: int
    size: int  # leading-axis extent of this frame in the (possibly merged) state
    t_submit: float
    tick_submit: int


@dataclasses.dataclass
class Flight:
    model_index: int
    members: list[FlightMember]
    state: Any
    stage: int  # segments already executed


@dataclasses.dataclass
class Completion:
    stream: str
    frame_id: int
    output: Any
    tick_submit: int
    tick_done: int
    latency_s: float  # wall-clock submit -> completion


class StreamExecutor:
    """Drives N staged models over their planned routes for K streams."""

    def __init__(
        self,
        models: list[StagedModel],
        routes: list[ModelRoute] | NModelPlan,
        streams: list[StreamSpec],
        max_queue: int = 8,
        microbatch: int = 1,
        merge_batches: bool | list[bool] = False,
        place_fns: list[Callable] | None = None,
        engine_names: list[str] | None = None,
        model_labels: list[str] | None = None,
        dispatch: str = "overlapped",
        jit_segments: bool = False,
    ):
        if isinstance(routes, NModelPlan):
            if engine_names is None:
                engine_names = list(routes.schedule.engines)
            routes = routes.routes
        if len(models) != len(routes):
            raise ValueError(f"{len(models)} models but {len(routes)} routes")
        for m, r in zip(models, routes):
            hi = 0
            for _, lo, seg_hi in r.segments:
                if lo != hi:
                    raise ValueError(f"route for {m.name} is not contiguous at {lo}")
                hi = seg_hi
            if hi != len(m.ops):
                raise ValueError(f"route for {m.name} covers [0,{hi}) but model has {len(m.ops)} ops")
        for s in streams:
            if not 0 <= s.model_index < len(models):
                raise ValueError(f"stream {s.name} references unknown model {s.model_index}")
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        if dispatch not in ("overlapped", "serialized"):
            raise ValueError(f"dispatch must be 'overlapped' or 'serialized', got {dispatch!r}")
        self.models = models
        self.routes = routes
        self.streams = streams
        self.microbatch = microbatch
        self.dispatch = dispatch
        if isinstance(merge_batches, bool):
            self.merge_batches = [merge_batches] * len(models)
        else:
            if len(merge_batches) != len(models):
                raise ValueError(f"{len(merge_batches)} merge flags but {len(models)} models")
            self.merge_batches = list(merge_batches)
        n_engines = max(e for r in routes for e, _, _ in r.segments) + 1
        self.place_fns = place_fns or [lambda x: x] * n_engines
        self.engine_names = engine_names or [f"E{i}" for i in range(n_engines)]
        self.model_labels = model_labels or [m.name for m in models]
        self.queues = [FrameQueue(max_queue) for _ in streams]
        self.in_flight: list[Flight] = []
        self.completions: list[Completion] = []
        self.outputs: dict[str, list] = {s.name: [] for s in streams}
        self.log: list[TickLog] = []
        self.tick_stats: list[TickStats] = []
        self.tick_count = 0
        self._frame_ids = [0] * len(streams)
        self._rr = [0] * len(models)  # round-robin cursor per model
        self._streams_of = [
            [i for i, s in enumerate(streams) if s.model_index == m] for m in range(len(models))
        ]
        self._max_stages = max(len(r.segments) for r in routes)
        self._blocked_s = 0.0  # block_until_ready time inside the current tick
        self._segments_issued = 0
        # jit fuses each route segment into one executable (one dispatch per
        # engine call instead of one per op). Off by default: XLA fusion may
        # flip low-order bits vs the eager op sequence, and the executor's
        # baseline contract is bit-exactness vs StagedModel.run_all.
        self.jit_segments = jit_segments
        # donation needs backend support; the CPU client ignores donated
        # buffers (and warns), so only donate segment state buffers off-CPU
        self._donate = jax.default_backend() not in ("cpu",)
        self._seg_fns: dict[tuple[int, int], Callable] = {}

    # -- submission ---------------------------------------------------------

    def submit(self, stream: int | str, frame: Any) -> bool:
        """Queue a frame on a stream; False = queue full (backpressure)."""
        si = stream if isinstance(stream, int) else self._stream_index(stream)
        fid = self._frame_ids[si]
        if not self.queues[si].push((fid, frame, time.perf_counter())):
            return False
        self._frame_ids[si] += 1
        return True

    def _stream_index(self, name: str) -> int:
        for i, s in enumerate(self.streams):
            if s.name == name:
                return i
        raise KeyError(f"unknown stream {name!r}")

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues) + sum(len(f.members) for f in self.in_flight)

    # -- execution ----------------------------------------------------------

    def _block(self, x):
        """block_until_ready with the wait charged to this tick's stats."""
        t0 = time.perf_counter()
        x = jax.block_until_ready(x)
        self._blocked_s += time.perf_counter() - t0
        return x

    def _segment_runner(self, mi: int, stage: int) -> Callable:
        key = (mi, stage)
        fn = self._seg_fns.get(key)
        if fn is None:
            model = self.models[mi]
            _, lo, hi = self.routes[mi].segments[stage]
            if self.jit_segments:
                # cached on the model: executors over the same route share
                # one compiled executable per (segment, shape)
                fn = model.jitted_segment_fn(lo, hi, donate=self._donate)
            else:
                fn = model.segment_fn(lo, hi)
            self._seg_fns[key] = fn
        return fn

    def _run_segment(self, flight: Flight):
        """Issue one route segment for a flight. In overlapped mode this
        only dispatches the computation (async); serialized mode waits for
        it — the per-engine-call sync the refactor removed."""
        model = self.models[flight.model_index]
        eng, lo, hi = self.routes[flight.model_index].segments[flight.stage]
        state = self.place_fns[eng](flight.state)
        flight.state = self._segment_runner(flight.model_index, flight.stage)(model.params, state)
        flight.stage += 1
        self._segments_issued += 1
        ids = ",".join(str(m.frame_id) for m in flight.members)
        self.log.append(
            TickLog(
                self.tick_count,
                self.engine_names[eng],
                f"{self.model_labels[flight.model_index]}[{lo}:{hi})#f{ids}",
            )
        )
        if self.dispatch == "serialized":
            self._block(flight.state)

    def _complete(self, flight: Flight):
        model = self.models[flight.model_index]
        out = self._block(model.finalize(flight.state))
        now = time.perf_counter()
        if len(flight.members) == 1:
            sliced = [out]
        else:
            off, sliced = 0, []
            for m in flight.members:
                o = off
                sliced.append(jax.tree.map(lambda a, o=o, n=m.size: a[o : o + n], out))
                off += m.size
        for m, o in zip(flight.members, sliced):
            name = self.streams[m.stream_index].name
            self.outputs[name].append(o)
            self.completions.append(
                Completion(
                    stream=name,
                    frame_id=m.frame_id,
                    output=o,
                    tick_submit=m.tick_submit,
                    tick_done=self.tick_count,
                    latency_s=now - m.t_submit,
                )
            )

    def _admit(self, mi: int) -> list[Flight]:
        """Admit queued frames for model ``mi`` into stage 0; returns the
        flights that already finished their route (single-segment models)."""
        model = self.models[mi]
        stream_idxs = self._streams_of[mi]
        if not stream_idxs:
            return []
        picked: list[tuple[int, int, Any, float]] = []
        n = len(stream_idxs)
        start = self._rr[mi]
        for k in range(n):
            if len(picked) >= self.microbatch:
                break
            si = stream_idxs[(start + k) % n]
            if len(self.queues[si]):
                fid, frame, t_sub = self.queues[si].pop()
                picked.append((si, fid, frame, t_sub))
        if not picked:
            return []
        self._rr[mi] = (start + len(picked)) % n
        members, states = [], []
        for si, fid, frame, t_sub in picked:
            size = int(frame.shape[0]) if hasattr(frame, "shape") and frame.shape else 1
            members.append(FlightMember(si, fid, size, t_sub, self.tick_count))
            states.append(model.init_state(frame))
        if self.merge_batches[mi] and len(states) > 1:
            merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *states)
            flights = [Flight(model_index=mi, members=members, state=merged, stage=0)]
        else:
            flights = [
                Flight(model_index=mi, members=[m], state=s, stage=0)
                for m, s in zip(members, states)
            ]
        done = []
        for flight in flights:
            self._run_segment(flight)
            if flight.stage == len(self.routes[mi].segments):
                done.append(flight)
            else:
                self.in_flight.append(flight)
        return done

    def tick(self):
        """One steady-state cycle. Issue phase: advance every in-flight
        frame one segment (deepest first), then admit new frames into
        stage 0 — all dispatched without waiting in overlapped mode.
        Resolve phase: block on (only) the frames whose route finished."""
        t_start = time.perf_counter()
        self._blocked_s = 0.0
        self._segments_issued = 0
        done: list[Flight] = []
        for stage in range(self._max_stages - 1, 0, -1):
            for mi in range(len(self.models)):
                for flight in [
                    f for f in self.in_flight if f.model_index == mi and f.stage == stage
                ]:
                    self._run_segment(flight)
                    if flight.stage == len(self.routes[mi].segments):
                        done.append(flight)
                        self.in_flight.remove(flight)
        for mi in range(len(self.models)):
            done.extend(self._admit(mi))
        for flight in done:
            self._complete(flight)
        self.tick_stats.append(
            TickStats(
                tick=self.tick_count,
                wall_s=time.perf_counter() - t_start,
                blocked_s=self._blocked_s,
                segments=self._segments_issued,
            )
        )
        self.tick_count += 1

    def run_until_drained(self, max_ticks: int = 100000):
        while self.pending:
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"executor did not drain within {max_ticks} ticks")
            self.tick()
        return self.outputs

    def overlap_efficiency(self) -> float:
        """Aggregate fraction of tick time the host was not blocked."""
        from .metrics import overlap_summary

        return overlap_summary(self.tick_stats)["overlap_efficiency"]
