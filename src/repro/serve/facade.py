"""``build_server`` — the one-call serving facade.

The pieces of the serving stack (staged models, the unified
``repro.core.plan`` scheduler, stream specs with SLO policies, admission
control, open-loop traffic, and the online re-planner) compose freely,
but every driver was re-assembling them by hand. ``build_server`` builds
the whole stack for the repo's reference workload (Pix2Pix
reconstruction + YOLOv8 detection on the calibrated Jetson engine pair)
and returns a ``ServerBundle`` holding each layer, so CLIs, examples,
benchmarks, and tests drive one construction path:

    bundle = build_server(n_pix=4, n_yolo=1, deadline_ms=50.0,
                          traffic=TrafficConfig(process="poisson", rate_hz=30),
                          admission=True)
    report = bundle.run_open_loop(horizon_s=2.0)

Unlike ``build_pix_yolo_serving`` (kept for ``NModelPlan`` callers), the
facade plans through ``repro.core.plan`` and carries the ``PlanIR``
contract end-to-end — including ``max_cuts="auto"`` budget escalation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from ..core.api import plan as core_plan
from ..core.cost_model import CostProvider, OnlineCost, make_cost_provider
from ..core.engine import DevicePool
from ..core.plan_ir import PlanIR
from .admission import AdmissionConfig
from .batching import BatchConfig
from .demo import _build_pix_yolo_models, merge_flags_for
from .fleet import FleetServer
from .multiproc import ProcFleetServer
from .replanner import ReplanConfig, Replanner
from .server import MultiStreamServer
from .streams import StreamSpec
from .traffic import SLOPolicy, TrafficConfig, run_open_loop


@dataclasses.dataclass
class ServerBundle:
    """Every layer of one constructed serving stack, plus drivers.

    ``traffic`` maps stream name -> ``TrafficConfig`` (empty when built
    without open-loop traffic); ``replanner``/``admission`` are None when
    those layers are off."""

    models: list
    plan: PlanIR
    streams: list[StreamSpec]
    engines: tuple  # planning order: (dla, gpu)
    provider: CostProvider
    server: MultiStreamServer | FleetServer | ProcFleetServer
    replanner: Replanner | None
    admission: AdmissionConfig | None
    traffic: dict[str, TrafficConfig]
    img: int = 64
    replicas: int = 1
    workers: int = 0

    def frame_for(self, stream_name: str, t: int = 0):
        """A deterministic input frame for the named stream (seeded by
        stream identity + frame index) — the default open-loop source."""
        si = next(i for i, s in enumerate(self.streams) if s.name == stream_name)
        return jax.random.normal(jax.random.key(1000 * si + t), (1, self.img, self.img, 3))

    def run_open_loop(
        self,
        horizon_s: float,
        frame_fn: Callable[[str], Any] | None = None,
        drain: bool = True,
        max_wall_s: float | None = None,
    ) -> dict:
        """Drive the server with the bundle's traffic processes for
        ``horizon_s`` seconds of arrival time; returns ``server.report()``."""
        if not self.traffic:
            raise ValueError("bundle was built without traffic; pass traffic= to build_server")
        if frame_fn is None:
            counts: dict[str, int] = {}

            def frame_fn(name: str):
                t = counts.get(name, 0)
                counts[name] = t + 1
                return self.frame_for(name, t)

        return run_open_loop(
            self.server, self.traffic, frame_fn, horizon_s, drain=drain, max_wall_s=max_wall_s
        )

    def report(self) -> dict:
        return self.server.report()

    def close(self):
        """Release server resources — shuts down the worker processes of a
        multi-process fleet; a no-op for in-process servers."""
        close = getattr(self.server, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "ServerBundle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _normalize_slos(slos, deadline_ms, streams: list[StreamSpec]):
    """Resolve the facade's SLO inputs to one policy (or None) per stream.

    ``slos`` may be a single ``SLOPolicy`` (every stream), a dict keyed by
    stream name or model index, or None. ``deadline_ms`` is the shorthand:
    one deadline for all streams, detection streams (model 1) at tier 0
    and reconstruction streams at tier 1 — the paper's priority split."""
    if slos is None and deadline_ms is None:
        return [None] * len(streams)
    out = []
    for s in streams:
        if isinstance(slos, SLOPolicy):
            out.append(slos)
        elif isinstance(slos, dict):
            p = slos.get(s.name, slos.get(s.model_index))
            out.append(p)
        else:
            tier = 0 if s.model_index == 1 else 1
            out.append(SLOPolicy(deadline_ms=deadline_ms, tier=tier, name=f"{s.name}-slo"))
    return out


def _normalize_traffic(traffic, streams: list[StreamSpec]) -> dict[str, TrafficConfig]:
    """One ``TrafficConfig`` per stream: a single config fans out to every
    stream (re-seeded per stream so arrival processes are independent);
    a dict keyed by stream name passes through (missing names get no
    traffic)."""
    if traffic is None:
        return {}
    if isinstance(traffic, TrafficConfig):
        return {
            s.name: dataclasses.replace(traffic, seed=traffic.seed + si)
            for si, s in enumerate(streams)
        }
    unknown = set(traffic) - {s.name for s in streams}
    if unknown:
        raise ValueError(f"traffic for unknown streams: {sorted(unknown)}")
    return dict(traffic)


def build_server(
    *,
    # workload
    img: int = 64,
    base: int = 8,
    n_pix: int = 4,
    n_yolo: int = 1,
    seed: int = 0,
    norm: str = "batch",
    # planning (repro.core.plan)
    cost: str | CostProvider = "analytic",
    search: str = "auto",
    granularity: str = "coarse",
    stride: int = 1,
    max_cuts: int | str = 1,
    impl: str = "xla",
    # serving
    max_queue: int = 4,
    microbatch: int = 1,
    merge_batches: bool | list[bool] | None = None,
    batching: BatchConfig | int | None = None,
    dispatch: str = "overlapped",
    jit_segments: bool = True,
    # SLOs + open loop
    slos: SLOPolicy | dict | None = None,
    deadline_ms: float | None = None,
    traffic: TrafficConfig | dict[str, TrafficConfig] | None = None,
    admission: AdmissionConfig | bool | None = None,
    resolution_flexible: bool | list[bool] = False,
    # online re-planning
    replan: bool | ReplanConfig = False,
    # fleet replication
    replicas: int = 1,
    router_seed: int = 0,
    # multi-process fleet
    workers: int = 0,
    calibration_path: str | None = None,
    calib_sync_every: int = 16,
) -> ServerBundle:
    """Build the full serving stack in one call; see module docstring.

    ``merge_batches=None`` derives the per-model flags from batch
    independence (``merge_flags_for``). ``batching`` turns on the
    deadline-aware continuous-batching coalescer: pass a ``BatchConfig``
    or an int shorthand (``batching=8`` == ``BatchConfig(max_batch=8)``);
    it only engages on batch-independent models (``merge_batches``), so
    with the default ``norm="batch"`` pix2pix streams do not coalesce —
    use ``norm="instance"`` for the batched reconstruction workload.
    ``admission=True`` uses the default degradation ladder;
    ``replan=True`` the default ``ReplanConfig``. ``deadline_ms`` is the SLO shorthand (detection
    tier 0, reconstruction tier 1); pass ``slos`` for full control.
    ``impl`` selects the implementation-planning mode (``xla`` | ``auto``
    | ``pallas``); segments planned ``pallas_fused`` stage the fused
    serving kernels end-to-end.

    ``replicas > 1`` returns the bundle over a ``FleetServer``: R
    replicated (plan, executor) groups over a ``DevicePool`` behind a
    sticky load-aware ``FleetRouter``. The plan is solved once — over
    replica 0's engine slice, which is value-identical to every other
    slice (only the device binding differs) — and each replica gets its
    own ``Replanner``, all sharing one thread-safe ``OnlineCost`` so
    calibration is fleet-wide.

    ``workers > 0`` returns the bundle over a ``ProcFleetServer`` instead:
    R worker *processes*, each rebuilding the same replica group from the
    serialized plan, behind the same sticky router over IPC
    (``serve.multiproc``). Mutually exclusive with ``replicas > 1`` — one
    replica group per worker process. ``cost`` must then be a provider
    name (the build spec crosses the process boundary as JSON), and with
    ``replan`` on the workers' calibrations sync fleet-wide every
    ``calib_sync_every`` front ticks, checkpointing atomically to
    ``calibration_path`` (which also warm-starts workers on spawn). Call
    ``bundle.close()`` (or use the bundle as a context manager) to shut
    the workers down."""
    if workers and replicas > 1:
        raise ValueError(
            "workers and replicas are mutually exclusive: a multi-process fleet "
            "hosts one replica group per worker process"
        )
    if workers and not isinstance(cost, str):
        raise ValueError(
            "multi-process fleet needs a cost provider *name* (the build spec "
            f"crosses the process boundary as JSON), got {type(cost).__name__}"
        )
    provider = cost if isinstance(cost, CostProvider) else make_cost_provider(cost)
    models, streams, (gpu, dla) = _build_pix_yolo_models(
        img=img, base=base, n_pix=n_pix, n_yolo=n_yolo, seed=seed, norm=norm,
        granularity=granularity,
    )
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    pool = DevicePool((dla, gpu))
    # one plan serves every replica: slice 0's bound engines plan exactly
    # like the abstract pair (device binding is excluded from spec equality)
    plan_engines = list(pool.engine_slice(0, replicas)) if replicas > 1 else [dla, gpu]
    plan_ir = core_plan(
        [m.graph for m in models],
        plan_engines,
        search=search,
        stride=stride,
        max_cuts=max_cuts,
        cost=provider,
        impl=impl,
    )
    policies = _normalize_slos(slos, deadline_ms, streams)
    streams = [
        dataclasses.replace(s, slo=p) if p is not None else s
        for s, p in zip(streams, policies)
    ]
    if merge_batches is None:
        merge_batches = merge_flags_for(models)
    if isinstance(batching, int):
        batching = BatchConfig(max_batch=batching)
    if admission is True:
        admission = AdmissionConfig()
    elif admission is False:
        admission = None
    replanner = None
    replanners = None
    if replan and not workers:
        config = replan if isinstance(replan, ReplanConfig) else None
        if replicas > 1:
            # one shared OnlineCost: every replica's Replanner reuses the
            # instance (thread-safe drain), so all replicas' segment
            # observations feed a single fleet-wide calibration store
            shared = provider if isinstance(provider, OnlineCost) else OnlineCost(base=provider)
            replanners = [
                Replanner(
                    [m.graph for m in models], [dla, gpu], config=config, base_provider=shared
                )
                for _ in range(replicas)
            ]
            replanner = replanners[0]
        else:
            replanner = Replanner(
                [m.graph for m in models], [dla, gpu], config=config, base_provider=provider
            )
    if workers:
        # workers rebuild their replanners in-process; the front only
        # carries the serialized config (True -> worker-side default)
        replan_payload = None
        if replan:
            replan_payload = (
                dataclasses.asdict(replan) if isinstance(replan, ReplanConfig) else {}
            )
        server = ProcFleetServer(
            plan_ir,
            streams,
            workers=workers,
            build={
                "img": img, "base": base, "n_pix": n_pix, "n_yolo": n_yolo,
                "seed": seed, "norm": norm, "granularity": granularity,
            },
            router_seed=router_seed,
            max_queue=max_queue,
            microbatch=microbatch,
            merge_batches=merge_batches,
            batching=batching,
            dispatch=dispatch,
            jit_segments=jit_segments,
            admission=admission,
            resolution_flexible=resolution_flexible,
            cost=cost,
            replan=replan_payload,
            calibration_path=calibration_path,
            calib_sync_every=calib_sync_every,
        )
    elif replicas > 1:
        server = FleetServer(
            models,
            plan_ir,
            streams,
            replicas=replicas,
            pool=pool,
            router_seed=router_seed,
            max_queue=max_queue,
            microbatch=microbatch,
            merge_batches=merge_batches,
            batching=batching,
            dispatch=dispatch,
            jit_segments=jit_segments,
            replanners=replanners,
            admission=admission,
            resolution_flexible=resolution_flexible,
        )
    else:
        server = MultiStreamServer(
            models,
            plan_ir,
            streams,
            max_queue=max_queue,
            microbatch=microbatch,
            merge_batches=merge_batches,
            batching=batching,
            dispatch=dispatch,
            jit_segments=jit_segments,
            replanner=replanner,
            admission=admission,
            resolution_flexible=resolution_flexible,
        )
    return ServerBundle(
        models=models,
        plan=plan_ir,
        streams=streams,
        engines=(dla, gpu),
        provider=provider,
        server=server,
        replanner=replanner,
        admission=admission,
        traffic=_normalize_traffic(traffic, streams),
        img=img,
        replicas=replicas,
        workers=workers,
    )
