"""Open-loop traffic: seeded arrival processes, SLO policies, and the
real-time driver that offers frames to a ``MultiStreamServer``.

Closed-loop benches submit a fixed number of frames and drain — the
system never sees arrivals it does not control, so "FPS" says nothing
about deadline behaviour under load. This module generates *offered*
load: per-stream arrival times drawn from a deterministic, seedable
process, pushed at the server in real time regardless of whether it is
keeping up. Three processes:

* ``poisson`` — homogeneous Poisson at ``rate_hz`` (i.i.d. exponential
  gaps), the memoryless baseline.
* ``bursty``  — a two-state Markov-modulated Poisson process: the stream
  alternates between a *quiet* state at ``rate_hz`` and a *burst* state
  at ``rate_hz * burst_factor``, with exponentially distributed dwell
  times (``mean_quiet_s`` / ``mean_burst_s``). Mean offered rate exceeds
  ``rate_hz`` by the burst duty cycle — size deadlines accordingly.
* ``diurnal`` — an inhomogeneous Poisson whose rate ramps sinusoidally
  between ``floor * rate_hz`` and ``rate_hz`` with period ``period_s``
  (thinning construction), the slow load-swing that exercises the
  re-planner's load-pressure trigger.

All draws come from a private ``random.Random(seed)``, so a
``TrafficConfig`` is a complete, reproducible description of a stream's
offered load.

``SLOPolicy`` attaches the service objective a stream is admitted under:
a completion deadline (arrival -> output, queue wait included) and a
priority tier (0 = highest). Admission control and the executor's
tier-ordered admission use the tier; metrics bucket goodput by it.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Per-stream service-level objective: deadline + priority tier."""

    deadline_ms: float
    tier: int = 0  # 0 = highest priority; larger = shed/dropped first
    name: str = ""

    def __post_init__(self):
        if self.deadline_ms <= 0:
            raise ValueError("SLO deadline must be positive")
        if self.tier < 0:
            raise ValueError("SLO tier must be >= 0")

    @property
    def deadline_s(self) -> float:
        return self.deadline_ms / 1e3

    def met(self, latency_s: float) -> bool:
        return latency_s <= self.deadline_s


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """One stream's offered-load process (see module docstring)."""

    process: str = "poisson"  # poisson | bursty | diurnal
    rate_hz: float = 10.0
    seed: int = 0
    burst_factor: float = 4.0  # bursty: rate multiplier while bursting
    mean_burst_s: float = 0.5  # bursty: mean dwell in the burst state
    mean_quiet_s: float = 2.0  # bursty: mean dwell in the quiet state
    period_s: float = 10.0  # diurnal: ramp period
    floor: float = 0.25  # diurnal: trough rate as a fraction of rate_hz

    def __post_init__(self):
        if self.process not in ("poisson", "bursty", "diurnal"):
            raise ValueError(f"unknown traffic process {self.process!r}")
        if self.rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        if self.process == "bursty" and (
            self.burst_factor < 1 or self.mean_burst_s <= 0 or self.mean_quiet_s <= 0
        ):
            raise ValueError("bursty traffic needs burst_factor >= 1 and positive dwell times")
        if self.process == "diurnal" and not (0 < self.floor <= 1 and self.period_s > 0):
            raise ValueError("diurnal traffic needs 0 < floor <= 1 and a positive period")


def arrival_times(cfg: TrafficConfig, horizon_s: float) -> list[float]:
    """Deterministic arrival times in ``[0, horizon_s)`` for one stream.

    Same config (seed included) -> same times, on every platform: the
    generators consume the ``random.Random`` stream in a fixed order.
    """
    if horizon_s <= 0:
        return []
    rng = random.Random(cfg.seed)
    if cfg.process == "poisson":
        return _poisson(rng, cfg.rate_hz, horizon_s)
    if cfg.process == "bursty":
        return _bursty(rng, cfg, horizon_s)
    return _diurnal(rng, cfg, horizon_s)


def _poisson(rng: random.Random, rate_hz: float, horizon_s: float) -> list[float]:
    out, t = [], 0.0
    while True:
        t += rng.expovariate(rate_hz)
        if t >= horizon_s:
            return out
        out.append(t)


def _bursty(rng: random.Random, cfg: TrafficConfig, horizon_s: float) -> list[float]:
    out, t = [], 0.0
    burst = False  # start quiet: the burst arrives mid-run, not at t=0
    while t < horizon_s:
        dwell = rng.expovariate(1.0 / (cfg.mean_burst_s if burst else cfg.mean_quiet_s))
        rate = cfg.rate_hz * (cfg.burst_factor if burst else 1.0)
        end = min(t + dwell, horizon_s)
        while True:
            t += rng.expovariate(rate)
            if t >= end:
                break
            out.append(t)
        t = end
        burst = not burst
    return out


def _diurnal(rng: random.Random, cfg: TrafficConfig, horizon_s: float) -> list[float]:
    # Lewis-Shedler thinning against the peak rate: candidate arrivals at
    # rate_hz, each kept with probability lambda(t) / rate_hz.
    out, t = [], 0.0
    while True:
        t += rng.expovariate(cfg.rate_hz)
        if t >= horizon_s:
            return out
        lam = cfg.floor + (1.0 - cfg.floor) * 0.5 * (1.0 - math.cos(2.0 * math.pi * t / cfg.period_s))
        if rng.random() < lam:
            out.append(t)


def merged_arrivals(traffic: dict[str, TrafficConfig], horizon_s: float) -> list[tuple[float, str]]:
    """All streams' arrivals merged into one time-ordered (t, stream)
    schedule — what the open-loop driver walks. Ties break by stream name
    (insertion order is irrelevant: the schedule is fully determined by
    the configs)."""
    events = [
        (t, name) for name, cfg in traffic.items() for t in arrival_times(cfg, horizon_s)
    ]
    events.sort()
    return events


def run_open_loop(
    server,
    traffic: dict[str, TrafficConfig],
    frame_fn,
    horizon_s: float,
    drain: bool = True,
    max_wall_s: float | None = None,
):
    """Drive ``server`` with open-loop arrivals in real time.

    ``traffic`` maps stream names to their arrival processes;
    ``frame_fn(stream_name)`` produces each offered frame. Arrivals are
    offered when due (``server.offer`` — admission-controlled, never
    blocking); whenever work is pending the executor ticks, otherwise the
    driver sleeps to the next arrival. With ``drain=True`` (default) the
    run continues past the horizon until every admitted frame completes —
    an overloaded unbounded-queue configuration pays for its backlog in
    wall time and missed deadlines, which is exactly the comparison the
    goodput metrics make. ``max_wall_s`` is a safety bound on total wall
    time (RuntimeError when exceeded). Returns ``server.report()``.
    """
    events = merged_arrivals(traffic, horizon_s)
    t0 = time.perf_counter()
    i = 0
    while i < len(events) or (drain and server.executor.pending):
        now = time.perf_counter() - t0
        if max_wall_s is not None and now > max_wall_s:
            raise RuntimeError(f"open-loop run exceeded max_wall_s={max_wall_s}")
        while i < len(events) and events[i][0] <= now:
            name = events[i][1]
            server.offer(name, frame_fn(name))
            i += 1
        if server.executor.pending:
            server.tick()
        elif i < len(events):
            time.sleep(min(1e-3, max(0.0, events[i][0] - (time.perf_counter() - t0))))
    server.finish()
    return server.report()
