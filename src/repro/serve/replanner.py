"""Online re-planning control loop: drift detection + live plan hot-swap.

The paper's engine allocation is only optimal while the per-layer costs
it was planned against still hold; on real edge deployments they drift
with batch size, thermal state, and co-located load. The ``Replanner``
closes the loop:

  1. **observe** — the executor's profiled ticks emit per-segment wall
     times (``SegmentObservation``). Observations accumulate per (tick,
     engine) and fold into an ``OnlineCost`` EMA as one magnitude-weighted
     (engine -> sum observed / sum expected) ratio per profiled tick —
     big segments dominate, so host-overhead noise on near-empty spans
     cannot swing the scale. *Expected* is re-derived from the graphs
     under the base provider — a fixed base-units -> wall-clock
     calibration that survives plan swaps regardless of which provider
     scored the active plan.
  2. **detect** — after calibration (every engine seen ``warmup_obs``
     times), per-engine drift is the relative change of its scale vs the
     calibration snapshot. The detector requires ``hysteresis``
     consecutive ticks above ``drift_threshold`` (noise stays quiet) and
     ``cooldown_ticks`` between swaps (no thrashing).
  3. **re-plan** — the beam-search planner re-runs on the live-calibrated
     costs. The refreshed costs also re-score the *current* partitions
     (``fixed=`` evaluation), and the swap only happens if the new plan's
     predicted cycle beats that by ``min_improvement``.
  4. **swap** — ``executor.prepare_plan`` warms the new segment
     executables on zero states (off the hot path), then
     ``executor.swap_plan`` installs the plan at the frame boundary:
     in-flight frames finish on their admitted routes, zero drops.

``background=True`` runs step 3 *and* the ``prepare_plan`` warmup in a
worker thread on a snapshot of the scales — the hot loop only pays for
the swap itself (compile times dominate the stall on real accelerators);
the default is synchronous for deterministic tests. Either way the
per-swap hot-path stall is recorded as a ``metrics.SwapStall`` and
folded into ``summary()``. An ``OnlineCost`` whose scales were
warm-started from a calibration JSON (``--calibration-cache``) seeds the
drift baseline immediately — no warmup ticks needed after a restart.
Attach to any ``StreamExecutor`` via ``attach`` (sets ``profile_every``,
``on_segment``, ``on_tick``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Sequence

from ..core.cost_model import ANALYTIC, CostProvider, OnlineCost
from ..core.scheduler import nmodel_schedule
from .executor import SegmentObservation, StreamExecutor
from .metrics import SwapStall, swap_stall_summary


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the drift detector + re-plan loop (see module docstring)."""

    drift_threshold: float = 0.6  # relative scale change that counts as drift
    hysteresis: int = 3  # consecutive drifting ticks required to fire
    cooldown_ticks: int = 10  # min ticks between plan swaps
    min_improvement: float = 0.05  # predicted cycle gain required to swap
    ema_alpha: float = 0.25  # OnlineCost EMA coefficient
    warmup_obs: int = 8  # per-engine folded ticks before auto-calibration
    profile_every: int = 2  # executor segment-profiling cadence (ticks)
    search: str = "auto"  # planner search mode for re-plans
    beam_width: int = 64
    stride: int = 1  # candidate cut-point stride (match the initial plan's)
    background: bool = False  # plan + prepare in a worker thread (off the hot path)


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    tick: int
    drift: dict[str, float]
    old_partitions: tuple[int, ...]
    new_partitions: tuple[int, ...]
    old_cycle: float  # current partitions re-scored under live costs
    new_cycle: float  # candidate plan under live costs
    swapped: bool
    revision: int  # executor plan revision after the event


class Replanner:
    """Watches one executor's live segment costs and hot-swaps its plan."""

    def __init__(
        self,
        graphs: Sequence,
        engines: Sequence,
        config: ReplanConfig | None = None,
        base_provider: CostProvider | None = None,
        allow_fallback: bool = True,
    ):
        self.graphs = list(graphs)
        self.engines = list(engines)
        self.config = config or ReplanConfig()
        if isinstance(base_provider, OnlineCost):
            # reuse the caller's OnlineCost (e.g. --cost online planned the
            # initial routes with it) instead of double-wrapping: the same
            # instance then receives the live observations, so later
            # planning calls through the caller's handle see the scales
            self.online = base_provider
            self.online.alpha = self.config.ema_alpha
        else:
            self.online = OnlineCost(base_provider or ANALYTIC, alpha=self.config.ema_alpha)
        self.allow_fallback = allow_fallback
        self.events: list[ReplanEvent] = []
        self.swap_stalls: list[SwapStall] = []
        self._baseline: dict[str, float] = {}  # calibration snapshot of scales
        if self.online.calibrated([e.name for e in self.engines]):
            # warm-started scales (e.g. loaded from a calibration JSON):
            # baseline immediately instead of waiting out warmup_obs ticks
            self._baseline = self.online.snapshot()
        self._obs_count: dict[str, int] = {}
        self._tick_acc: dict[str, list[float]] = {}  # engine -> [wall, expected]
        self._above = 0  # consecutive drifting ticks (hysteresis counter)
        self._last_swap_tick: int | None = None
        self._expected_cache: dict[tuple[int, int, int, int], float] = {}
        self._job: threading.Thread | None = None
        self._job_result: list = []

    # -- wiring -------------------------------------------------------------

    def attach(self, executor: StreamExecutor) -> StreamExecutor:
        """Wire the feedback loop into an executor (observer + tick hook)."""
        if executor.plan.n_engines != len(self.engines):
            raise ValueError(
                f"replanner has {len(self.engines)} engines but plan uses {executor.plan.n_engines}"
            )
        executor.profile_every = max(1, self.config.profile_every)
        executor.on_segment = self.observe
        executor.on_tick = self.maybe_replan
        return executor

    # -- observation --------------------------------------------------------

    def _expected_base(self, model_index: int, engine: int, lo: int, hi: int) -> float:
        """Base-provider cost of graph[lo:hi) on the engine — the fixed
        denominator of the wall-clock calibration (never a scaled plan's
        expected_cost, which would drift with each re-plan)."""
        key = (model_index, engine, lo, hi)
        t = self._expected_cache.get(key)
        if t is None:
            g = self.graphs[model_index]
            e = self.engines[engine]
            t = sum(self.online.base.layer_time(g[i], e) for i in range(lo, hi))
            self._expected_cache[key] = t
        return t

    def observe(self, obs: SegmentObservation):
        """Accumulate one profiled segment into the current tick's
        per-engine (wall, expected) sums; ``_fold_tick`` turns each sum
        pair into one magnitude-weighted EMA sample at the frame boundary
        (per-segment ratios on near-empty spans are all host overhead —
        summing first keeps them from swinging the scale)."""
        expected = self._expected_base(obs.model_index, obs.engine, obs.lo, obs.hi)
        # merged flights run the span once for the whole group; normalize
        # to a per-frame observation so microbatching doesn't read as drift
        wall = obs.wall_s / max(obs.batch, 1)
        name = self.engines[obs.engine].name
        acc = self._tick_acc.setdefault(name, [0.0, 0.0])
        acc[0] += wall
        acc[1] += expected

    def _fold_tick(self):
        for name, (wall, expected) in self._tick_acc.items():
            self.online.observe(name, wall, expected)
            self._obs_count[name] = self._obs_count.get(name, 0) + 1
        self._tick_acc.clear()

    # -- drift detection ----------------------------------------------------

    @property
    def calibrated(self) -> bool:
        return bool(self._baseline)

    def _try_calibrate(self):
        names = [e.name for e in self.engines]
        if all(self._obs_count.get(n, 0) >= self.config.warmup_obs for n in names):
            self._baseline = self.online.snapshot()

    def drift(self) -> dict[str, float]:
        """Per-engine relative scale change vs the calibration snapshot."""
        if not self._baseline:
            return {}
        out = {}
        for name, base in self._baseline.items():
            cur = self.online.scale(name)
            out[name] = abs(cur / base - 1.0) if base > 0 else 0.0
        return out

    def _rebaseline(self):
        self._baseline = self.online.snapshot()
        self._above = 0

    def calibrate(self):
        """Snapshot the current scales as the drift baseline now — callers
        that control warmup (benches) use this right after it, once
        compile-time walls have washed out of the EMA, instead of waiting
        for ``warmup_obs`` folded ticks."""
        self._fold_tick()
        self._rebaseline()

    def load_calibration(self, path: str) -> "Replanner":
        """Warm-start from a persisted calibration (``--calibration-cache``):
        restore the per-engine EMA state into the ``OnlineCost`` and, when
        it covers every engine, seed the drift baseline from it — works
        regardless of which base provider the online calibrator wraps."""
        self.online.load_calibration(path)
        if self.online.calibrated([e.name for e in self.engines]):
            self._baseline = self.online.snapshot()
        return self

    # -- the control loop ---------------------------------------------------

    def _plan(self, online: OnlineCost):
        return nmodel_schedule(
            self.graphs,
            self.engines,
            allow_fallback=self.allow_fallback,
            provider=online,
            search=self.config.search,
            beam_width=self.config.beam_width,
            stride=self.config.stride,
        )

    def _score_fixed(self, partitions, online: OnlineCost) -> float:
        return nmodel_schedule(
            self.graphs,
            self.engines,
            allow_fallback=self.allow_fallback,
            fixed=tuple(partitions),
            provider=online,
        ).cycle_time

    def _snapshot_online(self) -> OnlineCost:
        snap = OnlineCost(self.online.base, alpha=self.online.alpha)
        snap._num = dict(self.online._num)
        snap._den = dict(self.online._den)
        return snap

    def maybe_replan(self, executor: StreamExecutor) -> ReplanEvent | None:
        """Called at every frame boundary (executor ``on_tick``)."""
        cfg = self.config
        self._fold_tick()
        if not self._baseline:
            self._try_calibrate()
            return None
        # harvest a finished background planning job first
        if self._job is not None:
            if self._job.is_alive():
                return None
            self._job = None
            if self._job_result:
                return self._finish(executor, *self._job_result.pop())
            return None
        d = self.drift()
        if d and max(d.values()) > cfg.drift_threshold:
            self._above += 1
        else:
            self._above = 0
            return None
        if self._above < cfg.hysteresis:
            return None
        tick = executor.tick_count
        if self._last_swap_tick is not None and tick - self._last_swap_tick < cfg.cooldown_ticks:
            return None
        if cfg.background:
            online = self._snapshot_online()
            cur = list(executor.plan.partitions)

            def job():
                plan = self._plan(online)
                old_cycle = self._score_fixed(cur, online)
                # warm the candidate plan's segment executables here, in
                # the worker — compilation stays off the tick thread; the
                # warmup is harmless if the swap is later rejected (it
                # only seeds executable caches)
                t0 = time.perf_counter()
                executor.prepare_plan(plan.ir)
                prepare_s = time.perf_counter() - t0
                self._job_result.append((plan, old_cycle, dict(d), prepare_s))

            self._job = threading.Thread(target=job, daemon=True)
            self._job.start()
            return None
        online = self._snapshot_online()
        plan = self._plan(online)
        old_cycle = self._score_fixed(executor.plan.partitions, online)
        return self._finish(executor, plan, old_cycle, dict(d))

    def _finish(
        self, executor: StreamExecutor, plan, old_cycle: float, drift, prepare_s: float | None = None
    ) -> ReplanEvent:
        cfg = self.config
        background = prepare_s is not None
        old_partitions = tuple(executor.plan.partitions)
        improves = plan.cycle_time < old_cycle * (1.0 - cfg.min_improvement)
        changes = tuple(plan.ir.partitions) != old_partitions
        swapped = improves and changes
        if swapped:
            if not background:
                t0 = time.perf_counter()
                executor.prepare_plan(plan.ir)
                prepare_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            executor.swap_plan(plan.ir)
            self.swap_stalls.append(
                SwapStall(
                    tick=executor.tick_count,
                    prepare_s=prepare_s,
                    swap_s=time.perf_counter() - t0,
                    background=background,
                )
            )
            self._last_swap_tick = executor.tick_count
            self._rebaseline()
        else:
            # plan already as good as it gets under the drifted costs: stop
            # re-firing on the same signal until it changes again
            self._rebaseline()
            self._last_swap_tick = executor.tick_count
        ev = ReplanEvent(
            tick=executor.tick_count,
            drift=drift,
            old_partitions=old_partitions,
            new_partitions=tuple(plan.ir.partitions),
            old_cycle=old_cycle,
            new_cycle=plan.cycle_time,
            swapped=swapped,
            revision=executor.plan.revision,
        )
        self.events.append(ev)
        return ev

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "calibrated": self.calibrated,
            "observations": self.online.observations,
            "scales": self.online.snapshot(),
            "baseline": dict(self._baseline),
            "drift": self.drift(),
            "replans": len(self.events),
            "swaps": sum(e.swapped for e in self.events),
            "swap_stall": swap_stall_summary(self.swap_stalls),
            "events": [
                {
                    "tick": e.tick,
                    "drift": {k: round(v, 4) for k, v in e.drift.items()},
                    "old_partitions": list(e.old_partitions),
                    "new_partitions": list(e.new_partitions),
                    "old_cycle": e.old_cycle,
                    "new_cycle": e.new_cycle,
                    "swapped": e.swapped,
                    "revision": e.revision,
                }
                for e in self.events
            ],
        }
