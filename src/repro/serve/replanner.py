"""Online re-planning control loop: drift detection + live plan hot-swap.

The paper's engine allocation is only optimal while the per-layer costs
it was planned against still hold; on real edge deployments they drift
with batch size, thermal state, and co-located load. The ``Replanner``
closes the loop:

  1. **observe** — the executor's profiled ticks emit per-segment wall
     times (``SegmentObservation``). Observations accumulate per (tick,
     engine) and fold into an ``OnlineCost`` EMA as one magnitude-weighted
     (engine -> sum observed / sum expected) ratio per profiled tick —
     big segments dominate, so host-overhead noise on near-empty spans
     cannot swing the scale. *Expected* is re-derived from the graphs
     under the base provider — a fixed base-units -> wall-clock
     calibration that survives plan swaps regardless of which provider
     scored the active plan.
  2. **detect** — after calibration (every engine seen ``warmup_obs``
     times), per-engine drift is the relative change of its scale vs the
     calibration snapshot. The detector requires ``hysteresis``
     consecutive ticks above ``drift_threshold`` (noise stays quiet) and
     ``cooldown_ticks`` between swaps (no thrashing).
  3. **re-plan** — the beam-search planner re-runs on the live-calibrated
     costs, at the *incumbent plan's cut budget* (``max_cuts``: a
     multi-cut plan is re-planned as a multi-cut plan; override with
     ``ReplanConfig.max_cuts``). With ``partial_swaps`` the loop first
     tries a **partial re-plan**: every model's route is held fixed
     except the one carrying the most planned work on the most-drifted
     engine; if that single-route plan predicts a cycle within
     ``partial_tolerance`` of the full re-plan's, only the drifted route
     is swapped (recorded as a partial swap in ``metrics.SwapStall``).
     The refreshed costs also re-score the *current* routes (``fixed=``
     evaluation), and the swap only happens if the chosen plan's
     predicted cycle beats that by ``min_improvement``.
  4. **swap** — ``executor.prepare_plan`` warms the new segment
     executables on zero states (off the hot path), then
     ``executor.swap_plan`` installs the plan at the frame boundary:
     in-flight frames finish on their admitted routes, zero drops.

**Coarse -> fine escalation** (``escalate_after > 0``): after that many
drift-triggered re-plans the loop escalates its planning granularity —
sustained drift means the coarse cut set cannot rebalance the engines,
so the re-planner widens the search to the fine-grained boundary space.
Two deployments:

  * planner graphs == executor graphs (the common case): escalation
    re-plans with ``escalate_stride`` instead of ``stride`` — on
    expanded-graph deployments that unlocks the full stage-boundary cut
    set the initial (strided) plan skipped.
  * planner graphs are *coarse* while the executor's models were staged
    *fine* (cheap-planning deployment; detected at ``attach`` by layer
    counts): normal re-plans run on the coarse graphs and are translated
    to fine indices (``plan_ir.translate_ir``); escalation switches the
    planning graphs to the expansions themselves, unlocking cuts inside
    composite blocks that coarse planning cannot express.

``background=True`` runs step 3 *and* the ``prepare_plan`` warmup in a
worker thread on a snapshot of the scales — the hot loop only pays for
the swap itself (compile times dominate the stall on real accelerators);
the default is synchronous for deterministic tests. Either way the
per-swap hot-path stall is recorded as a ``metrics.SwapStall`` and
folded into ``summary()``. An ``OnlineCost`` whose scales were
warm-started from a calibration JSON (``--calibration-cache``) seeds the
drift baseline immediately — no warmup ticks needed after a restart.
Attach to any ``StreamExecutor`` via ``attach`` (sets ``profile_every``,
``on_segment``, ``on_tick``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Sequence

from ..core.api import plan as core_plan
from ..core.cost_model import ANALYTIC, CostProvider, OnlineCost, _effective_impls
from ..core.plan_ir import PlanIR, translate_ir
from .executor import SegmentObservation, StreamExecutor
from .metrics import SwapStall, swap_stall_summary


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the drift detector + re-plan loop (see module docstring)."""

    drift_threshold: float = 0.6  # relative scale change that counts as drift
    hysteresis: int = 3  # consecutive drifting ticks required to fire
    cooldown_ticks: int = 10  # min ticks between plan swaps
    min_improvement: float = 0.05  # predicted cycle gain required to swap
    ema_alpha: float = 0.25  # OnlineCost EMA coefficient
    warmup_obs: int = 8  # per-engine folded ticks before auto-calibration
    profile_every: int = 2  # executor segment-profiling cadence (ticks)
    search: str = "auto"  # planner search mode for re-plans
    beam_width: int = 64
    stride: int = 1  # candidate cut-point stride (match the initial plan's)
    background: bool = False  # plan + prepare in a worker thread (off the hot path)
    max_cuts: int = 0  # cut budget for re-plans; 0 = inherit the incumbent plan's
    partial_swaps: bool = True  # try single-route re-plans before full swaps
    # a partial plan is preferred when its predicted cycle is within this
    # factor of the full re-plan's (it swaps one route instead of all)
    partial_tolerance: float = 0.02
    escalate_after: int = 0  # drift fires before escalating granularity (0 = never)
    escalate_stride: int = 1  # the stride escalated re-plans search with
    # -- load-pressure trigger (0.0 = disabled) ----------------------------
    # Sustained queue growth or SLO-miss rate fires a re-plan too: an
    # overloaded server is mis-planned for the *offered* load even when
    # no per-engine cost has drifted.
    load_threshold: float = 0.0  # aggregate queue fill fraction that counts as pressure
    slo_miss_threshold: float = 0.0  # recent deadline-miss rate that counts as pressure
    load_hysteresis: int = 5  # consecutive pressured ticks required to fire


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    tick: int
    drift: dict[str, float]
    old_partitions: tuple[int, ...]
    new_partitions: tuple[int, ...]
    old_cycle: float  # current routes re-scored under live costs
    new_cycle: float  # candidate plan under live costs
    swapped: bool
    revision: int  # executor plan revision after the event
    old_cuts: tuple[tuple[int, ...], ...] = ()
    new_cuts: tuple[tuple[int, ...], ...] = ()
    partial: bool = False  # only the drifted model's route was re-planned
    escalated: bool = False  # this re-plan ran at escalated granularity
    trigger: str = "drift"  # what fired this re-plan: drift | load


class Replanner:
    """Watches one executor's live segment costs and hot-swaps its plan."""

    def __init__(
        self,
        graphs: Sequence,
        engines: Sequence,
        config: ReplanConfig | None = None,
        base_provider: CostProvider | None = None,
        allow_fallback: bool = True,
    ):
        self.graphs = list(graphs)
        self.engines = list(engines)
        self.config = config or ReplanConfig()
        if isinstance(base_provider, OnlineCost):
            # reuse the caller's OnlineCost (e.g. --cost online planned the
            # initial routes with it) instead of double-wrapping: the same
            # instance then receives the live observations, so later
            # planning calls through the caller's handle see the scales
            self.online = base_provider
            self.online.alpha = self.config.ema_alpha
        else:
            self.online = OnlineCost(base_provider or ANALYTIC, alpha=self.config.ema_alpha)
        self.allow_fallback = allow_fallback
        self.events: list[ReplanEvent] = []
        self.swap_stalls: list[SwapStall] = []
        self._baseline: dict[str, float] = {}  # calibration snapshot of scales
        if self.online.calibrated([e.name for e in self.engines]):
            # warm-started scales (e.g. loaded from a calibration JSON):
            # baseline immediately instead of waiting out warmup_obs ticks
            self._baseline = self.online.snapshot()
        self._obs_count: dict[str, int] = {}
        self._tick_acc: dict[str, list[float]] = {}  # engine -> [wall, expected]
        self._above = 0  # consecutive drifting ticks (hysteresis counter)
        self._load_above = 0  # consecutive load-pressured ticks
        # Hook for the SLO-pressure signal: () -> recent deadline-miss rate
        # (the server wires metrics.recent_slo_miss_rate here).
        self.slo_miss_fn = None
        self._last_swap_tick: int | None = None
        self._expected_cache: dict[tuple[int, int, int, int, str, int], float] = {}
        # continuous-batching state: the admission bucket the incumbent
        # plan was scored at, a magnitude-weighted EMA of the buckets
        # observed flights actually ran at, and the hysteresis counter of
        # the batch-shift trigger (sustained concurrency change re-plans
        # even when no per-engine scale has drifted)
        self._planned_batch = 1
        self._batch_ema = 1.0
        self._batch_above = 0
        self._tick_batch: list[float] = [0.0, 0.0]  # [sum bucket*w, sum w]
        # implementation-selection mode re-plans run with; inherited from
        # the attached executor's plan (and refreshed on every swap)
        self._impl_mode = "xla"
        self._job: threading.Thread | None = None
        self._job_result: list = []
        # granularity state: _fine holds the expanded planning graphs when
        # the executor's models are staged finer than self.graphs (plans
        # are then translated to fine indices); _escalated flips planning
        # onto the fine graphs / escalate_stride after sustained drift
        self._fine = None
        self._translate = False
        self._escalated = False
        self._fires = 0  # drift-triggered re-plans (escalation counter)
        self._incumbent_max_cuts = 1

    # -- wiring -------------------------------------------------------------

    def attach(self, executor: StreamExecutor) -> StreamExecutor:
        """Wire the feedback loop into an executor (observer + tick hook).

        When the executor's staged models carry more layers than the
        planning graphs (fine staging, coarse planning), the expansions
        must match the staged layer counts — re-plans are then made
        coarse and translated to fine indices, and escalation switches
        planning onto the expansions themselves."""
        if executor.plan.n_engines != len(self.engines):
            raise ValueError(
                f"replanner has {len(self.engines)} engines but plan uses {executor.plan.n_engines}"
            )
        n_exec = list(executor.plan.n_layers)
        if [len(g) for g in self.graphs] != n_exec:
            fine = [g.expand() for g in self.graphs]
            if [len(g) for g in fine] != n_exec:
                raise ValueError(
                    f"replanner graphs ({[len(g) for g in self.graphs]} layers) match "
                    f"neither the executor's models ({n_exec}) nor their expansions"
                )
            self._fine = fine
            self._translate = True
        self._incumbent_max_cuts = executor.plan.max_cuts
        self._impl_mode = getattr(executor.plan, "impl_mode", "xla")
        self._planned_batch = max(int(getattr(executor.plan, "batch", 1)), 1)
        self._batch_ema = float(self._planned_batch)
        executor.profile_every = max(1, self.config.profile_every)
        executor.on_segment = self.observe
        executor.on_tick = self.maybe_replan
        return executor

    # -- observation --------------------------------------------------------

    @property
    def _exec_graphs(self):
        """Graphs in the executor's (staged) index space — what profiled
        observations and incumbent plans are expressed in."""
        return self._fine if self._translate else self.graphs

    def _plan_graphs(self):
        """Graphs the next re-plan searches: coarse until escalation
        switches to the fine expansions (no-op when not translating)."""
        if self._translate and self._escalated:
            return self._fine
        return self.graphs

    def _expected_base(
        self, model_index: int, engine: int, lo: int, hi: int, impl: str = "xla", batch: int = 1
    ) -> float:
        """Base-provider cost of graph[lo:hi) on the engine — the fixed
        denominator of the wall-clock calibration (never a scaled plan's
        expected_cost, which would drift with each re-plan). Spans are
        executor-space indices, so the expectation walks the executor's
        graphs — under the implementation the span actually ran with, so
        each variant calibrates against its own expectation. ``batch``
        derives the expectation at the bucket the span actually ran at
        (per-frame amortized), so the modeled amortization curve cancels
        out of the engine scale instead of reading as drift."""
        key = (model_index, engine, lo, hi, impl, batch)
        t = self._expected_cache.get(key)
        if t is None:
            g = self._exec_graphs[model_index]
            e = self.engines[engine]
            eff = _effective_impls(g, lo, hi, impl)
            t = sum(
                self.online.base.layer_time(
                    g[i], e, eff[i - lo] if eff else "xla", batch=batch
                )
                for i in range(lo, hi)
            )
            self._expected_cache[key] = t
        return t

    def observe(self, obs: SegmentObservation):
        """Accumulate one profiled segment into the current tick's
        per-engine (wall, expected) sums; ``_fold_tick`` turns each sum
        pair into one magnitude-weighted EMA sample at the frame boundary
        (per-segment ratios on near-empty spans are all host overhead —
        summing first keeps them from swinging the scale)."""
        impl = getattr(obs, "impl", "xla")
        # coalesced flights run the span once for the whole (padded)
        # bucket; normalize wall AND expectation to that bucket so the
        # modeled batching amortization cancels out of the engine scale.
        # What remains in the per-bucket channels below is the *residual*
        # — how far the bucket's real batched wall deviates from the
        # amortization curve the planner scored it with.
        bucket = max(int(getattr(obs, "bucket", 0)), int(getattr(obs, "batch", 1)), 1)
        expected = self._expected_base(obs.model_index, obs.engine, obs.lo, obs.hi, impl, bucket)
        wall = obs.wall_s / bucket
        name = self.engines[obs.engine].name
        acc = self._tick_acc.setdefault(name, [0.0, 0.0])
        acc[0] += wall
        acc[1] += expected
        if impl != "xla":
            # fold into the variant's own calibration channel too, so
            # drift in one implementation (and only it) can flip the
            # planner's per-segment impl choice on the next re-plan
            ch = self._tick_acc.setdefault(f"{name}|{impl}", [0.0, 0.0])
            ch[0] += wall
            ch[1] += expected
        if bucket > 1:
            # per-bucket calibration channel (``OnlineCost.scale_for``
            # resolves ``{engine}[|{impl}]|b{bucket}`` before falling back
            # to the engine-wide scale): drift in one bucket's batching
            # efficiency re-scores plans at that bucket, and only them
            base_ch = name if impl == "xla" else f"{name}|{impl}"
            bch = self._tick_acc.setdefault(f"{base_ch}|b{bucket}", [0.0, 0.0])
            bch[0] += wall
            bch[1] += expected
        # magnitude-weighted admission-bucket sample for the batch-shift
        # trigger (big spans dominate, matching the scale folding above)
        self._tick_batch[0] += bucket * obs.wall_s
        self._tick_batch[1] += obs.wall_s

    def _fold_tick(self):
        for name, (wall, expected) in self._tick_acc.items():
            self.online.observe(name, wall, expected)
            self._obs_count[name] = self._obs_count.get(name, 0) + 1
        self._tick_acc.clear()
        if self._tick_batch[1] > 0:
            mean = self._tick_batch[0] / self._tick_batch[1]
            a = self.config.ema_alpha
            self._batch_ema = (1.0 - a) * self._batch_ema + a * mean
            self._tick_batch = [0.0, 0.0]

    # -- drift detection ----------------------------------------------------

    @property
    def calibrated(self) -> bool:
        return bool(self._baseline)

    def _try_calibrate(self):
        names = [e.name for e in self.engines]
        if all(self._obs_count.get(n, 0) >= self.config.warmup_obs for n in names):
            self._baseline = self.online.snapshot()

    def drift(self) -> dict[str, float]:
        """Per-engine relative scale change vs the calibration snapshot."""
        if not self._baseline:
            return {}
        out = {}
        for name, base in self._baseline.items():
            cur = self.online.scale(name)
            out[name] = abs(cur / base - 1.0) if base > 0 else 0.0
        return out

    def _rebaseline(self):
        self._baseline = self.online.snapshot()
        self._above = 0
        self._load_above = 0

    def calibrate(self):
        """Snapshot the current scales as the drift baseline now — callers
        that control warmup (benches) use this right after it, once
        compile-time walls have washed out of the EMA, instead of waiting
        for ``warmup_obs`` folded ticks."""
        self._fold_tick()
        self._rebaseline()

    def load_calibration(self, path: str) -> "Replanner":
        """Warm-start from a persisted calibration (``--calibration-cache``):
        restore the per-engine EMA state into the ``OnlineCost`` and, when
        it covers every engine, seed the drift baseline from it — works
        regardless of which base provider the online calibrator wraps."""
        self.online.load_calibration(path)
        if self.online.calibrated([e.name for e in self.engines]):
            self._baseline = self.online.snapshot()
        return self

    # -- planning -----------------------------------------------------------

    @property
    def escalated(self) -> bool:
        return self._escalated

    def _active_max_cuts(self) -> int:
        return self.config.max_cuts or self._incumbent_max_cuts

    def _plan(self, online: OnlineCost, fixed=None) -> PlanIR:
        cfg = self.config
        return core_plan(
            self._plan_graphs(),
            self.engines,
            allow_fallback=self.allow_fallback,
            cost=online,
            search=cfg.search,
            beam_width=cfg.beam_width,
            stride=cfg.escalate_stride if self._escalated else cfg.stride,
            max_cuts=self._active_max_cuts(),
            fixed=fixed,
            impl=self._impl_mode,
            batch=self._planned_batch,
        )

    def _score_fixed(self, routes, online: OnlineCost) -> float:
        """Re-score pinned routes under the live costs. ``routes`` entries
        are planning-space ``(cuts, engines)`` specs (or bare ints)."""
        return self._plan(online, fixed=list(routes)).expected_cycle

    def _incumbent_routes(self, plan: PlanIR):
        """The executor's live routes in *planning-space* indices, or None
        when they are not expressible there (a fine cut inside a
        composite while still planning coarse — forces escalation)."""
        specs = plan.route_specs()
        if not self._translate or self._escalated:
            return specs
        out = []
        for (cuts, engines), g in zip(specs, self._fine):
            coarse = tuple(g.coarse_cut(c) for c in cuts)
            if any(c is None for c in coarse):
                return None
            out.append((coarse, engines))
        return out

    def _to_exec_ir(self, ir: PlanIR, models: tuple[str, ...]) -> PlanIR:
        """Translate a planning-space IR to executor indices (identity
        unless planning coarse for a fine-staged executor) and restore the
        executor's model names — planning on an expansion renames graphs
        (``[expanded]``), but the swap contract matches names exactly."""
        if self._translate and not self._escalated:
            ir = translate_ir(ir, self._fine)
        if tuple(ir.models) != tuple(models):
            ir = dataclasses.replace(ir, models=tuple(models))
        return ir

    def _drift_target_model(self, plan: PlanIR, drift: dict[str, float]) -> int:
        """The model to re-route in a partial re-plan: the one whose
        incumbent route carries the most base-cost on the most-drifted
        engine (executor-space accounting)."""
        names = [e.name for e in self.engines]
        worst = max(range(len(names)), key=lambda e: drift.get(names[e], 0.0))
        loads = []
        for mi in range(plan.n_models):
            loads.append(
                sum(
                    self._expected_base(mi, s.engine, s.lo, s.hi, getattr(s, "impl", "xla"))
                    for s in plan.route(mi)
                    if s.engine == worst
                )
            )
        return max(range(len(loads)), key=lambda mi: (loads[mi], -mi))

    def _propose(self, executor_plan: PlanIR, online: OnlineCost, drift: dict[str, float]):
        """Produce the candidate swap for one drift fire: (plan, exec-space
        IR, incumbent cycle under live costs, partial?)."""
        cfg = self.config
        incumbent = self._incumbent_routes(executor_plan)
        if incumbent is None:
            # the live routes are not expressible at coarse planning
            # granularity — fall forward to fine planning permanently
            self._escalated = True
            incumbent = self._incumbent_routes(executor_plan)
        full = self._plan(online)
        old_cycle = self._score_fixed(incumbent, online)
        choice, partial = full, False
        if cfg.partial_swaps and len(self.graphs) > 1:
            target = self._drift_target_model(executor_plan, drift)
            pinned = [r if mi != target else None for mi, r in enumerate(incumbent)]
            part = self._plan(online, fixed=pinned)
            if part.expected_cycle <= full.expected_cycle * (1.0 + cfg.partial_tolerance):
                choice, partial = part, True
        return choice, self._to_exec_ir(choice, executor_plan.models), old_cycle, partial

    def _snapshot_online(self) -> OnlineCost:
        snap = OnlineCost(self.online.base, alpha=self.online.alpha)
        snap._num = dict(self.online._num)
        snap._den = dict(self.online._den)
        return snap

    # -- the control loop ---------------------------------------------------

    def _load_signal(self, executor: StreamExecutor) -> dict[str, float] | None:
        """Evaluate the load-pressure trigger for this tick: sustained
        queue growth or SLO-miss rate above threshold (``load_threshold``
        / ``slo_miss_threshold``; both disabled at 0.0). Returns the
        pressure readings when the hysteresis fires, else None."""
        cfg = self.config
        if not cfg.load_threshold and not cfg.slo_miss_threshold:
            return None
        pressure = executor.queue_pressure()
        miss = float(self.slo_miss_fn()) if self.slo_miss_fn is not None else 0.0
        hot = (cfg.load_threshold and pressure >= cfg.load_threshold) or (
            cfg.slo_miss_threshold and miss >= cfg.slo_miss_threshold
        )
        if not hot:
            self._load_above = 0
            return None
        self._load_above += 1
        if self._load_above < cfg.load_hysteresis:
            return None
        return {"queue_pressure": pressure, "slo_miss_rate": miss}

    def _batch_signal(self, executor: StreamExecutor) -> dict[str, float] | None:
        """Evaluate the batch-shift trigger: the coalescer's observed
        admission bucket (EMA, quantized to the executor's bucket ladder)
        has moved away from the bucket the incumbent plan was scored at,
        for ``hysteresis`` consecutive ticks. An arrival-concurrency
        shift re-plans even when no per-engine scale has drifted — the
        routes were balanced for a different effective batch."""
        bc = getattr(executor, "batching", None)
        if bc is None or not bc.enabled:
            return None
        observed = bc.bucket_for(int(round(self._batch_ema)))
        if observed == self._planned_batch:
            self._batch_above = 0
            return None
        self._batch_above += 1
        if self._batch_above < self.config.hysteresis:
            return None
        return {"observed_batch": float(observed), "planned_batch": float(self._planned_batch)}

    def maybe_replan(self, executor: StreamExecutor) -> ReplanEvent | None:
        """Called at every frame boundary (executor ``on_tick``)."""
        cfg = self.config
        self._fold_tick()
        # harvest a finished background planning job first
        if self._job is not None:
            if self._job.is_alive():
                return None
            self._job = None
            if self._job_result:
                return self._finish(executor, *self._job_result.pop())
            return None
        if not self._baseline:
            self._try_calibrate()
        trigger, d = None, {}
        if self._baseline:
            d = self.drift()
            if d and max(d.values()) > cfg.drift_threshold:
                self._above += 1
            else:
                self._above = 0
            if self._above >= cfg.hysteresis:
                trigger = "drift"
        if trigger is None:
            load = self._load_signal(executor)
            if load is not None:
                trigger, d = "load", load
        if trigger is None:
            shift = self._batch_signal(executor)
            if shift is not None:
                trigger, d = "batch", shift
        if trigger is None:
            return None
        tick = executor.tick_count
        if self._last_swap_tick is not None and tick - self._last_swap_tick < cfg.cooldown_ticks:
            return None
        if trigger == "batch":
            # commit the new planning bucket only once the fire is going
            # through: incumbent and candidates are then both re-scored at
            # the same amortized costs, and planned == observed afterwards
            # quiesces the trigger whether or not the swap happens
            self._planned_batch = int(d["observed_batch"])
            self._batch_above = 0
        # this is a re-plan fire: bump the escalation counter before
        # planning, so the escalate_after-th fire already plans fine
        self._fires += 1
        if cfg.escalate_after and not self._escalated and self._fires >= cfg.escalate_after:
            self._escalated = True
        if cfg.background:
            online = self._snapshot_online()
            plan_snapshot = executor.plan
            drift_snapshot = dict(d)
            fire_trigger = trigger

            def job():
                plan, ir, old_cycle, partial = self._propose(plan_snapshot, online, drift_snapshot)
                # warm the candidate plan's segment executables here, in
                # the worker — compilation stays off the tick thread; the
                # warmup is harmless if the swap is later rejected (it
                # only seeds executable caches)
                t0 = time.perf_counter()
                executor.prepare_plan(ir)
                prepare_s = time.perf_counter() - t0
                self._job_result.append(
                    (plan, old_cycle, drift_snapshot, prepare_s, partial, ir, fire_trigger)
                )

            self._job = threading.Thread(target=job, daemon=True)
            self._job.start()
            return None
        online = self._snapshot_online()
        plan, ir, old_cycle, partial = self._propose(executor.plan, online, dict(d))
        return self._finish(executor, plan, old_cycle, dict(d), partial=partial, ir=ir, trigger=trigger)

    def _finish(
        self,
        executor: StreamExecutor,
        plan,
        old_cycle: float,
        drift,
        prepare_s: float | None = None,
        partial: bool = False,
        ir: PlanIR | None = None,
        trigger: str = "drift",
    ) -> ReplanEvent:
        cfg = self.config
        background = prepare_s is not None
        # accept a legacy scheduler plan (NModelPlan et al.) as well as PlanIR
        if not isinstance(plan, PlanIR):
            plan = plan.ir
        ir = ir if ir is not None else plan
        old_partitions = tuple(executor.plan.partitions)
        old_cuts = executor.plan.cuts
        improves = plan.expected_cycle < old_cycle * (1.0 - cfg.min_improvement)
        changes = (
            ir.route_specs() != executor.plan.route_specs()
            or ir.impl_bindings() != executor.plan.impl_bindings()
        )
        swapped = improves and changes
        if swapped:
            if not background:
                t0 = time.perf_counter()
                executor.prepare_plan(ir)
                prepare_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            executor.swap_plan(ir)
            self.swap_stalls.append(
                SwapStall(
                    tick=executor.tick_count,
                    prepare_s=prepare_s,
                    swap_s=time.perf_counter() - t0,
                    background=background,
                    partial=partial,
                )
            )
            self._last_swap_tick = executor.tick_count
            self._incumbent_max_cuts = executor.plan.max_cuts
            self._impl_mode = getattr(executor.plan, "impl_mode", "xla")
            self._planned_batch = max(int(getattr(executor.plan, "batch", 1)), 1)
            self._rebaseline()
        else:
            # plan already as good as it gets under the drifted costs: stop
            # re-firing on the same signal until it changes again
            self._rebaseline()
            self._last_swap_tick = executor.tick_count
        self._load_above = 0
        ev = ReplanEvent(
            tick=executor.tick_count,
            drift=drift,
            old_partitions=old_partitions,
            new_partitions=tuple(ir.partitions),
            old_cycle=old_cycle,
            new_cycle=plan.expected_cycle,
            swapped=swapped,
            revision=executor.plan.revision,
            old_cuts=old_cuts,
            new_cuts=ir.cuts,
            partial=partial,
            escalated=self._escalated,
            trigger=trigger,
        )
        self.events.append(ev)
        return ev

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "calibrated": self.calibrated,
            "observations": self.online.observations,
            "scales": self.online.snapshot(),
            "baseline": dict(self._baseline),
            "drift": self.drift(),
            "replans": len(self.events),
            "swaps": sum(e.swapped for e in self.events),
            "partial_swaps": sum(e.swapped and e.partial for e in self.events),
            "escalated": self._escalated,
            "drift_fires": self._fires,
            "load_fires": sum(e.trigger == "load" for e in self.events),
            "batch_fires": sum(e.trigger == "batch" for e in self.events),
            "planned_batch": self._planned_batch,
            "batch_ema": round(self._batch_ema, 3),
            "swap_stall": swap_stall_summary(self.swap_stalls),
            "events": [
                {
                    "tick": e.tick,
                    "drift": {k: round(v, 4) for k, v in e.drift.items()},
                    "old_partitions": list(e.old_partitions),
                    "new_partitions": list(e.new_partitions),
                    "old_cuts": [list(c) for c in e.old_cuts],
                    "new_cuts": [list(c) for c in e.new_cuts],
                    "old_cycle": e.old_cycle,
                    "new_cycle": e.new_cycle,
                    "swapped": e.swapped,
                    "partial": e.partial,
                    "escalated": e.escalated,
                    "revision": e.revision,
                    "trigger": e.trigger,
                }
                for e in self.events
            ],
        }
