"""Per-stream frame queues with bounded depth and backpressure accounting.

A *stream* is an independent frame source bound to one staged model (the
paper's "camera"/"scan" analogue). The executor admits frames from these
queues; when a queue is full ``push`` refuses the frame — callers either
drop, retry after a tick, or propagate the backpressure upstream (the
server blocks the producer loop on it).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Binding of a named stream to a model index in the executor plan."""

    name: str
    model_index: int


class FrameQueue:
    """Bounded FIFO; refuses pushes past ``maxdepth`` instead of growing."""

    def __init__(self, maxdepth: int):
        if maxdepth < 1:
            raise ValueError("queue depth must be >= 1")
        self.maxdepth = maxdepth
        self._q: deque = deque()
        self.high_water = 0  # max depth ever observed (backpressure audit)
        self.rejected = 0  # pushes refused while full

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.maxdepth

    def push(self, item: Any) -> bool:
        if self.full:
            self.rejected += 1
            return False
        self._q.append(item)
        self.high_water = max(self.high_water, len(self._q))
        return True

    def pop(self) -> Any:
        return self._q.popleft()
