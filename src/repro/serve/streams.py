"""Per-stream frame queues with bounded depth and backpressure accounting.

A *stream* is an independent frame source bound to one staged model (the
paper's "camera"/"scan" analogue). The executor admits frames from these
queues; when a queue is full ``push`` refuses the frame — callers either
drop, retry after a tick, or propagate the backpressure upstream (the
server blocks the producer loop on it).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

from .traffic import SLOPolicy


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Binding of a named stream to a model index in the executor plan.

    ``slo`` attaches the stream's service objective (deadline + priority
    tier) for open-loop serving: admission control drops/sheds by tier,
    the executor admits strictly tier-first, and metrics bucket goodput
    by it. ``None`` (the closed-loop default) means no deadline and the
    neutral tier 0."""

    name: str
    model_index: int
    slo: SLOPolicy | None = None

    @property
    def tier(self) -> int:
        return self.slo.tier if self.slo is not None else 0


class FrameQueue:
    """Bounded FIFO; refuses pushes past ``maxdepth`` instead of growing."""

    def __init__(self, maxdepth: int):
        if maxdepth < 1:
            raise ValueError("queue depth must be >= 1")
        self.maxdepth = maxdepth
        self._q: deque = deque()
        self.high_water = 0  # max depth ever observed (backpressure audit)
        self.rejected = 0  # pushes refused while full
        self.evicted = 0  # frames evicted by admission control (make-room)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.maxdepth

    def push(self, item: Any) -> bool:
        if self.full:
            self.rejected += 1
            return False
        self._q.append(item)
        self.high_water = max(self.high_water, len(self._q))
        return True

    def pop(self) -> Any:
        return self._q.popleft()

    def peek(self, i: int = 0) -> Any:
        """Inspect the i-th queued item without popping — the coalescer's
        hold decision looks at waiting frames before committing to admit
        them (held frames must stay queued, not sit in limbo)."""
        return self._q[i]

    def evict_newest(self) -> Any | None:
        """Drop and return the most recent frame (admission control's
        make-room path: the newest low-priority frame has waited least,
        so evicting it wastes the least sunk queueing time). None when
        empty."""
        if not self._q:
            return None
        self.evicted += 1
        return self._q.pop()
