"""Per-stream serving metrics: latency percentiles, throughput, and
per-tick dispatch-overlap efficiency.

Latencies are wall-clock submit→completion seconds as stamped by the
executor. Percentiles use the nearest-rank method on the recorded sample
(exact for the small counts a bench run produces; no interpolation
surprises when comparing runs).

Overlap efficiency measures how much of each executor tick the host spent
usefully dispatching (or doing bookkeeping) versus blocked waiting on
device results: ``1 - blocked_s / wall_s``. The serialized dispatch mode
synchronizes after every engine segment, so most of its tick is blocked
time; the overlapped mode only synchronizes when a frame completes, so
counter-phased engine segments genuinely run concurrently and the
efficiency approaches 1.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque


@dataclasses.dataclass
class TickStats:
    """Host-side timing of one executor tick.

    ``engine_wait`` is the per-engine host-time breakdown of the tick:
    ``{engine_name: (issue_s, transfer_s, resolve_s)}`` — dispatch time
    spent issuing that engine's segments, time placing states onto it,
    and time blocked waiting for its results. Resolve-wait dominating the
    tick is the no-overlap signature the coalescer attacks."""

    tick: int
    wall_s: float
    blocked_s: float  # time inside block_until_ready during this tick
    segments: int  # engine segment calls issued this tick
    engine_wait: dict | None = None  # engine -> (issue_s, transfer_s, resolve_s)

    @property
    def overlap_efficiency(self) -> float:
        if self.wall_s <= 0:
            return 1.0
        return max(0.0, 1.0 - self.blocked_s / self.wall_s)


@dataclasses.dataclass(frozen=True)
class SwapStall:
    """Hot-path cost of one plan hot-swap.

    ``prepare_s`` is the segment-executable warmup (compile + first
    execution on zero states); ``background=True`` means it ran in the
    replanner's worker thread, so only ``swap_s`` stalled the tick
    thread. This is the number that decides whether ``prepare_plan``
    belongs in the worker on a given backend (compile times dominate on
    real accelerators)."""

    tick: int
    prepare_s: float
    swap_s: float
    background: bool
    # True when only the drifted model's route changed (the re-planner
    # held every other model fixed) — cheaper to prepare and lower-risk
    # than a full-plan swap
    partial: bool = False

    @property
    def hot_path_s(self) -> float:
        """Time the executor's tick thread was stalled by this swap."""
        return self.swap_s + (0.0 if self.background else self.prepare_s)


def swap_stall_summary(stalls: list[SwapStall]) -> dict:
    """Aggregate swap-stall accounting for one serving run."""
    if not stalls:
        return {"swaps": 0, "hot_path_stall_ms": 0.0, "hot_path_stall_max_ms": 0.0,
                "prepare_ms": 0.0, "background_prepares": 0,
                "partial_swaps": 0, "full_swaps": 0}
    return {
        "swaps": len(stalls),
        "hot_path_stall_ms": sum(s.hot_path_s for s in stalls) * 1e3,
        "hot_path_stall_max_ms": max(s.hot_path_s for s in stalls) * 1e3,
        "prepare_ms": sum(s.prepare_s for s in stalls) * 1e3,
        "background_prepares": sum(s.background for s in stalls),
        "partial_swaps": sum(s.partial for s in stalls),
        "full_swaps": sum(not s.partial for s in stalls),
    }


def overlap_summary(ticks: list[TickStats]) -> dict:
    """Aggregate per-tick overlap efficiency for one serving run."""
    if not ticks:
        return {"ticks": 0, "overlap_efficiency": math.nan, "blocked_s": 0.0, "tick_wall_s": 0.0}
    wall = sum(t.wall_s for t in ticks)
    blocked = sum(t.blocked_s for t in ticks)
    return {
        "ticks": len(ticks),
        "overlap_efficiency": max(0.0, 1.0 - blocked / wall) if wall > 0 else math.nan,
        "blocked_s": blocked,
        "tick_wall_s": wall,
    }


def engine_wait_summary(ticks: list[TickStats]) -> dict:
    """Per-engine idle-time breakdown over a run: where each engine's
    host time went — issue (dispatch), transfer (placement), resolve
    (blocked on results) — as absolute seconds and as fractions of the
    total tick wall. The diagnostic behind a flat overlap_speedup: when
    ``resolve_frac`` dominates, segments are serializing on the host
    instead of overlapping, which is exactly what batched executables
    amortize."""
    wall = sum(t.wall_s for t in ticks)
    acc: dict[str, list[float]] = {}
    for t in ticks:
        if not t.engine_wait:
            continue
        for name, w in t.engine_wait.items():
            a = acc.setdefault(name, [0.0, 0.0, 0.0])
            a[0] += w[0]
            a[1] += w[1]
            a[2] += w[2]
    return {
        name: {
            "issue_s": a[0],
            "transfer_s": a[1],
            "resolve_s": a[2],
            "issue_frac": a[0] / wall if wall > 0 else math.nan,
            "transfer_frac": a[1] / wall if wall > 0 else math.nan,
            "resolve_frac": a[2] / wall if wall > 0 else math.nan,
        }
        for name, a in sorted(acc.items())
    }


def segment_summary(observations) -> dict:
    """Aggregate profiled per-segment wall times by (model, engine, span).

    The executor's profiled ticks produce ``SegmentObservation``s; this is
    the report-side rollup — mean/p50 wall per distinct segment binding,
    so a serving report shows where each plan revision actually spent its
    time (the same numbers the replanner's EMA consumes).
    """
    by_seg: dict[tuple, list[float]] = {}
    for o in observations:
        by_seg.setdefault((o.model_index, o.engine, o.lo, o.hi), []).append(o.wall_s)
    return {
        f"m{mi}@E{eng}[{lo}:{hi})": {
            "samples": len(ws),
            "wall_mean_ms": sum(ws) / len(ws) * 1e3,
            "wall_p50_ms": percentile(ws, 50) * 1e3,
        }
        for (mi, eng, lo, hi), ws in sorted(by_seg.items())
    }


def percentile(samples: list[float], pct: float) -> float:
    """Nearest-rank percentile; pct in [0, 100]."""
    if not samples:
        return math.nan
    s = sorted(samples)
    rank = max(1, math.ceil(pct / 100.0 * len(s)))
    return s[min(rank, len(s)) - 1]


@dataclasses.dataclass
class StreamMetrics:
    name: str
    latencies_s: list[float] = dataclasses.field(default_factory=list)
    completed: int = 0
    in_slo: int = 0  # completions within the stream's deadline

    def record(self, latency_s: float, met_slo: bool = True):
        self.latencies_s.append(latency_s)
        self.completed += 1
        if met_slo:
            self.in_slo += 1

    def summary(self) -> dict:
        return {
            "completed": self.completed,
            "latency_p50_ms": percentile(self.latencies_s, 50) * 1e3,
            "latency_p99_ms": percentile(self.latencies_s, 99) * 1e3,
            "latency_mean_ms": (
                sum(self.latencies_s) / len(self.latencies_s) * 1e3 if self.latencies_s else math.nan
            ),
        }


@dataclasses.dataclass
class TierMetrics:
    """Per-priority-tier admission and goodput accounting.

    ``offered`` counts every open-loop arrival for the tier's streams;
    the admission ledger splits it into ``admitted`` (untouched),
    ``shed_res``/``shed_route`` (admitted degraded) and ``dropped``
    (evicted or rejected). ``in_slo`` counts completions within their
    stream's deadline — goodput-under-SLO is ``in_slo / wall``."""

    tier: int
    offered: int = 0
    admitted: int = 0
    shed_res: int = 0
    shed_route: int = 0
    dropped: int = 0
    completed: int = 0
    in_slo: int = 0
    latencies_s: list[float] = dataclasses.field(default_factory=list)

    def summary(self, wall_s: float) -> dict:
        return {
            "offered": self.offered,
            "admitted": self.admitted,
            "shed_res": self.shed_res,
            "shed_route": self.shed_route,
            "dropped": self.dropped,
            "completed": self.completed,
            "completed_in_slo": self.in_slo,
            "goodput_fps": self.in_slo / wall_s if wall_s > 0 else math.inf,
            "slo_attainment": self.in_slo / self.completed if self.completed else math.nan,
            "latency_p99_ms": percentile(self.latencies_s, 99) * 1e3,
        }


class ServeMetrics:
    """Aggregates completions across streams for one serving run.

    ``slos`` (stream name -> ``SLOPolicy`` or None) turns on SLO
    accounting: completions are checked against their stream's deadline,
    bucketed per priority tier, and a sliding window of recent SLO
    outcomes feeds the re-planner's load-pressure signal
    (``recent_slo_miss_rate``). Streams without a policy count as tier 0
    with an infinite deadline, so closed-loop reports are unchanged."""

    def __init__(self, stream_names: list[str], slos: dict | None = None, recent_window: int = 64):
        self.streams = {n: StreamMetrics(n) for n in stream_names}
        self.ticks: list[TickStats] = []
        self.slos = dict(slos) if slos else {}
        self.tiers: dict[int, TierMetrics] = {}
        self._recent: deque[bool] = deque(maxlen=recent_window)  # True = deadline met
        # continuous-batching occupancy ledger: effective-batch histogram
        # over completions (each frame counts the real frames in its
        # flight), plus the held-then-missed contract counter — a frame
        # the coalescer held that then missed its deadline. The hold rule
        # is built to keep that at exactly 0.
        self.batch_occupancy: dict[int, int] = {}
        self.held_frames = 0
        self.held_then_missed = 0

    def _tier(self, stream: str) -> TierMetrics:
        slo = self.slos.get(stream)
        t = slo.tier if slo is not None else 0
        tm = self.tiers.get(t)
        if tm is None:
            tm = self.tiers[t] = TierMetrics(t)
        return tm

    def record(self, stream: str, latency_s: float, degrade: int = 0,
               batch: int = 1, held: bool = False):
        slo = self.slos.get(stream)
        met = slo is None or latency_s <= slo.deadline_s
        self.streams[stream].record(latency_s, met_slo=met)
        tm = self._tier(stream)
        tm.completed += 1
        tm.latencies_s.append(latency_s)
        if met:
            tm.in_slo += 1
        self._recent.append(met)
        b = max(int(batch), 1)
        self.batch_occupancy[b] = self.batch_occupancy.get(b, 0) + 1
        if held:
            self.held_frames += 1
            if not met:
                self.held_then_missed += 1

    def mean_effective_batch(self) -> float:
        """Frame-weighted mean of the batch each completion rode in."""
        total = sum(self.batch_occupancy.values())
        if not total:
            return math.nan
        return sum(b * n for b, n in self.batch_occupancy.items()) / total

    def record_arrival(self, stream: str):
        self._tier(stream).offered += 1

    def record_admission(self, stream: str, decision: str):
        """Fold one admission decision (``serve.admission`` constants)."""
        tm = self._tier(stream)
        if decision == "admit":
            tm.admitted += 1
        elif decision == "shed_res":
            tm.shed_res += 1
        elif decision == "shed_route":
            tm.shed_route += 1
        elif decision == "drop":
            tm.dropped += 1
        else:
            raise ValueError(f"unknown admission decision {decision!r}")

    def record_tick(self, stats: TickStats):
        self.ticks.append(stats)

    def recent_slo_miss_rate(self) -> float:
        """Fraction of the last ``recent_window`` completions that missed
        their deadline — the re-planner's SLO-pressure signal. 0.0 until
        anything completes."""
        if not self._recent:
            return 0.0
        return 1.0 - sum(self._recent) / len(self._recent)

    def report(self, wall_s: float) -> dict:
        all_lat = [l for m in self.streams.values() for l in m.latencies_s]
        total = sum(m.completed for m in self.streams.values())
        in_slo = sum(m.in_slo for m in self.streams.values())
        rep = {
            "streams": len(self.streams),
            "frames": total,
            "wall_s": wall_s,
            "aggregate_fps": total / wall_s if wall_s > 0 else math.inf,
            "latency_p50_ms": percentile(all_lat, 50) * 1e3,
            "latency_p99_ms": percentile(all_lat, 99) * 1e3,
            "overlap": overlap_summary(self.ticks),
            "engines": engine_wait_summary(self.ticks),
            "batching": {
                "occupancy": {str(b): n for b, n in sorted(self.batch_occupancy.items())},
                "mean_effective_batch": self.mean_effective_batch(),
                "held_frames": self.held_frames,
                "held_then_missed": self.held_then_missed,
            },
            "per_stream": {n: m.summary() for n, m in self.streams.items()},
        }
        if self.slos:
            rep["goodput_fps"] = in_slo / wall_s if wall_s > 0 else math.inf
            rep["slo_miss_rate_recent"] = self.recent_slo_miss_rate()
            rep["tiers"] = {t: tm.summary(wall_s) for t, tm in sorted(self.tiers.items())}
            rep["admission"] = {
                "offered": sum(tm.offered for tm in self.tiers.values()),
                "admitted": sum(tm.admitted for tm in self.tiers.values()),
                "shed_res": sum(tm.shed_res for tm in self.tiers.values()),
                "shed_route": sum(tm.shed_route for tm in self.tiers.values()),
                "dropped": sum(tm.dropped for tm in self.tiers.values()),
            }
        return rep

    # -- cross-process serialization (see serve.multiproc) -------------------

    def to_payload(self) -> dict:
        """The full ledger as a JSON-able dict: fleet workers ship this
        over the RPC pipe and the front rebuilds a live ``ServeMetrics``
        with ``metrics_from_payload`` so the existing ``merge_metrics`` /
        ``fleet_report`` machinery works across process boundaries."""
        return {
            "streams": {
                n: {"latencies_s": list(m.latencies_s), "completed": m.completed,
                    "in_slo": m.in_slo}
                for n, m in self.streams.items()
            },
            "slos": {
                n: {"deadline_ms": p.deadline_ms, "tier": p.tier, "name": p.name}
                for n, p in self.slos.items() if p is not None
            },
            "tiers": {
                str(t): {
                    "offered": tm.offered, "admitted": tm.admitted,
                    "shed_res": tm.shed_res, "shed_route": tm.shed_route,
                    "dropped": tm.dropped, "completed": tm.completed,
                    "in_slo": tm.in_slo, "latencies_s": list(tm.latencies_s),
                }
                for t, tm in self.tiers.items()
            },
            "ticks": [
                [t.tick, t.wall_s, t.blocked_s, t.segments, t.engine_wait]
                for t in self.ticks
            ],
            "recent": [bool(b) for b in self._recent],
            "recent_window": self._recent.maxlen,
            "batch_occupancy": {str(b): n for b, n in self.batch_occupancy.items()},
            "held_frames": self.held_frames,
            "held_then_missed": self.held_then_missed,
        }


def metrics_from_payload(payload: dict) -> ServeMetrics:
    """Rebuild a live ``ServeMetrics`` from ``ServeMetrics.to_payload``.
    The reconstruction is exact — stream/tier counters, latency samples,
    tick log, and the recent-SLO window all round-trip — so a merged
    fleet report over worker payloads matches the in-process merge."""
    from .traffic import SLOPolicy  # local: traffic is a sibling leaf module

    slos = {
        n: SLOPolicy(deadline_ms=p["deadline_ms"], tier=p["tier"], name=p["name"])
        for n, p in payload.get("slos", {}).items()
    }
    m = ServeMetrics(
        list(payload.get("streams", {})),
        slos=slos or None,
        recent_window=payload.get("recent_window") or 64,
    )
    for name, st in payload.get("streams", {}).items():
        sm = m.streams[name]
        sm.latencies_s = [float(x) for x in st["latencies_s"]]
        sm.completed = int(st["completed"])
        sm.in_slo = int(st["in_slo"])
    for t, st in payload.get("tiers", {}).items():
        tm = m.tiers[int(t)] = TierMetrics(int(t))
        for f in ("offered", "admitted", "shed_res", "shed_route", "dropped",
                  "completed", "in_slo"):
            setattr(tm, f, int(st[f]))
        tm.latencies_s = [float(x) for x in st["latencies_s"]]
    m.ticks = [
        TickStats(
            int(row[0]), float(row[1]), float(row[2]), int(row[3]),
            engine_wait=(
                {n: tuple(float(x) for x in w) for n, w in row[4].items()}
                if len(row) > 4 and row[4] else None
            ),
        )
        for row in payload.get("ticks", [])
    ]
    m._recent.extend(bool(b) for b in payload.get("recent", []))
    m.batch_occupancy = {int(b): int(n) for b, n in payload.get("batch_occupancy", {}).items()}
    m.held_frames = int(payload.get("held_frames", 0))
    m.held_then_missed = int(payload.get("held_then_missed", 0))
    return m


# -- fleet aggregation -------------------------------------------------------


def router_imbalance(per_replica_counts) -> float:
    """Max/mean of per-replica routed-arrival counts: 1.0 is a perfectly
    balanced fleet; R means one replica took everything."""
    counts = list(per_replica_counts)
    if not counts:
        return math.nan
    mean = sum(counts) / len(counts)
    return max(counts) / mean if mean > 0 else 1.0


def merge_metrics(replica_metrics) -> "ServeMetrics":
    """Fold R replicas' per-replica ledgers into one fleet-level
    ``ServeMetrics``: stream latency samples concatenate, tier admission
    counters sum, and the tick log is pooled (fleet overlap efficiency is
    the replica aggregate). Streams are disjoint across replicas only in
    how traffic was routed — every replica declares the full stream set,
    so the union keys line up."""
    replica_metrics = list(replica_metrics)
    if not replica_metrics:
        raise ValueError("merge_metrics needs at least one replica")
    slos: dict = {}
    for m in replica_metrics:
        slos.update(m.slos)
    names: list[str] = []
    for m in replica_metrics:
        names.extend(n for n in m.streams if n not in names)
    agg = ServeMetrics(names, slos=slos or None)
    for m in replica_metrics:
        for name, sm in m.streams.items():
            a = agg.streams[name]
            a.latencies_s.extend(sm.latencies_s)
            a.completed += sm.completed
            a.in_slo += sm.in_slo
        for t, tm in m.tiers.items():
            at = agg.tiers.get(t)
            if at is None:
                at = agg.tiers[t] = TierMetrics(t)
            for f in ("offered", "admitted", "shed_res", "shed_route", "dropped",
                      "completed", "in_slo"):
                setattr(at, f, getattr(at, f) + getattr(tm, f))
            at.latencies_s.extend(tm.latencies_s)
        agg.ticks.extend(m.ticks)
        agg._recent.extend(m._recent)
        # batch occupancy merges across the fleet: histograms sum, so the
        # fleet report's mean effective batch is the frame-weighted mean
        for b, c in m.batch_occupancy.items():
            agg.batch_occupancy[b] = agg.batch_occupancy.get(b, 0) + c
        agg.held_frames += m.held_frames
        agg.held_then_missed += m.held_then_missed
    return agg


def fleet_report(replica_metrics, wall_s: float, routed_counts=None) -> dict:
    """Fleet-level serving report: the merged ledgers over one shared wall
    clock (replica FPS numbers do not sum — the fleet's throughput is
    total completions over the *fleet's* wall), plus the router-imbalance
    metric when per-replica routed-arrival counts are given."""
    rep = merge_metrics(replica_metrics).report(wall_s)
    rep["replicas"] = len(list(replica_metrics))
    if routed_counts is not None:
        rep["router_imbalance"] = router_imbalance(routed_counts)
    return rep
