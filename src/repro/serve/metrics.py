"""Per-stream serving metrics: latency percentiles, throughput, and
per-tick dispatch-overlap efficiency.

Latencies are wall-clock submit→completion seconds as stamped by the
executor. Percentiles use the nearest-rank method on the recorded sample
(exact for the small counts a bench run produces; no interpolation
surprises when comparing runs).

Overlap efficiency measures how much of each executor tick the host spent
usefully dispatching (or doing bookkeeping) versus blocked waiting on
device results: ``1 - blocked_s / wall_s``. The serialized dispatch mode
synchronizes after every engine segment, so most of its tick is blocked
time; the overlapped mode only synchronizes when a frame completes, so
counter-phased engine segments genuinely run concurrently and the
efficiency approaches 1.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class TickStats:
    """Host-side timing of one executor tick."""

    tick: int
    wall_s: float
    blocked_s: float  # time inside block_until_ready during this tick
    segments: int  # engine segment calls issued this tick

    @property
    def overlap_efficiency(self) -> float:
        if self.wall_s <= 0:
            return 1.0
        return max(0.0, 1.0 - self.blocked_s / self.wall_s)


@dataclasses.dataclass(frozen=True)
class SwapStall:
    """Hot-path cost of one plan hot-swap.

    ``prepare_s`` is the segment-executable warmup (compile + first
    execution on zero states); ``background=True`` means it ran in the
    replanner's worker thread, so only ``swap_s`` stalled the tick
    thread. This is the number that decides whether ``prepare_plan``
    belongs in the worker on a given backend (compile times dominate on
    real accelerators)."""

    tick: int
    prepare_s: float
    swap_s: float
    background: bool
    # True when only the drifted model's route changed (the re-planner
    # held every other model fixed) — cheaper to prepare and lower-risk
    # than a full-plan swap
    partial: bool = False

    @property
    def hot_path_s(self) -> float:
        """Time the executor's tick thread was stalled by this swap."""
        return self.swap_s + (0.0 if self.background else self.prepare_s)


def swap_stall_summary(stalls: list[SwapStall]) -> dict:
    """Aggregate swap-stall accounting for one serving run."""
    if not stalls:
        return {"swaps": 0, "hot_path_stall_ms": 0.0, "hot_path_stall_max_ms": 0.0,
                "prepare_ms": 0.0, "background_prepares": 0,
                "partial_swaps": 0, "full_swaps": 0}
    return {
        "swaps": len(stalls),
        "hot_path_stall_ms": sum(s.hot_path_s for s in stalls) * 1e3,
        "hot_path_stall_max_ms": max(s.hot_path_s for s in stalls) * 1e3,
        "prepare_ms": sum(s.prepare_s for s in stalls) * 1e3,
        "background_prepares": sum(s.background for s in stalls),
        "partial_swaps": sum(s.partial for s in stalls),
        "full_swaps": sum(not s.partial for s in stalls),
    }


def overlap_summary(ticks: list[TickStats]) -> dict:
    """Aggregate per-tick overlap efficiency for one serving run."""
    if not ticks:
        return {"ticks": 0, "overlap_efficiency": math.nan, "blocked_s": 0.0, "tick_wall_s": 0.0}
    wall = sum(t.wall_s for t in ticks)
    blocked = sum(t.blocked_s for t in ticks)
    return {
        "ticks": len(ticks),
        "overlap_efficiency": max(0.0, 1.0 - blocked / wall) if wall > 0 else math.nan,
        "blocked_s": blocked,
        "tick_wall_s": wall,
    }


def segment_summary(observations) -> dict:
    """Aggregate profiled per-segment wall times by (model, engine, span).

    The executor's profiled ticks produce ``SegmentObservation``s; this is
    the report-side rollup — mean/p50 wall per distinct segment binding,
    so a serving report shows where each plan revision actually spent its
    time (the same numbers the replanner's EMA consumes).
    """
    by_seg: dict[tuple, list[float]] = {}
    for o in observations:
        by_seg.setdefault((o.model_index, o.engine, o.lo, o.hi), []).append(o.wall_s)
    return {
        f"m{mi}@E{eng}[{lo}:{hi})": {
            "samples": len(ws),
            "wall_mean_ms": sum(ws) / len(ws) * 1e3,
            "wall_p50_ms": percentile(ws, 50) * 1e3,
        }
        for (mi, eng, lo, hi), ws in sorted(by_seg.items())
    }


def percentile(samples: list[float], pct: float) -> float:
    """Nearest-rank percentile; pct in [0, 100]."""
    if not samples:
        return math.nan
    s = sorted(samples)
    rank = max(1, math.ceil(pct / 100.0 * len(s)))
    return s[min(rank, len(s)) - 1]


@dataclasses.dataclass
class StreamMetrics:
    name: str
    latencies_s: list[float] = dataclasses.field(default_factory=list)
    completed: int = 0

    def record(self, latency_s: float):
        self.latencies_s.append(latency_s)
        self.completed += 1

    def summary(self) -> dict:
        return {
            "completed": self.completed,
            "latency_p50_ms": percentile(self.latencies_s, 50) * 1e3,
            "latency_p99_ms": percentile(self.latencies_s, 99) * 1e3,
            "latency_mean_ms": (
                sum(self.latencies_s) / len(self.latencies_s) * 1e3 if self.latencies_s else math.nan
            ),
        }


class ServeMetrics:
    """Aggregates completions across streams for one serving run."""

    def __init__(self, stream_names: list[str]):
        self.streams = {n: StreamMetrics(n) for n in stream_names}
        self.ticks: list[TickStats] = []

    def record(self, stream: str, latency_s: float):
        self.streams[stream].record(latency_s)

    def record_tick(self, stats: TickStats):
        self.ticks.append(stats)

    def report(self, wall_s: float) -> dict:
        all_lat = [l for m in self.streams.values() for l in m.latencies_s]
        total = sum(m.completed for m in self.streams.values())
        return {
            "streams": len(self.streams),
            "frames": total,
            "wall_s": wall_s,
            "aggregate_fps": total / wall_s if wall_s > 0 else math.inf,
            "latency_p50_ms": percentile(all_lat, 50) * 1e3,
            "latency_p99_ms": percentile(all_lat, 99) * 1e3,
            "overlap": overlap_summary(self.ticks),
            "per_stream": {n: m.summary() for n, m in self.streams.items()},
        }
