"""Multi-process serving fleet: R worker processes behind the IPC router.

PR 8's ``FleetServer`` replicates *within* one Python process, where the
GIL and a single XLA client cap how far ``--replicas`` can scale. This
module moves the replicas out of process: ``ProcFleetServer`` spawns R
worker processes, each hosting one ``(PlanIR, MultiStreamServer)``
replica group rebuilt from the same serialized plan, with the existing
sticky deadline-aware ``FleetRouter`` running in the front process.

Transport
    Control flows over duplex pipes as ``(method, kwargs)`` RPCs with
    per-call timeouts; frame payloads cross in
    ``multiprocessing.shared_memory`` ring buffers sized from the plan's
    input shapes (``ShmRing``), so arrays never pickle through the pipe
    on the hot path (oversized frames fall back to inline transfer).
    Workers are spawned with the ``spawn`` start method — fork is unsafe
    once JAX has started XLA threads in the front process.

Determinism
    The plan crosses as its pinned ``PlanIR`` JSON and models re-stage
    from the same seeded build parameters, so a worker's replica group is
    bit-identical to one built in-process. Routing is sticky per stream
    (frame order is preserved per stream), so per-stream outputs from a
    2-worker fleet are bit-exact vs a single executor fed the same
    arrivals — the ``workers=0`` in-process fleet stays the fast path
    and the oracle for that pin.

Calibration
    Workers' replanners each hold a process-local ``OnlineCost``. The
    front periodically pulls every worker's raw EMA sums, merges them
    magnitude-weighted (``merge_calibration`` — the same weighted-ratio
    idiom ``OnlineCost.observe`` applies per sample), broadcasts the
    merged state back, and mirrors it into a front-process ``OnlineCost``
    whose atomic ``save_calibration`` keeps ``--calibration-cache`` as
    the restart path (workers warm-start from it on spawn).

Failure
    A worker that dies or misses a heartbeat (any RPC error/timeout) is
    evicted: the router unpins its sticky streams so they re-route to
    survivors, and the event is recorded under ``worker_failures`` in
    the fleet report.
"""
from __future__ import annotations

import atexit
import dataclasses
import math
import os
import time
from multiprocessing import get_context, shared_memory
from typing import Any

import numpy as np

from ..core.cost_model import OnlineCost, make_cost_provider
from .fleet import FleetRouter
from .metrics import fleet_report, metrics_from_payload

_COST_NAMES = ("analytic", "measured", "blended", "online")


class WorkerError(RuntimeError):
    """A worker RPC failed (remote exception or transport fault)."""


class WorkerTimeout(WorkerError):
    """No reply within the per-call deadline — a missed heartbeat."""


class WorkerDied(WorkerError):
    """The worker process is gone (EOF / broken pipe / not started)."""


# ---------------------------------------------------------------------------
# Shared-memory frame transport
# ---------------------------------------------------------------------------


class ShmRing:
    """Fixed-slot shared-memory ring buffer for frame payloads.

    The front process creates one ring per worker, sized from the plan's
    input shapes (``slot_bytes`` covers the largest expected frame);
    ``put`` copies an array into the next slot round-robin and returns a
    JSON-able descriptor the worker resolves with ``read``. Slot reuse
    is safe without per-slot locks because every offer is a synchronous
    RPC: the worker copies the payload out before replying, so by the
    time the ring wraps the earlier slots are free again.
    """

    def __init__(self, slot_bytes: int, slots: int = 8, name: str | None = None):
        if slot_bytes < 1 or slots < 1:
            raise ValueError(f"need positive slot_bytes/slots, got {slot_bytes}/{slots}")
        self.slot_bytes = int(slot_bytes)
        self.slots = int(slots)
        if name is None:
            self.shm = shared_memory.SharedMemory(
                create=True, size=self.slot_bytes * self.slots
            )
            self._owner = True
        else:
            self.shm = shared_memory.SharedMemory(name=name)
            self._owner = False
        self._next = 0

    @property
    def name(self) -> str:
        return self.shm.name

    def fits(self, nbytes: int) -> bool:
        return nbytes <= self.slot_bytes

    def put(self, arr: np.ndarray) -> dict:
        arr = np.ascontiguousarray(arr)
        if not self.fits(arr.nbytes):
            raise ValueError(f"frame of {arr.nbytes} B exceeds slot size {self.slot_bytes} B")
        slot = self._next
        self._next = (self._next + 1) % self.slots
        off = slot * self.slot_bytes
        self.shm.buf[off : off + arr.nbytes] = arr.tobytes()
        return {"slot": slot, "shape": list(arr.shape), "dtype": str(arr.dtype)}

    def read(self, desc: dict) -> np.ndarray:
        shape = tuple(int(d) for d in desc["shape"])
        dtype = np.dtype(desc["dtype"])
        count = math.prod(shape) if shape else 1
        off = int(desc["slot"]) * self.slot_bytes
        out = np.frombuffer(self.shm.buf, dtype=dtype, count=count, offset=off)
        return out.reshape(shape).copy()

    def close(self):
        try:
            self.shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self):
        if self._owner:
            try:
                self.shm.unlink()
            except (OSError, FileNotFoundError):
                pass


def _encode_frame(frame: Any, ring: ShmRing | None) -> dict:
    """Frame -> wire descriptor: shared-memory slot when it fits, inline
    array (pipe pickle) as the fallback for oversized payloads."""
    arr = np.asarray(frame)
    if ring is not None and ring.fits(arr.nbytes):
        desc = ring.put(arr)
        desc["via"] = "shm"
        return desc
    return {"via": "pipe", "array": arr}


def _decode_frame(desc: dict, ring: ShmRing | None) -> np.ndarray:
    if desc.get("via") == "shm":
        if ring is None:
            raise WorkerError("shm frame descriptor but no ring attached")
        return ring.read(desc)
    return desc["array"]


# ---------------------------------------------------------------------------
# Calibration merge
# ---------------------------------------------------------------------------


def merge_calibration(states: list[dict]) -> dict:
    """Magnitude-weighted merge of per-worker ``OnlineCost.state()`` dicts.

    Per key, the merged (num, den) are the *means* of the contributing
    workers' decayed sums, so the fleet-wide scale is
    ``sum(num_w) / sum(den_w)`` — each worker's vote weighted by its
    decayed expected magnitude, exactly the weighted-ratio idiom
    ``OnlineCost.observe`` applies to individual samples: a worker that
    has only seen near-empty spans cannot swing the fleet calibration
    away from the workers carrying heavyweight segments."""
    merged: dict = {}
    for key in sorted({k for s in states for k in s}):
        pairs = [
            (float(s[key]["num"]), float(s[key]["den"]))
            for s in states
            if key in s and float(s[key]["num"]) > 0.0 and float(s[key]["den"]) > 0.0
        ]
        if not pairs:
            continue
        merged[key] = {
            "num": sum(n for n, _ in pairs) / len(pairs),
            "den": sum(d for _, d in pairs) / len(pairs),
        }
    return merged


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------


def _worker_main(spec: dict, conn) -> None:
    """Entry point of one worker process (``spawn`` target).

    Builds the replica group from the serialized spec — models re-staged
    deterministically from the seeded build params, plan rebuilt from its
    ``PlanIR`` JSON — then serves RPCs from the front-process router.
    Between RPCs the worker *self-ticks* whenever frames are outstanding,
    so R workers genuinely service their streams in parallel; the front's
    ``poll``/``tick`` RPCs sample load and pending counts, they are not
    what drives service."""
    ring: ShmRing | None = None
    try:
        import jax

        from ..core.engine import DevicePool
        from ..core.plan_ir import PlanIR
        from .admission import AdmissionConfig
        from .batching import BatchConfig
        from .demo import _build_pix_yolo_models
        from .replanner import ReplanConfig, Replanner
        from .server import MultiStreamServer
        from .streams import StreamSpec
        from .traffic import SLOPolicy

        models, _, (gpu, dla) = _build_pix_yolo_models(**spec["build"])
        plan = PlanIR.from_json(spec["plan_json"])
        streams = [
            StreamSpec(
                s["name"],
                s["model_index"],
                slo=SLOPolicy(**s["slo"]) if s.get("slo") else None,
            )
            for s in spec["streams"]
        ]
        pool = DevicePool((dla, gpu)).worker_pool(spec["worker"], spec["n_workers"])

        online: OnlineCost | None = None
        replanner = None
        if spec.get("replan") is not None:
            provider = make_cost_provider(spec.get("cost", "analytic"))
            online = provider if isinstance(provider, OnlineCost) else OnlineCost(base=provider)
            calib = spec.get("calibration_path")
            if calib and os.path.exists(calib):
                online.load_calibration(calib)
            cfg = spec["replan"]
            replanner = Replanner(
                [m.graph for m in models],
                [dla, gpu],
                config=ReplanConfig(**cfg) if cfg else None,
                base_provider=online,
            )
            online = replanner.online  # the instance the executor actually feeds

        skw = spec["server"]
        adm = skw.get("admission")
        server = MultiStreamServer(
            models,
            plan,
            streams,
            max_queue=skw["max_queue"],
            microbatch=skw["microbatch"],
            merge_batches=skw["merge_batches"],
            batching=BatchConfig.from_dict(skw.get("batching")),
            place_fns=pool.place_fns(0, 1),
            dispatch=skw["dispatch"],
            jit_segments=skw["jit_segments"],
            replanner=replanner,
            admission=AdmissionConfig(**adm) if adm else None,
            resolution_flexible=skw["resolution_flexible"],
        )

        if spec.get("warm", True):
            # compile/warm every stream's service path before declaring
            # ready, then wipe the traces: warm frames must pollute
            # neither the metrics window nor the drained outputs
            img = spec["build"].get("img", 64)
            z = np.zeros((1, img, img, 3), np.float32)
            for s in streams:
                server.offer(s.name, z)
            server.executor.run_until_drained()
            server.finish()
            server.reset_metrics()
            for frames_out in server.executor.outputs.values():
                frames_out.clear()  # keep the per-stream keys, drop warm frames

        if spec.get("shm"):
            ring = ShmRing(
                spec["shm"]["slot_bytes"], spec["shm"]["slots"], name=spec["shm"]["name"]
            )
    except Exception as e:  # build failure: tell the front, then exit
        try:
            conn.send(("err", f"worker build failed: {type(e).__name__}: {e}"))
        except (OSError, ValueError, BrokenPipeError):
            pass
        return

    def load_info() -> dict:
        return {
            "load": server.executor.pending + len(server._backlog),
            "pending": server.executor.pending,
        }

    def handle(method: str, kw: dict) -> dict:
        if method == "poll":
            return load_info()
        if method == "offer":
            decision = server.offer(kw["target"], _decode_frame(kw["frame"], ring))
            return {"decision": decision, **load_info()}
        if method == "submit":
            server.submit(kw["model_index"], _decode_frame(kw["frame"], ring))
            return load_info()
        if method == "tick":
            if server.executor.pending:
                server.tick()
            return load_info()
        if method == "pump":
            server.pump()
            return load_info()
        if method == "drain":
            outs = server.drain()
            return {"outputs": jax.tree.map(np.asarray, outs), **load_info()}
        if method == "finish":
            server.finish()
            return load_info()
        if method == "reset_metrics":
            server.reset_metrics()
            return load_info()
        if method == "report":
            return {
                "report": server.report(),
                "metrics": server.metrics.to_payload(),
                **load_info(),
            }
        if method == "calib_pull":
            return {"state": online.state() if online is not None else {}}
        if method == "calib_push":
            if online is not None:
                online.load_state(kw["state"])
            return {}
        raise ValueError(f"unknown worker RPC {method!r}")

    try:
        conn.send(("ready", {"worker": spec["worker"], "pid": os.getpid()}))
        while True:
            # serve an RPC when one is queued; otherwise self-tick any
            # outstanding work (poll with 0 timeout while busy so service
            # never waits on the front, 50 ms while idle to stay cheap)
            if conn.poll(0 if server.executor.pending else 0.05):
                try:
                    method, kw = conn.recv()
                except (EOFError, OSError):
                    return
                if method == "shutdown":
                    try:
                        conn.send(("ok", {}))
                    except (OSError, ValueError, BrokenPipeError):
                        pass
                    return
                try:
                    conn.send(("ok", handle(method, kw)))
                except (OSError, ValueError, BrokenPipeError):
                    return
                except Exception as e:
                    try:
                        conn.send(("err", f"{type(e).__name__}: {e}"))
                    except (OSError, ValueError, BrokenPipeError):
                        return
            elif server.executor.pending:
                server.tick()
    finally:
        if ring is not None:
            ring.close()
        try:
            conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Front-process worker handle
# ---------------------------------------------------------------------------


class RemoteReplica:
    """Front-process handle to one worker: the ``fleet.LocalReplica``
    surface over the RPC pipe, so the router and the fleet server are
    transport-agnostic. ``load``/``pending`` are caches folded from every
    reply (each RPC reply carries them), so the router's pick metric
    costs no extra round-trips."""

    def __init__(
        self,
        index: int,
        spec: dict,
        ring: ShmRing,
        *,
        ctx,
        rpc_timeout_s: float = 300.0,
        heartbeat_timeout_s: float = 60.0,
    ):
        self.index = index
        self.ring = ring
        self.rpc_timeout_s = rpc_timeout_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.alive = False
        self.load = 0
        self.pending = 0
        self._slos = {
            s["name"]: (s["slo"]["deadline_ms"] / 1e3 if s.get("slo") else None)
            for s in spec["streams"]
        }
        self.conn, child = ctx.Pipe(duplex=True)
        self.process = ctx.Process(
            target=_worker_main, args=(spec, child), name=f"repro-worker-{index}", daemon=True
        )
        self.process.start()
        child.close()

    def wait_ready(self, timeout_s: float):
        """Block until the worker finishes building (ready handshake).
        Split from the constructor so a fleet can spawn all workers first
        and let their builds overlap."""
        tag, payload = self._recv(timeout_s, "start")
        if tag != "ready":
            raise WorkerError(f"worker {self.index} failed to start: {payload}")
        self.alive = True

    # -- transport ----------------------------------------------------------

    def _recv(self, timeout_s: float, method: str):
        try:
            if not self.conn.poll(timeout_s):
                raise WorkerTimeout(
                    f"worker {self.index}: no reply to {method!r} within {timeout_s:.1f}s"
                )
            return self.conn.recv()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise WorkerDied(f"worker {self.index} died during {method!r}: {e!r}") from e

    def call(self, method: str, *, timeout: float | None = None, **kw) -> dict:
        if not self.alive:
            raise WorkerDied(f"worker {self.index} is not alive")
        try:
            self.conn.send((method, kw))
        except (OSError, ValueError, BrokenPipeError) as e:
            raise WorkerDied(f"worker {self.index}: send {method!r} failed: {e!r}") from e
        tag, payload = self._recv(timeout if timeout is not None else self.rpc_timeout_s, method)
        if tag == "err":
            raise WorkerError(f"worker {self.index} {method}: {payload}")
        return payload

    def _fold(self, out: dict) -> dict:
        self.load = int(out.get("load", self.load))
        self.pending = int(out.get("pending", self.pending))
        return out

    # -- LocalReplica surface -----------------------------------------------

    def offer(self, target: int | str, frame: Any) -> str:
        out = self._fold(self.call("offer", target=target, frame=_encode_frame(frame, self.ring)))
        return out["decision"]

    def submit(self, model_index: int, frame: Any):
        self._fold(
            self.call("submit", model_index=model_index, frame=_encode_frame(frame, self.ring))
        )

    def tick(self):
        if self.load or self.pending:
            self._fold(self.call("tick"))
        else:
            self.poll_load()

    def poll_load(self) -> int:
        """Heartbeat + load refresh (cheap; tighter timeout than service
        RPCs — a worker that can't answer this has missed its heartbeat)."""
        self._fold(self.call("poll", timeout=self.heartbeat_timeout_s))
        return self.load

    def pump(self):
        self._fold(self.call("pump"))

    def drain(self) -> dict:
        out = self._fold(self.call("drain", timeout=max(self.rpc_timeout_s, 600.0)))
        return out["outputs"]

    def finish(self):
        self._fold(self.call("finish"))

    def reset_metrics(self):
        self._fold(self.call("reset_metrics"))

    def deadline_of(self, stream: str) -> float | None:
        return self._slos.get(stream)

    def report(self) -> dict:
        """Raw worker report RPC: ``{"report", "metrics", ...}`` — the
        fleet server merges the serialized metrics payloads itself."""
        return self._fold(self.call("report"))

    def metrics(self):
        return metrics_from_payload(self.call("report")["metrics"])

    def calib_pull(self) -> dict:
        return self.call("calib_pull")["state"]

    def calib_push(self, state: dict):
        self.call("calib_push", state=state)

    def close(self, graceful: bool = True):
        if self.process is None:
            return
        if graceful and self.alive:
            try:
                self.conn.send(("shutdown", {}))
                if self.conn.poll(5.0):
                    self.conn.recv()
            except (OSError, ValueError, EOFError, BrokenPipeError):
                pass
        self.alive = False
        try:
            self.conn.close()
        except OSError:
            pass
        p, self.process = self.process, None
        p.join(timeout=5.0)
        if p.is_alive():
            p.terminate()
            p.join(timeout=5.0)
        if p.is_alive():
            p.kill()
            p.join(timeout=5.0)
        if self.ring is not None:
            self.ring.close()
            self.ring.unlink()
            self.ring = None


# ---------------------------------------------------------------------------
# Front-process fleet server
# ---------------------------------------------------------------------------


class _ProcExecutorView:
    """Duck-typed ``server.executor`` stand-in for open-loop drivers:
    ``pending`` totals the cached outstanding counts across alive
    workers (refreshed from every RPC reply)."""

    def __init__(self, fleet: "ProcFleetServer"):
        self._fleet = fleet

    @property
    def pending(self) -> int:
        return sum(h.pending for h in self._fleet.handles if h.alive)

    @property
    def merge_batches(self) -> list:
        return list(self._fleet.merge_batches)

    @property
    def dispatch(self) -> str:
        return self._fleet.dispatch


_DEFAULT_BUILD = {
    "img": 64, "base": 8, "n_pix": 4, "n_yolo": 1,
    "seed": 0, "norm": "batch", "granularity": "coarse",
}


class ProcFleetServer:
    """R worker *processes* behind the sticky deadline-aware router.

    Mirrors the ``MultiStreamServer``/``FleetServer`` surface (``offer``/
    ``submit``/``tick``/``pump``/``drain``/``finish``/``reset_metrics``/
    ``report``) so the open-loop traffic driver and the benches run
    unchanged. ``close()`` shuts the workers down (also registered with
    ``atexit`` as a safety net); the server is a context manager.

    ``cost`` must be a provider *name* (the spec crosses a process
    boundary as JSON); ``replan`` is None (off), ``{}`` (default
    ``ReplanConfig``) or a ``ReplanConfig``-field dict. When replanning
    is on, worker calibrations sync fleet-wide every
    ``calib_sync_every`` front ticks (see ``merge_calibration``) and the
    merged state checkpoints atomically to ``calibration_path``."""

    def __init__(
        self,
        plan,
        streams,
        *,
        workers: int = 2,
        build: dict | None = None,
        router_seed: int = 0,
        max_queue: int = 4,
        microbatch: int = 1,
        merge_batches: bool | list = False,
        batching=None,
        dispatch: str = "overlapped",
        jit_segments: bool = True,
        admission=None,
        resolution_flexible: bool | list = False,
        cost: str = "analytic",
        replan: dict | None = None,
        calibration_path: str | None = None,
        calib_sync_every: int = 16,
        warm_start: bool = True,
        rpc_timeout_s: float = 300.0,
        start_timeout_s: float = 600.0,
        heartbeat_timeout_s: float = 60.0,
        shm_slots: int = 8,
    ):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if cost not in _COST_NAMES:
            raise ValueError(
                f"multi-process fleet needs a serializable cost provider name "
                f"{_COST_NAMES}, got {cost!r}"
            )
        if admission is not None and getattr(admission, "degrade_frame", None) is not None:
            raise ValueError("custom degrade_frame callables cannot cross process boundaries")
        self.plan = plan
        self.streams = list(streams)
        self.n_workers = workers
        self.merge_batches = (
            list(merge_batches)
            if isinstance(merge_batches, (list, tuple))
            else [merge_batches]
        )
        self.dispatch = dispatch
        self.calibration_path = calibration_path
        self.calib_sync_every = calib_sync_every
        self._replan_enabled = replan is not None
        self.worker_failures: list[dict] = []
        self._slos = {
            s.name: (s.slo.deadline_s if s.slo is not None else None) for s in self.streams
        }
        self._t0: float | None = None
        self._ticks = 0
        self._closed = False

        build = dict(_DEFAULT_BUILD, **(build or {}))
        adm_payload = None
        if admission is not None:
            adm_payload = dataclasses.asdict(admission)
            adm_payload.pop("degrade_frame", None)
        # front-process mirror of the fleet calibration: holds the merged
        # EMA state and owns the atomic --calibration-cache checkpoints
        # (base matches the workers' OnlineCost base so the file round-trips)
        mirror_base = "blended" if cost == "online" else cost
        self._calib = OnlineCost(base=make_cost_provider(mirror_base))

        img = build.get("img", 64)
        slot_bytes = 4 * img * img * 3  # f32 NHWC frame, batch 1 — the plan's input shape
        ctx = get_context("spawn")  # fork is unsafe with live XLA threads
        self.handles: list[RemoteReplica] = []
        try:
            for w in range(workers):
                ring = ShmRing(slot_bytes, shm_slots)
                spec = {
                    "worker": w,
                    "n_workers": workers,
                    "plan_json": plan.to_json(),
                    "build": build,
                    "streams": [
                        {
                            "name": s.name,
                            "model_index": s.model_index,
                            "slo": (
                                {
                                    "deadline_ms": s.slo.deadline_ms,
                                    "tier": s.slo.tier,
                                    "name": s.slo.name,
                                }
                                if s.slo is not None
                                else None
                            ),
                        }
                        for s in self.streams
                    ],
                    "server": {
                        "max_queue": max_queue,
                        "microbatch": microbatch,
                        "merge_batches": merge_batches
                        if isinstance(merge_batches, bool)
                        else list(merge_batches),
                        "batching": batching.to_dict() if batching is not None else None,
                        "dispatch": dispatch,
                        "jit_segments": jit_segments,
                        "admission": adm_payload,
                        "resolution_flexible": resolution_flexible
                        if isinstance(resolution_flexible, bool)
                        else list(resolution_flexible),
                    },
                    "cost": cost,
                    "replan": replan,
                    "calibration_path": calibration_path,
                    "warm": warm_start,
                    "shm": {"name": ring.name, "slots": shm_slots, "slot_bytes": slot_bytes},
                }
                self.handles.append(
                    RemoteReplica(
                        w, spec, ring, ctx=ctx,
                        rpc_timeout_s=rpc_timeout_s,
                        heartbeat_timeout_s=heartbeat_timeout_s,
                    )
                )
            # handshake after spawning everything: worker builds overlap
            for h in self.handles:
                h.wait_ready(start_timeout_s)
        except BaseException:
            for h in self.handles:
                try:
                    h.close(graceful=False)
                except Exception:
                    pass
            raise
        self.router = FleetRouter(workers, seed=router_seed)
        self.executor = _ProcExecutorView(self)
        atexit.register(self.close)

    # -- failure handling ----------------------------------------------------

    def _evict(self, worker: int, reason: str):
        h = self.handles[worker]
        if not h.alive:
            return
        h.alive = False
        migrated = self.router.evict(worker)
        self.worker_failures.append(
            {
                "worker": worker,
                "reason": str(reason),
                "migrated_streams": migrated,
                "lost_in_flight": int(h.pending),
            }
        )
        h.pending = 0
        h.load = 0
        try:
            h.close(graceful=False)
        except Exception:
            pass

    def _alive(self):
        return [(w, h) for w, h in enumerate(self.handles) if h.alive]

    def _loads(self) -> list[int]:
        return [h.load for h in self.handles]

    # -- open-loop intake ----------------------------------------------------

    def offer(self, target: int | str, frame: Any) -> str:
        """Route one arriving frame to a worker, then run that worker's
        admission ladder remotely. A worker that fails mid-offer is
        evicted and the frame re-routes to a survivor."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        for _ in range(self.n_workers):
            if isinstance(target, str):
                w = self.router.route_arrival(target, self._loads(), self._slos.get(target))
            else:
                w = self.router.pick(self._loads())
                self.router.routed_frames[w] += 1
            try:
                return self.handles[w].offer(target, frame)
            except WorkerError as e:
                self._evict(w, f"offer: {e}")
        raise RuntimeError("no alive workers to route to")

    def tick(self):
        """One service pass: tick every busy worker (idle ones get a
        heartbeat poll), plus the periodic fleet-wide calibration sync."""
        self._ticks += 1
        for w, h in self._alive():
            try:
                h.tick()
            except WorkerError as e:
                self._evict(w, f"tick: {e}")
        if (
            self._replan_enabled
            and self.calib_sync_every
            and self._ticks % self.calib_sync_every == 0
        ):
            self.sync_calibration()

    def finish(self):
        for w, h in self._alive():
            try:
                h.finish()
            except WorkerError as e:
                self._evict(w, f"finish: {e}")
        if self._replan_enabled:
            self.sync_calibration()

    def reset_metrics(self):
        for w, h in self._alive():
            try:
                h.reset_metrics()
            except WorkerError as e:
                self._evict(w, f"reset_metrics: {e}")
        self.router.reset_counts()
        self._t0 = None

    # -- closed-loop intake --------------------------------------------------

    def submit(self, model_index: int, frame: Any):
        if self._t0 is None:
            self._t0 = time.perf_counter()
        for _ in range(self.n_workers):
            w = self.router.pick(self._loads())
            self.router.routed_frames[w] += 1
            try:
                return self.handles[w].submit(model_index, frame)
            except WorkerError as e:
                self._evict(w, f"submit: {e}")
        raise RuntimeError("no alive workers to route to")

    def pump(self):
        for w, h in self._alive():
            try:
                h.pump()
            except WorkerError as e:
                self._evict(w, f"pump: {e}")

    def drain(self) -> dict:
        outs: dict = {}
        for w, h in self._alive():
            try:
                for name, vals in h.drain().items():
                    outs.setdefault(name, []).extend(vals)
            except WorkerError as e:
                self._evict(w, f"drain: {e}")
        return outs

    # -- calibration sync ----------------------------------------------------

    def sync_calibration(self) -> dict:
        """Pull every worker's raw EMA sums, merge magnitude-weighted,
        broadcast the merged state back, mirror it into the front-process
        ``OnlineCost`` and checkpoint ``calibration_path`` atomically.
        Returns the merged state (empty when nothing is calibrated)."""
        states = []
        for w, h in self._alive():
            try:
                st = h.calib_pull()
                if st:
                    states.append(st)
            except WorkerError as e:
                self._evict(w, f"calib_pull: {e}")
        merged = merge_calibration(states)
        if not merged:
            return {}
        self._calib.load_state(merged)
        for w, h in self._alive():
            try:
                h.calib_push(merged)
            except WorkerError as e:
                self._evict(w, f"calib_push: {e}")
        if self.calibration_path:
            try:
                self._calib.save_calibration(self.calibration_path)
            except OSError:
                pass
        return merged

    # -- reporting -----------------------------------------------------------

    def report(self) -> dict:
        """Fleet-merged serving report over the front wall clock: worker
        metrics ledgers cross as serialized payloads and merge through
        the same ``fleet_report`` the in-process fleet uses, plus router
        state, per-worker reports, and the failure log."""
        wall = (time.perf_counter() - self._t0) if self._t0 is not None else 0.0
        payloads, reps, alive_workers = [], [], []
        for w, h in self._alive():
            try:
                out = h.report()
                payloads.append(out["metrics"])
                reps.append(out["report"])
                alive_workers.append(w)
            except WorkerError as e:
                self._evict(w, f"report: {e}")
        if not payloads:
            raise RuntimeError("no alive workers to report")
        rep = fleet_report(
            [metrics_from_payload(p) for p in payloads],
            wall,
            routed_counts=self.router.routed_frames,
        )
        rep["workers"] = self.n_workers
        rep["alive_workers"] = alive_workers
        rep["dispatch"] = self.dispatch
        rep["plan_revision"] = max((r.get("plan_revision", 0) for r in reps), default=0)
        rep["router"] = self.router.summary()
        rep["worker_failures"] = list(self.worker_failures)
        rep["per_worker"] = reps
        if self._replan_enabled:
            rep["replan"] = [r.get("replan") for r in reps]
            rep["fleet_calibration"] = self._calib.snapshot()
        return rep

    # -- lifecycle -----------------------------------------------------------

    def close(self):
        """Shut every worker down (graceful RPC, then terminate) and
        release the shared-memory rings. Idempotent."""
        if self._closed:
            return
        self._closed = True
        try:
            atexit.unregister(self.close)
        except Exception:
            pass
        for h in self.handles:
            try:
                h.close()
            except Exception:
                pass

    def __enter__(self) -> "ProcFleetServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
