"""Shared construction for the Pix2Pix + YOLO serving demos: one place
builds the staged models, the N-model plan, and the stream specs that the
example, the launch CLI, and the benchmark all drive.

``cost`` selects the planner's CostProvider (``analytic`` — the paper's
roofline — or ``measured``/``blended`` for XLA-measured per-layer costs),
``norm`` selects the Pix2Pix norm layer (``instance``/``group`` build the
batch-independent variant whose streams the executor may merge-batch).

``build_pix_yolo_serving`` keeps the historical ``NModelPlan`` return for
callers that read ``plan.cycle_time``/``plan.schedule``; new code should
use ``serve.build_server`` (facade over ``repro.core.plan``), which
returns the ``PlanIR`` contract directly.
"""
from __future__ import annotations

import jax

from ..core.constraints import DLA_ANALOGUE_CONSTRAINTS
from ..core.cost_model import CostProvider, make_cost_provider
from ..core.engine import jetson_orin_engines
from ..core.pipeline import pix2pix_staged, yolo_staged
from ..core.scheduler import _nmodel_schedule_impl
from .streams import StreamSpec


def _build_pix_yolo_models(
    img: int = 64,
    base: int = 8,
    n_pix: int = 4,
    n_yolo: int = 1,
    seed: int = 0,
    norm: str = "batch",
    granularity: str = "coarse",
):
    """Staged Pix2Pix + YOLOv8 models, their stream specs, and the
    calibrated Jetson engine pair — the common substrate both
    ``build_pix_yolo_serving`` and the ``build_server`` facade plan over.
    Returns ``(models, streams, (gpu, dla))``."""
    from ..models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config

    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    cfg = Pix2PixConfig(img_size=img, base=base, deconv_mode="cropping", norm=norm)
    gen = Pix2PixGenerator(cfg)
    sm_pix = pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(seed))}, granularity=granularity)
    ycfg = YOLOv8Config(img_size=img)
    ym = YOLOv8(ycfg)
    sm_yolo = yolo_staged(ycfg, ym.init(jax.random.key(seed + 1)), granularity=granularity)
    streams = [StreamSpec(f"mri-{i}", 0) for i in range(n_pix)] + [
        StreamSpec(f"det-{i}", 1) for i in range(n_yolo)
    ]
    return [sm_pix, sm_yolo], streams, (gpu, dla)


def build_pix_yolo_serving(
    img: int = 64,
    base: int = 8,
    n_pix: int = 4,
    n_yolo: int = 1,
    seed: int = 0,
    norm: str = "batch",
    cost: str | CostProvider = "analytic",
    search: str = "auto",
    granularity: str = "coarse",
    stride: int = 1,
    max_cuts: int = 1,
    impl: str = "xla",
):
    """Returns ``(models, plan, streams, (gpu, dla))`` for ``n_pix``
    Pix2Pix reconstruction streams + ``n_yolo`` YOLOv8 detection streams
    over the calibrated Jetson engine pair.

    ``granularity="fine"`` plans on the *expanded* (primitive) graphs —
    the planner may cut inside YOLO's ``c2f``/``sppf``/``head`` blocks at
    stage-callable boundaries, and the staged models execute those fine
    cuts. ``stride`` thins the legal candidate set (the beam-tractability
    knob; only meaningful at fine granularity). ``max_cuts`` raises the
    per-model cut budget: k-segment routes ping-pong a model across the
    engines (``max_cuts=1`` is the paper's single partition point).
    ``impl`` selects the implementation-planning mode: ``xla`` (per-op
    lowering, default), ``pallas`` (force the fused serving kernels), or
    ``auto`` (per-segment argmin over both)."""
    provider = cost if isinstance(cost, CostProvider) else make_cost_provider(cost)
    models, streams, (gpu, dla) = _build_pix_yolo_models(
        img=img, base=base, n_pix=n_pix, n_yolo=n_yolo, seed=seed, norm=norm,
        granularity=granularity,
    )
    plan = _nmodel_schedule_impl(
        [m.graph for m in models],
        [dla, gpu],
        provider=provider,
        search=search,
        stride=stride,
        max_cuts=max_cuts,
        impl=impl,
    )
    return models, plan, streams, (gpu, dla)


def build_replanner(models, config=None, cost: str | CostProvider = "analytic"):
    """Replanner over the same graphs + engine pair (in plan order:
    ``[dla, gpu]``) that ``build_pix_yolo_serving`` planned with — attach
    it to the server/executor to close the online re-planning loop."""
    from .replanner import Replanner

    provider = cost if isinstance(cost, CostProvider) else make_cost_provider(cost)
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return Replanner(
        [m.graph for m in models], [dla, gpu], config=config, base_provider=provider
    )


def merge_flags_for(models) -> list[bool]:
    """Per-model ``merge_batches`` flags: merge only batch-independent
    staged models (Pix2Pix with instance/group norm; never YOLO, whose
    BatchNorm takes batch statistics)."""
    return [m.batch_independent for m in models]
