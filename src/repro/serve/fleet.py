"""Replicated serving fleet: R (plan, executor) groups behind a router.

The paper's headline scaling result is that two GPU-aware pipeline
instances double aggregate throughput over one — replicas, not just
better partitions, are the path past single-pipeline FPS. ``FleetServer``
runs R ``MultiStreamServer`` replicas over the *same* staged models and
the *same* ``PlanIR`` (one ``core.plan`` solved once over the per-replica
engine slice — the slices are value-identical, only their device binding
differs, so one solution serves every replica and the jit caches on the
shared models mean one compilation fleet-wide). A ``DevicePool``
(``core.engine``) supplies each replica's engine slice and the
``jax.device_put`` placement closures its executor applies per segment;
on 1-device hosts (CPU CI) every replica binds the virtual GPU/DLA pair
to the single device and placement collapses to identity.

``FleetRouter`` assigns work to replicas by load: least outstanding
frames, deadline-pressure tie-break (a replica already carrying
tight-deadline streams yields to one carrying slack), then a seeded
replica permutation so ties resolve deterministically. Assignment is
*sticky per stream* — a stream's frames always land on the replica that
took its first arrival, so stream state, frame ordering, and micro-batch
merging stay replica-local. Routing is therefore a placement decision,
never a numerics change: per stream, a fleet run is bit-exact with the
same arrivals pushed through a single executor.

Each replica keeps its own ``Replanner`` (re-plans trigger from
replica-local drift), but all replanners may share one thread-safe
``OnlineCost`` so calibration is fleet-wide — ``serve.facade`` wires
exactly that.
"""
from __future__ import annotations

import random
import time
from typing import Any

from ..core.engine import DevicePool
from .metrics import fleet_report, router_imbalance, segment_summary
from .server import MultiStreamServer


class FleetRouter:
    """Deterministic load-aware stream->replica assignment.

    ``assign`` is sticky: the first arrival of a stream picks a replica by
    (outstanding frames, accumulated deadline pressure, seeded rank) and
    every later arrival of that stream follows it. ``route_arrival``
    additionally counts per-replica routed frames for the imbalance
    metric. Given the same seed and the same arrival sequence + load
    observations, assignments replay identically.
    """

    def __init__(self, n_replicas: int, seed: int = 0):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.n_replicas = n_replicas
        self.seed = seed
        # fixed seeded permutation: the deterministic last-resort tie-break
        order = list(range(n_replicas))
        random.Random(seed).shuffle(order)
        self._rank = {r: i for i, r in enumerate(order)}
        self.assignments: dict[str, int] = {}
        self.routed_frames = [0] * n_replicas
        # sum of 1/deadline_s over streams stuck to each replica — the
        # deadline-aware tie-break (tighter deadlines weigh heavier)
        self._deadline_pressure = [0.0] * n_replicas
        self._alive = set(range(n_replicas))

    def replica_of(self, stream: str) -> int | None:
        return self.assignments.get(stream)

    @property
    def alive(self) -> list[int]:
        return sorted(self._alive)

    def evict(self, replica: int) -> list[str]:
        """Remove a replica from routing (worker death / heartbeat miss):
        it never receives another pick, its deadline pressure is zeroed,
        and its sticky streams are unpinned so each one's next arrival
        re-routes to a survivor. Returns the migrated stream names."""
        if replica not in self._alive:
            return []
        self._alive.discard(replica)
        migrated = sorted(s for s, r in self.assignments.items() if r == replica)
        for s in migrated:
            del self.assignments[s]
        self._deadline_pressure[replica] = 0.0
        return migrated

    def pick(self, loads) -> int:
        """Least-loaded alive replica for non-sticky work (warmup,
        model-index submissions): same ordering, no assignment recorded."""
        if not self._alive:
            raise RuntimeError("no alive replicas to route to")
        return min(
            self._alive,
            key=lambda r: (loads[r], self._deadline_pressure[r], self._rank[r]),
        )

    def assign(self, stream: str, loads, deadline_s: float | None = None) -> int:
        """Sticky replica for one stream given current per-replica loads
        (outstanding frames). ``deadline_s`` feeds the pressure tie-break."""
        r = self.assignments.get(stream)
        if r is None or r not in self._alive:
            r = self.pick(loads)
            self.assignments[stream] = r
            if deadline_s and deadline_s > 0:
                self._deadline_pressure[r] += 1.0 / deadline_s
        return r

    def route_arrival(self, stream: str, loads, deadline_s: float | None = None) -> int:
        r = self.assign(stream, loads, deadline_s)
        self.routed_frames[r] += 1
        return r

    def reset_counts(self):
        """Fresh measurement window: zero the routed-frame counters but
        keep sticky assignments (streams stay where their state lives)."""
        self.routed_frames = [0] * self.n_replicas

    def summary(self) -> dict:
        return {
            "replicas": self.n_replicas,
            "seed": self.seed,
            "alive": self.alive,
            "evicted": sorted(set(range(self.n_replicas)) - self._alive),
            "streams_assigned": len(self.assignments),
            "routed_frames": list(self.routed_frames),
            "imbalance": router_imbalance(self.routed_frames),
            "assignments": dict(self.assignments),
        }


class LocalReplica:
    """In-process replica handle: the surface the router fronts replicas
    through, whatever their transport.

    ``FleetServer`` wraps each thread-local ``MultiStreamServer`` in one
    of these; ``serve.multiproc.RemoteReplica`` implements the *same*
    surface over a worker-process RPC pipe. Routing, service, drain, and
    report-merging code is written against this interface only, so the
    fleet is transport-agnostic — ``workers=0`` (in-process) stays the
    fast path and the bit-exactness oracle for the process fleet.

    Surface: ``alive`` flag; ``load`` (outstanding frames + backlog, the
    router's pick metric) and ``pending`` properties; ``offer`` /
    ``submit`` / ``tick`` / ``pump`` / ``drain`` / ``finish`` /
    ``reset_metrics`` service calls; ``deadline_of`` for the router's
    pressure tie-break; ``metrics`` / ``report`` for the fleet merge;
    ``close`` for teardown (a no-op in-process)."""

    def __init__(self, server: MultiStreamServer):
        self.server = server
        self.alive = True

    @property
    def load(self) -> int:
        return self.server.executor.pending + len(self.server._backlog)

    @property
    def pending(self) -> int:
        return self.server.executor.pending

    def offer(self, target: int | str, frame: Any) -> str:
        return self.server.offer(target, frame)

    def submit(self, model_index: int, frame: Any):
        self.server.submit(model_index, frame)

    def tick(self):
        if self.server.executor.pending:
            self.server.tick()

    def pump(self):
        self.server.pump()

    def drain(self) -> dict:
        return self.server.drain()

    def finish(self):
        self.server.finish()

    def reset_metrics(self):
        self.server.reset_metrics()

    def deadline_of(self, stream: str) -> float | None:
        for s in self.server.executor.streams:
            if s.name == stream:
                return s.slo.deadline_s if s.slo is not None else None
        return None

    def metrics(self):
        return self.server.metrics

    def report(self) -> dict:
        return self.server.report()

    def close(self):
        pass


class _FleetExecutorView:
    """Duck-typed stand-in for ``server.executor`` as open-loop drivers
    read it: ``pending`` totals outstanding frames across replicas; other
    (read-only) attributes proxy to replica 0's executor. Mutations must
    target ``fleet.servers[r].executor`` explicitly."""

    def __init__(self, servers):
        self._servers = servers

    @property
    def pending(self) -> int:
        return sum(s.executor.pending for s in self._servers)

    def __getattr__(self, attr):
        return getattr(self._servers[0].executor, attr)


class FleetServer:
    """R replicated serving pipelines behind a sticky load-aware router.

    Mirrors the ``MultiStreamServer`` surface (``offer``/``submit``/
    ``tick``/``pump``/``drain``/``finish``/``reset_metrics``/``report``)
    so the open-loop traffic driver and the benches run unchanged; every
    constructor knob is applied to each replica. ``pool`` defaults to a
    ``DevicePool.discover()`` over the plan's engines.
    """

    def __init__(
        self,
        models,
        plan,
        streams,
        *,
        replicas: int = 2,
        pool: DevicePool | None = None,
        engines=None,
        router_seed: int = 0,
        max_queue: int = 4,
        microbatch: int = 1,
        merge_batches: bool | list[bool] = False,
        batching=None,
        dispatch: str = "overlapped",
        jit_segments: bool = True,
        replanners=None,
        admission=None,
        resolution_flexible: bool | list[bool] = False,
    ):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if pool is None:
            pool = DevicePool(engines) if engines is not None else DevicePool.discover()
        if replanners is not None and len(replanners) != replicas:
            raise ValueError(f"need {replicas} replanners, got {len(replanners)}")
        self.pool = pool
        self.plan = plan
        self.models = models
        self.n_replicas = replicas
        self.servers = [
            MultiStreamServer(
                models,
                plan,
                streams,
                max_queue=max_queue,
                microbatch=microbatch,
                merge_batches=merge_batches,
                batching=batching,
                place_fns=pool.place_fns(r, replicas),
                dispatch=dispatch,
                jit_segments=jit_segments,
                replanner=replanners[r] if replanners is not None else None,
                admission=admission,
                resolution_flexible=resolution_flexible,
            )
            for r in range(replicas)
        ]
        self.handles = [LocalReplica(s) for s in self.servers]
        self.router = FleetRouter(replicas, seed=router_seed)
        self.executor = _FleetExecutorView(self.servers)
        self._t0: float | None = None

    # -- routing ------------------------------------------------------------

    def _loads(self) -> list[int]:
        return [h.load for h in self.handles]

    def _deadline_of(self, stream: str) -> float | None:
        return self.handles[0].deadline_of(stream)

    # -- open-loop intake ---------------------------------------------------

    def offer(self, target: int | str, frame: Any) -> str:
        """Route one arriving frame to a replica, then run that replica's
        admission ladder. Named streams are sticky; model-index targets go
        to the least-loaded replica."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if isinstance(target, str):
            r = self.router.route_arrival(target, self._loads(), self._deadline_of(target))
        else:
            r = self.router.pick(self._loads())
            self.router.routed_frames[r] += 1
        return self.handles[r].offer(target, frame)

    def tick(self):
        """Service every replica with outstanding work (one executor tick
        each + metrics fold)."""
        for h in self.handles:
            h.tick()

    def finish(self):
        for h in self.handles:
            h.finish()

    def reset_metrics(self):
        """Fresh measurement window on every replica + zeroed router frame
        counters; sticky assignments and warmed executors are kept."""
        for h in self.handles:
            h.reset_metrics()
        self.router.reset_counts()
        self._t0 = None

    # -- closed-loop intake -------------------------------------------------

    def submit(self, model_index: int, frame: Any):
        if self._t0 is None:
            self._t0 = time.perf_counter()
        r = self.router.pick(self._loads())
        self.router.routed_frames[r] += 1
        self.handles[r].submit(model_index, frame)

    def pump(self):
        for h in self.handles:
            h.pump()

    def drain(self) -> dict:
        outs: dict = {}
        for h in self.handles:
            for name, vals in h.drain().items():
                outs.setdefault(name, []).extend(vals)
        return outs

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        """Fleet-merged serving report over the shared wall clock, with
        router state and the per-replica reports nested under it."""
        wall = (time.perf_counter() - self._t0) if self._t0 is not None else 0.0
        rep = fleet_report(
            [s.metrics for s in self.servers], wall, routed_counts=self.router.routed_frames
        )
        rep["dispatch"] = self.servers[0].executor.dispatch
        rep["plan_revision"] = max(s.executor.plan_revision for s in self.servers)
        rep["router"] = self.router.summary()
        if any(s.replanner is not None for s in self.servers):
            rep["replan"] = [
                s.replanner.summary() if s.replanner is not None else None for s in self.servers
            ]
            rep["segments"] = segment_summary(
                [o for s in self.servers for o in s.executor.segment_obs]
            )
        rep["per_replica"] = [s.report() for s in self.servers]
        return rep
