"""Pix2Pix (Isola et al., CVPR'17) — U-Net generator + PatchGAN discriminator,
following the TF tutorial architecture the paper uses ([27], Fig. 5):
8 downsample blocks / 7 upsample blocks + final deconv, generator params
54,425,859 for 3-channel I/O (matches paper Table II exactly).

``deconv_mode`` selects the paper's hardware-aware variants:
  * "padded"   — original: transposed conv with torch padding=1 (ONE fused
                 op; violates the DLA-analogue 'deconv padding must be zero').
  * "cropping" — pad-free deconv + Crop2D(1). Numerically IDENTICAL to
                 "padded" (paper eq. 5+7 == eq. 6); engine-legal.
  * "conv"     — pad-free deconv + 3x3 VALID conv (paper eq. 8/9): adds
                 parameters (64,637,268 — Table II) and capacity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..core.graph import LayerGraph, conv_meta, pointwise_meta
from ..nn import (
    BatchNorm2D,
    Conv2D,
    ConvTranspose2D,
    Crop2D,
    GroupNorm2D,
    InstanceNorm2D,
    Module,
    leaky_relu,
)

DOWN_CHANNELS = (64, 128, 256, 512, 512, 512, 512, 512)
UP_CHANNELS = (512, 512, 512, 512, 256, 128, 64)


@dataclasses.dataclass(frozen=True)
class Pix2PixConfig:
    name: str = "pix2pix"
    img_size: int = 256
    in_channels: int = 3
    out_channels: int = 3
    deconv_mode: str = "padded"  # padded | cropping | conv
    deconv_backend: str = "xla"  # "xla" | "pallas" (phase-decomposed kernel)
    # "batch" is the TF-tutorial original (batch stats at inference too);
    # "instance"/"group" are batch-independent, so merged micro-batches
    # (serve.StreamExecutor merge_batches) leave every frame's math intact
    norm: str = "batch"  # batch | instance | group
    norm_groups: int = 8
    base: int = 64
    dropout_rate: float = 0.5
    lambda_l1: float = 100.0
    act_dtype: Any = jnp.float32

    @property
    def batch_independent(self) -> bool:
        """True when per-frame outputs do not depend on batch companions."""
        return self.norm in ("instance", "group")

    def norm2d(self, ch: int):
        if self.norm == "batch":
            return BatchNorm2D(ch)
        if self.norm == "instance":
            return InstanceNorm2D(ch)
        if self.norm == "group":
            return GroupNorm2D(ch, groups=math.gcd(self.norm_groups, ch))
        raise ValueError(f"unknown norm {self.norm!r} (want batch|instance|group)")

    @property
    def n_downs(self):
        # downsample to 1x1 bottleneck (8 blocks at 256; fewer on smoke sizes)
        return int(math.log2(self.img_size))

    def down_channels(self):
        b = self.base
        return tuple(min(8 * b, b * (2**i)) for i in range(self.n_downs))

    def up_channels(self):
        return tuple(reversed(self.down_channels()[:-1]))


@dataclasses.dataclass(frozen=True)
class UpBlockDeconv(Module):
    """One upsampling stage in the configured deconv mode.

    ``backend="pallas"`` routes padded/cropping modes through the
    phase-decomposed TPU kernel (repro.kernels.deconv) — one fused op,
    crop folded into indexing (interpret mode on CPU)."""

    c_in: int
    c_out: int
    mode: str
    use_bias: bool = False  # TF tutorial: final output deconv carries a bias
    backend: str = "xla"

    def specs(self):
        pad = 1 if self.mode == "padded" else 0
        s = {"deconv": ConvTranspose2D(self.c_in, self.c_out, 4, 2, padding=pad, use_bias=self.use_bias)}
        if self.mode == "conv":
            s["conv"] = Conv2D(self.c_out, self.c_out, 3, 1, padding=0, use_bias=False)
        return s

    def __call__(self, p, x):
        if self.backend == "pallas" and self.mode in ("padded", "cropping"):
            from ..kernels.deconv.ops import deconv2d

            b = p["deconv"].get("b") if self.use_bias else None
            return deconv2d(x, p["deconv"]["w"], b=b, stride=2, padding=1, interpret=True)
        if self.mode == "padded":
            return ConvTranspose2D(self.c_in, self.c_out, 4, 2, padding=1, use_bias=self.use_bias)(p["deconv"], x)
        y = ConvTranspose2D(self.c_in, self.c_out, 4, 2, padding=0, use_bias=self.use_bias)(p["deconv"], x)
        if self.mode == "cropping":
            return Crop2D(1)(None, y)
        return Conv2D(self.c_out, self.c_out, 3, 1, padding=0, use_bias=False)(p["conv"], y)


@dataclasses.dataclass(frozen=True)
class Pix2PixGenerator(Module):
    cfg: Pix2PixConfig

    def specs(self):
        c = self.cfg
        downs = []
        c_prev = c.in_channels
        for i, ch in enumerate(c.down_channels()):
            blk = {"conv": Conv2D(c_prev, ch, 4, 2, padding=1, use_bias=False)}
            if i != 0:
                blk["bn"] = c.norm2d(ch)
            downs.append(blk)
            c_prev = ch
        ups = []
        for i, ch in enumerate(c.up_channels()):
            blk = {"up": UpBlockDeconv(c_prev, ch, c.deconv_mode, backend=c.deconv_backend), "bn": c.norm2d(ch)}
            ups.append(blk)
            c_prev = ch * 2  # skip concat
        final = UpBlockDeconv(c_prev, c.out_channels, c.deconv_mode, use_bias=True, backend=c.deconv_backend)
        return {"downs": downs, "ups": ups, "final": final}

    def __call__(self, p, x, rng=None, train=False):
        c = self.cfg
        x = x.astype(c.act_dtype)
        skips = []
        c_prev = c.in_channels
        for i, ch in enumerate(c.down_channels()):
            x = Conv2D(c_prev, ch, 4, 2, padding=1, use_bias=False)(p["downs"][i]["conv"], x)
            if i != 0:
                x = c.norm2d(ch)(p["downs"][i]["bn"], x)
            x = leaky_relu(x)
            skips.append(x)
            c_prev = ch
        skips = skips[:-1][::-1]
        for i, ch in enumerate(c.up_channels()):
            x = UpBlockDeconv(c_prev, ch, c.deconv_mode, backend=c.deconv_backend)(p["ups"][i]["up"], x)
            x = c.norm2d(ch)(p["ups"][i]["bn"], x)
            if train and i < 3 and rng is not None:
                keep = 1.0 - c.dropout_rate
                mask = jax.random.bernoulli(jax.random.fold_in(rng, i), keep, x.shape)
                x = jnp.where(mask, x / keep, 0.0).astype(x.dtype)
            x = jax.nn.relu(x)
            x = jnp.concatenate([x, skips[i]], axis=-1)
            c_prev = ch * 2
        x = UpBlockDeconv(c_prev, c.out_channels, c.deconv_mode, use_bias=True, backend=c.deconv_backend)(p["final"], x)
        return jnp.tanh(x)

    # ---- layer graph for the scheduler ----------------------------------------
    def layer_graph(self, batch: int = 1, dtype_bytes: int = 2) -> LayerGraph:
        c = self.cfg
        layers = []
        idx = 0

        def add(meta):
            nonlocal idx
            meta.idx = idx
            layers.append(meta)
            idx += 1

        # (start_idx, span, kind, norm, act) of each pallas_fused block
        fuse_groups: list[tuple[int, int, str, str, str]] = []

        h = c.img_size
        c_prev = c.in_channels
        for i, ch in enumerate(c.down_channels()):
            fuse_groups.append((idx, 2 if i == 0 else 3, "conv", "none" if i == 0 else c.norm, "lrelu"))
            add(conv_meta(idx, f"down{i}.conv", batch, h, h, c_prev, ch, 4, 2, 1, dtype_bytes))
            h //= 2
            if i != 0:
                add(pointwise_meta(idx, f"down{i}.bn", "bn", (batch, h, h, ch), dtype_bytes, 2.0, 2 * ch))
            add(pointwise_meta(idx, f"down{i}.lrelu", "act", (batch, h, h, ch), dtype_bytes))
            c_prev = ch

        def add_up(i, name, ch, h, c_prev):
            if c.deconv_mode == "padded":
                add(conv_meta(idx, f"{name}.deconv", batch, h, h, c_prev, ch, 4, 2, 1, dtype_bytes, transposed=True))
                return 2 * h
            add(conv_meta(idx, f"{name}.deconv", batch, h, h, c_prev, ch, 4, 2, 0, dtype_bytes, transposed=True))
            if c.deconv_mode == "cropping":
                add(
                    pointwise_meta(idx, f"{name}.crop", "crop", (batch, 2 * h, 2 * h, ch), dtype_bytes, 0.0)
                )
            else:
                add(conv_meta(idx, f"{name}.conv", batch, 2 * h + 2, 2 * h + 2, ch, ch, 3, 1, 0, dtype_bytes))
            return 2 * h

        # deconv spans: padded fuses deconv+bn+relu, cropping also folds the
        # crop; "conv" mode's 3x3 refine has no fused kernel -> downs only
        up_span = {"padded": 2, "cropping": 3}.get(c.deconv_mode, 0)
        for i, ch in enumerate(c.up_channels()):
            if up_span:
                fuse_groups.append((idx, up_span + 1, "deconv", c.norm, "relu"))
            h = add_up(i, f"up{i}", ch, h, c_prev)
            add(pointwise_meta(idx, f"up{i}.bn", "bn", (batch, h, h, ch), dtype_bytes, 2.0, 2 * ch))
            add(pointwise_meta(idx, f"up{i}.relu", "act", (batch, h, h, ch), dtype_bytes))
            add(pointwise_meta(idx, f"up{i}.concat", "concat", (batch, h, h, 2 * ch), dtype_bytes, 0.0))
            c_prev = ch * 2
        if up_span:
            fuse_groups.append((idx, up_span, "deconv", "none", "tanh"))
        h = add_up(7, "final", c.out_channels, h, c_prev)
        add(pointwise_meta(idx, "tanh", "tanh", (batch, h, h, c.out_channels), dtype_bytes))

        # mark pallas_fused blocks: lead layer carries the fused analytic
        # totals (in + out + params only — the intermediate activations
        # never round-trip through HBM), folded members point back at it
        for lo, span, kind, norm, act in fuse_groups:
            members = layers[lo : lo + span]
            fused_bytes = dtype_bytes * (
                math.prod(members[0].in_shape) + math.prod(members[-1].out_shape)
            ) + 4.0 * sum(m.params for m in members)
            layers[lo].attrs["fuse"] = {
                "span": span,
                "flops": sum(m.flops for m in members),
                "bytes": fused_bytes,
                "kind": kind,
                "norm": norm,
                "act": act,
            }
            for m in members[1:]:
                m.attrs["fused_into"] = members[0].name

        g = LayerGraph(f"{c.name}.G[{c.deconv_mode}]", layers)
        # skip tensors stay live across the bottleneck: widen boundary bytes
        # (a partition between down_i and up_{7-i} must also move the skips)
        return g.renumber()


def generator_ops(cfg: Pix2PixConfig, impl: str = "xla"):
    """Per-layer executable ops aligned 1:1 with ``layer_graph`` indices.

    Each op is ``(name, fn)`` with ``fn(params, state) -> state`` where
    ``state = {"x": activations, "skips": [...]}``. Slicing this list at the
    scheduler's partition points yields runnable engine segments; composing
    all ops reproduces ``Pix2PixGenerator.__call__`` exactly (property-
    tested). The state dict (x + live skips) is what crosses a partition —
    matching ``LayerMeta.boundary_bytes`` accounting.

    ``impl="pallas_fused"`` returns the same-length list with each fused
    block (the graph's ``attrs["fuse"]`` groups) collapsed onto its lead op
    — one ``kernels.fused`` call doing conv/deconv+norm+act in a single
    kernel — and the folded members replaced by identity ops. Cut points
    interior to a fused block simply see the already-final activations.
    """
    ops = []
    c_prev = cfg.in_channels
    downs = list(enumerate(cfg.down_channels()))
    n_ups = len(cfg.up_channels())

    def mk_down_conv(i, ci, co):
        def f(p, s):
            s = dict(s)
            s["x"] = Conv2D(ci, co, 4, 2, padding=1, use_bias=False)(p["downs"][i]["conv"], s["x"])
            return s

        return f

    def mk_down_bn(i, ch):
        def f(p, s):
            s = dict(s)
            s["x"] = cfg.norm2d(ch)(p["downs"][i]["bn"], s["x"])
            return s

        return f

    def mk_down_act():
        def f(p, s):
            s = dict(s)
            s["x"] = leaky_relu(s["x"])
            s["skips"] = s["skips"] + [s["x"]]
            return s

        return f

    for i, ch in downs:
        ops.append((f"down{i}.conv", mk_down_conv(i, c_prev, ch)))
        if i != 0:
            ops.append((f"down{i}.bn", mk_down_bn(i, ch)))
        ops.append((f"down{i}.lrelu", mk_down_act()))
        c_prev = ch

    def up_params(p, i):
        return p["final"] if i == n_ups else p["ups"][i]["up"]

    def mk_deconv(i, ci, co, bias):
        pad = 1 if cfg.deconv_mode == "padded" else 0

        def f(p, s):
            s = dict(s)
            pp = up_params(p, i)
            s["x"] = ConvTranspose2D(ci, co, 4, 2, padding=pad, use_bias=bias)(pp["deconv"], s["x"])
            return s

        return f

    def mk_crop():
        def f(p, s):
            s = dict(s)
            s["x"] = Crop2D(1)(None, s["x"])
            return s

        return f

    def mk_upconv(i, co):
        def f(p, s):
            s = dict(s)
            pp = up_params(p, i)
            s["x"] = Conv2D(co, co, 3, 1, padding=0, use_bias=False)(pp["conv"], s["x"])
            return s

        return f

    def mk_up_bn(i, ch):
        def f(p, s):
            s = dict(s)
            s["x"] = cfg.norm2d(ch)(p["ups"][i]["bn"], s["x"])
            return s

        return f

    def mk_up_relu():
        def f(p, s):
            s = dict(s)
            s["x"] = jax.nn.relu(s["x"])
            return s

        return f

    def mk_concat(skip_idx):
        def f(p, s):
            s = dict(s)
            s["x"] = jnp.concatenate([s["x"], s["skips"][skip_idx]], axis=-1)
            return s

        return f

    skips_rev = list(range(len(downs) - 2, -1, -1))  # skip index for up i
    for i, ch in enumerate(cfg.up_channels()):
        ops.append((f"up{i}.deconv", mk_deconv(i, c_prev, ch, False)))
        if cfg.deconv_mode == "cropping":
            ops.append((f"up{i}.crop", mk_crop()))
        elif cfg.deconv_mode == "conv":
            ops.append((f"up{i}.conv", mk_upconv(i, ch)))
        ops.append((f"up{i}.bn", mk_up_bn(i, ch)))
        ops.append((f"up{i}.relu", mk_up_relu()))
        ops.append((f"up{i}.concat", mk_concat(skips_rev[i])))
        c_prev = ch * 2

    ops.append(("final.deconv", mk_deconv(n_ups, c_prev, cfg.out_channels, True)))
    if cfg.deconv_mode == "cropping":
        ops.append(("final.crop", mk_crop()))
    elif cfg.deconv_mode == "conv":
        ops.append(("final.conv", mk_upconv(n_ups, cfg.out_channels)))

    def mk_tanh():
        def f(p, s):
            s = dict(s)
            s["x"] = jnp.tanh(s["x"])
            return s

        return f

    ops.append(("tanh", mk_tanh()))
    if impl == "xla":
        return ops
    if impl != "pallas_fused":
        raise ValueError(f"unknown impl {impl!r} (want xla|pallas_fused)")

    from ..kernels.fused.ops import conv_block, deconv_block

    pos = {name: k for k, (name, _) in enumerate(ops)}

    def identity(p, s):
        return s

    def norm_groups(ch):
        return math.gcd(cfg.norm_groups, ch) if cfg.norm == "group" else 1

    def mk_down_fused(i, ch):
        def f(p, s):
            s = dict(s)
            blk = p["downs"][i]
            bn = blk.get("bn")
            s["x"] = conv_block(
                s["x"],
                blk["conv"]["w"],
                gamma=None if bn is None else bn["scale"],
                beta=None if bn is None else bn["bias"],
                stride=2,
                padding=1,
                norm="none" if bn is None else cfg.norm,
                groups=norm_groups(ch),
                act="lrelu",
            )
            s["skips"] = s["skips"] + [s["x"]]
            return s

        return f

    def mk_up_fused(i, ch):
        def f(p, s):
            s = dict(s)
            bn = p["ups"][i]["bn"]
            s["x"] = deconv_block(
                s["x"],
                up_params(p, i)["deconv"]["w"],
                gamma=bn["scale"],
                beta=bn["bias"],
                norm=cfg.norm,
                groups=norm_groups(ch),
                act="relu",
            )
            return s

        return f

    def mk_final_fused():
        def f(p, s):
            s = dict(s)
            pp = up_params(p, n_ups)["deconv"]
            s["x"] = deconv_block(s["x"], pp["w"], b=pp["b"], norm="none", act="tanh")
            return s

        return f

    def fold(lead, fused_fn, *folded):
        ops[pos[lead]] = (lead, fused_fn)
        for name in folded:
            ops[pos[name]] = (name, identity)

    for i, ch in downs:
        folded = ([f"down{i}.bn"] if i != 0 else []) + [f"down{i}.lrelu"]
        fold(f"down{i}.conv", mk_down_fused(i, ch), *folded)
    if cfg.deconv_mode in ("padded", "cropping"):
        crop = ["crop"] if cfg.deconv_mode == "cropping" else []
        for i, ch in enumerate(cfg.up_channels()):
            folded = [f"up{i}.{t}" for t in crop + ["bn", "relu"]]
            fold(f"up{i}.deconv", mk_up_fused(i, ch), *folded)
        fold("final.deconv", mk_final_fused(), *[f"final.{t}" for t in crop], "tanh")
    return ops


@dataclasses.dataclass(frozen=True)
class Pix2PixDiscriminator(Module):
    """70x70 PatchGAN on concat(condition, image) — 6 input channels."""

    cfg: Pix2PixConfig

    def specs(self):
        c = self.cfg
        ci = c.in_channels + c.out_channels
        return {
            "c1": Conv2D(ci, 64, 4, 2, padding=1, use_bias=False),
            "c2": Conv2D(64, 128, 4, 2, padding=1, use_bias=False),
            "bn2": BatchNorm2D(128),
            "c3": Conv2D(128, 256, 4, 2, padding=1, use_bias=False),
            "bn3": BatchNorm2D(256),
            "c4": Conv2D(256, 512, 4, 1, padding=0, use_bias=False),  # zero-pad then VALID
            "bn4": BatchNorm2D(512),
            "c5": Conv2D(512, 1, 4, 1, padding=0, use_bias=True),
        }

    def __call__(self, p, x, y):
        c = self.cfg
        h = jnp.concatenate([x, y], axis=-1).astype(c.act_dtype)
        ci = c.in_channels + c.out_channels
        h = leaky_relu(Conv2D(ci, 64, 4, 2, padding=1, use_bias=False)(p["c1"], h))
        h = Conv2D(64, 128, 4, 2, padding=1, use_bias=False)(p["c2"], h)
        h = leaky_relu(BatchNorm2D(128)(p["bn2"], h))
        h = Conv2D(128, 256, 4, 2, padding=1, use_bias=False)(p["c3"], h)
        h = leaky_relu(BatchNorm2D(256)(p["bn3"], h))
        h = jnp.pad(h, ((0, 0), (1, 1), (1, 1), (0, 0)))
        h = Conv2D(256, 512, 4, 1, padding=0, use_bias=False)(p["c4"], h)
        h = leaky_relu(BatchNorm2D(512)(p["bn4"], h))
        h = jnp.pad(h, ((0, 0), (1, 1), (1, 1), (0, 0)))
        return Conv2D(512, 1, 4, 1, padding=0, use_bias=True)(p["c5"], h)


@dataclasses.dataclass(frozen=True)
class Pix2Pix(Module):
    cfg: Pix2PixConfig

    def specs(self):
        return {
            "generator": Pix2PixGenerator(self.cfg),
            "discriminator": Pix2PixDiscriminator(self.cfg),
        }

    def generate(self, p, x, rng=None, train=False):
        return Pix2PixGenerator(self.cfg)(p["generator"], x, rng=rng, train=train)

    def discriminate(self, p, x, y):
        return Pix2PixDiscriminator(self.cfg)(p["discriminator"], x, y)

    def layer_graph(self, batch: int = 1, dtype_bytes: int = 2) -> LayerGraph:
        return Pix2PixGenerator(self.cfg).layer_graph(batch, dtype_bytes)
