from .lm import LMConfig, TransformerLM
from .mamba2 import Mamba2Config, Mamba2LM
from .hymba import HymbaConfig, HymbaLM
from .whisper import WhisperConfig, WhisperModel
from .pix2pix import Pix2Pix, Pix2PixConfig, Pix2PixGenerator, Pix2PixDiscriminator
from .yolov8 import YOLOv8, YOLOv8Config
