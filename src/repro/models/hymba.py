"""Hymba: hybrid-head architecture — parallel attention + Mamba(SSD) heads in
every layer (arXiv:2411.13676). Most layers use sliding-window attention;
a few (first/middle/last) are global. Attention and SSM branches run in
parallel on the same input and are fused by normalized averaging.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..nn import MLP, Attention, Embedding, Mamba2Block, Module, RMSNorm, Stacked


@dataclasses.dataclass(frozen=True)
class HymbaConfig:
    name: str
    n_layers: int
    d_model: int
    n_q: int
    n_kv: int
    d_ff: int
    vocab: int
    ssm_state: int = 16
    head_dim: int = 64
    local_window: int = 1024
    global_layers: tuple[int, ...] = (0, 15, 31)
    expand: int = 2
    ssm_head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128
    rope_base: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    remat: bool = True
    act_spec: Any = None

    def windows(self):
        return tuple(0 if i in self.global_layers else self.local_window for i in range(self.n_layers))

    def attn(self):
        return Attention(self.d_model, self.n_q, self.n_kv, self.head_dim,
                         rope_base=self.rope_base, attn_chunk=self.attn_chunk)

    def mamba(self):
        return Mamba2Block(
            self.d_model,
            d_state=self.ssm_state,
            d_conv=self.d_conv,
            expand=self.expand,
            head_dim=self.ssm_head_dim,
            n_groups=self.n_groups,
            chunk=self.chunk,
        )

    def n_params(self):
        d = self.d_model
        attn = d * self.head_dim * (self.n_q + 2 * self.n_kv) + self.n_q * self.head_dim * d
        b = self.mamba()
        d_in_proj = 2 * b.d_inner + 2 * b.n_groups * b.d_state + b.n_heads
        mamba = d * d_in_proj + b.d_conv * b.conv_dim + b.conv_dim + 3 * b.n_heads + b.d_inner + b.d_inner * d
        mlp = 3 * d * self.d_ff
        per_layer = attn + mamba + mlp + 4 * d
        return self.vocab * d + self.n_layers * per_layer + d

    def n_active_params(self):
        return self.n_params()


@dataclasses.dataclass(frozen=True)
class HymbaBlock(Module):
    cfg: HymbaConfig

    def specs(self):
        c = self.cfg
        return {
            "ln_mix": RMSNorm(c.d_model, c.norm_eps),
            "attn": c.attn(),
            "mamba": c.mamba(),
            "ln_attn_out": RMSNorm(c.d_model, c.norm_eps),
            "ln_mamba_out": RMSNorm(c.d_model, c.norm_eps),
            "ln_mlp": RMSNorm(c.d_model, c.norm_eps),
            "mlp": MLP(c.d_model, c.d_ff, act="silu"),
        }

    def _fuse(self, p, a, m):
        c = self.cfg
        a = RMSNorm(c.d_model, c.norm_eps)(p["ln_attn_out"], a)
        m = RMSNorm(c.d_model, c.norm_eps)(p["ln_mamba_out"], m)
        return 0.5 * (a + m)

    def __call__(self, p, x, positions, window):
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mix"], x)
        a = c.attn()(p["attn"], h, positions, window=window)
        m = c.mamba()(p["mamba"], h)
        x = x + self._fuse(p, a, m)
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mlp"], x)
        return x + MLP(c.d_model, c.d_ff, act="silu")(p["mlp"], h)

    def prefill(self, p, x, positions, window, cache_dtype=jnp.bfloat16):
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mix"], x)
        a, kv = c.attn().prefill(p["attn"], h, positions, window=window, cache_dtype=cache_dtype)
        m, st = c.mamba().prefill(p["mamba"], h, cache_dtype)
        x = x + self._fuse(p, a, m)
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mlp"], x)
        return x + MLP(c.d_model, c.d_ff, act="silu")(p["mlp"], h), {"kv": kv, "ssm": st}

    def decode(self, p, x, cache, t, window):
        c = self.cfg
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mix"], x)
        a, kv = c.attn().decode(p["attn"], h, cache["kv"], t, window=window)
        m, st = c.mamba().decode(p["mamba"], h, cache["ssm"])
        x = x + self._fuse(p, a, m)
        h = RMSNorm(c.d_model, c.norm_eps)(p["ln_mlp"], x)
        return x + MLP(c.d_model, c.d_ff, act="silu")(p["mlp"], h), {"kv": kv, "ssm": st}

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        if abstract:
            return {
                "kv": c.attn().abstract_cache(batch, max_len, dtype),
                "ssm": c.mamba().abstract_cache(batch, dtype),
            }
        return {
            "kv": c.attn().init_cache(batch, max_len, dtype),
            "ssm": c.mamba().init_cache(batch, dtype),
        }


@dataclasses.dataclass(frozen=True)
class HymbaLM(Module):
    cfg: HymbaConfig

    def specs(self):
        c = self.cfg
        return {
            "embed": Embedding(c.vocab, c.d_model),
            "blocks": Stacked(HymbaBlock(c), c.n_layers),
            "final_norm": RMSNorm(c.d_model, c.norm_eps),
        }

    def _logits(self, p, x):
        c = self.cfg
        return Embedding(c.vocab, c.d_model).attend(p["embed"], x)

    def __call__(self, p, tokens, positions=None, return_hidden=False):
        c = self.cfg
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = Embedding(c.vocab, c.d_model)(p["embed"], tokens).astype(c.act_dtype)
        windows = jnp.asarray(c.windows(), jnp.int32)
        blk = HymbaBlock(c)
        blk_call = jax.checkpoint(blk.__call__) if c.remat else blk.__call__

        def constrain(x):
            if c.act_spec is None:
                return x
            from jax.sharding import PartitionSpec as P

            return jax.lax.with_sharding_constraint(x, P(tuple(c.act_spec)))

        def body(x, xs):
            bp, w = xs
            return constrain(blk_call(bp, constrain(x), positions, w)), None

        x, _ = jax.lax.scan(body, x, (p["blocks"], windows))
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        return self._logits(p, x), jnp.zeros((), jnp.float32)

    def head(self, p, x):
        return self._logits(p, x)

    def init_caches(self, batch, max_len, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        one = HymbaBlock(c).init_cache(batch, max_len, dtype, abstract=abstract)
        if abstract:
            return jax.tree.map(lambda s: jax.ShapeDtypeStruct((c.n_layers, *s.shape), s.dtype), one)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (c.n_layers, *a.shape)).copy(), one)

    def prefill(self, p, tokens, positions=None, cache_dtype=jnp.bfloat16):
        c = self.cfg
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = Embedding(c.vocab, c.d_model)(p["embed"], tokens).astype(c.act_dtype)
        windows = jnp.asarray(c.windows(), jnp.int32)
        blk = HymbaBlock(c)

        def body(x, xs):
            bp, w = xs
            x, cache = blk.prefill(bp, x, positions, w, cache_dtype)
            return x, cache

        x, caches = jax.lax.scan(body, x, (p["blocks"], windows))
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        return self._logits(p, x[:, -1:]), caches

    def decode_step(self, p, token, caches, t):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model)(p["embed"], token).astype(c.act_dtype)
        windows = jnp.asarray(c.windows(), jnp.int32)
        blk = HymbaBlock(c)

        def body(x, xs):
            bp, cache, w = xs
            x, cache = blk.decode(bp, x, cache, t, w)
            return x, cache

        x, caches = jax.lax.scan(body, x, (p["blocks"], caches, windows))
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        return self._logits(p, x), caches
