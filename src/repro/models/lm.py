"""Unified decoder-only transformer LM.

One config covers: gemma-2b (MQA, GeGLU, head_dim 256), gemma2-2b/27b
(alternating local/global attention, logit softcaps, post-norms),
phi4-mini (GQA+SwiGLU), deepseek-moe-16b (fine-grained MoE, first layer
dense), deepseek-v2-lite (MLA + MoE), and the qwen2-vl text backbone
(M-RoPE). Layers are scanned (stacked params) so compiled HLO is O(1) in
depth; per-layer attention windows ride along as scan inputs so
local/global alternation stays a single homogeneous scan body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..nn import (
    MLP,
    Attention,
    Embedding,
    Linear,
    MLAAttention,
    MoE,
    Module,
    RMSNorm,
    Stacked,
)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_q: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention
    attn_type: str = "gqa"  # "gqa" | "mla"
    rope_base: float = 10000.0
    attn_softcap: float | None = None
    final_softcap: float | None = None
    local_window: int = 0  # sliding-window size for "local" layers
    layer_pattern: str = "global"  # "global" | "local_global" | "hymba"
    global_layers: tuple[int, ...] = ()  # explicit global layer ids (pattern="custom")
    query_scale: float | None = None
    mrope_sections: tuple[int, ...] | None = None
    # MLA
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_absorb: bool = True
    # MLP / MoE
    act: str = "silu"
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared: int = 0
    first_k_dense: int = 1
    capacity_factor: float = 1.25
    # misc
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    zero_centered_norm: bool = False  # gemma (1 + scale) RMSNorm
    post_norms: bool = False  # gemma2 post-attention/post-ffn norms
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    act_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024  # query-chunked attention block (0 = dense)
    remat: bool = True  # activation checkpointing on scanned layers
    # sharding constraint pinned on the residual stream between layers:
    # tuple of mesh axes for the batch dim (e.g. ("data",)). Stops GSPMD
    # from picking weight-stationary layouts that all-gather activations.
    act_spec: Any = None
    # "full": recompute everything (max memory savings, +fwd flops)
    # "dots": save matmul outputs, recompute elementwise only (Megatron-style
    #         selective checkpointing; recompute flops ~0)
    remat_policy: str = "full"

    # ---- derived -------------------------------------------------------------
    def windows(self) -> tuple[int, ...]:
        """Per-layer window (0 = global/full attention)."""
        if self.layer_pattern == "global":
            return (0,) * self.n_layers
        if self.layer_pattern == "local_global":
            # gemma2: even layers local (sliding window), odd layers global
            return tuple(
                self.local_window if i % 2 == 0 else 0 for i in range(self.n_layers)
            )
        if self.layer_pattern == "custom":
            return tuple(
                0 if i in self.global_layers else self.local_window
                for i in range(self.n_layers)
            )
        raise ValueError(self.layer_pattern)

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, v = self.d_model, self.vocab
        emb = v * d
        if self.attn_type == "mla":
            qd = self.qk_nope_dim + self.qk_rope_dim
            attn = (
                d * self.n_q * qd
                + d * self.kv_lora
                + d * self.qk_rope_dim
                + self.kv_lora * self.n_q * (self.qk_nope_dim + self.v_head_dim)
                + self.n_q * self.v_head_dim * d
            )
        else:
            attn = d * self.head_dim * (self.n_q + 2 * self.n_kv) + self.n_q * self.head_dim * d
        dense_mlp = 3 * d * self.d_ff
        if self.moe:
            expert = 3 * d * self.d_ff_expert
            moe_mlp = self.n_experts * expert + self.n_shared * expert + d * self.n_experts
            n_moe = self.n_layers - self.first_k_dense
            mlps = self.first_k_dense * dense_mlp + n_moe * moe_mlp
        else:
            mlps = self.n_layers * dense_mlp
        norms = self.n_layers * (4 if self.post_norms else 2) * d + d
        head = 0 if self.tie_embeddings else v * d
        return emb + head + self.n_layers * attn + mlps + norms

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        expert = 3 * d * self.d_ff_expert
        n_moe = self.n_layers - self.first_k_dense
        inactive = n_moe * (self.n_experts - self.top_k) * expert
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class LMBlock(Module):
    cfg: LMConfig
    use_moe: bool

    def _attn(self):
        c = self.cfg
        if c.attn_type == "mla":
            return MLAAttention(
                c.d_model,
                c.n_q,
                kv_lora=c.kv_lora,
                qk_nope_dim=c.qk_nope_dim,
                qk_rope_dim=c.qk_rope_dim,
                v_head_dim=c.v_head_dim,
                rope_base=c.rope_base,
                absorb=c.mla_absorb,
                attn_chunk=c.attn_chunk,
            )
        return Attention(
            c.d_model,
            c.n_q,
            c.n_kv,
            c.head_dim,
            rope_base=c.rope_base,
            softcap=c.attn_softcap,
            query_scale=c.query_scale,
            mrope_sections=c.mrope_sections,
            attn_chunk=c.attn_chunk,
        )

    def _mlp(self):
        c = self.cfg
        if self.use_moe:
            return MoE(
                c.d_model,
                c.d_ff_expert,
                c.n_experts,
                c.top_k,
                n_shared=c.n_shared,
                capacity_factor=c.capacity_factor,
                act=c.act,
            )
        return MLP(c.d_model, c.d_ff, act=c.act)

    def specs(self):
        c = self.cfg
        norm = lambda: RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm)
        s = {"ln_attn": norm(), "attn": self._attn(), "ln_mlp": norm(), "mlp": self._mlp()}
        if c.post_norms:
            s["ln_attn_post"] = norm()
            s["ln_mlp_post"] = norm()
        return s

    def _norm(self, p, name, x):
        c = self.cfg
        return RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm)(p[name], x)

    def __call__(self, p, x, positions, window):
        c = self.cfg
        h = self._norm(p, "ln_attn", x)
        h = self._attn()(p["attn"], h, positions, window=window)
        if c.post_norms:
            h = self._norm(p, "ln_attn_post", h)
        x = x + h
        h = self._norm(p, "ln_mlp", x)
        if self.use_moe:
            h, aux = self._mlp()(p["mlp"], h)
        else:
            h, aux = self._mlp()(p["mlp"], h), jnp.zeros((), jnp.float32)
        if c.post_norms:
            h = self._norm(p, "ln_mlp_post", h)
        return x + h, aux

    def prefill(self, p, x, positions, window, cache_dtype=jnp.bfloat16):
        c = self.cfg
        h = self._norm(p, "ln_attn", x)
        h, cache = self._attn().prefill(p["attn"], h, positions, window=window, cache_dtype=cache_dtype)
        if c.post_norms:
            h = self._norm(p, "ln_attn_post", h)
        x = x + h
        h = self._norm(p, "ln_mlp", x)
        if self.use_moe:
            h, aux = self._mlp()(p["mlp"], h)
        else:
            h, aux = self._mlp()(p["mlp"], h), jnp.zeros((), jnp.float32)
        if c.post_norms:
            h = self._norm(p, "ln_mlp_post", h)
        return x + h, cache, aux

    def decode(self, p, x, cache, t, window):
        c = self.cfg
        h = self._norm(p, "ln_attn", x)
        h, cache = self._attn().decode(p["attn"], h, cache, t, window=window)
        if c.post_norms:
            h = self._norm(p, "ln_attn_post", h)
        x = x + h
        h = self._norm(p, "ln_mlp", x)
        if self.use_moe:
            h, _ = self._mlp()(p["mlp"], h)
        else:
            h = self._mlp()(p["mlp"], h)
        if c.post_norms:
            h = self._norm(p, "ln_mlp_post", h)
        return x + h, cache

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16):
        return self._attn().init_cache(batch, max_len, dtype)

    def abstract_cache(self, batch, max_len, dtype=jnp.bfloat16):
        return self._attn().abstract_cache(batch, max_len, dtype)


@dataclasses.dataclass(frozen=True)
class TransformerLM(Module):
    cfg: LMConfig

    @property
    def n_dense(self):
        return self.cfg.first_k_dense if self.cfg.moe else 0

    @property
    def n_scan(self):
        return self.cfg.n_layers - self.n_dense

    def specs(self):
        c = self.cfg
        s: dict[str, Any] = {
            "embed": Embedding(c.vocab, c.d_model, scale_by_sqrt_d=c.embed_scale),
            "blocks": Stacked(LMBlock(c, use_moe=c.moe), self.n_scan),
            "final_norm": RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm),
        }
        if self.n_dense:
            s["dense_blocks"] = [LMBlock(c, use_moe=False) for _ in range(self.n_dense)]
        if not c.tie_embeddings:
            s["lm_head"] = Linear(c.d_model, c.vocab, in_axis="embed", out_axis="vocab")
        return s

    # ---- helpers ---------------------------------------------------------------
    def _windows(self):
        return jnp.asarray(self.cfg.windows(), jnp.int32)

    def _logits(self, p, x):
        c = self.cfg
        if c.tie_embeddings:
            logits = Embedding(c.vocab, c.d_model).attend(p["embed"], x)
        else:
            logits = Linear(c.d_model, c.vocab)(p["lm_head"], x)
        if c.final_softcap:
            logits = (c.final_softcap * jnp.tanh(logits.astype(jnp.float32) / c.final_softcap)).astype(logits.dtype)
        return logits

    def _embed(self, p, tokens, extra_embeds=None, embed_positions=None):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model, scale_by_sqrt_d=c.embed_scale)(p["embed"], tokens)
        x = x.astype(c.act_dtype)
        if extra_embeds is not None:
            # VLM stub frontend: scatter precomputed patch embeddings into the
            # sequence at the given positions (B, n_img) int32.
            B = x.shape[0]
            bidx = jnp.arange(B)[:, None]
            x = x.at[bidx, embed_positions].set(extra_embeds.astype(c.act_dtype))
        return x

    # ---- train forward -----------------------------------------------------------
    def __call__(self, p, tokens, positions=None, extra_embeds=None, embed_positions=None, return_hidden=False):
        c = self.cfg
        B, S = tokens.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if c.mrope_sections is not None:
                positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
        x = self._embed(p, tokens, extra_embeds, embed_positions)
        windows = self._windows()
        aux_total = jnp.zeros((), jnp.float32)
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if c.remat_policy == "dots"
            else None
        )
        dense_blk = LMBlock(c, use_moe=False)
        dense_call = jax.checkpoint(dense_blk.__call__, policy=policy) if c.remat else dense_blk.__call__
        for i in range(self.n_dense):
            x, aux = dense_call(p["dense_blocks"][i], x, positions, windows[i])
            aux_total = aux_total + aux

        blk = LMBlock(c, use_moe=c.moe)
        blk_call = jax.checkpoint(blk.__call__, policy=policy) if c.remat else blk.__call__

        def constrain(x):
            if c.act_spec is None:
                return x
            from jax.sharding import PartitionSpec as P

            return jax.lax.with_sharding_constraint(x, P(tuple(c.act_spec)))

        def body(carry, xs):
            x, aux_acc = carry
            bp, w = xs
            x, aux = blk_call(bp, constrain(x), positions, w)
            return (constrain(x), aux_acc + aux), None

        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), (p["blocks"], windows[self.n_dense :])
        )
        x = RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm)(p["final_norm"], x)
        if return_hidden:
            return x, aux_total
        return self._logits(p, x), aux_total

    def head(self, p, x):
        return self._logits(p, x)

    # ---- caches -------------------------------------------------------------------
    def _cache_len(self, layer_idx, max_len):
        """Ring-buffer caches for pure-local layers: size = window."""
        w = self.cfg.windows()[layer_idx]
        return max_len if w == 0 else min(max_len, w)

    def init_caches(self, batch, max_len, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        blk = LMBlock(c, use_moe=c.moe)
        fn = blk.abstract_cache if abstract else blk.init_cache
        dense = [
            LMBlock(c, use_moe=False).abstract_cache(batch, self._cache_len(i, max_len), dtype)
            if abstract
            else LMBlock(c, use_moe=False).init_cache(batch, self._cache_len(i, max_len), dtype)
            for i in range(self.n_dense)
        ]
        # scanned layers must share one cache length: use the max over them
        scan_lens = {self._cache_len(i, max_len) for i in range(self.n_dense, c.n_layers)}
        scan_len = max(scan_lens)
        one = fn(batch, scan_len, dtype)
        if abstract:
            scanned = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((self.n_scan, *s.shape), s.dtype), one
            )
        else:
            scanned = jax.tree.map(lambda a: jnp.broadcast_to(a, (self.n_scan, *a.shape)).copy(), one)
        return {"dense": dense, "scan": scanned}

    # ---- serving ------------------------------------------------------------------
    def prefill(self, p, tokens, positions=None, cache_dtype=jnp.bfloat16):
        c = self.cfg
        B, S = tokens.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if c.mrope_sections is not None:
                positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
        x = self._embed(p, tokens)
        windows = self._windows()
        dense_caches = []
        for i in range(self.n_dense):
            blk = LMBlock(c, use_moe=False)
            x, cache, _ = blk.prefill(p["dense_blocks"][i], x, positions, windows[i], cache_dtype)
            dense_caches.append(cache)

        blk = LMBlock(c, use_moe=c.moe)

        def body(x, xs):
            bp, w = xs
            x, cache, _ = blk.prefill(bp, x, positions, w, cache_dtype)
            return x, cache

        x, scan_caches = jax.lax.scan(body, x, (p["blocks"], windows[self.n_dense :]))
        x = RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm)(p["final_norm"], x)
        return self._logits(p, x[:, -1:]), {"dense": dense_caches, "scan": scan_caches}

    def decode_step(self, p, token, caches, t):
        """token: (B, 1) int32; t: scalar position. Returns (logits, caches)."""
        c = self.cfg
        x = self._embed(p, token)
        windows = self._windows()
        new_dense = []
        for i in range(self.n_dense):
            blk = LMBlock(c, use_moe=False)
            x, cache = blk.decode(p["dense_blocks"][i], x, caches["dense"][i], t, windows[i])
            new_dense.append(cache)

        blk = LMBlock(c, use_moe=c.moe)

        def body(x, xs):
            bp, cache, w = xs
            x, cache = blk.decode(bp, x, cache, t, w)
            return x, cache

        x, new_scan = jax.lax.scan(body, x, (p["blocks"], caches["scan"], windows[self.n_dense :]))
        x = RMSNorm(c.d_model, c.norm_eps, zero_centered=c.zero_centered_norm)(p["final_norm"], x)
        return self._logits(p, x), {"dense": new_dense, "scan": new_scan}
