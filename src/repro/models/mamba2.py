"""Mamba-2 language model (attention-free, SSD blocks; arXiv:2405.21060)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..nn import Embedding, Mamba2Block, Module, RMSNorm, Stacked


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    act_dtype: Any = jnp.bfloat16
    remat: bool = True
    act_spec: Any = None

    def block(self) -> Mamba2Block:
        return Mamba2Block(
            self.d_model,
            d_state=self.d_state,
            d_conv=self.d_conv,
            expand=self.expand,
            head_dim=self.head_dim,
            n_groups=self.n_groups,
            chunk=self.chunk,
        )

    def n_params(self) -> int:
        b = self.block()
        d_in_proj = 2 * b.d_inner + 2 * b.n_groups * b.d_state + b.n_heads
        per_layer = (
            self.d_model * d_in_proj
            + b.d_conv * b.conv_dim
            + b.conv_dim
            + 3 * b.n_heads
            + b.d_inner
            + b.d_inner * self.d_model
            + self.d_model
        )
        return self.vocab * self.d_model + self.n_layers * per_layer + self.d_model

    def n_active_params(self) -> int:
        return self.n_params()


@dataclasses.dataclass(frozen=True)
class Mamba2LayerWrapped(Module):
    """Pre-norm residual wrapper around a Mamba2Block."""

    cfg: Mamba2Config

    def specs(self):
        return {"norm": RMSNorm(self.cfg.d_model, self.cfg.norm_eps), "mixer": self.cfg.block()}

    def __call__(self, p, x):
        h = RMSNorm(self.cfg.d_model, self.cfg.norm_eps)(p["norm"], x)
        return x + self.cfg.block()(p["mixer"], h)

    def prefill(self, p, x, cache_dtype=jnp.bfloat16):
        h = RMSNorm(self.cfg.d_model, self.cfg.norm_eps)(p["norm"], x)
        y, cache = self.cfg.block().prefill(p["mixer"], h, cache_dtype)
        return x + y, cache

    def decode(self, p, x, cache):
        h = RMSNorm(self.cfg.d_model, self.cfg.norm_eps)(p["norm"], x)
        y, cache = self.cfg.block().decode(p["mixer"], h, cache)
        return x + y, cache


@dataclasses.dataclass(frozen=True)
class Mamba2LM(Module):
    cfg: Mamba2Config

    def specs(self):
        c = self.cfg
        return {
            "embed": Embedding(c.vocab, c.d_model),
            "blocks": Stacked(Mamba2LayerWrapped(c), c.n_layers),
            "final_norm": RMSNorm(c.d_model, c.norm_eps),
        }

    def _logits(self, p, x):
        c = self.cfg
        return Embedding(c.vocab, c.d_model).attend(p["embed"], x)

    def __call__(self, p, tokens, positions=None, return_hidden=False):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model)(p["embed"], tokens).astype(c.act_dtype)
        layer = Mamba2LayerWrapped(c)
        layer_call = jax.checkpoint(layer.__call__) if c.remat else layer.__call__

        def constrain(x):
            if c.act_spec is None:
                return x
            from jax.sharding import PartitionSpec as P

            return jax.lax.with_sharding_constraint(x, P(tuple(c.act_spec)))

        def body(x, bp):
            return constrain(layer_call(bp, constrain(x))), None

        x, _ = jax.lax.scan(body, x, p["blocks"])
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        return self._logits(p, x), jnp.zeros((), jnp.float32)

    def head(self, p, x):
        return self._logits(p, x)

    def init_caches(self, batch, max_len=0, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        b = c.block()
        one = b.abstract_cache(batch, dtype) if abstract else b.init_cache(batch, dtype)
        if abstract:
            return jax.tree.map(lambda s: jax.ShapeDtypeStruct((c.n_layers, *s.shape), s.dtype), one)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (c.n_layers, *a.shape)).copy(), one)

    def prefill(self, p, tokens, positions=None, cache_dtype=jnp.bfloat16):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model)(p["embed"], tokens).astype(c.act_dtype)
        layer = Mamba2LayerWrapped(c)

        def body(x, bp):
            x, cache = layer.prefill(bp, x, cache_dtype)
            return x, cache

        x, caches = jax.lax.scan(body, x, p["blocks"])
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        return self._logits(p, x[:, -1:]), caches

    def decode_step(self, p, token, caches, t=None):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model)(p["embed"], token).astype(c.act_dtype)
        layer = Mamba2LayerWrapped(c)

        def body(x, xs):
            bp, cache = xs
            x, cache = layer.decode(bp, x, cache)
            return x, cache

        x, caches = jax.lax.scan(body, x, (p["blocks"], caches))
        x = RMSNorm(c.d_model, c.norm_eps)(p["final_norm"], x)
        return self._logits(p, x), caches
