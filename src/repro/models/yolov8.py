"""YOLOv8-style one-stage detector (Ultralytics [31]): C2f backbone, SPPF,
PAN/FPN neck, anchor-free decoupled head with DFL box regression.

Used by the paper for stroke detection on CT. Scaled by (depth, width)
multiples; default matches the "n" scale. The training loss here is a
simplified grid-assignment objective (BCE cls + DFL + CIoU-lite L1) — the
paper itself only consumes detector *throughput*, which depends on the
architecture, not the loss."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..core.graph import LayerGraph, LayerMeta, conv_meta, pointwise_meta
from ..nn import BatchNorm2D, Conv2D, Module, max_pool


@dataclasses.dataclass(frozen=True)
class YOLOv8Config:
    name: str = "yolov8n"
    img_size: int = 256
    n_classes: int = 2  # stroke / no-stroke lesion classes
    depth: float = 0.33
    width: float = 0.25
    reg_max: int = 16
    act_dtype: Any = jnp.float32

    def ch(self, c):
        return max(16, int(round(c * self.width / 8)) * 8)

    def n(self, n):
        return max(1, round(n * self.depth))


@dataclasses.dataclass(frozen=True)
class ConvBlock(Module):
    """conv+bn+silu. ``impl="pallas_fused"`` runs the whole block as one
    fused kernel (``kernels.fused``) instead of three XLA ops; params and
    math are identical (same specs, per-sample batch stats at B == 1)."""

    c_in: int
    c_out: int
    k: int = 3
    s: int = 1
    impl: str = "xla"

    def specs(self):
        pad = self.k // 2
        return {
            "conv": Conv2D(self.c_in, self.c_out, self.k, self.s, padding=pad, use_bias=False),
            "bn": BatchNorm2D(self.c_out),
        }

    def __call__(self, p, x):
        pad = self.k // 2
        if self.impl == "pallas_fused":
            from ..kernels.fused.ops import conv_block

            return conv_block(
                x, p["conv"]["w"], gamma=p["bn"]["scale"], beta=p["bn"]["bias"],
                stride=self.s, padding=pad, norm="batch", act="silu",
            )
        x = Conv2D(self.c_in, self.c_out, self.k, self.s, padding=pad, use_bias=False)(p["conv"], x)
        return jax.nn.silu(BatchNorm2D(self.c_out)(p["bn"], x))


@dataclasses.dataclass(frozen=True)
class Bottleneck(Module):
    c: int
    shortcut: bool = True
    impl: str = "xla"

    def specs(self):
        return {"cv1": ConvBlock(self.c, self.c, 3), "cv2": ConvBlock(self.c, self.c, 3)}

    def __call__(self, p, x):
        y = ConvBlock(self.c, self.c, 3, impl=self.impl)(p["cv1"], x)
        y = ConvBlock(self.c, self.c, 3, impl=self.impl)(p["cv2"], y)
        return x + y if self.shortcut else y


@dataclasses.dataclass(frozen=True)
class C2f(Module):
    c_in: int
    c_out: int
    n: int = 1
    shortcut: bool = True

    def specs(self):
        c_h = self.c_out // 2
        return {
            "cv1": ConvBlock(self.c_in, self.c_out, 1),
            "bn": [Bottleneck(c_h, self.shortcut) for _ in range(self.n)],
            "cv2": ConvBlock((2 + self.n) * c_h, self.c_out, 1),
        }

    def __call__(self, p, x):
        c_h = self.c_out // 2
        y = ConvBlock(self.c_in, self.c_out, 1)(p["cv1"], x)
        y1, y2 = jnp.split(y, 2, axis=-1)
        outs = [y1, y2]
        for i in range(self.n):
            y2 = Bottleneck(c_h, self.shortcut)(p["bn"][i], y2)
            outs.append(y2)
        return ConvBlock((2 + self.n) * c_h, self.c_out, 1)(p["cv2"], jnp.concatenate(outs, -1))


@dataclasses.dataclass(frozen=True)
class SPPF(Module):
    c: int

    def specs(self):
        c_h = self.c // 2
        return {"cv1": ConvBlock(self.c, c_h, 1), "cv2": ConvBlock(4 * c_h, self.c, 1)}

    def __call__(self, p, x):
        c_h = self.c // 2
        x = ConvBlock(self.c, c_h, 1)(p["cv1"], x)
        p1 = max_pool(x, 5, 1, padding=2)
        p2 = max_pool(p1, 5, 1, padding=2)
        p3 = max_pool(p2, 5, 1, padding=2)
        return ConvBlock(4 * c_h, self.c, 1)(p["cv2"], jnp.concatenate([x, p1, p2, p3], -1))


def _upsample2(x):
    B, H, W, C = x.shape
    return jax.image.resize(x, (B, 2 * H, 2 * W, C), "nearest")


@dataclasses.dataclass(frozen=True)
class DetectHead(Module):
    c_in: int
    n_classes: int
    reg_max: int

    def specs(self):
        c2 = max(16, self.c_in, self.reg_max * 4)
        c3 = max(self.c_in, min(self.n_classes, 100))
        return {
            "box1": ConvBlock(self.c_in, c2, 3),
            "box2": ConvBlock(c2, c2, 3),
            "box3": Conv2D(c2, 4 * self.reg_max, 1, 1, padding=0),
            "cls1": ConvBlock(self.c_in, c3, 3),
            "cls2": ConvBlock(c3, c3, 3),
            "cls3": Conv2D(c3, self.n_classes, 1, 1, padding=0),
        }

    def __call__(self, p, x):
        c2 = max(16, self.c_in, self.reg_max * 4)
        c3 = max(self.c_in, min(self.n_classes, 100))
        b = ConvBlock(self.c_in, c2, 3)(p["box1"], x)
        b = ConvBlock(c2, c2, 3)(p["box2"], b)
        b = Conv2D(c2, 4 * self.reg_max, 1, 1, padding=0)(p["box3"], b)
        c = ConvBlock(self.c_in, c3, 3)(p["cls1"], x)
        c = ConvBlock(c3, c3, 3)(p["cls2"], c)
        c = Conv2D(c3, self.n_classes, 1, 1, padding=0)(p["cls3"], c)
        return jnp.concatenate([b, c], axis=-1)


@dataclasses.dataclass(frozen=True)
class YOLOv8(Module):
    cfg: YOLOv8Config

    def _dims(self):
        c = self.cfg
        return c.ch(64), c.ch(128), c.ch(256), c.ch(512), c.ch(1024)

    def specs(self):
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        return {
            "stem": ConvBlock(3, c1, 3, 2),
            "down2": ConvBlock(c1, c2, 3, 2),
            "c2f_2": C2f(c2, c2, n(3)),
            "down3": ConvBlock(c2, c3, 3, 2),
            "c2f_3": C2f(c3, c3, n(6)),
            "down4": ConvBlock(c3, c4, 3, 2),
            "c2f_4": C2f(c4, c4, n(6)),
            "down5": ConvBlock(c4, c5, 3, 2),
            "c2f_5": C2f(c5, c5, n(3)),
            "sppf": SPPF(c5),
            # neck (PAN)
            "n_c2f_4": C2f(c5 + c4, c4, n(3), shortcut=False),
            "n_c2f_3": C2f(c4 + c3, c3, n(3), shortcut=False),
            "n_down3": ConvBlock(c3, c3, 3, 2),
            "n_c2f_4b": C2f(c3 + c4, c4, n(3), shortcut=False),
            "n_down4": ConvBlock(c4, c4, 3, 2),
            "n_c2f_5b": C2f(c4 + c5, c5, n(3), shortcut=False),
            "head3": DetectHead(c3, cfg.n_classes, cfg.reg_max),
            "head4": DetectHead(c4, cfg.n_classes, cfg.reg_max),
            "head5": DetectHead(c5, cfg.n_classes, cfg.reg_max),
        }

    def __call__(self, p, x):
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        x = x.astype(cfg.act_dtype)
        x = ConvBlock(3, c1, 3, 2)(p["stem"], x)
        x = ConvBlock(c1, c2, 3, 2)(p["down2"], x)
        x = C2f(c2, c2, n(3))(p["c2f_2"], x)
        x = ConvBlock(c2, c3, 3, 2)(p["down3"], x)
        f3 = C2f(c3, c3, n(6))(p["c2f_3"], x)
        x = ConvBlock(c3, c4, 3, 2)(p["down4"], f3)
        f4 = C2f(c4, c4, n(6))(p["c2f_4"], x)
        x = ConvBlock(c4, c5, 3, 2)(p["down5"], f4)
        x = C2f(c5, c5, n(3))(p["c2f_5"], x)
        f5 = SPPF(c5)(p["sppf"], x)
        # top-down
        u4 = C2f(c5 + c4, c4, n(3), shortcut=False)(
            p["n_c2f_4"], jnp.concatenate([_upsample2(f5), f4], -1)
        )
        u3 = C2f(c4 + c3, c3, n(3), shortcut=False)(
            p["n_c2f_3"], jnp.concatenate([_upsample2(u4), f3], -1)
        )
        # bottom-up
        d4 = C2f(c3 + c4, c4, n(3), shortcut=False)(
            p["n_c2f_4b"], jnp.concatenate([ConvBlock(c3, c3, 3, 2)(p["n_down3"], u3), u4], -1)
        )
        d5 = C2f(c4 + c5, c5, n(3), shortcut=False)(
            p["n_c2f_5b"], jnp.concatenate([ConvBlock(c4, c4, 3, 2)(p["n_down4"], d4), f5], -1)
        )
        o3 = DetectHead(c3, cfg.n_classes, cfg.reg_max)(p["head3"], u3)
        o4 = DetectHead(c4, cfg.n_classes, cfg.reg_max)(p["head4"], d4)
        o5 = DetectHead(c5, cfg.n_classes, cfg.reg_max)(p["head5"], d5)
        return {"p3": o3, "p4": o4, "p5": o5}

    # ---- per-node executable ops aligned with layer_graph ----------------------
    def staged_ops(self, graph: LayerGraph | None = None, impl: str = "xla"):
        """Coarse per-node ops: each op composes its node's stage callables,
        so the coarse executor runs the exact same primitive sequence the
        fine-grained (expanded) executor does — bit-exact in eager mode.
        Pass an already-built ``layer_graph()`` to avoid rebuilding it.
        ``impl`` selects a registered stage-callable variant (nodes without
        one — pools, concats, 1x1 output convs — keep their base stages)."""

        def composed(stages):
            def f(p, s):
                for _, _, fn in stages:
                    s = fn(p, s)
                return s

            return f

        graph = graph if graph is not None else self.layer_graph()
        return [(l.name, composed(node_stages(l, impl))) for l in graph]

    # ---- hierarchical layer graph for the scheduler ----------------------------
    def layer_graph(
        self, batch: int = 1, dtype_bytes: int = 2, _impl: str = "xla"
    ) -> LayerGraph:
        """Coarse graph whose composite nodes (`c2f`/`sppf`/`head` and the
        fused conv blocks) carry (a) their primitive-only ``sublayers``
        decomposition — flop/byte/param totals are the decomposition sums,
        so ``expand()`` conserves them exactly — and (b) executable
        ``stages`` callables in ``attrs`` so cuts at any stage boundary of
        the expanded graph are runnable. Interior primitives of one stage
        refuse cuts (``cut_after=False``); boundary bytes on interior
        points charge the *live set* (e.g. the accumulated skip tensors
        inside ``c2f``), not just the flowing activation."""
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        impl = _impl
        layers: list[LayerMeta] = []

        def act_bytes(h, c):
            return float(dtype_bytes * batch * h * h * c)

        def node(name, kind, in_shape, out_shape, stages, attrs=None):
            """Composite meta from its stages: totals are sums over prims."""
            prims = [p for _, ps, _ in stages for p in ps]
            a = dict(attrs or {})
            a["stages"] = [(sn, len(ps), fn) for sn, ps, fn in stages]
            layers.append(
                LayerMeta(
                    idx=len(layers),
                    name=name,
                    kind=kind,
                    in_shape=in_shape,
                    out_shape=out_shape,
                    attrs=a,
                    flops=sum(p.flops for p in prims),
                    bytes_accessed=sum(p.bytes_accessed for p in prims),
                    params=sum(p.params for p in prims),
                    boundary_bytes=float(dtype_bytes * math.prod(out_shape)),
                    sublayers=prims,
                )
            )

        def cb_prims(scope, h_in, c_in, c_out, k, stride, live_extra=0.0):
            """ConvBlock primitives (conv+bn+silu); ``live_extra`` bytes of
            companion tensors stay live across every interior cut point."""
            cm = conv_meta(
                0, f"{scope}.conv", batch, h_in, h_in, c_in, c_out, k, stride, k // 2, dtype_bytes
            )
            h_out = cm.out_shape[1]
            shape = (batch, h_out, h_out, c_out)
            bn = pointwise_meta(0, f"{scope}.bn", "bn", shape, dtype_bytes, 2.0, 2 * c_out)
            act = pointwise_meta(0, f"{scope}.silu", "act", shape, dtype_bytes)
            for m in (cm, bn, act):
                m.boundary_bytes += live_extra
                m.attrs["cut_after"] = False
            # every ConvBlock is a pallas_fused candidate: one kernel, one
            # HBM round trip (in + out + params) instead of three
            cm.attrs["fuse"] = {
                "span": 3,
                "flops": cm.flops + bn.flops + act.flops,
                "bytes": dtype_bytes * (math.prod(cm.in_shape) + math.prod(shape))
                + 4.0 * (cm.params + bn.params),
                "kind": "conv",
                "norm": "batch",
                "act": "silu",
            }
            bn.attrs["fused_into"] = cm.name
            act.attrs["fused_into"] = cm.name
            return [cm, bn, act], h_out

        def end_stage(prims):
            prims[-1].attrs["cut_after"] = True
            return prims

        def conv_node(name, h_in, c_in, c_out, src="x", dst="x"):
            prims, h_out = cb_prims(name, h_in, c_in, c_out, 3, 2)

            def fn(p, s, ci=c_in, co=c_out, key=name, sk=src, d=dst):
                s = dict(s)
                s[d] = ConvBlock(ci, co, 3, 2, impl=impl)(p[key], s[sk])
                return s

            node(
                name,
                "conv",
                (batch, h_in, h_in, c_in),
                prims[0].out_shape,
                [(name, end_stage(prims), fn)],
                attrs={"kernel": 3, "stride": 2, "padding": 1},
            )
            return h_out

        def c2f_node(name, h, c_in, c_out, nb, shortcut, src, dst, cat=None):
            c_h = c_out // 2
            tmp = "_" + name
            stages = []
            cv1_prims = []
            if cat is not None:
                cc = pointwise_meta(
                    0, f"{name}.in_concat", "concat", (batch, h, h, c_in), dtype_bytes, 0.0
                )
                cc.attrs["cut_after"] = False
                cv1_prims.append(cc)
            blk, _ = cb_prims(f"{name}.cv1", h, c_in, c_out, 1, 1)
            cv1_prims += blk
            src_compute = cat if cat is not None else (lambda p, s, sk=src: s[sk])

            def cv1_fn(p, s, ci=c_in, co=c_out, key=name, t=tmp, sc=src_compute):
                s = dict(s)
                y = ConvBlock(ci, co, 1, impl=impl)(p[key]["cv1"], sc(p, s))
                y1, y2 = jnp.split(y, 2, axis=-1)
                s[t] = [y1, y2]
                return s

            stages.append((f"{name}.cv1", end_stage(cv1_prims), cv1_fn))
            for i in range(nb):
                # outs[0:2+i] stay live across the bottleneck — the interior
                # skip tensors a cut inside c2f must move
                live = act_bytes(h, (2 + i) * c_h)
                p1, _ = cb_prims(f"{name}.bn{i}.cv1", h, c_h, c_h, 3, 1, live_extra=live)
                p2, _ = cb_prims(f"{name}.bn{i}.cv2", h, c_h, c_h, 3, 1, live_extra=live)
                prims = p1 + p2
                if shortcut:
                    add = pointwise_meta(
                        0, f"{name}.bn{i}.add", "add", (batch, h, h, c_h), dtype_bytes
                    )
                    add.boundary_bytes += live
                    add.attrs["cut_after"] = False
                    prims.append(add)

                def bn_fn(p, s, key=name, i=i, ch=c_h, sc=shortcut, t=tmp):
                    s = dict(s)
                    outs = list(s[t])
                    outs.append(Bottleneck(ch, sc, impl=impl)(p[key]["bn"][i], outs[-1]))
                    s[t] = outs
                    return s

                stages.append((f"{name}.bn{i}", end_stage(prims), bn_fn))
            cat_m = pointwise_meta(
                0, f"{name}.cat", "concat", (batch, h, h, (2 + nb) * c_h), dtype_bytes, 0.0
            )
            cat_m.attrs["cut_after"] = False
            blk2, _ = cb_prims(f"{name}.cv2", h, (2 + nb) * c_h, c_out, 1, 1)

            def cv2_fn(p, s, key=name, ch=c_h, nb=nb, co=c_out, t=tmp, d=dst):
                s = dict(s)
                y = ConvBlock((2 + nb) * ch, co, 1, impl=impl)(p[key]["cv2"], jnp.concatenate(s[t], -1))
                del s[t]
                s[d] = y
                return s

            stages.append((f"{name}.cv2", end_stage([cat_m] + blk2), cv2_fn))
            node(name, "c2f", (batch, h, h, c_in), (batch, h, h, c_out), stages)

        def sppf_node(name, h, c, src, dst):
            c_h = c // 2
            tmp = "_" + name
            stages = []
            blk, _ = cb_prims(f"{name}.cv1", h, c, c_h, 1, 1)

            def cv1_fn(p, s, key=name, cc=c, ch=c_h, t=tmp, sk=src):
                s = dict(s)
                s[t] = [ConvBlock(cc, ch, 1, impl=impl)(p[key]["cv1"], s[sk])]
                return s

            stages.append((f"{name}.cv1", end_stage(blk), cv1_fn))
            pool_prims = []
            for i in range(3):
                pm = pointwise_meta(
                    0, f"{name}.pool{i + 1}", "pool", (batch, h, h, c_h), dtype_bytes, 25.0
                )
                pm.attrs.update({"window": 5, "stride": 1})
                pm.boundary_bytes += act_bytes(h, (i + 1) * c_h)  # pooled pyramid stays live
                pool_prims.append(pm)
            # the pool pyramid (+ the concat it feeds) is a pallas_fused
            # candidate: read cv1's output once, write the 4*c_h concat once
            # instead of round-tripping every pyramid level through HBM
            pool_prims[0].attrs["fuse"] = {
                "span": 3,
                "flops": sum(p.flops for p in pool_prims),
                "bytes": dtype_bytes * batch * h * h * c_h * 5.0,
                "kind": "pool",
                "window": 5,
            }
            for pm in pool_prims[1:]:
                pm.attrs["fused_into"] = pool_prims[0].name
            for i, pm in enumerate(pool_prims):
                if impl == "pallas_fused":
                    # whole pyramid runs in the pool3 stage as one kernel;
                    # pool1/pool2 pass through (the planner only binds the
                    # fused variant when all three stages share a segment)
                    if i < 2:
                        def pool_fn(p, s):
                            return s
                    else:
                        def pool_fn(p, s, t=tmp):
                            from ..kernels.fused.ops import sppf_pyramid

                            s = dict(s)
                            s[t] = [sppf_pyramid(s[t][0])]
                            return s
                else:
                    def pool_fn(p, s, t=tmp):
                        s = dict(s)
                        s[t] = s[t] + [max_pool(s[t][-1], 5, 1, padding=2)]
                        return s

                stages.append((f"{name}.pool{i + 1}", end_stage([pm]), pool_fn))
            cat_m = pointwise_meta(0, f"{name}.cat", "concat", (batch, h, h, 4 * c_h), dtype_bytes, 0.0)
            cat_m.attrs["cut_after"] = False
            blk2, _ = cb_prims(f"{name}.cv2", h, 4 * c_h, c, 1, 1)

            def cv2_fn(p, s, key=name, cc=c, ch=c_h, t=tmp, d=dst):
                s = dict(s)
                y = ConvBlock(4 * ch, cc, 1, impl=impl)(p[key]["cv2"], jnp.concatenate(s[t], -1))
                del s[t]
                s[d] = y
                return s

            stages.append((f"{name}.cv2", end_stage([cat_m] + blk2), cv2_fn))
            node(name, "sppf", (batch, h, h, c), (batch, h, h, c), stages)

        def head_node(name, h, c_in, src, dst):
            rm, ncl = cfg.reg_max, cfg.n_classes
            c2_ = max(16, c_in, rm * 4)
            c3_ = max(c_in, min(ncl, 100))
            tb, tc = f"_{name}.b", f"_{name}.c"
            stages = []

            def cb_stage(sname, sub, ci, co, read, write, live):
                prims, _ = cb_prims(f"{name}.{sname}", h, ci, co, 3, 1, live_extra=live)

                def fn(p, s, key=name, sub=sub, ci=ci, co=co, r=read, w=write):
                    s = dict(s)
                    s[w] = ConvBlock(ci, co, 3, impl=impl)(p[key][sub], s[r])
                    return s

                stages.append((f"{name}.{sname}", end_stage(prims), fn))

            def conv1_stage(sname, sub, ci, co, read, write, live):
                cm = conv_meta(0, f"{name}.{sname}", batch, h, h, ci, co, 1, 1, 0, dtype_bytes)
                cm.boundary_bytes += live
                # the bare 1x1 head convs (box3/cls3) are span-1 pallas_fused
                # candidates too: conv+bias in one kernel, no norm/act, so
                # the fused path is exact at any batch (no batch-norm caveat)
                cm.attrs["fuse"] = {
                    "span": 1,
                    "flops": cm.flops,
                    "bytes": dtype_bytes
                    * (math.prod(cm.in_shape) + math.prod(cm.out_shape))
                    + 4.0 * cm.params,
                    "kind": "conv",
                    "norm": "none",
                    "act": "none",
                }

                def fn(p, s, key=name, sub=sub, ci=ci, co=co, r=read, w=write):
                    s = dict(s)
                    if impl == "pallas_fused":
                        from ..kernels.fused.ops import conv_block

                        s[w] = conv_block(
                            s[r], p[key][sub]["w"], b=p[key][sub]["b"],
                            stride=1, padding=0, norm="none", act="none",
                        )
                    else:
                        s[w] = Conv2D(ci, co, 1, 1, padding=0)(p[key][sub], s[r])
                    return s

                stages.append((f"{name}.{sname}", end_stage([cm]), fn))

            src_live = act_bytes(h, c_in)  # cls branch still reads the head input
            cb_stage("box1", "box1", c_in, c2_, src, tb, src_live)
            cb_stage("box2", "box2", c2_, c2_, tb, tb, src_live)
            conv1_stage("box3", "box3", c2_, 4 * rm, tb, tb, src_live)
            box_live = act_bytes(h, 4 * rm)  # the finished box branch stays live
            cb_stage("cls1", "cls1", c_in, c3_, src, tc, box_live)
            cb_stage("cls2", "cls2", c3_, c3_, tc, tc, box_live)
            conv1_stage("cls3", "cls3", c3_, ncl, tc, tc, box_live)
            out_m = pointwise_meta(
                0, f"{name}.cat", "concat", (batch, h, h, 4 * rm + ncl), dtype_bytes, 0.0
            )

            def out_fn(p, s, b=tb, c=tc, d=dst):
                s = dict(s)
                s[d] = jnp.concatenate([s[b], s[c]], axis=-1)
                del s[b]
                del s[c]
                return s

            stages.append((f"{name}.out", end_stage([out_m]), out_fn))
            node(name, "head", (batch, h, h, c_in), (batch, h, h, 4 * rm + ncl), stages)

        h = cfg.img_size
        h = conv_node("stem", h, 3, c1)
        h = conv_node("down2", h, c1, c2)
        c2f_node("c2f_2", h, c2, c2, n(3), True, "x", "x")
        h = conv_node("down3", h, c2, c3)
        c2f_node("c2f_3", h, c3, c3, n(6), True, "x", "f3")
        h = conv_node("down4", h, c3, c4, src="f3")
        c2f_node("c2f_4", h, c4, c4, n(6), True, "x", "f4")
        h = conv_node("down5", h, c4, c5, src="f4")
        c2f_node("c2f_5", h, c5, c5, n(3), True, "x", "x")
        sppf_node("sppf", h, c5, "x", "f5")
        h3, h4 = h * 4, h * 2
        c2f_node(
            "n_c2f_4", h4, c5 + c4, c4, n(3), False, None, "u4",
            cat=lambda p, s: jnp.concatenate([_upsample2(s["f5"]), s["f4"]], -1),
        )
        c2f_node(
            "n_c2f_3", h3, c4 + c3, c3, n(3), False, None, "u3",
            cat=lambda p, s: jnp.concatenate([_upsample2(s["u4"]), s["f3"]], -1),
        )
        conv_node("n_down3", h3, c3, c3, src="u3")
        c2f_node(
            "n_c2f_4b", h4, c3 + c4, c4, n(3), False, None, "d4",
            cat=lambda p, s: jnp.concatenate([s["x"], s["u4"]], -1),
        )
        conv_node("n_down4", h4, c4, c4, src="d4")
        c2f_node(
            "n_c2f_5b", h, c4 + c5, c5, n(3), False, None, "d5",
            cat=lambda p, s: jnp.concatenate([s["x"], s["f5"]], -1),
        )
        head_node("head3", h3, c3, "u3", "o3")
        head_node("head4", h4, c4, "d4", "o4")
        head_node("head5", h, c5, "d5", "o5")
        g = LayerGraph(cfg.name, layers).renumber()
        if _impl == "xla":
            # graft the pallas_fused stage callables as named variants: same
            # stage structure/boundaries, every ConvBlock runs as one kernel
            alt = self.layer_graph(batch, dtype_bytes, _impl="pallas_fused")
            for l, al in zip(g.layers, alt.layers):
                l.attrs["stage_variants"] = {"pallas_fused": al.attrs["stages"]}
        return g


def node_stages(meta: LayerMeta, impl: str = "xla"):
    """A node's stage callables under the given implementation (falls back
    to the base ``stages`` for nodes with no registered variant)."""
    if impl != "xla":
        return meta.attrs.get("stage_variants", {}).get(impl, meta.attrs["stages"])
    return meta.attrs["stages"]
