"""YOLOv8-style one-stage detector (Ultralytics [31]): C2f backbone, SPPF,
PAN/FPN neck, anchor-free decoupled head with DFL box regression.

Used by the paper for stroke detection on CT. Scaled by (depth, width)
multiples; default matches the "n" scale. The training loss here is a
simplified grid-assignment objective (BCE cls + DFL + CIoU-lite L1) — the
paper itself only consumes detector *throughput*, which depends on the
architecture, not the loss."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..core.graph import LayerGraph, LayerMeta, conv_meta
from ..nn import BatchNorm2D, Conv2D, Module, max_pool


@dataclasses.dataclass(frozen=True)
class YOLOv8Config:
    name: str = "yolov8n"
    img_size: int = 256
    n_classes: int = 2  # stroke / no-stroke lesion classes
    depth: float = 0.33
    width: float = 0.25
    reg_max: int = 16
    act_dtype: Any = jnp.float32

    def ch(self, c):
        return max(16, int(round(c * self.width / 8)) * 8)

    def n(self, n):
        return max(1, round(n * self.depth))


@dataclasses.dataclass(frozen=True)
class ConvBlock(Module):
    c_in: int
    c_out: int
    k: int = 3
    s: int = 1

    def specs(self):
        pad = self.k // 2
        return {
            "conv": Conv2D(self.c_in, self.c_out, self.k, self.s, padding=pad, use_bias=False),
            "bn": BatchNorm2D(self.c_out),
        }

    def __call__(self, p, x):
        pad = self.k // 2
        x = Conv2D(self.c_in, self.c_out, self.k, self.s, padding=pad, use_bias=False)(p["conv"], x)
        return jax.nn.silu(BatchNorm2D(self.c_out)(p["bn"], x))


@dataclasses.dataclass(frozen=True)
class Bottleneck(Module):
    c: int
    shortcut: bool = True

    def specs(self):
        return {"cv1": ConvBlock(self.c, self.c, 3), "cv2": ConvBlock(self.c, self.c, 3)}

    def __call__(self, p, x):
        y = ConvBlock(self.c, self.c, 3)(p["cv1"], x)
        y = ConvBlock(self.c, self.c, 3)(p["cv2"], y)
        return x + y if self.shortcut else y


@dataclasses.dataclass(frozen=True)
class C2f(Module):
    c_in: int
    c_out: int
    n: int = 1
    shortcut: bool = True

    def specs(self):
        c_h = self.c_out // 2
        return {
            "cv1": ConvBlock(self.c_in, self.c_out, 1),
            "bn": [Bottleneck(c_h, self.shortcut) for _ in range(self.n)],
            "cv2": ConvBlock((2 + self.n) * c_h, self.c_out, 1),
        }

    def __call__(self, p, x):
        c_h = self.c_out // 2
        y = ConvBlock(self.c_in, self.c_out, 1)(p["cv1"], x)
        y1, y2 = jnp.split(y, 2, axis=-1)
        outs = [y1, y2]
        for i in range(self.n):
            y2 = Bottleneck(c_h, self.shortcut)(p["bn"][i], y2)
            outs.append(y2)
        return ConvBlock((2 + self.n) * c_h, self.c_out, 1)(p["cv2"], jnp.concatenate(outs, -1))


@dataclasses.dataclass(frozen=True)
class SPPF(Module):
    c: int

    def specs(self):
        c_h = self.c // 2
        return {"cv1": ConvBlock(self.c, c_h, 1), "cv2": ConvBlock(4 * c_h, self.c, 1)}

    def __call__(self, p, x):
        c_h = self.c // 2
        x = ConvBlock(self.c, c_h, 1)(p["cv1"], x)
        p1 = max_pool(x, 5, 1, padding=2)
        p2 = max_pool(p1, 5, 1, padding=2)
        p3 = max_pool(p2, 5, 1, padding=2)
        return ConvBlock(4 * c_h, self.c, 1)(p["cv2"], jnp.concatenate([x, p1, p2, p3], -1))


def _upsample2(x):
    B, H, W, C = x.shape
    return jax.image.resize(x, (B, 2 * H, 2 * W, C), "nearest")


@dataclasses.dataclass(frozen=True)
class DetectHead(Module):
    c_in: int
    n_classes: int
    reg_max: int

    def specs(self):
        c2 = max(16, self.c_in, self.reg_max * 4)
        c3 = max(self.c_in, min(self.n_classes, 100))
        return {
            "box1": ConvBlock(self.c_in, c2, 3),
            "box2": ConvBlock(c2, c2, 3),
            "box3": Conv2D(c2, 4 * self.reg_max, 1, 1, padding=0),
            "cls1": ConvBlock(self.c_in, c3, 3),
            "cls2": ConvBlock(c3, c3, 3),
            "cls3": Conv2D(c3, self.n_classes, 1, 1, padding=0),
        }

    def __call__(self, p, x):
        c2 = max(16, self.c_in, self.reg_max * 4)
        c3 = max(self.c_in, min(self.n_classes, 100))
        b = ConvBlock(self.c_in, c2, 3)(p["box1"], x)
        b = ConvBlock(c2, c2, 3)(p["box2"], b)
        b = Conv2D(c2, 4 * self.reg_max, 1, 1, padding=0)(p["box3"], b)
        c = ConvBlock(self.c_in, c3, 3)(p["cls1"], x)
        c = ConvBlock(c3, c3, 3)(p["cls2"], c)
        c = Conv2D(c3, self.n_classes, 1, 1, padding=0)(p["cls3"], c)
        return jnp.concatenate([b, c], axis=-1)


@dataclasses.dataclass(frozen=True)
class YOLOv8(Module):
    cfg: YOLOv8Config

    def _dims(self):
        c = self.cfg
        return c.ch(64), c.ch(128), c.ch(256), c.ch(512), c.ch(1024)

    def specs(self):
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        return {
            "stem": ConvBlock(3, c1, 3, 2),
            "down2": ConvBlock(c1, c2, 3, 2),
            "c2f_2": C2f(c2, c2, n(3)),
            "down3": ConvBlock(c2, c3, 3, 2),
            "c2f_3": C2f(c3, c3, n(6)),
            "down4": ConvBlock(c3, c4, 3, 2),
            "c2f_4": C2f(c4, c4, n(6)),
            "down5": ConvBlock(c4, c5, 3, 2),
            "c2f_5": C2f(c5, c5, n(3)),
            "sppf": SPPF(c5),
            # neck (PAN)
            "n_c2f_4": C2f(c5 + c4, c4, n(3), shortcut=False),
            "n_c2f_3": C2f(c4 + c3, c3, n(3), shortcut=False),
            "n_down3": ConvBlock(c3, c3, 3, 2),
            "n_c2f_4b": C2f(c3 + c4, c4, n(3), shortcut=False),
            "n_down4": ConvBlock(c4, c4, 3, 2),
            "n_c2f_5b": C2f(c4 + c5, c5, n(3), shortcut=False),
            "head3": DetectHead(c3, cfg.n_classes, cfg.reg_max),
            "head4": DetectHead(c4, cfg.n_classes, cfg.reg_max),
            "head5": DetectHead(c5, cfg.n_classes, cfg.reg_max),
        }

    def __call__(self, p, x):
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        x = x.astype(cfg.act_dtype)
        x = ConvBlock(3, c1, 3, 2)(p["stem"], x)
        x = ConvBlock(c1, c2, 3, 2)(p["down2"], x)
        x = C2f(c2, c2, n(3))(p["c2f_2"], x)
        x = ConvBlock(c2, c3, 3, 2)(p["down3"], x)
        f3 = C2f(c3, c3, n(6))(p["c2f_3"], x)
        x = ConvBlock(c3, c4, 3, 2)(p["down4"], f3)
        f4 = C2f(c4, c4, n(6))(p["c2f_4"], x)
        x = ConvBlock(c4, c5, 3, 2)(p["down5"], f4)
        x = C2f(c5, c5, n(3))(p["c2f_5"], x)
        f5 = SPPF(c5)(p["sppf"], x)
        # top-down
        u4 = C2f(c5 + c4, c4, n(3), shortcut=False)(
            p["n_c2f_4"], jnp.concatenate([_upsample2(f5), f4], -1)
        )
        u3 = C2f(c4 + c3, c3, n(3), shortcut=False)(
            p["n_c2f_3"], jnp.concatenate([_upsample2(u4), f3], -1)
        )
        # bottom-up
        d4 = C2f(c3 + c4, c4, n(3), shortcut=False)(
            p["n_c2f_4b"], jnp.concatenate([ConvBlock(c3, c3, 3, 2)(p["n_down3"], u3), u4], -1)
        )
        d5 = C2f(c4 + c5, c5, n(3), shortcut=False)(
            p["n_c2f_5b"], jnp.concatenate([ConvBlock(c4, c4, 3, 2)(p["n_down4"], d4), f5], -1)
        )
        o3 = DetectHead(c3, cfg.n_classes, cfg.reg_max)(p["head3"], u3)
        o4 = DetectHead(c4, cfg.n_classes, cfg.reg_max)(p["head4"], d4)
        o5 = DetectHead(c5, cfg.n_classes, cfg.reg_max)(p["head5"], d5)
        return {"p3": o3, "p4": o4, "p5": o5}

    # ---- per-node executable ops aligned with layer_graph ----------------------
    def staged_ops(self):
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n

        def upd(key, fn, src="x"):
            def f(p, s):
                s = dict(s)
                s[key] = fn(p, s[src] if isinstance(src, str) else src(s))
                return s

            return f

        ops = [
            ("stem", upd("x", lambda p, v: ConvBlock(3, c1, 3, 2)(p["stem"], v))),
            ("down2", upd("x", lambda p, v: ConvBlock(c1, c2, 3, 2)(p["down2"], v))),
            ("c2f_2", upd("x", lambda p, v: C2f(c2, c2, n(3))(p["c2f_2"], v))),
            ("down3", upd("x", lambda p, v: ConvBlock(c2, c3, 3, 2)(p["down3"], v))),
            ("c2f_3", upd("f3", lambda p, v: C2f(c3, c3, n(6))(p["c2f_3"], v))),
            ("down4", upd("x", lambda p, v: ConvBlock(c3, c4, 3, 2)(p["down4"], v), src="f3")),
            ("c2f_4", upd("f4", lambda p, v: C2f(c4, c4, n(6))(p["c2f_4"], v))),
            ("down5", upd("x", lambda p, v: ConvBlock(c4, c5, 3, 2)(p["down5"], v), src="f4")),
            ("c2f_5", upd("x", lambda p, v: C2f(c5, c5, n(3))(p["c2f_5"], v))),
            ("sppf", upd("f5", lambda p, v: SPPF(c5)(p["sppf"], v))),
            (
                "n_c2f_4",
                upd(
                    "u4",
                    lambda p, v: C2f(c5 + c4, c4, n(3), shortcut=False)(p["n_c2f_4"], v),
                    src=lambda s: jnp.concatenate([_upsample2(s["f5"]), s["f4"]], -1),
                ),
            ),
            (
                "n_c2f_3",
                upd(
                    "u3",
                    lambda p, v: C2f(c4 + c3, c3, n(3), shortcut=False)(p["n_c2f_3"], v),
                    src=lambda s: jnp.concatenate([_upsample2(s["u4"]), s["f3"]], -1),
                ),
            ),
            ("n_down3", upd("x", lambda p, v: ConvBlock(c3, c3, 3, 2)(p["n_down3"], v), src="u3")),
            (
                "n_c2f_4b",
                upd(
                    "d4",
                    lambda p, v: C2f(c3 + c4, c4, n(3), shortcut=False)(p["n_c2f_4b"], v),
                    src=lambda s: jnp.concatenate([s["x"], s["u4"]], -1),
                ),
            ),
            ("n_down4", upd("x", lambda p, v: ConvBlock(c4, c4, 3, 2)(p["n_down4"], v), src="d4")),
            (
                "n_c2f_5b",
                upd(
                    "d5",
                    lambda p, v: C2f(c4 + c5, c5, n(3), shortcut=False)(p["n_c2f_5b"], v),
                    src=lambda s: jnp.concatenate([s["x"], s["f5"]], -1),
                ),
            ),
            ("head3", upd("o3", lambda p, v: DetectHead(c3, cfg.n_classes, cfg.reg_max)(p["head3"], v), src="u3")),
            ("head4", upd("o4", lambda p, v: DetectHead(c4, cfg.n_classes, cfg.reg_max)(p["head4"], v), src="d4")),
            ("head5", upd("o5", lambda p, v: DetectHead(c5, cfg.n_classes, cfg.reg_max)(p["head5"], v), src="d5")),
        ]
        return ops

    # ---- coarse layer graph for the scheduler ---------------------------------
    def layer_graph(self, batch: int = 1, dtype_bytes: int = 2) -> LayerGraph:
        cfg = self.cfg
        c1, c2, c3, c4, c5 = self._dims()
        n = cfg.n
        s = cfg.img_size
        layers: list[LayerMeta] = []

        def block(name, kind, h, c_in, c_out, flops, params):
            layers.append(
                LayerMeta(
                    idx=len(layers),
                    name=name,
                    kind=kind,
                    in_shape=(batch, h, h, c_in),
                    out_shape=(batch, h, h, c_out),
                    flops=flops,
                    bytes_accessed=dtype_bytes * batch * h * h * (c_in + c_out) + 4 * params,
                    params=params,
                    boundary_bytes=dtype_bytes * batch * h * h * c_out,
                )
            )

        def conv_fl(h, cin, cout, k, stride=1):
            return 2.0 * batch * (h / stride) ** 2 * cout * k * k * cin

        def c2f_fl(h, cin, cout, nb):
            ch = cout // 2
            f = conv_fl(h, cin, cout, 1) + conv_fl(h, (2 + nb) * ch, cout, 1)
            f += nb * 2 * conv_fl(h, ch, ch, 3)
            pr = cin * cout + (2 + nb) * ch * cout + nb * 2 * 9 * ch * ch
            return f, pr

        h = s
        block("stem", "conv", h, 3, c1, conv_fl(h, 3, c1, 3, 2), 9 * 3 * c1)
        h //= 2
        plan = [
            ("down2", "conv", c1, c2, 2), ("c2f_2", "c2f", c2, c2, n(3)),
            ("down3", "conv", c2, c3, 2), ("c2f_3", "c2f", c3, c3, n(6)),
            ("down4", "conv", c3, c4, 2), ("c2f_4", "c2f", c4, c4, n(6)),
            ("down5", "conv", c4, c5, 2), ("c2f_5", "c2f", c5, c5, n(3)),
        ]
        for name, kind, cin, cout, arg in plan:
            if kind == "conv":
                block(name, "conv", h, cin, cout, conv_fl(h, cin, cout, 3, 2), 9 * cin * cout)
                h //= 2
            else:
                f, pr = c2f_fl(h, cin, cout, arg)
                block(name, "c2f", h, cin, cout, f, pr)
        f, pr = c2f_fl(h, c5, c5, 1)
        block("sppf", "sppf", h, c5, c5, f * 0.6, c5 * c5 // 2 * 5)
        f, pr = c2f_fl(h * 2, c5 + c4, c4, n(3))
        block("n_c2f_4", "c2f", h * 2, c5 + c4, c4, f, pr)
        f, pr = c2f_fl(h * 4, c4 + c3, c3, n(3))
        block("n_c2f_3", "c2f", h * 4, c4 + c3, c3, f, pr)
        block("n_down3", "conv", h * 4, c3, c3, conv_fl(h * 4, c3, c3, 3, 2), 9 * c3 * c3)
        f, pr = c2f_fl(h * 2, c3 + c4, c4, n(3))
        block("n_c2f_4b", "c2f", h * 2, c3 + c4, c4, f, pr)
        block("n_down4", "conv", h * 2, c4, c4, conv_fl(h * 2, c4, c4, 3, 2), 9 * c4 * c4)
        f, pr = c2f_fl(h, c4 + c5, c5, n(3))
        block("n_c2f_5b", "c2f", h, c4 + c5, c5, f, pr)
        for hn, (name, cin) in zip((h * 4, h * 2, h), (("head3", c3), ("head4", c4), ("head5", c5))):
            c_box = max(16, cin, cfg.reg_max * 4)
            fl = 2 * conv_fl(hn, cin, c_box, 3) + conv_fl(hn, c_box, 4 * cfg.reg_max, 1)
            fl += 2 * conv_fl(hn, cin, cin, 3) + conv_fl(hn, cin, cfg.n_classes, 1)
            pr = 9 * cin * c_box + 9 * c_box * c_box + 9 * cin * cin * 2
            block(name, "head", hn, cin, 4 * cfg.reg_max + cfg.n_classes, fl, pr)
        return LayerGraph(cfg.name, layers).renumber()
