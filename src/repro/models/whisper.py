"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv/audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d). Whisper uses
absolute (sinusoidal) positions and LayerNorm; no RoPE.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..nn import MLP, Attention, Embedding, LayerNorm, Module, ParamSpec, Stacked, normal_init


def sinusoid_pos(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (n, d)


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500
    max_text: int = 448
    norm_eps: float = 1e-5
    act_dtype: Any = jnp.bfloat16
    attn_chunk: int = 512
    remat: bool = True

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def attn(self):
        return Attention(self.d_model, self.n_heads, self.n_heads, self.head_dim,
                         use_rope=False, attn_chunk=self.attn_chunk)

    def n_params(self):
        d = self.d_model
        attn = 4 * d * d
        mlp = 2 * d * self.d_ff
        enc = self.n_enc_layers * (attn + mlp + 4 * d)
        dec = self.n_dec_layers * (2 * attn + mlp + 6 * d)
        return self.vocab * d + self.max_text * d + enc + dec + 4 * d

    def n_active_params(self):
        return self.n_params()


@dataclasses.dataclass(frozen=True)
class EncBlock(Module):
    cfg: WhisperConfig

    def specs(self):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model, c.norm_eps),
            "attn": c.attn(),
            "ln2": LayerNorm(c.d_model, c.norm_eps),
            "mlp": MLP(c.d_model, c.d_ff, act="gelu", gated=False),
        }

    def __call__(self, p, x):
        c = self.cfg
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln1"], x)
        x = x + c.attn()(p["attn"], h, causal=False)
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln2"], x)
        return x + MLP(c.d_model, c.d_ff, act="gelu", gated=False)(p["mlp"], h)


@dataclasses.dataclass(frozen=True)
class DecBlock(Module):
    cfg: WhisperConfig

    def specs(self):
        c = self.cfg
        return {
            "ln1": LayerNorm(c.d_model, c.norm_eps),
            "self_attn": c.attn(),
            "ln_x": LayerNorm(c.d_model, c.norm_eps),
            "cross_attn": c.attn(),
            "ln2": LayerNorm(c.d_model, c.norm_eps),
            "mlp": MLP(c.d_model, c.d_ff, act="gelu", gated=False),
        }

    def __call__(self, p, x, enc_out):
        c = self.cfg
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln1"], x)
        x = x + c.attn()(p["self_attn"], h, causal=True)
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln_x"], x)
        x = x + c.attn()(p["cross_attn"], h, causal=False, kv_x=enc_out)
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln2"], x)
        return x + MLP(c.d_model, c.d_ff, act="gelu", gated=False)(p["mlp"], h)

    def prefill(self, p, x, enc_out, cache_dtype=jnp.bfloat16):
        c = self.cfg
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln1"], x)
        y, self_kv = c.attn().prefill(p["self_attn"], h, cache_dtype=cache_dtype)
        x = x + y
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln_x"], x)
        x = x + c.attn()(p["cross_attn"], h, causal=False, kv_x=enc_out)
        ck, cv = c.attn().project_kv(p["cross_attn"], enc_out)
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln2"], x)
        x = x + MLP(c.d_model, c.d_ff, act="gelu", gated=False)(p["mlp"], h)
        return x, {"self": self_kv, "cross_k": ck.astype(cache_dtype), "cross_v": cv.astype(cache_dtype)}

    def decode(self, p, x, cache, t):
        c = self.cfg
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln1"], x)
        y, self_kv = c.attn().decode(p["self_attn"], h, cache["self"], t)
        x = x + y
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln_x"], x)
        x = x + c.attn().attend_kv(p["cross_attn"], h, cache["cross_k"], cache["cross_v"])
        h = LayerNorm(c.d_model, c.norm_eps)(p["ln2"], x)
        x = x + MLP(c.d_model, c.d_ff, act="gelu", gated=False)(p["mlp"], h)
        return x, {"self": self_kv, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

    def init_cache(self, batch, max_len, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        sds = jax.ShapeDtypeStruct
        cross_shape = (batch, c.n_frames, c.n_heads, c.head_dim)
        if abstract:
            return {
                "self": c.attn().abstract_cache(batch, max_len, dtype),
                "cross_k": sds(cross_shape, dtype),
                "cross_v": sds(cross_shape, dtype),
            }
        return {
            "self": c.attn().init_cache(batch, max_len, dtype),
            "cross_k": jnp.zeros(cross_shape, dtype),
            "cross_v": jnp.zeros(cross_shape, dtype),
        }


@dataclasses.dataclass(frozen=True)
class WhisperModel(Module):
    cfg: WhisperConfig

    def specs(self):
        c = self.cfg
        return {
            "embed": Embedding(c.vocab, c.d_model),
            "pos_embed": ParamSpec((c.max_text, c.d_model), (None, "embed"), normal_init(0.01)),
            "enc_blocks": Stacked(EncBlock(c), c.n_enc_layers),
            "dec_blocks": Stacked(DecBlock(c), c.n_dec_layers),
            "ln_enc": LayerNorm(c.d_model, c.norm_eps),
            "ln_dec": LayerNorm(c.d_model, c.norm_eps),
        }

    def encode(self, p, frames):
        """frames: (B, n_frames, d) precomputed embeddings (conv-stub)."""
        c = self.cfg
        x = frames.astype(c.act_dtype) + sinusoid_pos(frames.shape[1], c.d_model).astype(c.act_dtype)
        blk = EncBlock(c)
        blk_call = jax.checkpoint(blk.__call__) if c.remat else blk.__call__
        x, _ = jax.lax.scan(lambda x, bp: (blk_call(bp, x), None), x, p["enc_blocks"])
        return LayerNorm(c.d_model, c.norm_eps)(p["ln_enc"], x)

    def _dec_embed(self, p, tokens):
        c = self.cfg
        x = Embedding(c.vocab, c.d_model)(p["embed"], tokens).astype(c.act_dtype)
        S = tokens.shape[1]
        pe_full = p["pos_embed"]
        if S <= c.max_text:
            pe = pe_full[:S]
        else:  # mechanical long-decode cells exceed whisper's 448 positions: tile
            reps = -(-S // c.max_text)
            pe = jnp.tile(pe_full, (reps, 1))[:S]
        return x + pe.astype(c.act_dtype)

    def __call__(self, p, frames, tokens, return_hidden=False):
        c = self.cfg
        enc_out = self.encode(p, frames)
        x = self._dec_embed(p, tokens)
        blk = DecBlock(c)
        blk_call = jax.checkpoint(blk.__call__) if c.remat else blk.__call__
        x, _ = jax.lax.scan(lambda x, bp: (blk_call(bp, x, enc_out), None), x, p["dec_blocks"])
        x = LayerNorm(c.d_model, c.norm_eps)(p["ln_dec"], x)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        logits = Embedding(c.vocab, c.d_model).attend(p["embed"], x)
        return logits, jnp.zeros((), jnp.float32)

    def head(self, p, x):
        c = self.cfg
        return Embedding(c.vocab, c.d_model).attend(p["embed"], x)

    def init_caches(self, batch, max_len, dtype=jnp.bfloat16, abstract=False):
        c = self.cfg
        one = DecBlock(c).init_cache(batch, max_len, dtype, abstract=abstract)
        if abstract:
            return jax.tree.map(lambda s: jax.ShapeDtypeStruct((c.n_dec_layers, *s.shape), s.dtype), one)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (c.n_dec_layers, *a.shape)).copy(), one)

    def prefill(self, p, frames, tokens, cache_dtype=jnp.bfloat16):
        c = self.cfg
        enc_out = self.encode(p, frames)
        x = self._dec_embed(p, tokens)
        blk = DecBlock(c)

        def body(x, bp):
            x, cache = blk.prefill(bp, x, enc_out, cache_dtype)
            return x, cache

        x, caches = jax.lax.scan(body, x, p["dec_blocks"])
        x = LayerNorm(c.d_model, c.norm_eps)(p["ln_dec"], x)
        logits = Embedding(c.vocab, c.d_model).attend(p["embed"], x[:, -1:])
        return logits, caches

    def decode_step(self, p, token, caches, t):
        c = self.cfg
        pe_idx = jnp.minimum(jnp.asarray(t, jnp.int32), c.max_text - 1)
        x = Embedding(c.vocab, c.d_model)(p["embed"], token).astype(c.act_dtype)
        x = x + jax.lax.dynamic_slice_in_dim(p["pos_embed"], pe_idx, 1, axis=0).astype(c.act_dtype)
        blk = DecBlock(c)

        def body(x, xs):
            bp, cache = xs
            x, cache = blk.decode(bp, x, cache, t)
            return x, cache

        x, caches = jax.lax.scan(body, x, (p["dec_blocks"], caches))
        x = LayerNorm(c.d_model, c.norm_eps)(p["ln_dec"], x)
        logits = Embedding(c.vocab, c.d_model).attend(p["embed"], x)
        return logits, caches
