"""Conv layers (NHWC), torch-semantics padding, for the Pix2Pix/YOLO models.

``ConvTranspose2D`` implements *torch* semantics: ``padding=p`` trims ``p``
rows/cols from each border of the pad-free (VALID) transposed convolution —
this makes the paper's eq.(6) == eq.(5)+(7) equivalence exact by
construction (property-tested in tests/test_surgery.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .module import Module, ParamSpec, conv_init, zeros_init, ones_init

DN = ("NHWC", "HWIO", "NHWC")


def _pad_arg(padding, k):
    if padding == "SAME":
        return "SAME"
    if padding == "VALID" or padding == 0:
        return "VALID"
    if isinstance(padding, int):
        return [(padding, padding), (padding, padding)]
    return padding


@dataclasses.dataclass(frozen=True)
class Conv2D(Module):
    c_in: int
    c_out: int
    kernel: int = 3
    stride: int = 1
    padding: int | str = 0  # torch-style int or SAME/VALID
    use_bias: bool = True
    groups: int = 1

    def specs(self):
        s = {
            "w": ParamSpec(
                (self.kernel, self.kernel, self.c_in // self.groups, self.c_out),
                (None, None, "conv_in", "conv_out"),
                conv_init(),
            )
        }
        if self.use_bias:
            s["b"] = ParamSpec((self.c_out,), ("conv_out",), zeros_init())
        return s

    def __call__(self, p, x):
        y = jax.lax.conv_general_dilated(
            x,
            p["w"].astype(x.dtype),
            window_strides=(self.stride, self.stride),
            padding=_pad_arg(self.padding, self.kernel),
            dimension_numbers=DN,
            feature_group_count=self.groups,
        )
        if self.use_bias:
            y = y + p["b"].astype(x.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class ConvTranspose2D(Module):
    """Torch-semantics transposed conv: out = stride*(in-1) + k - 2*padding."""

    c_in: int
    c_out: int
    kernel: int = 4
    stride: int = 2
    padding: int = 0  # torch padding; implemented as VALID + crop
    use_bias: bool = True

    def specs(self):
        s = {
            "w": ParamSpec(
                (self.kernel, self.kernel, self.c_in, self.c_out),
                (None, None, "conv_in", "conv_out"),
                conv_init(),
            )
        }
        if self.use_bias:
            s["b"] = ParamSpec((self.c_out,), ("conv_out",), zeros_init())
        return s

    def __call__(self, p, x):
        y = jax.lax.conv_transpose(
            x,
            p["w"].astype(x.dtype),
            strides=(self.stride, self.stride),
            padding="VALID",
            dimension_numbers=DN,
        )
        if self.padding:
            pad = self.padding
            y = y[:, pad:-pad, pad:-pad, :]
        if self.use_bias:
            y = y + p["b"].astype(x.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class Crop2D(Module):
    """Remove ``crop`` rows/cols from each border (the paper's substitution)."""

    crop: int = 1

    def specs(self):
        return {}

    def __call__(self, p, x):
        c = self.crop
        return x[:, c:-c, c:-c, :]


@dataclasses.dataclass(frozen=True)
class BatchNorm2D(Module):
    """Batch-statistics norm over (B, H, W). Pix2Pix uses batch stats at
    inference too (batch-size-1 instance-norm behaviour), so no running
    stats are tracked."""

    c: int
    eps: float = 1e-5

    def specs(self):
        return {
            "scale": ParamSpec((self.c,), ("conv_out",), ones_init()),
            "bias": ParamSpec((self.c,), ("conv_out",), zeros_init()),
            # carried like TF (counted in Table II's totals); updated by EMA
            # in the training loop when eval-mode stats are wanted
            "moving_mean": ParamSpec((self.c,), ("conv_out",), zeros_init()),
            "moving_var": ParamSpec((self.c,), ("conv_out",), ones_init()),
        }

    def __call__(self, p, x, use_running: bool = False):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        if use_running:
            mean = p["moving_mean"].astype(jnp.float32)
            var = p["moving_var"].astype(jnp.float32)
        else:
            mean = jnp.mean(x32, axis=(0, 1, 2), keepdims=True)
            var = jnp.var(x32, axis=(0, 1, 2), keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + self.eps)
        return (y * p["scale"] + p["bias"]).astype(dtype)


def max_pool(x, window: int = 2, stride: int | None = None, padding="VALID"):
    stride = stride or window
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        padding if isinstance(padding, str) else [(0, 0), (padding, padding), (padding, padding), (0, 0)],
    )


def avg_pool(x, window: int = 2, stride: int | None = None):
    stride = stride or window
    y = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    )
    return y / (window * window)


def leaky_relu(x, slope: float = 0.2):
    return jax.nn.leaky_relu(x, slope)
