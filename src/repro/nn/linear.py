"""Dense layers with logical sharding axes."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .module import Module, ParamSpec, lecun_init, zeros_init


@dataclasses.dataclass(frozen=True)
class Linear(Module):
    """y = x @ w + b, contracting the last dim of x."""

    d_in: int
    d_out: int
    use_bias: bool = False
    in_axis: str | None = "embed"
    out_axis: str | None = "mlp"

    def specs(self):
        s = {
            "w": ParamSpec(
                (self.d_in, self.d_out), (self.in_axis, self.out_axis), lecun_init((-2,))
            )
        }
        if self.use_bias:
            s["b"] = ParamSpec((self.d_out,), (self.out_axis,), zeros_init())
        return s

    def __call__(self, p, x):
        y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
        if self.use_bias:
            y = y + p["b"].astype(x.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class MultiLinear(Module):
    """x (..., d_in) -> (..., heads, per_head). Used for attention projections."""

    d_in: int
    heads: int
    per_head: int
    in_axis: str | None = "embed"
    head_axis: str | None = "heads"

    def specs(self):
        return {
            "w": ParamSpec(
                (self.d_in, self.heads, self.per_head),
                (self.in_axis, self.head_axis, None),
                lecun_init((-3,)),
            )
        }

    def __call__(self, p, x):
        return jnp.einsum("...d,dhp->...hp", x, p["w"].astype(x.dtype))


@dataclasses.dataclass(frozen=True)
class OutputLinear(Module):
    """(..., heads, per_head) -> (..., d_out). Attention output projection."""

    heads: int
    per_head: int
    d_out: int
    head_axis: str | None = "heads"
    out_axis: str | None = "embed"

    def specs(self):
        return {
            "w": ParamSpec(
                (self.heads, self.per_head, self.d_out),
                (self.head_axis, None, self.out_axis),
                lecun_init((-3, -2)),
            )
        }

    def __call__(self, p, x):
        return jnp.einsum("...hp,hpd->...d", x, p["w"].astype(x.dtype))
