"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

``ssd_chunked`` is the matmul-rich chunked SSD algorithm (MXU-friendly);
it doubles as the oracle for the Pallas kernel in ``repro.kernels.ssd``.
``Mamba2Block`` is the full block: in_proj -> causal depthwise conv ->
SSD -> gated RMSNorm -> out_proj, with a single-token ``decode`` path that
carries (conv buffer, ssm state).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .module import Module, ParamSpec, lecun_init, normal_init, ones_init, zeros_init
from .norm import RMSNorm


def segsum(x):
    """Stable 'segment sum': out[..., i, j] = sum_{j < k <= i} x[..., k].

    Returns lower-triangular log-decay matrix; upper triangle = -inf.
    x: (..., L) -> (..., L, L)
    """
    L = x.shape[-1]
    x = jnp.broadcast_to(x[..., None], (*x.shape, L))  # [..., i, j] = x[..., i]
    mask = jnp.tril(jnp.ones((L, L), bool), -1)
    x = jnp.where(mask, x, 0.0)
    x_segsum = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, x_segsum, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int = 128, return_state: bool = False):
    """Chunked SSD scan.

    x:  (b, s, h, p)   inputs per head
    dt: (b, s, h)      positive step sizes (softplus already applied)
    A:  (h,)           negative per-head decay
    B:  (b, s, g, n)   input projections (g groups broadcast over h)
    C:  (b, s, g, n)   output projections
    Returns y: (b, s, h, p) (and the final state (b,h,p,n) if return_state).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:  # pad with dt=0 steps (identity updates: no decay, no input)
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b, nc, l, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A  # (b, nc, l, h) negative
    dA = jnp.moveaxis(dA, -1, 2)  # (b, nc, h, l)
    dA_cum = jnp.cumsum(dA, axis=-1)  # (b, nc, h, l)

    # ---- intra-chunk (quadratic within chunk, dense matmuls) ----
    L = jnp.exp(segsum(dA))  # (b, nc, h, l, l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)  # (b,nc,h,l,s)
    y_intra = jnp.einsum("bchls,bcshp,bcsh->bclhp", scores * L, xc, dtc)

    # ---- chunk states ----
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (b,nc,h,l)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bh, decay_states * jnp.moveaxis(dtc, -1, 2), xc)

    # ---- inter-chunk recurrence over nc (associative scan-able; lax.scan here) ----
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (b, nc, h)

    def step(hprev, inputs):
        st, dec = inputs  # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    h_final, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (b, nc, h, p, n) state entering each chunk

    # ---- inter-chunk output ----
    decay_in = jnp.exp(dA_cum)  # (b,nc,h,l) decay from chunk start to position l
    y_inter = jnp.einsum("bclhn,bchpn,bchl->bclhp", Ch, h_prevs, decay_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    if return_state:
        return y, h_final
    return y


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD update.

    state: (b, h, p, n); x_t: (b, h, p); dt_t: (b, h); B_t/C_t: (b, g, n)
    Returns (y_t, new_state).
    """
    h, g = x_t.shape[1], B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1)  # (b, h, n)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(dt_t * A)  # (b, h)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt_t, Bh, x_t)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


@dataclasses.dataclass(frozen=True)
class Mamba2Block(Module):
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.n_groups * self.d_state

    def specs(self):
        d, di = self.d_model, self.d_inner
        H, gn = self.n_heads, self.n_groups * self.d_state
        # separate projections (equivalent to the fused in_proj up to a
        # column permutation) so each weight is cleanly TP-shardable —
        # the fused width 2*di+2*g*n+H is generally not lane-divisible.
        return {
            "wz": ParamSpec((d, di), ("embed", "mlp"), lecun_init((-2,))),
            "wx": ParamSpec((d, di), ("embed", "mlp"), lecun_init((-2,))),
            "wB": ParamSpec((d, gn), ("embed", None), lecun_init((-2,))),
            "wC": ParamSpec((d, gn), ("embed", None), lecun_init((-2,))),
            "wdt": ParamSpec((d, H), ("embed", None), lecun_init((-2,))),
            "conv_w": ParamSpec((self.d_conv, self.conv_dim), (None, None), normal_init(0.1)),
            "conv_b": ParamSpec((self.conv_dim,), (None,), zeros_init()),
            "A_log": ParamSpec((H,), (None,), _a_log_init(H)),
            "D": ParamSpec((H,), (None,), ones_init()),
            "dt_bias": ParamSpec((H,), (None,), _dt_bias_init(H, self.dt_min, self.dt_max)),
            "norm": RMSNorm(di),
            "out_proj": ParamSpec((di, d), ("mlp", "embed"), lecun_init((-2,))),
        }

    def _project(self, p, x):
        """x (..., d) -> (z (..., di), xbc (..., conv_dim), dt (..., H))."""
        w = lambda name: p[name].astype(x.dtype)
        z = x @ w("wz")
        xbc = jnp.concatenate([x @ w("wx"), x @ w("wB"), x @ w("wC")], axis=-1)
        dt = x @ w("wdt")
        return z, xbc, dt

    def _conv(self, p, xbc):
        """Causal depthwise conv over (B, S, conv_dim)."""
        w = p["conv_w"].astype(xbc.dtype)  # (k, conv_dim)
        k = self.d_conv
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
        return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))

    def __call__(self, p, x):
        B_, S, _ = x.shape
        di, g, n, H, P = self.d_inner, self.n_groups, self.d_state, self.n_heads, self.head_dim
        z, xbc, dt = self._project(p, x)
        xbc = self._conv(p, xbc)
        xs = xbc[..., :di].reshape(B_, S, H, P)
        Bmat = xbc[..., di : di + g * n].reshape(B_, S, g, n)
        Cmat = xbc[..., di + g * n :].reshape(B_, S, g, n)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)  # (B,S,H)
        A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)  # (H,)
        y = ssd_chunked(xs, dt, A, Bmat, Cmat, chunk=min(self.chunk, S))
        y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(B_, S, di)
        y = RMSNorm(di)(p["norm"], y) * jax.nn.silu(z)
        return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))

    def prefill(self, p, x, cache_dtype=jnp.bfloat16):
        """Forward over the prompt, returning output + (conv, ssm) state."""
        B_, S, _ = x.shape
        di, g, n, H, P = self.d_inner, self.n_groups, self.d_state, self.n_heads, self.head_dim
        z, xbc_raw, dt = self._project(p, x)
        xbc = self._conv(p, xbc_raw)
        xs = xbc[..., :di].reshape(B_, S, H, P)
        Bmat = xbc[..., di : di + g * n].reshape(B_, S, g, n)
        Cmat = xbc[..., di + g * n :].reshape(B_, S, g, n)
        dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
        A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
        y, final_state = ssd_chunked(xs, dt_, A, Bmat, Cmat, chunk=min(self.chunk, S), return_state=True)
        y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(B_, S, di)
        y = RMSNorm(di)(p["norm"], y) * jax.nn.silu(z)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
        conv_tail = xbc_raw[:, -(self.d_conv - 1) :, :]
        return out, {"conv": conv_tail.astype(cache_dtype), "ssm": final_state.astype(jnp.float32)}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, dtype=jnp.bfloat16):
        return {
            "conv": jnp.zeros((batch, self.d_conv - 1, self.conv_dim), dtype),
            "ssm": jnp.zeros((batch, self.n_heads, self.head_dim, self.d_state), jnp.float32),
        }

    def abstract_cache(self, batch: int, dtype=jnp.bfloat16):
        sds = jax.ShapeDtypeStruct
        return {
            "conv": sds((batch, self.d_conv - 1, self.conv_dim), dtype),
            "ssm": sds((batch, self.n_heads, self.head_dim, self.d_state), jnp.float32),
        }

    def decode(self, p, x, cache):
        """x: (B, 1, d) -> (y (B,1,d), cache)."""
        B_ = x.shape[0]
        di, g, n, H, P = self.d_inner, self.n_groups, self.d_state, self.n_heads, self.head_dim
        z, xbc, dt = self._project(p, x)  # (B,1,...)
        # conv ring buffer
        window = jnp.concatenate([cache["conv"].astype(x.dtype), xbc], axis=1)  # (B, k, conv_dim)
        w = p["conv_w"].astype(x.dtype)
        conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
        xbc_t = jax.nn.silu(conv_out)  # (B, conv_dim)
        new_conv = window[:, 1:, :]
        xs = xbc_t[:, :di].reshape(B_, H, P)
        Bmat = xbc_t[:, di : di + g * n].reshape(B_, g, n)
        Cmat = xbc_t[:, di + g * n :].reshape(B_, g, n)
        dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, new_ssm = ssd_decode_step(
            cache["ssm"], xs.astype(jnp.float32), dt_t, A, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)
        )
        y = y.astype(x.dtype) + xs * p["D"].astype(x.dtype)[None, :, None]
        y = y.reshape(B_, 1, di)
        y = RMSNorm(di)(p["norm"], y) * jax.nn.silu(z)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
        return out, {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}


def _a_log_init(H):
    def f(key, shape, dtype):
        return jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dtype)

    return f


def _dt_bias_init(H, dt_min, dt_max):
    def f(key, shape, dtype):
        u = jax.random.uniform(key, (H,), jnp.float32)
        dt = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
        # inverse softplus
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(dtype)

    return f
