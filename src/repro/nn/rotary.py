"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, base: float = 10000.0):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base**exponent)  # (head_dim/2,)


def apply_rope(x, positions, base: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, base)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (B, S, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # (B, S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, sections: tuple[int, ...], base: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    x: (B, S, H, hd); positions: (B, S, 3) — (temporal, height, width) ids.
    ``sections`` gives the per-component frequency split (sums to hd/2).
    Text-only tokens carry identical t/h/w ids, reducing to standard RoPE.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, base)  # (hd/2,)
    # choose which positional stream feeds each frequency band
    comp = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (hd/2,) values in {0,1,2}
    idx = jnp.broadcast_to(comp[None, None, :], (*positions.shape[:2], comp.shape[0]))
    pos = jnp.take_along_axis(positions.astype(jnp.float32), idx, axis=-1)  # (B, S, hd/2)
    angles = pos * inv
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
