"""Normalization layers (computed in fp32, cast back)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .module import Module, ParamSpec, ones_init, zeros_init


@dataclasses.dataclass(frozen=True)
class RMSNorm(Module):
    d: int
    eps: float = 1e-6
    # gemma convention: weight stored as (1 + scale) with zero-init scale
    zero_centered: bool = False
    axis_name: str | None = None

    def specs(self):
        init = zeros_init() if self.zero_centered else ones_init()
        return {"scale": ParamSpec((self.d,), (self.axis_name,), init)}

    def __call__(self, p, x):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jnp.reciprocal(jnp.sqrt(var + self.eps))
        scale = p["scale"].astype(jnp.float32)
        if self.zero_centered:
            scale = 1.0 + scale
        return (y * scale).astype(dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm(Module):
    d: int
    eps: float = 1e-5
    use_bias: bool = True
    axis_name: str | None = None

    def specs(self):
        s = {"scale": ParamSpec((self.d,), (self.axis_name,), ones_init())}
        if self.use_bias:
            s["bias"] = ParamSpec((self.d,), (self.axis_name,), zeros_init())
        return s

    def __call__(self, p, x):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jnp.reciprocal(jnp.sqrt(var + self.eps))
        y = y * p["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + p["bias"].astype(jnp.float32)
        return y.astype(dtype)
