"""Normalization layers (computed in fp32, cast back)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .module import Module, ParamSpec, ones_init, zeros_init


@dataclasses.dataclass(frozen=True)
class RMSNorm(Module):
    d: int
    eps: float = 1e-6
    # gemma convention: weight stored as (1 + scale) with zero-init scale
    zero_centered: bool = False
    axis_name: str | None = None

    def specs(self):
        init = zeros_init() if self.zero_centered else ones_init()
        return {"scale": ParamSpec((self.d,), (self.axis_name,), init)}

    def __call__(self, p, x):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jnp.reciprocal(jnp.sqrt(var + self.eps))
        scale = p["scale"].astype(jnp.float32)
        if self.zero_centered:
            scale = 1.0 + scale
        return (y * scale).astype(dtype)


@dataclasses.dataclass(frozen=True)
class InstanceNorm2D(Module):
    """Per-sample, per-channel statistics over (H, W) on NHWC tensors.

    Batch-independent drop-in for ``BatchNorm2D``'s batch-stats inference
    behaviour (identical math at batch size 1): a model built with it can
    be micro-batched with ``merge_batches`` without changing any frame's
    outputs."""

    c: int
    eps: float = 1e-5

    def specs(self):
        return {
            "scale": ParamSpec((self.c,), ("conv_out",), ones_init()),
            "bias": ParamSpec((self.c,), ("conv_out",), zeros_init()),
        }

    def __call__(self, p, x):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=(1, 2), keepdims=True)
        var = jnp.var(x32, axis=(1, 2), keepdims=True)
        y = (x32 - mean) * jnp.reciprocal(jnp.sqrt(var + self.eps))
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class GroupNorm2D(Module):
    """Per-sample statistics over (H, W, C/groups) on NHWC tensors.

    ``groups=1`` is layer-norm-over-space, ``groups=c`` is instance norm;
    batch-independent for any group count."""

    c: int
    groups: int = 8
    eps: float = 1e-5

    def __post_init__(self):
        if self.c % self.groups:
            raise ValueError(f"channels {self.c} not divisible by groups {self.groups}")

    def specs(self):
        return {
            "scale": ParamSpec((self.c,), ("conv_out",), ones_init()),
            "bias": ParamSpec((self.c,), ("conv_out",), zeros_init()),
        }

    def __call__(self, p, x):
        dtype = x.dtype
        b, h, w, _ = x.shape
        x32 = x.astype(jnp.float32).reshape(b, h, w, self.groups, self.c // self.groups)
        mean = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
        var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
        y = (x32 - mean) * jnp.reciprocal(jnp.sqrt(var + self.eps))
        y = y.reshape(b, h, w, self.c)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm(Module):
    d: int
    eps: float = 1e-5
    use_bias: bool = True
    axis_name: str | None = None

    def specs(self):
        s = {"scale": ParamSpec((self.d,), (self.axis_name,), ones_init())}
        if self.use_bias:
            s["bias"] = ParamSpec((self.d,), (self.axis_name,), zeros_init())
        return s

    def __call__(self, p, x):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jnp.reciprocal(jnp.sqrt(var + self.eps))
        y = y * p["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + p["bias"].astype(jnp.float32)
        return y.astype(dtype)
