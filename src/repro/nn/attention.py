"""Attention: MHA/GQA/MQA with causal + sliding-window masks, logit softcap,
RoPE/M-RoPE, KV caches (full + ring-buffer), and DeepSeek-V2 MLA.

Full-sequence path is used by train/prefill; ``decode`` consumes a KV cache.
``window`` may be a python int or a traced scalar so that local/global
alternating stacks (gemma2, hymba) scan over one homogeneous layer body.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .linear import MultiLinear, OutputLinear
from .module import Module
from .rotary import apply_mrope, apply_rope

NEG_INF = -2.3819763e38  # large negative for masking (fits bf16 after cast via fp32)


def _mask_bias(mask):
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def causal_window_mask(q_pos, k_pos, window=None):
    """Boolean mask (..., Sq, Sk): causal and optionally within a left window.

    q_pos/k_pos: int arrays broadcastable to (..., Sq) / (..., Sk).
    window: None, python int, or traced int scalar (jnp int). window == 0 or
    None means unbounded (global attention).
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    m = k <= q
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        local = (q - k) < w
        m = m & jnp.where(w > 0, local, True)
    return m


def _sdpa(q, k, v, mask, scale, softcap=None):
    """q: (B,Sq,Hk,G,D) k: (B,Sk,Hk,D) v: (B,Sk,Hk,Dv) mask: (B|1,1,Sq,Sk)."""
    assert mask.ndim == 4, mask.shape
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + _mask_bias(mask)[:, :, None, :, :]  # -> (B,1,1,Sq,Sk)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out


def effective_chunk(chunk: int, Sq: int, Sk: int, budget: int = 1 << 22) -> int:
    """Adapt the query-chunk so the transient (chunk, Sk) score block stays
    within ~``budget`` elements per head (long-context prefill would
    otherwise hold chunk*Sk = 1024*32768 fp32 scores per head)."""
    ck = min(chunk, max(128, budget // max(Sk, 1)))
    while Sq % ck:
        ck //= 2
    return max(ck, 1)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, scale, softcap, chunk):
    """Query-chunked SDPA: loops query blocks with lax.map; each block body
    is rematerialized so neither forward nor backward holds (Sq, Sk)."""
    B, Sq = q.shape[:2]
    nc = Sq // chunk

    def body(i):
        start = i * chunk
        qs = jax.lax.dynamic_slice_in_dim(q, start, chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, start, chunk, axis=-1)
        mask = causal_window_mask(qp, k_pos, window)[:, None]
        return _sdpa(qs, k, v, mask, scale, softcap)

    outs = jax.lax.map(jax.checkpoint(body), jnp.arange(nc, dtype=jnp.int32))
    # (nc, B, chunk, Hk, G, D) -> (B, Sq, Hk, G, D)
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, *q.shape[2:])
    return outs


@dataclasses.dataclass(frozen=True)
class Attention(Module):
    """GQA attention block (q/k/v/o projections + rotary)."""

    d_model: int
    n_q: int
    n_kv: int
    head_dim: int
    rope_base: float = 10000.0
    softcap: float | None = None
    query_scale: float | None = None  # default 1/sqrt(head_dim)
    mrope_sections: tuple[int, ...] | None = None
    use_rope: bool = True
    # query-chunked attention: bounds the transient (B,H,chunk,S) score
    # tensor instead of materializing (B,H,S,S); chunks are individually
    # rematerialized so train memory is O(S) per layer.
    attn_chunk: int = 0

    @property
    def _scale(self):
        return self.query_scale if self.query_scale is not None else 1.0 / math.sqrt(self.head_dim)

    def specs(self):
        return {
            "wq": MultiLinear(self.d_model, self.n_q, self.head_dim),
            "wk": MultiLinear(self.d_model, self.n_kv, self.head_dim),
            "wv": MultiLinear(self.d_model, self.n_kv, self.head_dim),
            "wo": OutputLinear(self.n_q, self.head_dim, self.d_model),
        }

    # -- helpers -------------------------------------------------------------
    def _qkv(self, p, x, positions, kv_x=None):
        kv_x = x if kv_x is None else kv_x
        q = MultiLinear(self.d_model, self.n_q, self.head_dim)(p["wq"], x)
        k = MultiLinear(self.d_model, self.n_kv, self.head_dim)(p["wk"], kv_x)
        v = MultiLinear(self.d_model, self.n_kv, self.head_dim)(p["wv"], kv_x)
        if self.use_rope and positions is not None:
            if self.mrope_sections is not None:
                q = apply_mrope(q, positions, self.mrope_sections, self.rope_base)
                k = apply_mrope(k, positions, self.mrope_sections, self.rope_base)
            else:
                q = apply_rope(q, positions, self.rope_base)
                k = apply_rope(k, positions, self.rope_base)
        return q, k, v

    def _group(self, q):
        b, s, _, d = q.shape
        return q.reshape(b, s, self.n_kv, self.n_q // self.n_kv, d)

    # -- full-sequence (train / prefill) ---------------------------------------
    def prefill(self, p, x, positions=None, window=None, cache_dtype=jnp.bfloat16):
        """Full forward that also returns the KV cache for subsequent decode."""
        B, S = x.shape[:2]
        q, k, v = self._qkv(p, x, positions)
        q_pos = positions if positions is not None else jnp.arange(S)[None, :]
        if positions is not None and positions.ndim == 3:
            q_pos = positions[..., 0]
        qg = self._group(q)
        ck = effective_chunk(self.attn_chunk, S, S) if self.attn_chunk else 0
        if ck and S > ck and S % ck == 0:
            out = _sdpa_chunked(qg, k, v, q_pos, q_pos, window, self._scale, self.softcap, ck)
        else:
            mask = causal_window_mask(q_pos, q_pos, window)[:, None]
            out = _sdpa(qg, k, v, mask, self._scale, self.softcap)
        out = out.reshape(B, S, self.n_q, self.head_dim)
        y = OutputLinear(self.n_q, self.head_dim, self.d_model)(p["wo"], out)
        cache = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
        return y, cache

    def __call__(self, p, x, positions=None, window=None, causal=True, kv_x=None, kv_positions=None):
        B, S = x.shape[:2]
        q, k, v = self._qkv(p, x, positions, kv_x=kv_x)
        if kv_x is None:
            q_pos = positions if positions is not None else jnp.arange(S)[None, :]
            k_pos = q_pos
        else:
            q_pos = positions if positions is not None else jnp.arange(S)[None, :]
            k_pos = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])[None, :]
        if positions is not None and positions.ndim == 3:  # mrope: use temporal ids for mask
            q_pos = positions[..., 0]
            k_pos = q_pos if kv_x is None else k_pos
        qg = self._group(q)
        ck = effective_chunk(self.attn_chunk, S, k.shape[1]) if self.attn_chunk else 0
        if causal and ck and S > ck and S % ck == 0:
            out = _sdpa_chunked(qg, k, v, q_pos, k_pos, window, self._scale, self.softcap, ck)
        else:
            if causal:
                mask = causal_window_mask(q_pos, k_pos, window)[:, None]  # (B,1,Sq,Sk)
            else:
                mask = jnp.ones((1, 1, S, k.shape[1]), bool)
            out = _sdpa(qg, k, v, mask, self._scale, self.softcap)
        out = out.reshape(B, S, self.n_q, self.head_dim)
        return OutputLinear(self.n_q, self.head_dim, self.d_model)(p["wo"], out)

    def project_kv(self, p, kv_x):
        """Compute (k, v) only — used to precompute cross-attention caches."""
        k = MultiLinear(self.d_model, self.n_kv, self.head_dim)(p["wk"], kv_x)
        v = MultiLinear(self.d_model, self.n_kv, self.head_dim)(p["wv"], kv_x)
        return k, v

    def attend_kv(self, p, x, k, v, mask=None):
        """Attention of queries from ``x`` against precomputed (k, v)."""
        B, S = x.shape[:2]
        q = MultiLinear(self.d_model, self.n_q, self.head_dim)(p["wq"], x)
        if mask is None:
            mask = jnp.ones((1, 1, S, k.shape[1]), bool)
        out = _sdpa(self._group(q), k.astype(q.dtype), v.astype(q.dtype), mask, self._scale, self.softcap)
        out = out.reshape(B, S, self.n_q, self.head_dim)
        return OutputLinear(self.n_q, self.head_dim, self.d_model)(p["wo"], out)

    # -- decode with cache -----------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return {
            "k": jnp.zeros((batch, max_len, self.n_kv, self.head_dim), dtype),
            "v": jnp.zeros((batch, max_len, self.n_kv, self.head_dim), dtype),
        }

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        sds = jax.ShapeDtypeStruct
        return {
            "k": sds((batch, max_len, self.n_kv, self.head_dim), dtype),
            "v": sds((batch, max_len, self.n_kv, self.head_dim), dtype),
        }

    def decode(self, p, x, cache, t, window=None):
        """x: (B,1,d); t: scalar index of the new token. Returns (y, cache)."""
        B = x.shape[0]
        pos = jnp.full((B, 1), t, jnp.int32)
        if self.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
        q, k_new, v_new = self._qkv(p, x, pos)
        S = cache["k"].shape[1]
        if S == 1:  # degenerate: window-1 cache
            k, v = k_new.astype(cache["k"].dtype), v_new.astype(cache["v"].dtype)
            cache = {"k": k, "v": v}
            k_pos = jnp.full((1, 1), t, jnp.int32)
        else:
            slot = jnp.asarray(t, jnp.int32) % S  # full cache: S >= max_len so slot == t
            k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
            cache = {"k": k, "v": v}
            base = jnp.arange(S, dtype=jnp.int32)
            # ring buffer: absolute position of each slot given current t
            # slots <= slot hold positions t - (slot - i); slots > slot hold t - (S - (i - slot))
            k_pos = jnp.where(base <= slot, t - (slot - base), t - (S - (base - slot)))[None, :]
        q_pos = jnp.full((1, 1), t, jnp.int32)
        mask = causal_window_mask(q_pos, k_pos, window) & (k_pos >= 0)[..., None, :]
        mask = mask[:, None]  # (1,1,1,S)
        out = _sdpa(self._group(q), k.astype(q.dtype), v.astype(q.dtype), mask, self._scale, self.softcap)
        out = out.reshape(B, 1, self.n_q, self.head_dim)
        y = OutputLinear(self.n_q, self.head_dim, self.d_model)(p["wo"], out)
        return y, cache


@dataclasses.dataclass(frozen=True)
class MLAAttention(Module):
    """Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

    KV is compressed into a rank-``kv_lora`` latent + a shared RoPE key.
    The cache stores only (c_kv, k_rope): 512+64 floats per token instead of
    2 * n_heads * head_dim. ``absorb`` enables the paper's weight-absorption
    decode optimization (attend in latent space; no per-step k/v expansion).
    """

    d_model: int
    n_q: int
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0
    absorb: bool = False
    attn_chunk: int = 0

    @property
    def _scale(self):
        return 1.0 / math.sqrt(self.qk_nope_dim + self.qk_rope_dim)

    def specs(self):
        qd = self.qk_nope_dim + self.qk_rope_dim
        return {
            "wq": MultiLinear(self.d_model, self.n_q, qd),
            "wdkv": MultiLinear(self.d_model, 1, self.kv_lora, head_axis=None),
            "wkr": MultiLinear(self.d_model, 1, self.qk_rope_dim, head_axis=None),
            "wuk": MultiLinear(self.kv_lora, self.n_q, self.qk_nope_dim, in_axis=None),
            "wuv": MultiLinear(self.kv_lora, self.n_q, self.v_head_dim, in_axis=None),
            "wo": OutputLinear(self.n_q, self.v_head_dim, self.d_model),
        }

    def _latents(self, p, x, positions):
        c_kv = MultiLinear(self.d_model, 1, self.kv_lora, head_axis=None)(p["wdkv"], x)[:, :, 0]
        k_r = MultiLinear(self.d_model, 1, self.qk_rope_dim, head_axis=None)(p["wkr"], x)
        if positions is not None:
            k_r = apply_rope(k_r, positions, self.rope_base)
        return c_kv, k_r[:, :, 0]

    def __call__(self, p, x, positions=None, window=None, causal=True):
        B, S, _ = x.shape
        qd = self.qk_nope_dim + self.qk_rope_dim
        q = MultiLinear(self.d_model, self.n_q, qd)(p["wq"], x)
        q_nope, q_rope = q[..., : self.qk_nope_dim], q[..., self.qk_nope_dim :]
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q_rope = apply_rope(q_rope, positions, self.rope_base)
        c_kv, k_r = self._latents(p, x, positions)
        k_nope = MultiLinear(self.kv_lora, self.n_q, self.qk_nope_dim, in_axis=None)(p["wuk"], c_kv)
        v = MultiLinear(self.kv_lora, self.n_q, self.v_head_dim, in_axis=None)(p["wuv"], c_kv)

        def attend(q_nope_c, q_rope_c, q_pos_c):
            mask = (
                causal_window_mask(q_pos_c, positions, window)[:, None]
                if causal
                else jnp.ones((1, 1, q_pos_c.shape[-1], S), bool)
            )
            scores = (
                jnp.einsum("bqhd,bkhd->bhqk", q_nope_c, k_nope)
                + jnp.einsum("bqhd,bkd->bhqk", q_rope_c, k_r)
            ).astype(jnp.float32) * self._scale
            scores = scores + _mask_bias(mask)
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

        ck = effective_chunk(self.attn_chunk, S, S) if self.attn_chunk else 0
        if ck and S > ck and S % ck == 0:

            def body(i):
                st = i * ck
                return attend(
                    jax.lax.dynamic_slice_in_dim(q_nope, st, ck, 1),
                    jax.lax.dynamic_slice_in_dim(q_rope, st, ck, 1),
                    jax.lax.dynamic_slice_in_dim(positions, st, ck, -1),
                )

            outs = jax.lax.map(jax.checkpoint(body), jnp.arange(S // ck, dtype=jnp.int32))
            out = jnp.moveaxis(outs, 0, 1).reshape(B, S, self.n_q, self.v_head_dim)
        else:
            out = attend(q_nope, q_rope, positions)
        return OutputLinear(self.n_q, self.v_head_dim, self.d_model)(p["wo"], out)

    def prefill(self, p, x, positions=None, window=None, cache_dtype=jnp.bfloat16):
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)[None, :]
        y = self(p, x, positions, window=window)
        c_kv, k_r = self._latents(p, x, positions)
        return y, {"c_kv": c_kv.astype(cache_dtype), "k_r": k_r.astype(cache_dtype)}

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return {
            "c_kv": jnp.zeros((batch, max_len, self.kv_lora), dtype),
            "k_r": jnp.zeros((batch, max_len, self.qk_rope_dim), dtype),
        }

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        sds = jax.ShapeDtypeStruct
        return {
            "c_kv": sds((batch, max_len, self.kv_lora), dtype),
            "k_r": sds((batch, max_len, self.qk_rope_dim), dtype),
        }

    def decode(self, p, x, cache, t, window=None):
        B = x.shape[0]
        pos = jnp.full((B, 1), t, jnp.int32)
        qd = self.qk_nope_dim + self.qk_rope_dim
        q = MultiLinear(self.d_model, self.n_q, qd)(p["wq"], x)
        q_nope, q_rope = q[..., : self.qk_nope_dim], q[..., self.qk_nope_dim :]
        q_rope = apply_rope(q_rope, pos, self.rope_base)
        c_new, kr_new = self._latents(p, x, pos)
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, t, 0)),
            "k_r": jax.lax.dynamic_update_slice(cache["k_r"], kr_new.astype(cache["k_r"].dtype), (0, t, 0)),
        }
        c_kv, k_r = cache["c_kv"].astype(x.dtype), cache["k_r"].astype(x.dtype)
        S = c_kv.shape[1]
        k_pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        mask = causal_window_mask(jnp.full((1, 1), t, jnp.int32), k_pos, window)  # (1,1,S)
        if self.absorb:
            # weight absorption: q_nope' = q_nope @ W_uk  -> attend against c_kv
            wuk = p["wuk"]["w"].astype(x.dtype)  # (kv_lora, H, nope)
            q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wuk)  # (B,1,H,r)
            scores = (
                jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv)
                + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_r)
            ).astype(jnp.float32) * self._scale
            scores = scores + _mask_bias(mask)[:, None]
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out_lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv)  # (B,1,H,r)
            wuv = p["wuv"]["w"].astype(x.dtype)  # (kv_lora, H, v)
            out = jnp.einsum("bqhr,rhd->bqhd", out_lat, wuv)
        else:
            k_nope = MultiLinear(self.kv_lora, self.n_q, self.qk_nope_dim, in_axis=None)(p["wuk"], c_kv)
            v = MultiLinear(self.kv_lora, self.n_q, self.v_head_dim, in_axis=None)(p["wuv"], c_kv)
            scores = (
                jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
                + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_r)
            ).astype(jnp.float32) * self._scale
            scores = scores + _mask_bias(mask)[:, None]
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        y = OutputLinear(self.n_q, self.v_head_dim, self.d_model)(p["wo"], out)
        return y, cache
