"""Gated / plain MLPs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .linear import Linear
from .module import Module

ACTS = {
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
}


@dataclasses.dataclass(frozen=True)
class MLP(Module):
    """Gated (SwiGLU/GeGLU) or plain MLP."""

    d_model: int
    d_ff: int
    act: str = "silu"
    gated: bool = True

    def specs(self):
        s = {
            "up": Linear(self.d_model, self.d_ff, in_axis="embed", out_axis="mlp"),
            "down": Linear(self.d_ff, self.d_model, in_axis="mlp", out_axis="embed"),
        }
        if self.gated:
            s["gate"] = Linear(self.d_model, self.d_ff, in_axis="embed", out_axis="mlp")
        return s

    def __call__(self, p, x):
        up = Linear(self.d_model, self.d_ff)(p["up"], x)
        act = ACTS[self.act]
        if self.gated:
            gate = Linear(self.d_model, self.d_ff)(p["gate"], x)
            h = act(gate) * up
        else:
            h = act(up)
        return Linear(self.d_ff, self.d_model)(p["down"], h)
