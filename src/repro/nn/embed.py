"""Token embedding with optional tied output head."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .module import Module, ParamSpec, normal_init


@dataclasses.dataclass(frozen=True)
class Embedding(Module):
    vocab: int
    d: int
    scale_by_sqrt_d: bool = False  # gemma multiplies embeddings by sqrt(d)

    def specs(self):
        return {"table": ParamSpec((self.vocab, self.d), ("vocab", "embed"), normal_init(0.02))}

    def __call__(self, p, tokens):
        x = jnp.take(p["table"], tokens, axis=0)
        if self.scale_by_sqrt_d:
            x = x * jnp.sqrt(jnp.asarray(self.d, x.dtype))
        return x

    def attend(self, p, x):
        """Tied logits: (..., d) -> (..., vocab)."""
        return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
