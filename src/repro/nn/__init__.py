from .module import (
    Module,
    ParamSpec,
    Stacked,
    param_count,
    cast_tree,
    zeros_init,
    ones_init,
    normal_init,
    lecun_init,
    conv_init,
)
from .linear import Linear, MultiLinear, OutputLinear
from .norm import RMSNorm, LayerNorm, GroupNorm2D, InstanceNorm2D
from .embed import Embedding
from .attention import Attention, MLAAttention, causal_window_mask
from .mlp import MLP
from .moe import MoE
from .ssm import Mamba2Block, ssd_chunked, ssd_decode_step
from .conv import (
    Conv2D,
    ConvTranspose2D,
    Crop2D,
    BatchNorm2D,
    max_pool,
    avg_pool,
    leaky_relu,
)
from .rotary import apply_rope, apply_mrope
