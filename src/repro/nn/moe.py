"""Mixture-of-Experts (DeepSeekMoE-style: shared + fine-grained routed experts).

Dispatch uses sort + static-capacity gather/scatter (NOT one-hot dispatch
einsums): expert GEMM FLOPs stay linear in tokens —
``E * C * d * ff`` with ``C = ceil(T * top_k * capacity_factor / E)`` —
so compiled-HLO FLOPs track MODEL_FLOPS instead of blowing up O(T^2).
Routed weights are stacked (E, ...) with logical axis "expert" for EP.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .linear import Linear
from .mlp import MLP
from .module import Module, ParamSpec, lecun_init, normal_init


@dataclasses.dataclass(frozen=True)
class MoE(Module):
    d_model: int
    d_ff_expert: int  # fine-grained expert width
    n_experts: int
    top_k: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    act: str = "silu"
    router_scale: bool = False  # deepseek-v2 uses routed_scaling_factor
    routed_scaling_factor: float = 1.0

    def specs(self):
        E, d, f = self.n_experts, self.d_model, self.d_ff_expert
        s = {
            "router": ParamSpec((d, E), ("embed", None), normal_init(0.02)),
            "w_gate": ParamSpec((E, d, f), ("expert", "embed", "mlp"), lecun_init((-2,))),
            "w_up": ParamSpec((E, d, f), ("expert", "embed", "mlp"), lecun_init((-2,))),
            "w_down": ParamSpec((E, f, d), ("expert", "mlp", "embed"), lecun_init((-2,))),
        }
        if self.n_shared:
            s["shared"] = MLP(d, f * self.n_shared, act=self.act, gated=True)
        return s

    def capacity(self, n_tokens: int) -> int:
        c = math.ceil(n_tokens * self.top_k * self.capacity_factor / self.n_experts)
        return max(8, int(c))

    def __call__(self, p, x):
        """x: (B, S, d) -> (y, aux_loss)."""
        B, S, d = x.shape
        T = B * S
        E, k = self.n_experts, self.top_k
        xf = x.reshape(T, d)

        logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
        if self.router_scale:
            gates = gates * self.routed_scaling_factor

        # ---- sort-based dispatch with static capacity ----
        C = self.capacity(T)
        flat_e = eidx.reshape(T * k)
        order = jnp.argsort(flat_e, stable=True)  # (T*k,)
        tok = order // k  # source token per sorted slot
        sorted_e = jnp.take(flat_e, order)
        # index of each entry within its expert group
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
        valid = pos < C
        slot = jnp.where(valid, sorted_e * C + pos, E * C)  # overflow -> dropped row

        # token id per (expert, capacity) slot; E*C slot 'T' reads the zero pad row
        slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(jnp.where(valid, tok, T))[: E * C]
        slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
            jnp.where(valid, jnp.take(gates.reshape(T * k), order), 0.0)
        )[: E * C]

        x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        ein = jnp.take(x_pad, slot_tok, axis=0).reshape(E, C, d)

        # ---- expert GEMMs (E, C, d) x (E, d, f) ----
        g = jnp.einsum("ecd,edf->ecf", ein, p["w_gate"].astype(ein.dtype))
        u = jnp.einsum("ecd,edf->ecf", ein, p["w_up"].astype(ein.dtype))
        h = jax.nn.silu(g) * u
        eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(ein.dtype))

        # ---- combine (scatter-add back to tokens) ----
        weighted = eout.reshape(E * C, d) * slot_gate[:, None].astype(eout.dtype)
        y = jax.ops.segment_sum(weighted, slot_tok, num_segments=T + 1)[:T]
        y = y.reshape(B, S, d).astype(x.dtype)

        if self.n_shared:
            y = y + MLP(self.d_model, self.d_ff_expert * self.n_shared, act=self.act)(p["shared"], x)

        # Switch-style load-balance aux loss
        me = jnp.mean(probs, axis=0)  # (E,)
        ce = jnp.mean(
            jax.nn.one_hot(eidx, E, dtype=jnp.float32).sum(axis=1), axis=0
        )  # fraction routed per expert
        aux = jnp.sum(me * ce) * E / k
        return y, aux
