"""Minimal functional module system.

No flax in this environment, so we roll a small, explicit system:

- A ``Module`` is a frozen dataclass of hyper-parameters exposing
  ``specs() -> dict[str, ParamSpec | Module | list]``.
- ``init(key)`` materializes the params pytree (nested dicts of jnp arrays).
- ``axes()`` returns the *same-structure* pytree of logical sharding axis
  tuples (one logical name or None per array dim). ``dist.sharding`` maps
  logical names onto mesh axes.
- ``abstract(dtype)`` returns the ShapeDtypeStruct pytree — used by the
  dry-run so full-size params are never allocated.
- ``Stacked(module, n)`` stacks ``n`` copies with a leading layer axis for
  ``jax.lax.scan`` over layers (keeps HLO size O(1) in depth).

Modules are pure: ``__call__(params, *args)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def zeros_init() -> InitFn:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> InitFn:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02) -> InitFn:
    def f(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return f


def lecun_init(fan_in_dims: tuple[int, ...] = (-2,)) -> InitFn:
    """Variance-scaling (fan_in) init. ``fan_in_dims`` index shape dims."""

    def f(key, shape, dtype):
        fan_in = 1
        for d in fan_in_dims:
            fan_in *= shape[d]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return f


def conv_init() -> InitFn:
    """Fan-in over (kh, kw, cin) for HWIO conv kernels."""

    def f(key, shape, dtype):
        fan_in = 1
        for d in shape[:-1]:
            fan_in *= d
        std = math.sqrt(2.0 / max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return f


# ---------------------------------------------------------------------------
# Param spec + module base
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: InitFn = dataclasses.field(default_factory=lambda: lecun_init())
    dtype: Any = None  # None -> use the dtype passed to Module.init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


class Module:
    """Base class; subclasses are dataclasses implementing specs()/__call__."""

    def specs(self) -> dict[str, Any]:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- param tree construction ------------------------------------------------
    def init(self, key: jax.Array, dtype: Any = jnp.float32) -> dict[str, Any]:
        return _init_tree(self.specs(), key, dtype)

    def axes(self) -> dict[str, Any]:
        return _axes_tree(self.specs())

    def abstract(self, dtype: Any = jnp.float32) -> dict[str, Any]:
        return _abstract_tree(self.specs(), dtype)

    def __call__(self, params, *args, **kwargs):  # pragma: no cover - abstract
        raise NotImplementedError


def _split_key(key, n):
    return list(jax.random.split(key, n)) if n > 0 else []


def _init_tree(spec: Any, key: jax.Array, dtype: Any) -> Any:
    if isinstance(spec, ParamSpec):
        return spec.init(key, spec.shape, spec.dtype or dtype)
    if isinstance(spec, Module):
        return spec.init(key, dtype)
    if isinstance(spec, dict):
        keys = _split_key(key, len(spec))
        return {k: _init_tree(v, sk, dtype) for (k, v), sk in zip(sorted(spec.items()), keys)}
    if isinstance(spec, (list, tuple)):
        keys = _split_key(key, len(spec))
        return [_init_tree(v, sk, dtype) for v, sk in zip(spec, keys)]
    raise TypeError(f"bad spec: {type(spec)}")


def _axes_tree(spec: Any) -> Any:
    if isinstance(spec, ParamSpec):
        return spec.axes
    if isinstance(spec, Module):
        return spec.axes()
    if isinstance(spec, dict):
        return {k: _axes_tree(v) for k, v in spec.items()}
    if isinstance(spec, (list, tuple)):
        return [_axes_tree(v) for v in spec]
    raise TypeError(f"bad spec: {type(spec)}")


def _abstract_tree(spec: Any, dtype: Any) -> Any:
    if isinstance(spec, ParamSpec):
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype or dtype)
    if isinstance(spec, Module):
        return spec.abstract(dtype)
    if isinstance(spec, dict):
        return {k: _abstract_tree(v, dtype) for k, v in spec.items()}
    if isinstance(spec, (list, tuple)):
        return [_abstract_tree(v, dtype) for v in spec]
    raise TypeError(f"bad spec: {type(spec)}")


# ---------------------------------------------------------------------------
# Stacked (scan-over-layers) wrapper
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stacked(Module):
    """Stack ``n`` copies of ``inner`` along a leading 'layers' axis.

    Params come out with shape (n, *inner_shape) so the model can
    ``jax.lax.scan`` over the leading axis. Logical axis for the stacking
    dim is "layers" (mapped to no mesh axis by default).
    """

    inner: Module
    n: int

    def specs(self):
        return {"stack": self}  # sentinel; init/axes/abstract overridden

    def init(self, key, dtype=jnp.float32):
        keys = jax.random.split(key, self.n)
        return jax.vmap(lambda k: self.inner.init(k, dtype))(keys)

    def axes(self):
        inner_axes = self.inner.axes()
        return jax.tree.map(
            lambda a: ("layers", *a),
            inner_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )

    def abstract(self, dtype=jnp.float32):
        inner = self.inner.abstract(dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n, *s.shape), s.dtype), inner
        )

    def __call__(self, params, *args, **kwargs):
        raise TypeError("Stacked params are consumed via jax.lax.scan in the parent model")


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
