"""The paper's detector: YOLOv8 (n-scale) for stroke detection on CT."""
from repro.models import YOLOv8Config

FAMILY = "yolo"

CONFIG = YOLOv8Config(name="yolov8n-stroke", img_size=256, n_classes=2)

SMOKE = YOLOv8Config(name="yolov8-smoke", img_size=64, n_classes=2)
