"""Qwen2-VL-7B [arXiv:2409.12191; hf Qwen/Qwen2-VL-7B-Instruct].

Backbone only per the assignment: 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, head_dim=128, M-RoPE sections (16,24,24).
The vision frontend is a STUB: input_specs provides precomputed patch
embeddings scattered into the token sequence + (t,h,w) position ids.
Pure full attention -> long_500k skipped.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="qwen2-vl-7b",
    n_layers=28,
    d_model=3584,
    n_q=28,
    n_kv=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    act="silu",
    rope_base=1000000.0,
    mrope_sections=(16, 24, 24),
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="qwen2-vl-smoke",
    n_layers=3,
    d_model=64,
    n_q=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    mrope_sections=(2, 3, 3),
    tie_embeddings=False,
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "pure full-attention arch (quadratic); per assignment skip"}

TRAIN_MICRO = 16
