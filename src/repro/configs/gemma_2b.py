"""Gemma 2B [arXiv:2403.08295; hf google/gemma-2b].

18L d_model=2048 8H MQA (kv=1) d_ff=16384 vocab=256000, head_dim=256,
GeGLU, sqrt(d) embed scaling, (1+scale) RMSNorm. Pure full attention ->
long_500k skipped.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_q=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="gemma-2b-smoke",
    n_layers=3,
    d_model=64,
    n_q=4,
    n_kv=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "pure full-attention arch (quadratic); per assignment skip"}
