"""Hymba-1.5B [arXiv:2411.13676; hf nvidia/Hymba-1.5B-Base].

32L d_model=1600, 25 attention heads (GQA kv=5, head_dim=64) fused in
parallel with Mamba heads (ssm_state=16), d_ff=5504, vocab=32001.
Sliding-window attention everywhere except 3 global layers (first /
middle / last). Hybrid sub-quadratic -> long_500k runs.
"""
from repro.models import HymbaConfig

FAMILY = "hymba"

CONFIG = HymbaConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_q=25,
    n_kv=5,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    head_dim=64,
    ssm_head_dim=64,
    local_window=1024,
    global_layers=(0, 15, 31),
    expand=2,
    chunk=256,
)

SMOKE = HymbaConfig(
    name="hymba-smoke",
    n_layers=3,
    d_model=64,
    n_q=4,
    n_kv=2,
    d_ff=128,
    vocab=512,
    ssm_state=16,
    head_dim=16,
    ssm_head_dim=16,
    local_window=8,
    global_layers=(0, 2),
    chunk=8,
)
