"""Gemma-2 27B [arXiv:2408.00118; hf google/gemma-2-27b].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128,
query scale 1/sqrt(d_model/n_q)=1/12 (the 27B's query_pre_attn_scalar),
alternating local(4096)/global, softcaps 50/30. long_500k runs.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    n_q=32,
    n_kv=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    layer_pattern="local_global",
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=1.0 / 12.0,
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
    post_norms=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="gemma2-27b-smoke",
    n_layers=4,
    d_model=96,
    n_q=8,
    n_kv=4,
    head_dim=16,
    d_ff=256,
    vocab=512,
    layer_pattern="local_global",
    local_window=8,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=1.0 / 12.0,
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
    post_norms=True,
)

TRAIN_MICRO = 16
