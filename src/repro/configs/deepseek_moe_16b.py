"""DeepSeekMoE-16B [arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base].

28L d_model=2048 16H (GQA kv=16 = MHA) vocab=102400; fine-grained MoE:
64 routed experts top-6 + 2 shared, expert d_ff=1408; first layer dense
(width 8x expert = shared+routed active capacity). Full (quadratic)
attention -> long_500k skipped per assignment rules.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_q=16,
    n_kv=16,
    head_dim=128,
    d_ff=8 * 1408,  # dense first layer (~ the 10944 of the HF config)
    vocab=102400,
    moe=True,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared=2,
    first_k_dense=1,
    act="silu",
    rope_base=10000.0,
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="deepseek-moe-smoke",
    n_layers=3,
    d_model=64,
    n_q=4,
    n_kv=4,
    head_dim=16,
    d_ff=8 * 32,
    vocab=512,
    moe=True,
    n_experts=8,
    top_k=2,
    d_ff_expert=32,
    n_shared=2,
    first_k_dense=1,
    tie_embeddings=False,
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "pure full-attention arch (quadratic); per assignment skip"}
