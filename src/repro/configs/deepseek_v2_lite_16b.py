"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2-Lite].

27L d_model=2048 16H vocab=102400; MLA kv_lora_rank=512 (qk_nope=128,
qk_rope=64, v_head=128); fine-grained MoE expert d_ff=1408 top-6 with
2 shared experts; first layer dense. NOTE: the assignment line says
"2 shared+160 routed", but 160 routed experts gives a ~36B model — the
*Lite-16B* config is 64 routed (160 belongs to full DeepSeek-V2); we use
64 to match the 16B parameter count (see DESIGN.md). MLA still has full
quadratic attention -> long_500k skipped.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_q=16,
    n_kv=16,
    head_dim=128,
    d_ff=8 * 1408,
    vocab=102400,
    attn_type="mla",
    kv_lora=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mla_absorb=True,
    moe=True,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared=2,
    first_k_dense=1,
    act="silu",
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=3,
    d_model=64,
    n_q=4,
    n_kv=4,
    head_dim=16,
    d_ff=8 * 32,
    vocab=512,
    attn_type="mla",
    kv_lora=32,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    moe=True,
    n_experts=8,
    top_k=2,
    d_ff_expert=32,
    n_shared=2,
    first_k_dense=1,
    tie_embeddings=False,
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "MLA compresses the KV cache but attention is still quadratic full attention"}
