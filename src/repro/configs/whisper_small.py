"""Whisper-small [arXiv:2212.04356; hf openai/whisper-small].

12L enc + 12L dec, d_model=768, 12H, d_ff=3072, vocab=51865, enc-dec.
Audio conv frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings (B, 1500, 768). decode_32k runs mechanically
with a 32k-token decoder self-KV (beyond Whisper's 448-token design —
positions tile; noted in DESIGN.md). long_500k skipped (full attention,
30 s audio window).
"""
from repro.models import WhisperConfig

FAMILY = "whisper"

CONFIG = WhisperConfig(
    name="whisper-small",
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
    vocab=51865,
    n_frames=1500,
    max_text=448,
)

SMOKE = WhisperConfig(
    name="whisper-smoke",
    n_enc_layers=2,
    n_dec_layers=2,
    d_model=64,
    n_heads=4,
    d_ff=128,
    vocab=512,
    n_frames=32,
    max_text=64,
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "enc-dec with full attention and a 30s audio window; per assignment skip"}
