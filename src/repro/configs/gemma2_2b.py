"""Gemma-2 2B [arXiv:2408.00118; hf google/gemma-2-2b].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; alternating
local (4096-window) / global attention, attn softcap 50, final softcap 30,
head_dim 256, GeGLU, (1+scale) RMSNorm, post-norms, sqrt(d) embed scale.
Half the layers are sliding-window -> long_500k runs (ring-buffer caches).
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_q=8,
    n_kv=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    layer_pattern="local_global",
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=0.0625,  # 1/sqrt(256)
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
    post_norms=True,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="gemma2-2b-smoke",
    n_layers=4,
    d_model=64,
    n_q=4,
    n_kv=2,
    head_dim=32,
    d_ff=128,
    vocab=512,
    layer_pattern="local_global",
    local_window=8,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu_tanh",
    embed_scale=True,
    zero_centered_norm=True,
    post_norms=True,
)
