"""The paper's own model: Pix2Pix CT->MRI (256x256), three variants."""
import dataclasses

from repro.models import Pix2PixConfig

FAMILY = "pix2pix"

CONFIG = Pix2PixConfig(name="pix2pix-mri", img_size=256, deconv_mode="padded")
CONFIG_CROPPING = dataclasses.replace(CONFIG, deconv_mode="cropping")
CONFIG_CONV = dataclasses.replace(CONFIG, deconv_mode="conv")

SMOKE = Pix2PixConfig(name="pix2pix-smoke", img_size=64, base=8, deconv_mode="cropping")
