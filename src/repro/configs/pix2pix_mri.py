"""The paper's own model: Pix2Pix CT->MRI (256x256), three deconv variants
plus a batch-independent serving variant (instance norm instead of batch
stats) that the multi-stream executor may merge-micro-batch."""
import dataclasses

from repro.models import Pix2PixConfig

FAMILY = "pix2pix"

CONFIG = Pix2PixConfig(name="pix2pix-mri", img_size=256, deconv_mode="padded")
CONFIG_CROPPING = dataclasses.replace(CONFIG, deconv_mode="cropping")
CONFIG_CONV = dataclasses.replace(CONFIG, deconv_mode="conv")
# batch-independent: per-frame outputs unaffected by merge_batches grouping
CONFIG_MERGEABLE = dataclasses.replace(CONFIG_CROPPING, name="pix2pix-mri-in", norm="instance")

SMOKE = Pix2PixConfig(name="pix2pix-smoke", img_size=64, base=8, deconv_mode="cropping")
SMOKE_MERGEABLE = dataclasses.replace(SMOKE, name="pix2pix-smoke-in", norm="instance")
