"""Phi-4-mini 3.8B [arXiv:2412.08905; hf microsoft/Phi-4-mini-instruct].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, head_dim=128,
RoPE + SwiGLU + GQA, tied embeddings. Pure full attention -> long_500k
skipped.
"""
from repro.models import LMConfig

FAMILY = "lm"

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_q=24,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    act="silu",
    rope_base=10000.0,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="phi4-mini-smoke",
    n_layers=3,
    d_model=96,
    n_q=6,
    n_kv=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    act="silu",
)

SKIP_SHAPES = ("long_500k",)
SKIP_REASONS = {"long_500k": "pure full-attention arch (quadratic); per assignment skip"}

TRAIN_MICRO = 16
