"""Architecture registry: one module per assigned arch, exact public configs.

Each arch module exposes ``CONFIG`` (full, assignment-exact), ``SMOKE``
(reduced same-family config for CPU tests), and optionally ``SKIP_SHAPES``
(e.g. pure-full-attention archs skip ``long_500k`` — see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

ARCH_IDS = (
    "deepseek_moe_16b",
    "deepseek_v2_lite_16b",
    "gemma2_2b",
    "gemma_2b",
    "gemma2_27b",
    "phi4_mini_3_8b",
    "mamba2_2_7b",
    "whisper_small",
    "hymba_1_5b",
    "qwen2_vl_7b",
)

# assignment shape set (LM transformers): seq_len x global_batch
SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # lm | mamba2 | hymba | whisper | pix2pix | yolo
    config: Any
    smoke: Any
    skip_shapes: tuple[str, ...] = ()
    skip_reasons: dict | None = None
    train_micro: int = 8  # microbatches for the train-shape dry-run/launcher
    train_fsdp: bool = True  # False => TP-only weights (small models: kills FSDP gathers)


_CACHE: dict[str, ArchSpec] = {}


def get_arch(name: str) -> ArchSpec:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _CACHE:
        mod = importlib.import_module(f"repro.configs.{key}")
        _CACHE[key] = ArchSpec(
            name=key,
            family=mod.FAMILY,
            config=mod.CONFIG,
            smoke=mod.SMOKE,
            skip_shapes=tuple(getattr(mod, "SKIP_SHAPES", ())),
            skip_reasons=getattr(mod, "SKIP_REASONS", None),
            train_micro=getattr(mod, "TRAIN_MICRO", 8),
            train_fsdp=getattr(mod, "TRAIN_FSDP", True),
        )
    return _CACHE[key]


def all_archs() -> list[ArchSpec]:
    return [get_arch(a) for a in ARCH_IDS]


def build_model(cfg):
    from ..models import (
        HymbaConfig,
        HymbaLM,
        LMConfig,
        Mamba2Config,
        Mamba2LM,
        Pix2Pix,
        Pix2PixConfig,
        TransformerLM,
        WhisperConfig,
        WhisperModel,
        YOLOv8,
        YOLOv8Config,
    )

    if isinstance(cfg, LMConfig):
        return TransformerLM(cfg)
    if isinstance(cfg, Mamba2Config):
        return Mamba2LM(cfg)
    if isinstance(cfg, HymbaConfig):
        return HymbaLM(cfg)
    if isinstance(cfg, WhisperConfig):
        return WhisperModel(cfg)
    if isinstance(cfg, Pix2PixConfig):
        return Pix2Pix(cfg)
    if isinstance(cfg, YOLOv8Config):
        return YOLOv8(cfg)
    raise TypeError(type(cfg))
