"""Mamba-2 2.7B [arXiv:2405.21060; state-spaces/mamba2-2.7b].

64L d_model=2560 (attention-free), ssm_state=128, head_dim=64, expand=2,
vocab=50280. SSD (state-space duality) blocks. Sub-quadratic: all four
shapes run, including long_500k (decode state is O(1) in context).
"""
from repro.models import Mamba2Config

FAMILY = "mamba2"

CONFIG = Mamba2Config(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    vocab=50280,
    d_state=128,
    d_conv=4,
    expand=2,
    head_dim=64,
    n_groups=1,
    chunk=256,
)

SMOKE = Mamba2Config(
    name="mamba2-smoke",
    n_layers=3,
    d_model=64,
    vocab=512,
    d_state=16,
    head_dim=16,
    chunk=8,
)

# Perf hillclimb (EXPERIMENTS.md §Perf): TP-only weights cut per-layer
# per-microbatch FSDP gathers 8.3x; 2.7B params fit sharded over model=16.
TRAIN_FSDP = False
