"""Serving launchers.

``lm`` (default): batched prefill + greedy decode with the KV-cache paths
the dry-run lowers at scale. ``streams``: the N-model multi-stream
serving subsystem — K frame streams over the planned engine routes.
``--cost`` switches the planner between paper-mode analytic costs and
XLA-measured per-layer costs; ``--dispatch serialized`` restores the
per-segment-synchronized executor for comparison.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --mode streams --streams 4 --frames 6
  PYTHONPATH=src python -m repro.launch.serve --mode streams --cost measured --norm instance
  PYTHONPATH=src python -m repro.launch.serve --mode streams --granularity fine
  PYTHONPATH=src python -m repro.launch.serve --mode streams --cost online --replan \
      --calibration-cache calib.json   # scales persist across restarts
  PYTHONPATH=src python -m repro.launch.serve --mode streams \
      --traffic poisson --rate 30 --deadline-ms 50 --duration 2 --admission
  PYTHONPATH=src python -m repro.launch.serve --mode streams --replicas 2 \
      --traffic poisson --rate 30 --duration 2 --admission   # replicated fleet
  PYTHONPATH=src python -m repro.launch.serve --mode streams --workers 2 \
      --traffic poisson --rate 30 --duration 2   # multi-process fleet (IPC router)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, build_model


def run_streams(args) -> None:
    from ..core.cost_model import OnlineCost, make_cost_provider
    from ..serve import BatchConfig, ReplanConfig, TrafficConfig, build_server

    provider = make_cost_provider(
        args.cost, cache_path=args.cost_cache, calibration_path=args.calibration_cache
    )
    if isinstance(provider, OnlineCost) and provider.snapshot():
        print(f"[serve] warm-started calibration: {provider.describe()}")
    replan_cfg = None
    if args.replan:
        replan_cfg = ReplanConfig(
            drift_threshold=args.replan_threshold,
            hysteresis=args.replan_hysteresis,
            cooldown_ticks=args.replan_cooldown,
            profile_every=args.profile_every,
            stride=args.planner_stride,
            background=args.replan_background,
            escalate_after=args.replan_escalate,
            load_threshold=args.load_threshold,
            slo_miss_threshold=args.slo_miss_threshold,
        )
    open_loop = args.traffic is not None
    bundle = build_server(
        img=args.img,
        base=args.base,
        n_pix=args.streams,
        n_yolo=args.yolo_streams,
        norm=args.norm,
        # worker processes rebuild the provider from its name (the build
        # spec crosses the process boundary as JSON)
        cost=args.cost if args.workers else provider,
        granularity=args.granularity,
        stride=args.planner_stride,
        max_cuts="auto" if args.max_cuts == "auto" else int(args.max_cuts),
        impl=args.impl,
        max_queue=args.queue_depth,
        microbatch=args.microbatch,
        batching=BatchConfig(max_batch=args.max_batch, hold_ms=args.batch_hold_ms)
        if args.max_batch > 1
        else None,
        dispatch=args.dispatch,
        jit_segments=not args.no_jit_segments,
        deadline_ms=args.deadline_ms if open_loop or args.deadline_ms else None,
        traffic=TrafficConfig(
            process=args.traffic, rate_hz=args.rate, seed=args.traffic_seed
        )
        if open_loop
        else None,
        admission=args.admission,
        replan=replan_cfg if replan_cfg is not None else False,
        replicas=args.replicas,
        router_seed=args.router_seed,
        workers=args.workers,
        calibration_path=args.calibration_cache if args.workers else None,
    )
    plan, replanner = bundle.plan, bundle.replanner
    if args.cost_cache and hasattr(provider, "save"):
        provider.save()  # measured AND blended both persist their timings
    print(
        f"[serve] plan cuts={plan.cuts} cycle={plan.expected_cycle*1e3:.2f} ms "
        f"search={plan.search} cost={plan.cost_provider} granularity={args.granularity} "
        f"max_cuts={args.max_cuts} (budget={plan.cut_budget})"
    )
    if args.max_batch > 1:
        print(
            f"[serve] continuous batching: max_batch={args.max_batch} "
            f"hold={args.batch_hold_ms}ms (norm={args.norm}; batch-norm models never coalesce)"
        )
    if args.workers:
        print(
            f"[serve] fleet: {args.workers} worker processes "
            f"(pids {[h.process.pid for h in bundle.server.handles]}), "
            f"router seed {args.router_seed}"
        )
    elif args.replicas > 1:
        print(
            f"[serve] fleet: {args.replicas} replicas over "
            f"{bundle.server.pool.n_devices} device(s), router seed {args.router_seed}"
        )
    if args.impl != "xla":
        print(f"[serve] impl={args.impl} bindings={plan.impl_bindings()}")
    if replanner is not None and (
        args.calibration_cache
        and os.path.exists(args.calibration_cache)
        and not replanner.online.snapshot()
    ):
        # non-online base providers wrap a fresh OnlineCost inside the
        # replanner; warm-start that one too, so --calibration-cache
        # survives restarts for every --cost mode
        try:
            replanner.load_calibration(args.calibration_cache)
            print(f"[serve] warm-started replanner calibration: {replanner.online.describe()}")
        except ValueError as e:
            # scales learned under a different base provider are in
            # different units — re-calibrate live instead
            print(f"[serve] calibration cache not applicable, re-calibrating: {e}")
    server, streams = bundle.server, bundle.streams
    if open_loop:
        # warm the compiled segments with one closed-loop frame per stream
        # so the open-loop phase measures service, not compilation
        for s in streams:
            server.submit(s.model_index, bundle.frame_for(s.name, 0))
        server.drain()
        print(
            f"[serve] open loop: {args.traffic} arrivals at {args.rate} Hz/stream "
            f"for {args.duration}s, deadline={args.deadline_ms}ms, "
            f"admission={'on' if bundle.admission else 'off'}"
        )
        bundle.run_open_loop(args.duration)
    else:
        for t in range(args.frames):
            for s in streams:
                server.submit(s.model_index, jax.random.normal(jax.random.key(t), (1, args.img, args.img, 3)))
            server.pump()
        server.drain()
    if args.workers:
        # the multi-process fleet checkpoints its merged calibration itself
        # (sync_calibration writes --calibration-cache atomically)
        pass
    elif args.calibration_cache and replanner is not None and replanner.online.snapshot():
        # persist the learned per-engine scales so the next process
        # warm-starts its calibration instead of re-learning it
        replanner.online.save_calibration(args.calibration_cache)
        print(f"[serve] saved calibration -> {args.calibration_cache}")
    elif args.calibration_cache and isinstance(provider, OnlineCost) and provider.snapshot():
        provider.save_calibration(args.calibration_cache)
        print(f"[serve] saved calibration -> {args.calibration_cache}")
    print(json.dumps(server.report(), indent=2))
    bundle.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "streams"), default="lm")
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    # streams mode
    ap.add_argument("--streams", type=int, default=4, help="Pix2Pix stream count")
    ap.add_argument("--yolo-streams", type=int, default=1)
    ap.add_argument("--frames", type=int, default=6, help="frames per stream")
    ap.add_argument("--img", type=int, default=64)
    ap.add_argument("--base", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument(
        "--max-batch",
        type=int,
        default=1,
        help="continuous batching: coalesce frames across streams of a batch-independent "
        "model into power-of-two buckets up to this size (1 = off; batch-norm models "
        "never coalesce — use --norm instance)",
    )
    ap.add_argument(
        "--batch-hold-ms",
        type=float,
        default=0.0,
        help="longest a partial batch bucket may hold for co-riders; frames only wait "
        "when every member's SLO slack covers the batched service time plus this window",
    )
    ap.add_argument("--queue-depth", type=int, default=4)
    ap.add_argument(
        "--cost", choices=("analytic", "measured", "blended", "online"), default="analytic"
    )
    ap.add_argument("--cost-cache", default=None, help="JSON cache for measured layer timings")
    ap.add_argument(
        "--granularity",
        choices=("coarse", "fine"),
        default="coarse",
        help="plan at composite-node or expanded (primitive) granularity",
    )
    ap.add_argument(
        "--planner-stride",
        type=int,
        default=1,
        help="keep every k-th legal cut point (fine-granularity beam tractability knob)",
    )
    ap.add_argument(
        "--max-cuts",
        default="1",
        help="per-model cut budget (int), or 'auto' to escalate while the cycle improves",
    )
    ap.add_argument(
        "--impl",
        choices=("auto", "xla", "pallas"),
        default="xla",
        help="implementation planning: xla per-op lowering, pallas fused serving kernels, "
        "or auto (per-segment argmin over both)",
    )
    ap.add_argument(
        "--calibration-cache",
        default=None,
        help="JSON file persisting OnlineCost per-engine scales across restarts",
    )
    ap.add_argument(
        "--replicas",
        type=int,
        default=1,
        help="replicated serving pipelines over the device pool (sticky load-aware router)",
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=0,
        help="multi-process fleet: spawn this many worker processes, each hosting one "
        "replica group behind the IPC router (mutually exclusive with --replicas)",
    )
    ap.add_argument("--router-seed", type=int, default=0, help="fleet router tie-break seed")
    ap.add_argument("--dispatch", choices=("overlapped", "serialized"), default="overlapped")
    ap.add_argument("--norm", choices=("batch", "instance", "group"), default="batch")
    ap.add_argument("--no-jit-segments", action="store_true", help="eager per-op dispatch")
    # online re-planning runtime
    ap.add_argument(
        "--replan", action="store_true", help="watch live segment costs and hot-swap the plan"
    )
    ap.add_argument("--replan-threshold", type=float, default=0.5, help="relative drift to fire on")
    ap.add_argument("--replan-hysteresis", type=int, default=3, help="consecutive drifting ticks")
    ap.add_argument("--replan-cooldown", type=int, default=10, help="min ticks between swaps")
    ap.add_argument("--profile-every", type=int, default=2, help="segment-profiling cadence (ticks)")
    ap.add_argument(
        "--replan-background", action="store_true", help="run the planner in a worker thread"
    )
    ap.add_argument(
        "--replan-escalate",
        type=int,
        default=0,
        help="escalate re-planning to fine granularity after this many drift fires (0 = never)",
    )
    ap.add_argument(
        "--load-threshold",
        type=float,
        default=0.0,
        help="aggregate queue fill fraction that fires a load re-plan (0 = off)",
    )
    ap.add_argument(
        "--slo-miss-threshold",
        type=float,
        default=0.0,
        help="recent deadline-miss rate that fires a load re-plan (0 = off)",
    )
    # open-loop serving + SLOs
    ap.add_argument(
        "--traffic",
        choices=("poisson", "bursty", "diurnal"),
        default=None,
        help="drive the server open-loop with this arrival process (default: closed loop)",
    )
    ap.add_argument("--rate", type=float, default=10.0, help="mean arrival rate per stream (Hz)")
    ap.add_argument("--duration", type=float, default=2.0, help="open-loop horizon (seconds)")
    ap.add_argument("--traffic-seed", type=int, default=0)
    ap.add_argument(
        "--deadline-ms",
        type=float,
        default=None,
        help="per-frame SLO deadline (detection tier 0, reconstruction tier 1); default 100 in open loop",
    )
    ap.add_argument(
        "--admission",
        action="store_true",
        help="enable the graceful-degradation admission ladder (shed resolution -> shed staging -> drop)",
    )
    args = ap.parse_args()
    if args.traffic is not None and args.deadline_ms is None:
        args.deadline_ms = 100.0

    if args.mode == "streams":
        run_streams(args)
        return

    spec = get_arch(args.arch)
    cfg = dataclasses.replace(spec.smoke, act_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.tokens

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    decode = jax.jit(lambda p, tok, caches, t: model.decode_step(p, tok, caches, t))
    caches = model.init_caches(args.batch, max_len, dtype=jnp.float32)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    outs = []
    for t in range(max_len - 1):
        logits, caches = decode(params, tok, caches, t)
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1 : t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] arch={args.arch} generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * gen.shape[1] / dt:.1f} tok/s on CPU)")
    print(gen[:2])


if __name__ == "__main__":
    main()
