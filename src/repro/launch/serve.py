"""Batched serving demo: prefill + greedy decode with the KV-cache paths
the dry-run lowers at scale.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = dataclasses.replace(spec.smoke, act_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_len = args.prompt_len + args.tokens

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    decode = jax.jit(lambda p, tok, caches, t: model.decode_step(p, tok, caches, t))
    caches = model.init_caches(args.batch, max_len, dtype=jnp.float32)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    outs = []
    for t in range(max_len - 1):
        logits, caches = decode(params, tok, caches, t)
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1 : t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] arch={args.arch} generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * gen.shape[1] / dt:.1f} tok/s on CPU)")
    print(gen[:2])


if __name__ == "__main__":
    main()
