"""Production training entrypoint.

Single host (this container):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2_2b --smoke --steps 100

Multi-host (one invocation per host; see launch/distributed.py):
  python -m repro.launch.train --arch gemma2_27b --coordinator $ADDR \
      --num-processes $N --process-id $I --multipod
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from ..configs import get_arch, build_model
from ..data import Prefetcher, token_batches
from ..dist.sharding import train_shardings
from ..train import LoopConfig, run_train_loop
from ..train.optimizer import AdamW, warmup_cosine
from ..train.steps import make_lm_train_step
from .distributed import maybe_initialize_distributed
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()

    maybe_initialize_distributed(args.coordinator, args.num_processes, args.process_id)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    cfg = dataclasses.replace(cfg, act_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    model = build_model(cfg)
    mesh = (
        make_production_mesh(multi_pod=args.multipod)
        if args.production_mesh
        else make_host_mesh(args.model_parallel)
    )

    params = model.init(jax.random.key(0))
    opt = AdamW(lr=warmup_cosine(args.lr, 50, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    step = make_lm_train_step(model, opt, n_micro=args.n_micro)

    # all sharding plumbing in one call: fitted param shardings, optimizer
    # state derived structurally, batch over the data-like axes. Explicit
    # NamedShardings only — no mesh context manager, so this runs on every
    # jax that has jax.make_mesh.
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    sh = train_shardings(mesh, model.axes(), abstract, opt_state, args.batch)
    params = jax.device_put(params, sh.params)
    opt_state = jax.device_put(opt_state, sh.opt_state)
    jstep = jax.jit(
        step,
        in_shardings=(sh.params, sh.opt_state, {"tokens": sh.batch, "labels": sh.batch}),
        donate_argnums=(0, 1),
    )

    data = Prefetcher(
        token_batches(args.batch, args.seq, cfg.vocab, seed=jax.process_index()),
        transform=lambda b: {k: jax.device_put(jnp.asarray(v), sh.batch) for k, v in b.items()},
    )
    out = run_train_loop(
        jstep,
        params,
        opt_state,
        data,
        LoopConfig(args.steps, args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=20),
        shardings={"params": sh.params, "opt_state": sh.opt_state},
    )
    print(f"[train] finished at step {out.step}; stragglers={len(out.straggler_events)}")


if __name__ == "__main__":
    main()
