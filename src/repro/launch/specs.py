"""Dry-run cell builder: (arch x shape x mesh) -> jit-ready function,
abstract inputs (ShapeDtypeStructs — nothing allocated), and shardings.

Conventions:
  train   -> full train_step(params fp32, opt_state, batch) incl. AdamW
  prefill -> prefill(params, tokens) returning (logits, caches)
  decode  -> decode_step(params, token, caches, t) with a max_len=seq KV
             cache; batch=1 cells shard the KV sequence dim (SP).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ArchSpec, build_model
from ..dist.sharding import (
    batch_sharding,
    cache_shardings,
    default_rules,
    tree_shardings_shaped,
)
from ..train.optimizer import AdamW, warmup_cosine
from ..train.steps import make_lm_train_step

N_IMG_PATCHES = 1024  # VLM stub: patches per sequence


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple
    model_flops: float
    n_chips: int
    flops_scale: float = 1.0  # cost_analysis counts scan bodies once


def model_flops_estimate(spec: ArchSpec, shape_name: str) -> float:
    cfg = spec.config
    sh = SHAPES[shape_name]
    n_active = cfg.n_active_params()
    if sh["kind"] == "train":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 6.0 * n_active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh["global_batch"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_cell(
    spec: ArchSpec,
    shape_name: str,
    mesh,
    fsdp: bool | None = None,  # None -> the arch's TRAIN_FSDP default
    n_micro: int | None = None,
    bf16_params: bool = False,  # bf16 params + fp32 master in opt state
) -> Cell:
    cfg = spec.config
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    B, S = sh["global_batch"], sh["seq_len"]
    # FSDP weight sharding only helps when optimizer state exists; for
    # serving it makes GSPMD go weight-stationary and all-gather the full
    # batch (measured 3x18GiB on 27b prefill). Serve cells use pure TP.
    if fsdp is None:
        fsdp = spec.train_fsdp
    rules = default_rules(fsdp=fsdp and kind == "train", mesh_axes=mesh.axis_names)
    if n_micro is None:
        n_micro = spec.train_micro
    if kind == "train" and hasattr(cfg, "act_spec"):
        import dataclasses as _dc

        cfg = _dc.replace(cfg, act_spec=tuple(rules["batch"]))
    # per-microbatch size must stay divisible by the DP extent
    dp = 1
    for ax in rules["batch"]:
        dp *= mesh.shape[ax]
    while n_micro > 1 and (B // n_micro) % dp:
        n_micro //= 2
    model = build_model(cfg)
    n_chips = mesh.size

    train_dtype = jnp.bfloat16 if bf16_params else jnp.float32
    abstract_params = model.abstract(train_dtype if kind == "train" else jnp.bfloat16)
    param_sh = tree_shardings_shaped(mesh, model.axes(), abstract_params, rules)
    rep = NamedSharding(mesh, P())
    # train batches spread over every chip (FSDP-style DP); serving batches
    # over the DP axes only (the model axis carries TP for serving).
    bsh = batch_sharding(mesh, B, rules, key="batch")
    seq_sharded = B == 1

    mf = model_flops_estimate(spec, shape_name)

    if kind == "train":
        opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000), weight_decay=0.01, master_weights=bf16_params)
        step = make_lm_train_step(model, opt, n_micro=n_micro)
        opt_state = opt.abstract_state(abstract_params)
        opt_sh = {"m": param_sh, "v": param_sh, "step": rep}
        if bf16_params:
            opt_sh["master"] = param_sh
        batch, batch_sh = _train_batch(spec, B, S, bsh, rep)
        return Cell(
            arch=spec.name,
            shape=shape_name,
            kind=kind,
            fn=step,
            args=(abstract_params, opt_state, batch),
            in_shardings=(param_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
            model_flops=mf,
            n_chips=n_chips,
            flops_scale=float(n_micro),
        )

    if kind == "prefill":
        if spec.family == "whisper":
            fn = lambda p, frames, tokens: model.prefill(p, frames, tokens)
            args = (
                abstract_params,
                _sds((B, cfg.n_frames, cfg.d_model), jnp.bfloat16),
                _sds((B, S), jnp.int32),
            )
            in_sh = (param_sh, bsh, bsh)
        else:
            fn = lambda p, tokens: model.prefill(p, tokens)
            args = (abstract_params, _sds((B, S), jnp.int32))
            in_sh = (param_sh, bsh)
        return Cell(
            arch=spec.name,
            shape=shape_name,
            kind=kind,
            fn=fn,
            args=args,
            in_shardings=in_sh,
            donate_argnums=(),
            model_flops=mf,
            n_chips=n_chips,
        )

    # decode
    caches = model.init_caches(B, S, dtype=jnp.bfloat16, abstract=True)
    cache_sh = cache_shardings(mesh, caches, rules, seq_sharded=seq_sharded)
    fn = lambda p, token, caches, t: model.decode_step(p, token, caches, t)
    args = (abstract_params, _sds((B, 1), jnp.int32), caches, _sds((), jnp.int32))
    in_sh = (param_sh, bsh, cache_sh, rep)
    return Cell(
        arch=spec.name,
        shape=shape_name,
        kind=kind,
        fn=fn,
        args=args,
        in_shardings=in_sh,
        donate_argnums=(2,),
        model_flops=mf,
        n_chips=n_chips,
    )


def _train_batch(spec: ArchSpec, B, S, bsh, rep):
    cfg = spec.config
    if spec.family == "whisper":
        batch = {
            "frames": _sds((B, cfg.n_frames, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        sh = {"frames": bsh, "tokens": bsh, "labels": bsh}
        return batch, sh
    batch = {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}
    sh = {"tokens": bsh, "labels": bsh}
    if getattr(cfg, "mrope_sections", None):
        batch["positions"] = _sds((B, S, 3), jnp.int32)
        batch["extra_embeds"] = _sds((B, N_IMG_PATCHES, cfg.d_model), jnp.bfloat16)
        batch["embed_positions"] = _sds((B, N_IMG_PATCHES), jnp.int32)
        sh["positions"] = bsh
        sh["extra_embeds"] = bsh
        sh["embed_positions"] = bsh
    return batch, sh
