import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split  — the two lines above MUST run before any jax import.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--single]

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json (+ aggregated
table printed at the end). Compile failures (sharding mismatch, OOM,
unsupported collective) are bugs and reported as such.
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, get_arch
from .mesh import make_production_mesh
from .analytic import analytic_bytes, analytic_flops
from .roofline import analyze
from .specs import build_cell

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    spec = get_arch(arch_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if shape_name in spec.skip_shapes:
        reason = (spec.skip_reasons or {}).get(shape_name, "skipped per assignment")
        return {"arch": spec.name, "shape": shape_name, "mesh": mesh_name, "status": "skip", "reason": reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(spec, shape_name, mesh)
    try:
        with jax.set_mesh(mesh):
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rf = analyze(
            spec.name,
            shape_name,
            mesh_name,
            mesh.size,
            compiled,
            cell.model_flops,
            analytic_flops(spec, shape_name),
            analytic_bytes(spec, shape_name, mesh.size),
        )
        row = rf.row()
        row.update(
            {
                "status": "ok",
                "kind": cell.kind,
                "t_lower_s": round(t_lower, 2),
                "t_compile_s": round(t_compile, 2),
            }
        )
        if verbose:
            mem = row["memory_per_device"]["total"] / 2**30
            print(
                f"[dryrun] {spec.name:>22} {shape_name:<12} {mesh_name:<8} OK "
                f"comp={rf.t_compute*1e3:.2f}ms mem={rf.t_memory*1e3:.2f}ms "
                f"coll={rf.t_collective*1e3:.2f}ms bneck={rf.bottleneck:<10} "
                f"useful={rf.useful_flops_ratio:.2f} mem/dev={mem:.2f}GiB "
                f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                flush=True,
            )
        return row
    except Exception as e:
        if verbose:
            print(f"[dryrun] {spec.name:>22} {shape_name:<12} {mesh_name:<8} FAIL {e}", flush=True)
        return {
            "arch": spec.name,
            "shape": shape_name,
            "mesh": mesh_name,
            "status": "fail",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-4000:],
        }


def save_row(row: dict):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{row['arch']}__{row['shape']}__{row['mesh']}.json")
    with open(path, "w") as f:
        json.dump(row, f, indent=1, default=str)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="2x16x16 mesh (512 chips)")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multipod,)

    rows = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                row = run_cell(a, s, mp)
                save_row(row)
                rows.append(row)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail / {len(rows)} cells")
    if n_fail:
        for r in rows:
            if r["status"] == "fail":
                print(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
