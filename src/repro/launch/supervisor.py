"""Fault-tolerant restart supervisor.

Wraps any launch command; on non-zero exit it restarts with exponential
backoff, relying on the atomic-manifest checkpoints for exactly-resumable
state. At cluster scale one supervisor runs per host; a missing-heartbeat
(straggler watchdog in train.loop) or hardware fault kills the process
and this loop brings it back from the last durable step.

  PYTHONPATH=src python -m repro.launch.supervisor --max-restarts 3 -- \
      python -m repro.launch.train --arch gemma2_2b --smoke --steps 50 \
      --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def supervise(cmd, max_restarts=5, backoff=2.0, log=print):
    attempt = 0
    while True:
        t0 = time.time()
        log(f"[supervisor] attempt {attempt}: {' '.join(cmd)}")
        proc = subprocess.run(cmd)
        if proc.returncode == 0:
            log("[supervisor] clean exit")
            return 0
        attempt += 1
        if attempt > max_restarts:
            log(f"[supervisor] giving up after {max_restarts} restarts")
            return proc.returncode
        delay = min(backoff**attempt, 60.0)
        log(f"[supervisor] exit={proc.returncode} after {time.time()-t0:.0f}s; restart in {delay:.0f}s")
        time.sleep(delay)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        raise SystemExit("usage: supervisor [--max-restarts N] -- <command...>")
    sys.exit(supervise(cmd, args.max_restarts))


if __name__ == "__main__":
    main()
