"""Roofline-term extraction from compiled dry-run artifacts.

Terms per (arch x shape x mesh), all in seconds:

  compute    = per-device HLO flops / (197 TFLOP/s bf16)
  memory     = per-device HLO bytes / (819 GB/s HBM)
  collective = per-device collective bytes / (50 GB/s ICI link)

XLA's ``compiled.cost_analysis()`` is *per partitioned device* (verified
empirically), so no further division by chip count. Collective bytes are
not in cost_analysis: we parse the post-SPMD HLO text and sum the result
shapes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops (per-device shard shapes — i.e. bytes that hit
this chip's links; the single-link divisor is conservative).
"""
from __future__ import annotations

import dataclasses
import re

from ..core.engine import TPU_V5E_BF16_FLOPS, TPU_V5E_HBM_BW, TPU_V5E_ICI_BW

DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _computation_blocks(hlo_text: str):
    """Split HLO text into {computation_name: [lines]}."""
    blocks: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        # computation headers: "%name (args...) -> type {"  (args may nest parens)
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
        if m:
            cur = m.group(1)
            blocks[cur] = []
        elif cur is not None:
            blocks[cur].append(line)
    return blocks


def _loop_multipliers(hlo_text: str) -> dict[str, float]:
    """computation -> product of enclosing while-loop trip counts.

    XLA annotates ``backend_config={"known_trip_count":{"n":...}}`` on
    while ops; multipliers propagate from the entry computation into loop
    bodies and everything they call (fusions, remat bodies, nested loops)."""
    blocks = _computation_blocks(hlo_text)
    call_re = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
    trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in blocks}
    for caller, lines in blocks.items():
        for line in lines:
            trips = trip_re.search(line)
            is_while = " while(" in line or "= while(" in line
            weight = float(trips.group(1)) if (is_while and trips) else 1.0
            for callee in call_re.findall(line):
                if callee in blocks:
                    edges[caller].append((callee, weight))
    referenced = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in blocks if c not in referenced]
    mult: dict[str, float] = {}

    def visit(c, m, depth=0):
        if depth > 32 or mult.get(c, 0.0) >= m:
            return
        mult[c] = m
        for callee, w in edges.get(c, []):
            visit(callee, m * w, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes per collective kind from (post-SPMD) HLO,
    weighting each op by the product of its enclosing while-loop trip
    counts — so per-microbatch / per-layer-scan collectives count once
    per iteration, not once per program text."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    mult = _loop_multipliers(hlo_text)
    blocks = _computation_blocks(hlo_text)
    for comp, lines in blocks.items():
        m_comp = mult.get(comp, 1.0)
        for line in lines:
            s = line.strip()
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)(?:-start|-done)?\(", s)
            if not m:
                continue
            type_str, op = m.groups()
            if op in COLLECTIVE_OPS:
                if "-done(" in s:  # async pairs: count the -start only
                    continue
                out[op] += _shape_bytes(type_str) * m_comp
                counts[op] += 1
    out["counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: dict
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float  # 6ND (train) / 2·N_active·tokens (decode), global
    hlo_flops_global: float
    memory_per_device: dict
    loop_correction: float = 1.0
    hlo_flops_raw: float = 0.0
    bytes_upper_bound: float = 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops_global if self.hlo_flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term floor that is useful model compute:
        (model_flops / chips / peak) / t_total."""
        ideal = self.model_flops / self.n_chips / TPU_V5E_BF16_FLOPS
        return ideal / self.t_total if self.t_total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_counts": self.collective_counts,
            "memory_per_device": self.memory_per_device,
            "loop_correction": self.loop_correction,
            "hlo_flops_raw_per_device": self.hlo_flops_raw,
            "t_memory_upper_s": self.bytes_upper_bound / TPU_V5E_HBM_BW,
        }


def analyze(arch, shape, mesh_name, n_chips, compiled, model_flops, analytic_total=None, analytic_bytes_dev=None) -> Roofline:
    """``analytic_total`` (global executed flops from launch.analytic) powers
    the compute term; XLA under-counts while-loop bodies inconsistently on
    this backend, so the measured HLO flops only *calibrate* a loop
    correction factor that re-scales the byte / collective terms (the same
    loops hold those bytes)."""
    from ..core.profiler import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    hlo_flops = float(ca.get("flops", 0.0))
    if analytic_total is None:
        analytic_total = hlo_flops * n_chips
    correction = max(1.0, (analytic_total / n_chips) / hlo_flops) if hlo_flops else 1.0
    flops = analytic_total / n_chips
    bytes_hlo = float(ca.get("bytes accessed", 0.0)) * correction
    # the loop-corrected HLO byte count is a (loose, CPU-backend-inflated)
    # upper bound; the analytic streaming model is the floor we report.
    bytes_ = analytic_bytes_dev if analytic_bytes_dev is not None else bytes_hlo
    coll = parse_collective_bytes(compiled.as_text())  # loop-weighted
    counts = coll.pop("counts")
    coll_bytes = sum(coll.values())
    ma = compiled.memory_analysis()
    mem = {
        "arguments": int(ma.argument_size_in_bytes),
        "outputs": int(ma.output_size_in_bytes),
        "temps": int(ma.temp_size_in_bytes),
        "code": int(ma.generated_code_size_in_bytes),
        "total": int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
        ),
    }
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_device=flops,
        bytes_per_device=bytes_,
        collective_bytes_per_device=coll_bytes,
        collective_counts={**counts, "bytes_by_kind": coll},
        t_compute=flops / TPU_V5E_BF16_FLOPS,
        t_memory=bytes_ / TPU_V5E_HBM_BW,
        t_collective=coll_bytes / TPU_V5E_ICI_BW,
        model_flops=model_flops,
        hlo_flops_global=analytic_total,
        memory_per_device=mem,
        loop_correction=correction,
        hlo_flops_raw=hlo_flops,
        bytes_upper_bound=bytes_hlo,
    )
