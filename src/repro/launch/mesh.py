"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the inter-pod (DCI) links; gradient compression in
``repro.dist.compression`` targets exactly that axis.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import math

import jax


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across versions: ``axis_types`` (and AxisType) only
    exist on newer jax — everything downstream uses explicit
    NamedShardings, for which the default (auto) axis types are right."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} "
            "(dry-run sets --xla_force_host_platform_device_count=512)"
        )
    return _make_mesh(shape, axes, devices)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    dp = max(1, n // model_parallel)
    return _make_mesh((dp, model_parallel), ("data", "model"), jax.devices()[: dp * model_parallel])
