"""Analytic executed-FLOP model per (arch x shape) cell.

XLA's ``cost_analysis`` under-counts while-loop bodies inconsistently
(nested scan bodies are multiplied by trip count at some levels only —
measured on this backend), so the roofline's compute term uses this
matmul-exact analytic model instead; the HLO numbers calibrate a
*loop correction factor* applied to the byte/collective terms (the same
loops hold those bytes).

Conventions: one MAC = 2 flops; train executes fwd(2F) + bwd(4F) + remat
recompute(+2F) = 8F-per-fwd-flop-pair (i.e. x4 the forward). Causal
attention averages (S+1)/2 visible keys; sliding-window layers see
min(window, S_avg).
"""
from __future__ import annotations

from ..configs import SHAPES, ArchSpec
from ..models import HymbaConfig, LMConfig, Mamba2Config, WhisperConfig


def _attn_gqa_per_token(cfg, avg_keys: float) -> float:
    proj = 2 * cfg.d_model * cfg.head_dim * (cfg.n_q + 2 * cfg.n_kv)
    out = 2 * cfg.n_q * cfg.head_dim * cfg.d_model
    sdpa = 4 * cfg.n_q * cfg.head_dim * avg_keys  # qk + av
    return proj + out + sdpa


def _attn_mla_per_token(cfg, avg_keys: float) -> float:
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    proj = 2 * cfg.d_model * (cfg.n_q * qd + cfg.kv_lora + cfg.qk_rope_dim)
    expand = 2 * cfg.kv_lora * cfg.n_q * (cfg.qk_nope_dim + cfg.v_head_dim)
    out = 2 * cfg.n_q * cfg.v_head_dim * cfg.d_model
    sdpa = 2 * cfg.n_q * (qd + cfg.v_head_dim) * avg_keys
    return proj + expand + out + sdpa


def _mlp_per_token(d_model, d_ff, gated=True) -> float:
    return (6 if gated else 4) * d_model * d_ff


def _moe_per_token(cfg) -> float:
    router = 2 * cfg.d_model * cfg.n_experts
    shared = 6 * cfg.d_model * cfg.d_ff_expert * cfg.n_shared
    routed = 6 * cfg.d_model * cfg.d_ff_expert * cfg.top_k * cfg.capacity_factor
    return router + shared + routed


def _mamba_per_token(blk) -> float:
    d, di = blk.d_model, blk.d_inner
    gn = blk.n_groups * blk.d_state
    proj = 2 * d * (2 * di + 2 * gn + blk.n_heads)
    conv = 2 * blk.d_conv * blk.conv_dim
    # SSD: intra-chunk scores/apply (avg chunk/2 keys) + state in/out
    intra = (blk.chunk / 2) * (2 * gn + 2 * blk.n_heads * blk.head_dim)
    states = 4 * blk.d_state * blk.n_heads * blk.head_dim
    out = 2 * di * d
    return proj + conv + intra + states + out


def _avg_keys(S, window, kind):
    full = (S + 1) / 2 if kind != "decode" else S
    if window and window > 0:
        return min(window, full)
    return full


def fwd_flops_per_token(cfg, S: int, kind: str) -> float:
    """Average forward flops per token at context length S."""
    if isinstance(cfg, LMConfig):
        total = 2 * cfg.d_model * cfg.vocab  # head (tied or not)
        for w in cfg.windows():
            ak = _avg_keys(S, w, kind)
            attn = (
                _attn_mla_per_token(cfg, ak)
                if cfg.attn_type == "mla"
                else _attn_gqa_per_token(cfg, ak)
            )
            total += attn
        n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
        n_dense = cfg.n_layers - n_moe
        total += n_dense * _mlp_per_token(cfg.d_model, cfg.d_ff)
        total += n_moe * _moe_per_token(cfg)
        return total
    if isinstance(cfg, Mamba2Config):
        blk = cfg.block()
        return 2 * cfg.d_model * cfg.vocab + cfg.n_layers * _mamba_per_token(blk)
    if isinstance(cfg, HymbaConfig):
        blk = cfg.mamba()
        total = 2 * cfg.d_model * cfg.vocab
        for w in cfg.windows():
            total += _attn_gqa_per_token(cfg, _avg_keys(S, w, kind))
            total += _mamba_per_token(blk)
            total += _mlp_per_token(cfg.d_model, cfg.d_ff)
        return total
    if isinstance(cfg, WhisperConfig):
        # decoder per-token costs; encoder handled separately
        ak = _avg_keys(S, 0, kind)
        dec = cfg.n_dec_layers * (
            _attn_gqa_per_token(cfg_attn(cfg), ak)
            + _attn_gqa_per_token(cfg_attn(cfg), cfg.n_frames)  # cross
            + _mlp_per_token(cfg.d_model, cfg.d_ff, gated=False)
        )
        return 2 * cfg.d_model * cfg.vocab + dec
    raise TypeError(type(cfg))


def cfg_attn(cfg: "WhisperConfig"):
    class _A:  # minimal attr view for the gqa formula
        d_model = cfg.d_model
        n_q = cfg.n_heads
        n_kv = cfg.n_heads
        head_dim = cfg.head_dim

    return _A


def whisper_encoder_flops(cfg: WhisperConfig, B: int) -> float:
    F = cfg.n_frames
    per_tok = cfg.n_enc_layers * (
        _attn_gqa_per_token(cfg_attn(cfg), F)  # bidirectional: all F keys
        + _mlp_per_token(cfg.d_model, cfg.d_ff, gated=False)
    )
    return B * F * per_tok


def _cache_bytes_per_layer_token(cfg) -> float:
    """KV/state bytes appended per token per layer (bf16)."""
    if isinstance(cfg, LMConfig):
        if cfg.attn_type == "mla":
            return 2.0 * (cfg.kv_lora + cfg.qk_rope_dim)
        return 2.0 * 2 * cfg.n_kv * cfg.head_dim
    if isinstance(cfg, HymbaConfig):
        return 2.0 * 2 * cfg.n_kv * cfg.head_dim  # + O(1) ssm state
    if isinstance(cfg, WhisperConfig):
        return 2.0 * 2 * cfg.n_heads * cfg.head_dim
    return 0.0  # mamba: O(1) state


def analytic_bytes(spec: ArchSpec, shape_name: str, n_chips: int) -> float:
    """Per-device HBM traffic floor for one step (bytes).

    Streaming model: every resident parameter is read once per forward
    pass (weights >> cache reuse at these batch sizes); train adds the
    remat re-read, gradient write and Adam state read+write (12B/param
    fp32 m,v + master-ish); activations stream layers x tokens x d twice
    per pass; decode adds the full KV/state cache read + append.
    """
    cfg = spec.config
    sh = SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    n_params = cfg.n_params()
    n_layers = getattr(cfg, "n_layers", None) or (cfg.n_enc_layers + cfg.n_dec_layers)
    d = cfg.d_model
    act_bytes = 2.0

    if kind == "train":
        tokens_local = B * S / n_chips
        # params fp32: fwd read + remat re-read + bwd read + grad write + m/v r/w
        param_traffic = n_params / n_chips * (3 * 4 + 4 + 4 * 4)
        act_traffic = tokens_local * d * n_layers * act_bytes * 6  # w+r fwd, recompute, bwd
        return param_traffic + act_traffic
    if kind == "prefill":
        tokens_local = B * S / n_chips
        param_traffic = n_params / n_chips * 2.0  # bf16 read once
        act_traffic = tokens_local * d * n_layers * act_bytes * 2
        cache_traffic = B * S / n_chips * n_layers * _cache_bytes_per_layer_token(cfg)
        return param_traffic + act_traffic + cache_traffic
    # decode: params once + cache read (window-limited for local layers)
    param_traffic = n_params / n_chips * 2.0
    cache = 0.0
    windows = cfg.windows() if hasattr(cfg, "windows") else [0] * n_layers
    per_tok = _cache_bytes_per_layer_token(cfg)
    for w in windows:
        span = min(S, w) if w else S
        cache += B * span * per_tok
    if isinstance(cfg, (Mamba2Config, HymbaConfig)):
        blk = cfg.block() if isinstance(cfg, Mamba2Config) else cfg.mamba()
        cache += B * n_layers * blk.n_heads * blk.head_dim * blk.d_state * 4.0 * 2
    return param_traffic + cache / n_chips


def graph_cost_rows(graph, engines, provider=None) -> list[dict]:
    """Per-layer timing table for a layer graph under a ``CostProvider`` —
    the layer-graph analogue of the arch-level analytic model above, and
    the quickest way to see where measured costs diverge from analytic
    ones (``python -m repro.launch.analytic --cost measured``)."""
    from ..core.cost_model import ANALYTIC

    provider = provider or ANALYTIC
    rows = []
    for l in graph:
        row = {"layer": l.name, "kind": l.kind, "flops": l.flops}
        for e in engines:
            row[f"t_{e.name}_us"] = provider.layer_time(l, e) * 1e6
        row["measured"] = provider.available(l)
        rows.append(row)
    return rows


def analytic_flops(spec: ArchSpec, shape_name: str, remat: bool = True) -> float:
    """Total executed flops for one step of the cell (global)."""
    cfg = spec.config
    sh = SHAPES[shape_name]
    B, S, kind = sh["global_batch"], sh["seq_len"], sh["kind"]
    if kind == "decode":
        tokens = B  # one new token per sequence
        fwd = tokens * fwd_flops_per_token(cfg, S, "decode")
        if isinstance(cfg, WhisperConfig):
            pass  # encoder already ran at prefill; decode reuses cross KV
        return fwd
    tokens = B * S
    fwd = tokens * fwd_flops_per_token(cfg, S, kind)
    if isinstance(cfg, WhisperConfig):
        fwd += whisper_encoder_flops(cfg, B)
    if kind == "train":
        policy = getattr(cfg, "remat_policy", "full")
        if not getattr(cfg, "remat", True):
            factor = 3.0
        elif policy == "dots":
            factor = 3.1  # matmuls saved; only elementwise recomputed
        else:
            factor = 4.0
        return fwd * factor
    return fwd


def main() -> None:
    """Planner-view cost report for the paper's serving pair: graph totals
    and the N-model schedule under the selected provider.

      PYTHONPATH=src python -m repro.launch.analytic --cost measured --per-layer
    """
    import argparse
    import json

    from ..core.constraints import DLA_ANALOGUE_CONSTRAINTS
    from ..core.cost_model import make_cost_provider
    from ..core.engine import jetson_orin_engines
    from ..core.scheduler import _nmodel_schedule_impl as nmodel_schedule
    from ..models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config

    ap = argparse.ArgumentParser()
    ap.add_argument("--cost", choices=("analytic", "measured", "blended"), default="analytic")
    ap.add_argument("--cost-cache", default=None, help="JSON cache for measured layer timings")
    ap.add_argument("--img", type=int, default=256)
    ap.add_argument("--per-layer", action="store_true", help="dump the per-layer table")
    ap.add_argument(
        "--granularity",
        choices=("coarse", "fine"),
        default="coarse",
        help="plan at composite-node or expanded (primitive) granularity",
    )
    ap.add_argument("--stride", type=int, default=1, help="keep every k-th legal cut point")
    ap.add_argument(
        "--max-cuts",
        type=int,
        default=1,
        help="per-model cut budget: k-segment routes ping-pong each model across engines",
    )
    ap.add_argument(
        "--impl",
        choices=("auto", "xla", "pallas"),
        default="xla",
        help="implementation planning: xla per-op lowering, pallas fused serving kernels, "
        "or auto (per-segment argmin over both)",
    )
    args = ap.parse_args()

    provider = make_cost_provider(args.cost, cache_path=args.cost_cache)
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    g_pix = Pix2PixGenerator(Pix2PixConfig(img_size=args.img, deconv_mode="cropping")).layer_graph()
    g_yolo = YOLOv8(YOLOv8Config(img_size=args.img)).layer_graph()
    if args.granularity == "fine":
        g_pix, g_yolo = g_pix.expand(), g_yolo.expand()
    plan = nmodel_schedule(
        [g_pix, g_yolo], [dla, gpu], provider=provider, stride=args.stride,
        max_cuts=args.max_cuts, impl=args.impl,
    )
    if args.cost_cache and hasattr(provider, "save"):
        provider.save()  # measured AND blended both persist their timings
    print(
        f"[analytic] cost={plan.cost_provider} search={plan.search} "
        f"cuts={plan.cuts} cycle={plan.cycle_time*1e3:.3f} ms "
        f"aggregate={plan.schedule.aggregate_fps:.1f} FPS"
    )
    if args.impl != "xla":
        print(f"[analytic] impl={args.impl} bindings={plan.ir.impl_bindings()}")
    print(plan.schedule.ascii_timeline())
    if args.per_layer:
        for graph in (g_pix, g_yolo):
            print(f"\n# {graph.model_name}")
            print(json.dumps(graph_cost_rows(graph, (dla, gpu), provider), indent=2))


if __name__ == "__main__":
    main()
