"""Multi-host initialization + env-driven config.

On a real pod slice each host runs the same program; JAX discovers its
local devices and the coordinator wires the global mesh. We honor both
explicit flags and the standard env vars (COORDINATOR_ADDRESS, NPROC,
PROCESS_ID) so the same entrypoint works under SLURM/GKE/manual launch.
"""
from __future__ import annotations

import os


def maybe_initialize_distributed(coordinator=None, num_processes=None, process_id=None):
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    if not coordinator:
        return False
    import jax

    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(num_processes or os.environ["NPROC"]),
        process_id=int(process_id or os.environ["PROCESS_ID"]),
    )
    return True
