"""Perf hillclimb driver: lower a cell under variant knobs and report the
three roofline terms + memory, for the hypothesis->change->measure loop.

  PYTHONPATH=src python experiments/hillclimb.py --arch mamba2_2_7b --shape train_4k \
      --variant fsdp=False n_micro=2
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json
import sys
import time

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import get_arch
from repro.launch.analytic import analytic_bytes, analytic_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.specs import build_cell


def run(arch, shape, set_cfg=None, **kw):
    import dataclasses
    spec = get_arch(arch)
    if set_cfg:
        spec = dataclasses.replace(spec, config=dataclasses.replace(spec.config, **set_cfg))
    mesh = make_production_mesh()
    cell = build_cell(spec, shape, mesh, **kw)
    t0 = time.time()
    with jax.set_mesh(mesh):
        compiled = (
            jax.jit(cell.fn, in_shardings=cell.in_shardings, donate_argnums=cell.donate_argnums)
            .lower(*cell.args)
            .compile()
        )
    rf = analyze(spec.name, shape, "16x16", mesh.size, compiled, cell.model_flops, analytic_flops(spec, shape), analytic_bytes(spec, shape, mesh.size))
    mem = rf.memory_per_device["total"] / 2**30
    print(
        f"[{arch}|{shape}|{kw}] comp={rf.t_compute*1e3:.1f}ms mem={rf.t_memory*1e3:.1f}ms "
        f"coll={rf.t_collective*1e3:.1f}ms bneck={rf.bottleneck} frac={rf.roofline_fraction:.4f} "
        f"mem/dev={mem:.2f}GiB corr={rf.loop_correction:.1f} (compile {time.time()-t0:.0f}s)"
    )
    return rf


def parse_kw(items):
    out = {}
    for it in items:
        k, v = it.split("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        elif v.isdigit():
            out[k] = int(v)
        else:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", nargs="*", default=[])
    ap.add_argument("--set", nargs="*", default=[], help="config overrides, e.g. remat=False attn_chunk=512")
    a = ap.parse_args()
    run(a.arch, a.shape, set_cfg=parse_kw(a.set) or None, **parse_kw(a.variant))
