"""Consistency tests for the analytic FLOP/byte models that power the
roofline: on single-level-scan programs XLA's HLO flop count is trustworthy
(verified earlier); the analytic model must agree there."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_arch
from repro.launch.analytic import analytic_bytes, analytic_flops, fwd_flops_per_token


def test_analytic_vs_hlo_forward_smoke():
    """Small LM forward: analytic fwd flops within 20% of XLA's count."""
    spec = get_arch("phi4_mini_3_8b")
    cfg = dataclasses.replace(
        spec.smoke, n_layers=2, vocab=2048, attn_chunk=0, remat=False, act_dtype=jnp.float32
    )
    from repro.configs import build_model

    model = build_model(cfg)
    B, S = 2, 256
    ab = model.abstract(jnp.float32)
    c = (
        jax.jit(lambda p, t: model(p, t))
        .lower(ab, jax.ShapeDtypeStruct((B, S), jnp.int32))
        .compile()
    )
    from repro.core.profiler import cost_analysis_dict

    hlo = float(cost_analysis_dict(c)["flops"])
    analytic = B * S * fwd_flops_per_token(cfg, S, "train")
    # the analytic model counts causal-HALF attention (what a flash kernel
    # executes); XLA's dense-masked path does the full S^2 — so analytic may
    # sit up to ~30% above HLO at tiny scale where attention dominates.
    assert abs(hlo - analytic) / hlo < 0.35, (hlo, analytic)


def test_analytic_flops_scaling_relations():
    spec = get_arch("gemma2_2b")
    train = analytic_flops(spec, "train_4k")
    prefill = analytic_flops(spec, "prefill_32k")
    decode = analytic_flops(spec, "decode_32k")
    # train executes fwd+bwd+remat on 1M tokens; decode touches B tokens
    assert train > prefill > decode
    # decode flops per token exceed prefill per-token (full-context keys)
    t_pre = prefill / (32 * 32768)
    t_dec = decode / 128
    assert t_dec > t_pre


def test_analytic_bytes_mla_cache_advantage():
    """MLA's compressed KV must show up as lower decode traffic."""
    moe = analytic_bytes(get_arch("deepseek_moe_16b"), "decode_32k", 256)
    mla = analytic_bytes(get_arch("deepseek_v2_lite_16b"), "decode_32k", 256)
    assert mla < moe * 0.6


def test_analytic_bytes_window_advantage():
    """Sliding-window archs read less cache than full attention."""
    g2 = analytic_bytes(get_arch("gemma2_2b"), "decode_32k", 256)  # half local
    g1 = analytic_bytes(get_arch("gemma_2b"), "decode_32k", 256)  # MQA though!
    # gemma-2b has kv=1 (tiny cache); compare gemma2 against itself w/o windows
    spec = get_arch("gemma2_2b")
    full = dataclasses.replace(spec.config, layer_pattern="global")
    spec_full = dataclasses.replace(spec, config=full)
    assert g2 < analytic_bytes(spec_full, "decode_32k", 256)
