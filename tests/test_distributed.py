"""Distribution layer: sharding rules, multi-device train step, compressed
gradients, elastic checkpoint restore onto a different mesh (subprocesses
with fake host devices)."""
import numpy as np
import pytest

from conftest import run_subprocess


def test_sharding_rules_unit():
    import jax

    from repro.dist.sharding import default_rules, spec_for_axes, spec_for_axes_shaped
    from jax.sharding import PartitionSpec as P

    rules = default_rules(True, ("data", "model"))
    assert spec_for_axes(("embed", "mlp"), rules) == P(None, ("model", "data"))
    # duplicate mesh axes are never reused
    s = spec_for_axes(("mlp", "vocab"), rules)
    flat = []
    for e in s:
        if isinstance(e, tuple):
            flat += list(e)
        elif e:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_train_shardings_plumbing():
    """``train_shardings`` derives the whole launch plumbing: fitted param
    shardings, optimizer state by structure (moments follow params, step
    replicates), and the batch sharding — no hand-rolled osh dicts."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import LMConfig, TransformerLM
    from repro.train.optimizer import AdamW
    from repro.dist.sharding import train_shardings

    cfg = LMConfig(
        name="t", n_layers=1, d_model=32, n_q=2, n_kv=1, head_dim=16, d_ff=64,
        vocab=128, act_dtype=jnp.float32,
    )
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.key(0))
    opt_state = AdamW(lr=1e-3).init(params)
    mesh = jax.make_mesh((1,), ("data",))
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    sh = train_shardings(mesh, lm.axes(), abstract, opt_state, batch_size=4)
    # params: every leaf got a NamedSharding
    assert all(isinstance(s, NamedSharding) for s in jax.tree.leaves(sh.params))
    # moments mirror the param shardings exactly; the step counter replicates
    assert jax.tree.structure(sh.opt_state["m"]) == jax.tree.structure(sh.params)
    assert sh.opt_state["m"] == sh.params
    assert sh.opt_state["v"] == sh.params
    assert sh.opt_state["step"].spec == P()
    # batch leading dim maps to the data-like axes (1-device: fitted away or data)
    assert isinstance(sh.batch, NamedSharding)
    # master-weight states follow params too (structure-matched branch)
    opt_state_mw = AdamW(lr=1e-3, master_weights=True).init(params)
    sh2 = train_shardings(mesh, lm.axes(), abstract, opt_state_mw, batch_size=4)
    assert sh2.opt_state["master"] == sh2.params
    # the whole tree is consumable by device_put (smoke on the 1-device mesh)
    jax.block_until_ready(jax.device_put(params, sh.params))
    jax.block_until_ready(jax.device_put(opt_state, sh.opt_state))


@pytest.mark.slow
def test_mesh_sharded_train_step_matches_single_device():
    code = '''
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import LMConfig, TransformerLM
from repro.train.optimizer import AdamW
from repro.train.steps import make_lm_train_step
from repro.dist.sharding import default_rules, tree_shardings_shaped, batch_sharding
from repro.data import token_batches

cfg = LMConfig(name="t", n_layers=2, d_model=64, n_q=4, n_kv=2, head_dim=16, d_ff=128,
               vocab=256, act_dtype=jnp.float32)
lm = TransformerLM(cfg)
params = lm.init(jax.random.key(0))
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)
batch = {k: jnp.asarray(v) for k, v in next(token_batches(8, 32, 256, seed=0)).items()}
step = make_lm_train_step(lm, opt)

# single device reference
p1, s1, m1 = jax.jit(step)(params, opt_state, batch)

# explicit NamedShardings only: works on every jax with jax.make_mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = default_rules(True, mesh.axis_names)
psh = tree_shardings_shaped(mesh, lm.axes(), jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), rules)
osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
bsh = batch_sharding(mesh, 8, rules)
p8, s8, m8 = jax.jit(step, in_shardings=(psh, osh, {"tokens": bsh, "labels": bsh}))(params, opt_state, batch)
assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-3, (float(m1["loss"]), float(m8["loss"]))
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
    np.testing.assert_allclose(np.float32(a), np.float32(b), atol=2e-3)
print("SHARDED==SINGLE OK")
'''
    out = run_subprocess(code, devices=8)
    assert "SHARDED==SINGLE OK" in out


@pytest.mark.slow
def test_compressed_pod_gradients():
    code = '''
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import make_compressed_dp_grad_fn, zeros_like_error
mesh = jax.make_mesh((2, 4), ("pod", "data"))
def loss_fn(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"])**2)
params = {"w": jnp.ones((8, 4))}
batch = {"x": jax.random.normal(jax.random.key(0), (16, 8)),
         "y": jax.random.normal(jax.random.key(1), (16, 4))}
gf = jax.jit(make_compressed_dp_grad_fn(loss_fn, mesh, P(("pod", "data"))))
g, err = gf(params, batch, zeros_like_error(params, 2))
g_ref = jax.grad(loss_fn)(params, batch)
rel = float(jnp.abs(g["w"] - g_ref["w"]).max() / jnp.abs(g_ref["w"]).max())
assert rel < 0.02, rel
# error feedback: a second identical step must not diverge
g2, err2 = gf(params, batch, err)
rel2 = float(jnp.abs(g2["w"] - g_ref["w"]).max() / jnp.abs(g_ref["w"]).max())
assert rel2 < 0.04, rel2
print("COMPRESSED OK")
'''
    out = run_subprocess(code, devices=8)
    assert "COMPRESSED OK" in out


@pytest.mark.slow
def test_elastic_restore_onto_different_mesh():
    code = '''
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import save_checkpoint, restore_checkpoint
tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
with tempfile.TemporaryDirectory() as d:
    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    t4 = jax.device_put(tree, NamedSharding(mesh4, P("data")))
    save_checkpoint(d, 7, t4)
    # restore onto an 8-way mesh (elastic scale-up)
    mesh8 = jax.make_mesh((8,), ("data",))
    sh8 = {"w": NamedSharding(mesh8, P("data")), "b": NamedSharding(mesh8, P())}
    got, step, _ = restore_checkpoint(d, tree, shardings=sh8)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["w"].sharding.num_devices == 8 or got["w"].sharding.mesh.size == 8
print("ELASTIC OK")
'''
    out = run_subprocess(code, devices=8)
    assert "ELASTIC OK" in out


def test_cache_spec_fitting_drops_nondivisible_axes():
    """kv=1 head can't shard over model=16: _fit_spec must drop the axis
    (tested against a mock 16x16 mesh shape)."""
    from repro.dist.sharding import _fit_spec

    class MockMesh:
        shape = {"data": 16, "model": 16}

    # (L, B, S, kv=1, hd): model proposed on the kv dim -> dropped
    fitted = _fit_spec((None, "data", None, "model", None), (4, 32, 64, 1, 16), MockMesh())
    assert fitted[3] is None
    # divisible dims keep their axes
    fitted = _fit_spec((None, "data", None, "model", None), (4, 32, 64, 16, 16), MockMesh())
    assert fitted[3] == "model" and fitted[1] == "data"
