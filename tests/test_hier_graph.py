"""The hierarchical layer graph: composite expansion for fine-grained
planning, measurement, and execution.

Pins the refactor's load-bearing guarantees:
  (a) ``expand()``/``flatten()`` conserve total flops/bytes/params and
      carry a consistent index map back to the coarse nodes,
  (b) cut legality: only stage-callable boundaries are candidate points
      on expanded graphs, coarse boundaries remain a subset, and the
      stride knob thins the set,
  (c) ``MeasuredCost.coverage() == 1.0`` on both serving graphs (the
      ROADMAP composite gap is closed),
  (d) the fine-granularity planner's analytic cost is never worse than
      the coarse plan re-scored at fine granularity, and the executed
      outputs are bit-exact (eager) between a coarse cut and the same
      cut expressed on the expanded graph,
  (e) PlanIR segments round-trip their coarse spans through JSON,
  (f) OnlineCost calibration persists to JSON and warm-starts a
      Replanner, and swap stalls are recorded (background prepare keeps
      the warmup off the hot path).
"""
import json

import jax
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.cost_model import ANALYTIC, MeasuredCost, OnlineCost
from repro.core.engine import jetson_orin_engines
from repro.core.pipeline import stage_ops_from_graph
from repro.core.plan_ir import PlanIR, make_plan_ir
from repro.core.scheduler import nmodel_schedule
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import StreamExecutor, StreamSpec


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def yolo_graph():
    return YOLOv8(YOLOv8Config(img_size=256)).layer_graph()


@pytest.fixture(scope="module")
def pix_graph():
    return Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()


@pytest.fixture(scope="module")
def staged_fine_pair():
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    params = {"generator": gen.init(jax.random.key(0))}
    sm_pix_c = core.pix2pix_staged(cfg, params)
    sm_pix_f = core.pix2pix_staged(cfg, params, granularity="fine")
    ycfg = YOLOv8Config(img_size=32)
    yparams = YOLOv8(ycfg).init(jax.random.key(1))
    sm_yolo_c = core.yolo_staged(ycfg, yparams)
    sm_yolo_f = core.yolo_staged(ycfg, yparams, granularity="fine")
    return (sm_pix_c, sm_yolo_c), (sm_pix_f, sm_yolo_f)


# ---- expansion: conservation + index maps ----------------------------------


def test_expansion_conserves_totals(yolo_graph, pix_graph):
    for g in (yolo_graph, pix_graph):
        eg = g.expand()
        assert eg.total_flops() == pytest.approx(g.total_flops())
        assert eg.total_bytes() == pytest.approx(g.total_bytes())
        assert eg.total_params() == g.total_params()
    # yolo genuinely decomposes; pix is already primitive-only
    assert len(yolo_graph.expand()) > len(yolo_graph)
    assert len(pix_graph.expand()) == len(pix_graph)
    # flatten is the primitive-only alias
    assert [l.name for l in yolo_graph.flatten()] == [l.name for l in yolo_graph.expand()]
    assert all(not l.is_composite for l in yolo_graph.flatten())


def test_expansion_index_maps_consistent(yolo_graph):
    eg = yolo_graph.expand()
    assert len(eg.coarse_of) == len(eg)
    pos = 0
    for ci, (lo, hi) in enumerate(eg.spans):
        assert lo == pos and hi > lo
        assert all(eg.coarse_of[i] == ci for i in range(lo, hi))
        pos = hi
    assert pos == len(eg)
    # coarse cut points map onto legal fine cut points
    fine_pts = set(eg.cut_points())
    for p in range(1, len(yolo_graph)):
        assert eg.fine_cut(p) in fine_pts
    # coarse_span round-trips a whole coarse node
    for ci, (lo, hi) in enumerate(eg.spans):
        assert eg.coarse_span(lo, hi) == (ci, ci + 1)


def test_per_node_totals_match_decomposition(yolo_graph):
    for l in yolo_graph:
        prims = l.primitives()
        assert l.flops == pytest.approx(sum(p.flops for p in prims))
        assert l.bytes_accessed == pytest.approx(sum(p.bytes_accessed for p in prims))
        assert l.params == sum(p.params for p in prims)


def test_interior_cuts_charge_live_skip_tensors(yolo_graph):
    """Inside c2f, the accumulated bottleneck outputs stay live: an
    interior boundary must cost more than the flowing activation alone."""
    import math

    c2f = next(l for l in yolo_graph if l.kind == "c2f")
    adds = [p for p in c2f.sublayers if p.name.endswith(".add")]
    assert adds, "expected a shortcut bottleneck inside the backbone c2f"
    flowing = 2 * math.prod(adds[0].out_shape)  # dtype_bytes=2
    assert adds[0].boundary_bytes > flowing  # live outs charged on top
    # the composite's exit boundary matches the coarse accounting
    assert c2f.sublayers[-1].boundary_bytes == pytest.approx(c2f.boundary_bytes)


# ---- legality mask + stride knob -------------------------------------------


def test_cut_legality_and_stride(yolo_graph):
    eg = yolo_graph.expand()
    pts = eg.cut_points()
    # strictly fewer candidates than interior points: interior primitives
    # of a fused stage refuse cuts...
    assert 0 < len(pts) < len(eg) - 1
    # ...e.g. never between a conv and its bn
    for p in pts:
        assert eg[p - 1].cut_after
        assert not eg[p - 1].name.endswith(".conv")
    # but strictly more candidates than the coarse graph exposes
    assert len(pts) > len(yolo_graph) - 1
    # stride thins the legal set, keeping legality
    strided = eg.cut_points(stride=4)
    assert strided == pts[::4]
    # coarse graphs: every interior point remains legal (seed behavior)
    assert yolo_graph.cut_points() == list(range(1, len(yolo_graph)))


def test_fine_staged_ops_align_with_stage_boundaries(staged_fine_pair):
    (_, _), (_, sm_yolo_f) = staged_fine_pair
    assert sm_yolo_f.op_spans is not None
    assert sm_yolo_f.n_layers == len(sm_yolo_f.graph) > len(sm_yolo_f.ops) > 19
    # every legal cut maps to an op boundary; an illegal one raises
    for p in sm_yolo_f.graph.cut_points():
        olo, ohi = sm_yolo_f.op_range(0, p)
        assert olo == 0 and 0 < ohi <= len(sm_yolo_f.ops)
    conv_interior = next(
        p for p in range(1, sm_yolo_f.n_layers) if not sm_yolo_f.graph[p - 1].cut_after
    )
    with pytest.raises(ValueError):
        sm_yolo_f.op_range(0, conv_interior)
    # stage_ops_from_graph refuses graphs without stage callables
    with pytest.raises(ValueError):
        stage_ops_from_graph(Pix2PixGenerator(Pix2PixConfig(img_size=8, base=4)).layer_graph())


# ---- measured coverage (ROADMAP item) --------------------------------------


def test_measured_coverage_is_complete(yolo_graph, pix_graph):
    mc = MeasuredCost()
    assert mc.coverage(pix_graph) == 1.0
    assert mc.coverage(yolo_graph) == 1.0  # composites measured via expansion
    assert mc.coverage(yolo_graph.expand()) == 1.0


# ---- fine plan >= coarse plan, executed bit-exactly ------------------------


def test_fine_plan_cost_never_worse_than_coarse(engines, yolo_graph, pix_graph):
    """The fine planner searches a superset of the coarse cut points, so
    its analytic cost is <= the coarse plan re-scored on the expanded
    graphs (the acceptance bar for the granularity refactor)."""
    gpu, dla = engines
    coarse = nmodel_schedule([pix_graph, yolo_graph], [dla, gpu])
    fine_graphs = [pix_graph.expand(), yolo_graph.expand()]
    fine = nmodel_schedule(fine_graphs, [dla, gpu])
    rescored = nmodel_schedule(
        fine_graphs,
        [dla, gpu],
        fixed=tuple(g.fine_cut(p) for g, p in zip(fine_graphs, coarse.partitions)),
    )
    assert fine.cycle_time <= rescored.cycle_time
    # the IR reports the fine cuts in coarse terms
    for segs, g in zip(fine.ir.segments, fine_graphs):
        for s in segs:
            assert s.coarse_span == g.coarse_span(s.lo, s.hi)


def test_coarse_cut_bit_exact_on_expanded_graph(engines, staged_fine_pair):
    """The same physical cut executed at coarse granularity and expressed
    on the expanded graph produces bit-identical outputs (eager)."""
    (_, sm_yolo_c), (_, sm_yolo_f) = staged_fine_pair
    eg = sm_yolo_f.graph
    p_coarse = len(sm_yolo_c.graph) // 2
    p_fine = eg.fine_cut(p_coarse)
    ir_c = make_plan_ir(
        (sm_yolo_c.name,), ("con", "flex"),
        [[(0, 0, p_coarse, 0.0), (1, p_coarse, sm_yolo_c.n_layers, 0.0)]],
    )
    ir_f = make_plan_ir(
        (sm_yolo_f.name,), ("con", "flex"),
        [[(0, 0, p_fine, 0.0), (1, p_fine, sm_yolo_f.n_layers, 0.0)]],
        graphs=(eg,),
    )
    frames = [jax.random.normal(jax.random.key(i), (1, 32, 32, 3)) for i in range(3)]

    def run(sm, ir):
        ex = StreamExecutor([sm], ir, [StreamSpec("det", 0)], max_queue=8, jit_segments=False)
        for f in frames:
            assert ex.submit(0, f)
            ex.tick()
        return ex.run_until_drained()["det"]

    outs_c, outs_f = run(sm_yolo_c, ir_c), run(sm_yolo_f, ir_f)
    for a, b in zip(outs_c, outs_f):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_fine_plan_executes_with_outputs_equal_to_coarse(engines, staged_fine_pair):
    """End-to-end acceptance: the planned fine cut points (inside
    composites) run through the executor with outputs bit-equal (eager)
    to the coarse plan's on the YOLO+Pix2Pix pair."""
    gpu, dla = engines
    (sm_pix_c, sm_yolo_c), (sm_pix_f, sm_yolo_f) = staged_fine_pair
    plan_c = nmodel_schedule([sm_pix_c.graph, sm_yolo_c.graph], [dla, gpu])
    plan_f = nmodel_schedule([sm_pix_f.graph, sm_yolo_f.graph], [dla, gpu])
    assert plan_f.cycle_time <= plan_c.cycle_time
    # the interesting case: the fine planner picked a yolo cut strictly
    # inside a composite (not expressible on the coarse graph)
    coarse_boundaries = {sm_yolo_f.graph.fine_cut(p) for p in range(len(sm_yolo_c.graph) + 1)}
    assert plan_f.partitions[1] not in coarse_boundaries
    streams = [StreamSpec("mri", 0), StreamSpec("det", 1)]
    frames = [jax.random.normal(jax.random.key(i), (1, 32, 32, 3)) for i in range(3)]

    def run(models, plan):
        ex = StreamExecutor(models, plan, streams, max_queue=8, jit_segments=False)
        for f in frames:
            assert ex.submit(0, f) and ex.submit(1, f)
            ex.tick()
        return ex.run_until_drained()

    outs_c = run([sm_pix_c, sm_yolo_c], plan_c)
    outs_f = run([sm_pix_f, sm_yolo_f], plan_f)
    for k in ("mri", "det"):
        for a, b in zip(outs_c[k], outs_f[k]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---- PlanIR coarse spans ----------------------------------------------------


def test_plan_ir_coarse_spans_roundtrip(yolo_graph):
    eg = yolo_graph.expand()
    p = eg.fine_cut(5) + 2  # a cut inside coarse node 5
    while not eg[p - 1].cut_after:
        p += 1
    ir = make_plan_ir(
        ("yolo",), ("E0", "E1"), [[(0, 0, p, 1.0), (1, p, len(eg), 2.0)]], graphs=(eg,)
    )
    seg0, seg1 = ir.segments[0]
    assert seg0.coarse_span == eg.coarse_span(0, p)
    assert seg1.coarse_span == eg.coarse_span(p, len(eg))
    assert seg0.coarse_hi >= 6  # the cut is inside node 5, span covers it
    rt = PlanIR.from_json(ir.to_json())
    assert rt == ir
    assert "~c[" in seg0.describe(("E0", "E1"))
    # coarse-plan IRs stay unannotated (and old JSON still loads)
    plain = make_plan_ir(("m",), ("E0",), [[(0, 0, 3, 0.0)]])
    assert plain.segments[0][0].coarse_span is None
    d = json.loads(plain.to_json())
    for segs in d["segments"]:
        for s in segs:
            del s["coarse_lo"], s["coarse_hi"]
    assert PlanIR.from_json(json.dumps(d)).segments[0][0].coarse_span is None


def test_inefficiency_derate_applies_once_on_composites():
    """Hierarchical metas surface one violation per mis-aligned primitive;
    the roofline derate must apply once, not compound to 0.5^k."""
    from repro.core.constraints import LaneAlignment
    from repro.core.cost_model import INEFFICIENT_DERATE, layer_time
    from repro.core.engine import EngineSpec
    from repro.core.graph import LayerMeta

    eng = EngineSpec("E", 1, 1e12, 1e18, 32e9, (LaneAlignment(128),))

    def prim(i):
        return LayerMeta(
            idx=i, name=f"p{i}", kind="conv",
            in_shape=(1, 8, 8, 192), out_shape=(1, 8, 8, 192),
            flops=1e9, bytes_accessed=1.0,
        )

    comp = LayerMeta(
        idx=0, name="c", kind="c2f",
        in_shape=(1, 8, 8, 192), out_shape=(1, 8, 8, 192),
        flops=4e9, bytes_accessed=4.0, sublayers=[prim(i) for i in range(4)],
    )
    assert len(eng.supports(comp)) == 5  # composite + 4 primitives
    assert layer_time(comp, eng) == pytest.approx(4e9 / (1e12 * INEFFICIENT_DERATE))


def test_replanner_replans_with_configured_stride():
    """Drift-triggered re-plans must search the same thinned candidate set
    the initial plan used (ReplanConfig.stride)."""
    from repro.core.graph import LayerGraph, pointwise_meta
    from repro.serve import ReplanConfig, Replanner

    g = LayerGraph(
        "toy",
        [pointwise_meta(i, f"m{i}", "act", (1, 64), flops_per_elem=(i + 1) * 1e8 / 64) for i in range(10)],
    ).renumber()
    gpu, dla = jetson_orin_engines()
    rp = Replanner([g], [dla, gpu], ReplanConfig(stride=3))
    plan = rp._plan(rp._snapshot_online())
    assert plan.partitions[0] in g.cut_points(stride=3)


# ---- OnlineCost persistence + warm start (ROADMAP replanner item) ----------


def test_online_calibration_roundtrip_and_warm_start(tmp_path, engines):
    from repro.serve import Replanner

    gpu, dla = engines
    oc = OnlineCost(ANALYTIC, alpha=0.5)
    oc.observe("GPU", 2.0, 1.0)
    oc.observe("DLA", 3.0, 2.0)
    path = str(tmp_path / "calib.json")
    assert oc.save_calibration(path) == path
    oc2 = OnlineCost(ANALYTIC, alpha=0.5).load_calibration(path)
    assert oc2.snapshot() == oc.snapshot()
    # further observations keep folding into the restored EMA state
    oc.observe("GPU", 2.0, 1.0)
    oc2.observe("GPU", 2.0, 1.0)
    assert oc2.scale("GPU") == pytest.approx(oc.scale("GPU"))
    # a Replanner over a warm-started OnlineCost is calibrated immediately
    g = Pix2PixGenerator(Pix2PixConfig(img_size=16, base=4, deconv_mode="cropping")).layer_graph()
    rp = Replanner([g], [dla, gpu], base_provider=oc2)
    assert rp.calibrated
    cold = Replanner([g], [dla, gpu], base_provider=OnlineCost(ANALYTIC))
    assert not cold.calibrated
    # ...and a replanner over any NON-online base provider can warm-start
    # its internally wrapped OnlineCost from the same JSON
    cold2 = Replanner([g], [dla, gpu], base_provider=ANALYTIC)
    assert not cold2.calibrated
    cold2.load_calibration(path)
    assert cold2.calibrated
    assert cold2.online.scale("GPU") == pytest.approx(oc.snapshot()["GPU"], rel=0.3)


def test_make_cost_provider_warm_starts_online(tmp_path):
    from repro.core.cost_model import make_cost_provider

    oc = make_cost_provider("online")  # blended base, like the CLI flow
    oc.observe("GPU", 2.0, 1.0)
    path = str(tmp_path / "calib.json")
    oc.save_calibration(path)
    warm = make_cost_provider("online", calibration_path=path)
    assert warm.scale("GPU") == pytest.approx(2.0)
    missing = make_cost_provider("online", calibration_path=str(tmp_path / "nope.json"))
    assert missing.snapshot() == {}
    # scales are base-provider units: loading under a different base raises
    with pytest.raises(ValueError, match="base provider"):
        OnlineCost(ANALYTIC).load_calibration(path)


# ---- swap stalls (ROADMAP replanner item) ----------------------------------


def _toy_setup(background: bool):
    from repro.core.graph import LayerGraph, pointwise_meta
    from repro.core.pipeline import StagedModel
    from repro.serve import ReplanConfig, Replanner

    n = 6
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * 1.5 + 0.5}) for i in range(n)]
    graph = LayerGraph(
        "toy",
        [pointwise_meta(i, f"mul{i}", "act", (1, 64), flops_per_elem=1e9 / 64) for i in range(n)],
    ).renumber()
    sm = StagedModel(
        name="toy", ops=ops, params=None, graph=graph,
        init_state=lambda x: {"x": x}, finalize=lambda s: s["x"],
    )
    from repro.core.engine import EngineSpec

    engines = [EngineSpec("E0", 1, 1e12, 1e12, 32e9), EngineSpec("E1", 1, 2e12, 1e12, 32e9)]
    plan = nmodel_schedule([sm.graph], engines)
    rp = Replanner([sm.graph], engines, ReplanConfig(background=background))
    ex = StreamExecutor([sm], plan, [StreamSpec("s", 0)], max_queue=8)
    return sm, rp, ex, engines


@pytest.mark.parametrize("background", [False, True])
def test_swap_stall_recorded(background):
    import jax.numpy as jnp

    sm, rp, ex, engines = _toy_setup(background)
    ex.submit(0, jnp.ones((1, 64)))
    ex.tick()
    # force a drifted plan through _finish directly (the detector path is
    # pinned elsewhere); prepare must run off the tick thread only when
    # the background worker supplied it
    alt = nmodel_schedule([sm.graph], engines, fixed=(max(1, ex.plan.partitions[0] - 1),))
    prepared = None
    if background:
        prepared = 0.01  # the worker's measured prepare time
    ev = rp._finish(ex, alt, old_cycle=alt.cycle_time * 10, drift={"E0": 1.0}, prepare_s=prepared)
    assert ev.swapped
    assert len(rp.swap_stalls) == 1
    st = rp.swap_stalls[0]
    assert st.background is background
    assert st.hot_path_s >= 0.0
    if background:
        assert st.prepare_s == pytest.approx(0.01)
        assert st.hot_path_s == st.swap_s  # warmup stayed off the hot path
    summ = rp.summary()["swap_stall"]
    assert summ["swaps"] == 1
    assert summ["background_prepares"] == (1 if background else 0)


def test_background_replan_prepares_in_worker():
    """End-to-end background path: the worker thread plans AND warms the
    new segment executables; the harvested swap records a background
    prepare (hot path pays only the swap)."""
    import time as _time

    from repro.serve import ReplanConfig, Replanner
    from repro.serve.executor import SegmentObservation

    sm, _, ex, engines = _toy_setup(False)
    cfg = ReplanConfig(
        drift_threshold=0.5, hysteresis=2, cooldown_ticks=2, warmup_obs=2,
        min_improvement=0.01, background=True,
    )
    rp = Replanner([sm.graph], engines, cfg)

    def feed(walls):
        for eng, wall in walls.items():
            seg = ex.plan.route(0)[eng]
            rp.observe(
                SegmentObservation(
                    tick=ex.tick_count, model_index=0, stage=seg.stage, engine=seg.engine,
                    lo=seg.lo, hi=seg.hi, wall_s=wall, batch=1, revision=ex.plan_revision,
                )
            )
        return rp.maybe_replan(ex)

    e0 = rp._expected_base(0, 0, *ex.plan.route(0)[0].span)
    e1 = rp._expected_base(0, 1, *ex.plan.route(0)[1].span)
    for _ in range(4):
        assert feed({0: 100 * e0, 1: 100 * e1}) is None
    assert rp.calibrated
    # sustained 4x skew on E0: the detector launches a background worker
    # (plan + prepare), then a later tick harvests and swaps
    ev, deadline = None, _time.time() + 30.0
    while ev is None and _time.time() < deadline:
        ev = feed({0: 400 * e0, 1: 100 * e1})
        _time.sleep(0.005)
    assert ev is not None and ev.swapped
    assert rp.swap_stalls and rp.swap_stalls[0].background
    assert rp.swap_stalls[0].hot_path_s == rp.swap_stalls[0].swap_s
    assert rp.summary()["swap_stall"]["background_prepares"] == 1
