"""The measured-cost planning stack: CostProvider implementations and the
beam-search N-model planner.

Pins the PR's load-bearing guarantees: (a) beam search is bit-identical
to exhaustive search on small N/E spaces, (b) beam search is never worse
than the legacy coordinate descent on the N=3/N=4 benchmark graphs,
(c) MeasuredCost round-trips its per-(layer, engine, dtype) timing cache
through JSON, and (d) providers thread through the whole cost stack."""
import dataclasses

import pytest

from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.cost_model import (
    ANALYTIC,
    AnalyticCost,
    BlendedCost,
    MeasuredCost,
    graph_time,
    layer_time,
    make_cost_provider,
    segment_cost,
)
from repro.core.engine import EngineSpec, jetson_orin_engines
from repro.core.graph import LayerGraph
from repro.core.scheduler import nmodel_schedule
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config


@pytest.fixture(scope="module")
def engines():
    return jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)


@pytest.fixture(scope="module")
def pix_graph():
    return Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()


@pytest.fixture(scope="module")
def yolo_graph():
    return YOLOv8(YOLOv8Config(img_size=256)).layer_graph()


def _slice_graph(graph, n, name):
    return LayerGraph(name, [l.clone() for l in list(graph)[:n]]).renumber()


# ---- cost providers --------------------------------------------------------


def test_analytic_provider_is_default(pix_graph, engines):
    gpu, dla = engines
    base = segment_cost(pix_graph, 0, len(pix_graph), dla, gpu)
    via = segment_cost(pix_graph, 0, len(pix_graph), dla, gpu, provider=AnalyticCost())
    assert base.elapsed == via.elapsed
    assert ANALYTIC.layer_time(pix_graph[0], gpu) == layer_time(pix_graph[0], gpu)


def test_make_cost_provider_names():
    assert make_cost_provider("analytic").name == "analytic"
    assert make_cost_provider("measured").name == "measured"
    assert make_cost_provider("blended").name == "blended"
    with pytest.raises(ValueError):
        make_cost_provider("vibes")


@pytest.fixture(scope="module")
def tiny_graph():
    # 8x8 images: a handful of conv/deconv layers with near-instant lowering
    return Pix2PixGenerator(Pix2PixConfig(img_size=8, base=4, deconv_mode="cropping")).layer_graph()


def test_measured_cost_cache_roundtrip(tmp_path, tiny_graph, engines):
    gpu, dla = engines
    path = str(tmp_path / "timings.json")
    mc = MeasuredCost(cache_path=path)
    times = [mc.layer_time(l, dla) for l in tiny_graph]
    n_measurable = sum(mc.available(l) for l in tiny_graph)
    # distinct (kind, shape, signature) keys: elementwise layers repeat
    # (e.g. several same-shape activations), so measurements < layers
    n_unique = len({mc._key(l, dla) for l in tiny_graph if mc.available(l)})
    assert n_measurable > 0
    assert mc.measure_count == n_unique <= n_measurable
    assert all(t > 0 for t in times)
    assert mc.save() == path

    # a fresh instance serves every measurable layer from the JSON cache
    mc2 = MeasuredCost(cache_path=path)
    assert mc2.cache_size == n_unique
    times2 = [mc2.layer_time(l, dla) for l in tiny_graph]
    assert times2 == times
    assert mc2.measure_count == 0
    assert mc2.hits == n_measurable
    # engine is part of the key: the GPU timing is a fresh measurement
    mc2.layer_time(tiny_graph[0], gpu)
    assert mc2.measure_count == 0 or mc2.cache_size > n_unique


def test_measured_cost_dtype_mismatch_rejected(tmp_path):
    path = str(tmp_path / "timings.json")
    mc = MeasuredCost(cache_path=path, dtype="bfloat16")
    mc._cache["x"] = 1.0
    mc.save()
    with pytest.raises(ValueError):
        MeasuredCost(cache_path=path, dtype="float32")


def test_measured_covers_elementwise_kinds(tiny_graph, engines):
    """Pointwise/norm/concat kinds go through the generic elementwise
    lowering: every layer of the Pix2Pix graph is served by an XLA
    measurement (the online EMA then covers every segment)."""
    _, dla = engines
    mc = MeasuredCost()
    kinds = {l.kind for l in tiny_graph}
    assert {"bn", "act", "tanh", "concat"} <= kinds  # the graph exercises them
    assert mc.coverage(tiny_graph) == 1.0
    for l in tiny_graph:
        assert mc.available(l), l.kind
        assert mc.layer_time(l, dla) > 0.0


def test_measured_composites_covered_via_expansion(yolo_graph, engines):
    """Composite graph-level kinds (c2f/sppf/head) are measured through
    their primitive decomposition: YOLO coverage reaches 1.0 (the old
    composite gap is closed) and a composite's time is the sum of its
    primitives' measured times."""
    gpu, _ = engines
    mc = MeasuredCost()
    composite = [l for l in yolo_graph if l.kind in ("c2f", "sppf", "head")]
    assert composite
    for l in composite:
        assert l.is_composite
        assert mc.available(l)
    assert mc.coverage(yolo_graph) == 1.0
    # a composite whose decomposition contains an unmeasurable primitive
    # falls back to the analytic roofline (and blended keeps working)
    broken = composite[0].clone()
    broken.sublayers = [broken.sublayers[0].clone(kind="other")]
    assert not mc.available(broken)
    assert mc.layer_time(broken, gpu) == layer_time(broken, gpu)


def test_measured_composite_time_is_sum_of_primitives(engines):
    """On a CPU-sized graph, actually lower one c2f block: the composite's
    measured time equals the sum over its sublayers."""
    from repro.models import YOLOv8, YOLOv8Config

    gpu, _ = engines
    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    c2f = next(l for l in g if l.kind == "c2f")
    mc = MeasuredCost()
    total = mc.layer_time(c2f, gpu)
    assert total == pytest.approx(sum(mc.layer_time(p, gpu) for p in c2f.sublayers))
    assert total > 0.0


def test_blended_falls_back_to_analytic(tiny_graph, engines):
    gpu, _ = engines
    blended = BlendedCost()
    for l in tiny_graph:
        t = blended.layer_time(l, gpu)
        if not blended.available(l):
            assert t == layer_time(l, gpu)  # bn/act/crop: analytic fallback
        else:
            assert t == blended.measured.layer_time(l, gpu)


def test_measured_provider_plans_end_to_end(tiny_graph, engines):
    gpu, dla = engines
    mc = MeasuredCost()
    plan = nmodel_schedule([tiny_graph, tiny_graph], [dla, gpu], provider=mc)
    assert plan.cost_provider == "measured"
    assert plan.cycle_time > 0
    assert all(0 < p < len(tiny_graph) for p in plan.partitions)
    assert any(n.startswith("search=") for n in plan.schedule.notes)


# ---- beam search vs exhaustive (small spaces, bit-identical) ---------------


def _third_engine():
    return EngineSpec("AUX", 1, 0.9e12, 80e9, 32e9, ())


@pytest.mark.parametrize("n_models", [1, 2, 3])
@pytest.mark.parametrize("n_engines", [1, 2, 3])
def test_beam_equals_exhaustive_small_spaces(n_models, n_engines, pix_graph, yolo_graph, engines):
    """A non-truncating beam (width >= the candidate product) enumerates the
    exact product in product order, so its argmin — including every
    tie-break — is bit-identical to the exhaustive scan on any space."""
    import math

    gpu, dla = engines
    engine_sets = {1: [gpu], 2: [dla, gpu], 3: [dla, gpu, _third_engine()]}
    gs = [
        _slice_graph(pix_graph, 7, "pixA"),
        _slice_graph(yolo_graph, 6, "yoloB"),
        _slice_graph(pix_graph, 8, "pixC"),
    ][:n_models]
    width = math.prod(len(g) - 1 for g in gs)
    ex = nmodel_schedule(gs, engine_sets[n_engines], search="exhaustive")
    bm = nmodel_schedule(gs, engine_sets[n_engines], search="beam", beam_width=width)
    assert bm.partitions == ex.partitions
    assert bm.cycle_time == ex.cycle_time
    assert bm.engine_times == ex.engine_times
    assert bm.search == "beam" and ex.search == "exhaustive"
    # the default (truncating) width still matches the optimum cycle time
    bm_default = nmodel_schedule(gs, engine_sets[n_engines], search="beam")
    assert bm_default.cycle_time <= ex.cycle_time or bm_default.cycle_time == pytest.approx(
        ex.cycle_time
    )


def test_beam_equals_exhaustive_with_fallback_graphs(engines):
    """Padded graphs exercise the fallback/peer-steal terms of the key."""
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(deconv_mode="padded")).layer_graph()
    gs = [_slice_graph(g, 9, "padA"), _slice_graph(g, 11, "padB")]
    ex = nmodel_schedule(gs, [dla, gpu], search="exhaustive")
    bm = nmodel_schedule(gs, [dla, gpu], search="beam")
    assert bm.partitions == ex.partitions
    assert bm.cycle_time == ex.cycle_time


# ---- beam search vs coordinate descent (benchmark graphs) ------------------


@pytest.mark.parametrize("case", ["3pix", "3mixed", "4pix", "4mixed", "4mixed2"])
def test_beam_never_worse_than_descent(case, pix_graph, yolo_graph, engines):
    gpu, dla = engines
    gp = Pix2PixGenerator(Pix2PixConfig(deconv_mode="padded")).layer_graph()
    graphs = {
        "3pix": [pix_graph] * 3,
        "3mixed": [pix_graph, yolo_graph, gp],
        "4pix": [pix_graph] * 4,
        "4mixed": [pix_graph, yolo_graph, pix_graph, yolo_graph],
        "4mixed2": [gp, yolo_graph, pix_graph, pix_graph],
    }[case]
    descent = nmodel_schedule(graphs, [dla, gpu], search="descent")
    beam = nmodel_schedule(graphs, [dla, gpu], search="beam")
    assert beam.cycle_time <= descent.cycle_time
    assert beam.search == "beam" and descent.search == "descent"


def test_auto_mode_selects_beam_beyond_exhaustive_limit(pix_graph, engines):
    gpu, dla = engines
    plan = nmodel_schedule([pix_graph] * 3, [dla, gpu])
    assert plan.search == "beam"
    small = _slice_graph(pix_graph, 7, "small")
    plan2 = nmodel_schedule([small, small], [dla, gpu])
    assert plan2.search == "exhaustive"


def test_provider_threads_into_balanced_and_graph_time(tiny_graph, engines):
    gpu, dla = engines
    mc = MeasuredCost()
    t_analytic = graph_time(tiny_graph, dla, gpu).elapsed
    t_measured = graph_time(tiny_graph, dla, gpu, provider=mc).elapsed
    assert t_measured > 0 and t_analytic > 0
    assert t_measured != t_analytic  # XLA numbers differ from the analytic model


def test_dataclass_plan_records_provider(pix_graph, engines):
    gpu, dla = engines
    plan = nmodel_schedule([pix_graph, pix_graph], [dla, gpu])
    assert plan.cost_provider == "analytic"
    assert dataclasses.asdict(plan.schedule)  # schedule remains serializable
