import os

import jax
import pytest

# Smoke tests and benches run on ONE device; the dry-run alone forces 512
# host devices (inside repro.launch.dryrun / subprocesses spawned here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def run_subprocess(code: str, devices: int = 8, timeout: int = 480):
    """Run a snippet in a subprocess with N fake devices (mesh tests)."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout
