"""The multi-stream serving subsystem: N-model planner + stream executor.

Pins the load-bearing invariants: (a) the N-model planner degenerates to
the paper's two-model HaX-CoNN schedule exactly, (b) the tick-based
executor is a pure re-orchestration — outputs bit-exact vs the monolithic
models on the eager path (``jit_segments=False``), within the fusion
tolerance on the default jitted path — and (c) bounded queues actually
bound (backpressure).

``jit_segments=True`` is the executor default: XLA fusion of a segment
may flip low-order bits vs the eager op sequence, so default-path output
pins are *tolerance* pins (the observed drift ceiling on these 32x32
models is sub-1e-3 absolute); the eager path keeps the bit-exact pins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.core.graph import LayerGraph, pointwise_meta
from repro.core.pipeline import StagedModel
from repro.core.scheduler import ModelRoute, nmodel_schedule
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import FrameQueue, MultiStreamServer, StreamExecutor, StreamSpec
from repro.serve.metrics import percentile


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def staged_pair():
    """Small executable Pix2Pix + YOLO staged models (CPU-sized)."""
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    sm_pix = core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    sm_yolo = core.yolo_staged(ycfg, ym.init(jax.random.key(1)))
    return sm_pix, sm_yolo


# ---- planner ---------------------------------------------------------------


def test_nmodel_n2_reproduces_haxconn(engines):
    """The N=2 specialization must pick the same partitions and cycle time
    as the exact two-model search — bit-identical, not just close."""
    gpu, dla = engines
    yolo = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    for mode in ("padded", "cropping"):
        g = Pix2PixGenerator(Pix2PixConfig(deconv_mode=mode)).layer_graph()
        for a, b in ((g, g), (g, yolo)):
            ref = core.haxconn_schedule(a, b, dla, gpu)
            plan = nmodel_schedule([a, b], [dla, gpu])
            assert plan.partitions == [ref.p_a, ref.p_b], (mode, a.model_name, b.model_name)
            assert plan.cycle_time == ref.schedule.cycle_time
            # per-engine occupancy matches the two-phase accounting too
            assert plan.engine_times["DLA"] == ref.phase["constrained"]
            assert plan.engine_times["GPU"] == ref.phase["flexible"]


def test_nmodel_three_models_schedule_is_consistent(engines):
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()
    plan = nmodel_schedule([g, g, g], [dla, gpu])  # search space > exhaustive limit
    assert len(plan.partitions) == 3
    for p, route in zip(plan.partitions, plan.routes):
        assert 0 < p < len(g)
        assert route.segments[0][2] == p and route.segments[-1][2] == len(g)
    assert plan.cycle_time == pytest.approx(max(plan.engine_times.values()))
    # three concurrent instances should out-serve one standalone instance
    solo = core.standalone_schedule(g, dla, gpu)
    assert plan.schedule.aggregate_fps > 1.0 / solo.cycle_time


def test_nmodel_fixed_partitions_respected(engines):
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()
    plan = nmodel_schedule([g, g], [dla, gpu], fixed=(4, 53))
    assert plan.partitions == [4, 53]
    ref = core.haxconn_schedule(g, g, dla, gpu, fixed=(4, 53))
    assert plan.cycle_time == ref.schedule.cycle_time


# ---- executor --------------------------------------------------------------


def _plan_and_streams(sm_pix, sm_yolo, engines, n_pix=2, n_yolo=1):
    gpu, dla = engines
    plan = nmodel_schedule([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    streams = [StreamSpec(f"mri-{i}", 0) for i in range(n_pix)] + [
        StreamSpec(f"det-{i}", 1) for i in range(n_yolo)
    ]
    return plan, streams


def _assert_outputs_bit_exact(outs, frames, sm_pix, sm_yolo, streams):
    for s in streams:
        sm = sm_pix if s.model_index == 0 else sm_yolo
        assert len(outs[s.name]) == len(frames[s.name])
        for f, o in zip(frames[s.name], outs[s.name]):
            ref = sm.run_all(f)
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(o)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_outputs_close(outs, frames, sm_pix, sm_yolo, streams, atol=2e-3, rtol=1e-2):
    """Tolerance pin for the default jitted path: fusion reassociates f32
    reductions; sub-1e-3 abs drift is the observed ceiling on these
    32x32 models."""
    for s in streams:
        sm = sm_pix if s.model_index == 0 else sm_yolo
        assert len(outs[s.name]) == len(frames[s.name])
        for f, o in zip(frames[s.name], outs[s.name]):
            ref = sm.run_all(f)
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(o)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


def test_executor_bit_exact_three_streams(staged_pair, engines):
    """3 concurrent streams through the planned routes produce outputs
    bit-exact vs StagedModel.run_all, in per-stream submission order
    (eager segment path — the pure-re-orchestration pin)."""
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    ex = StreamExecutor([sm_pix, sm_yolo], plan, streams, max_queue=8, jit_segments=False)
    frames = {
        s.name: [jax.random.normal(jax.random.key(10 * i + t), (1, 32, 32, 3)) for t in range(3)]
        for i, s in enumerate(streams)
    }
    for t in range(3):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
    outs = ex.run_until_drained()
    _assert_outputs_bit_exact(outs, frames, sm_pix, sm_yolo, streams)
    # double buffering: interior ticks keep both engines occupied
    ticks = {}
    for e in ex.log:
        ticks.setdefault(e.tick, set()).add(e.engine)
    interior = [t for t in ticks if 0 < t < max(ticks)]
    assert interior and all(ticks[t] == {"DLA", "GPU"} for t in interior)


def test_executor_microbatch_admits_groups_and_stays_exact(staged_pair, engines):
    """microbatch=2 admits both Pix2Pix streams in one tick (one engine
    switch per group) without changing any frame's math."""
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    ex = StreamExecutor(
        [sm_pix, sm_yolo], plan, streams, max_queue=8, microbatch=2, jit_segments=False
    )
    frames = {
        s.name: [jax.random.normal(jax.random.key(7 * i + t), (1, 32, 32, 3)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    for t in range(2):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
    outs = ex.run_until_drained()
    _assert_outputs_bit_exact(outs, frames, sm_pix, sm_yolo, streams)
    # both pix streams admitted at tick 0 (grouped), not serialized over ticks
    tick0_admissions = [e.work for e in ex.log if e.tick == 0 and e.work.endswith("#f0")]
    assert sum(w.startswith(sm_pix.name) for w in tick0_admissions) == 2


def _toy_staged(n_layers=4, scale=2.0):
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * scale + 1.0}) for i in range(n_layers)]
    graph = LayerGraph(
        "toy", [pointwise_meta(i, f"mul{i}", "act", (1, 8)) for i in range(n_layers)]
    ).renumber()
    return StagedModel(
        name="toy",
        ops=ops,
        params=None,
        graph=graph,
        init_state=lambda x: {"x": x},
        finalize=lambda s: s["x"],
    )


def test_executor_merge_batches_elementwise_model():
    """Array-level merging is exact for batch-independent models."""
    sm = _toy_staged()
    routes = [ModelRoute("toy", 2, [(0, 0, 2), (1, 2, 4)])]
    streams = [StreamSpec("s0", 0), StreamSpec("s1", 0)]
    ex = StreamExecutor([sm], routes, streams, max_queue=4, microbatch=2, merge_batches=True)
    frames = {s.name: [jnp.full((1, 8), float(i + t)) for t in range(2)] for i, s in enumerate(streams)}
    for t in range(2):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
    outs = ex.run_until_drained()
    for s in streams:
        for f, o in zip(frames[s.name], outs[s.name]):
            np.testing.assert_array_equal(np.asarray(sm.run_all(f)), np.asarray(o))
    # merged flights really ran as one group: first tick logs one segment
    # covering both streams' frames
    merged = [e for e in ex.log if e.tick == 0]
    assert len(merged) == 1 and "#f0,0" in merged[0].work


def test_backpressure_caps_queue_depth():
    sm = _toy_staged()
    routes = [ModelRoute("toy", 2, [(0, 0, 2), (1, 2, 4)])]
    ex = StreamExecutor([sm], routes, [StreamSpec("s0", 0)], max_queue=2)
    accepted = [ex.submit(0, jnp.ones((1, 8)) * t) for t in range(6)]
    assert accepted == [True, True, False, False, False, False]
    assert ex.queues[0].high_water == 2
    assert ex.queues[0].rejected == 4
    ex.tick()  # one admission frees one slot
    assert ex.submit(0, jnp.ones((1, 8)))
    assert ex.queues[0].high_water == 2  # bound never exceeded
    ex.run_until_drained()
    assert len(ex.outputs["s0"]) == 3


def test_frame_queue_contract():
    q = FrameQueue(2)
    assert q.push(1) and q.push(2) and not q.push(3)
    assert len(q) == 2 and q.full and q.rejected == 1
    assert q.pop() == 1 and not q.full
    with pytest.raises(ValueError):
        FrameQueue(0)


# ---- dispatch modes --------------------------------------------------------


def _run_executor(sm_pix, sm_yolo, plan, streams, frames, **kw):
    ex = StreamExecutor([sm_pix, sm_yolo], plan, streams, max_queue=8, **kw)
    for t in range(len(next(iter(frames.values())))):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
    outs = ex.run_until_drained()
    return ex, outs


def test_overlapped_matches_serialized_bit_exact(staged_pair, engines):
    """Overlapped dispatch is a pure re-orchestration: outputs identical to
    the per-segment-synchronized path (both default to the same jitted
    segment executables, so the comparison stays bit-exact); vs the eager
    monolithic models the default path holds the fusion tolerance pin."""
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    frames = {
        s.name: [jax.random.normal(jax.random.key(13 * i + t), (1, 32, 32, 3)) for t in range(3)]
        for i, s in enumerate(streams)
    }
    _, outs_ser = _run_executor(sm_pix, sm_yolo, plan, streams, frames, dispatch="serialized")
    ex_ovl, outs_ovl = _run_executor(sm_pix, sm_yolo, plan, streams, frames, dispatch="overlapped")
    for s in streams:
        for a, b in zip(outs_ser[s.name], outs_ovl[s.name]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # vs the monolithic eager models: tolerance pin (jit default)
    _assert_outputs_close(outs_ovl, frames, sm_pix, sm_yolo, streams)
    # per-tick overlap stats were recorded and are sane
    assert len(ex_ovl.tick_stats) == ex_ovl.tick_count
    assert all(t.wall_s >= t.blocked_s >= 0 for t in ex_ovl.tick_stats)
    assert 0.0 <= ex_ovl.overlap_efficiency() <= 1.0


def test_jit_segments_default_and_eager_modes_agree(staged_pair, engines):
    """jit_segments defaults to True; the eager opt-out stays bit-exact vs
    run_all and the two paths agree within the fusion tolerance."""
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    ex = StreamExecutor([sm_pix, sm_yolo], plan, streams)
    assert ex.jit_segments is True
    frames = {
        s.name: [jax.random.normal(jax.random.key(31 * i + t), (1, 32, 32, 3)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    _, outs_eager = _run_executor(sm_pix, sm_yolo, plan, streams, frames, jit_segments=False)
    _assert_outputs_bit_exact(outs_eager, frames, sm_pix, sm_yolo, streams)
    _, outs_jit = _run_executor(sm_pix, sm_yolo, plan, streams, frames)
    _assert_outputs_close(outs_jit, frames, sm_pix, sm_yolo, streams)


def test_executor_rejects_unknown_dispatch(staged_pair, engines):
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    with pytest.raises(ValueError):
        StreamExecutor([sm_pix, sm_yolo], plan, streams, dispatch="yolo")


def test_jit_segments_outputs_close(staged_pair, engines):
    """Fused-segment executables may differ in low-order bits (XLA fusion)
    but must stay numerically equivalent to the eager path."""
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    frames = {
        s.name: [jax.random.normal(jax.random.key(29 * i + t), (1, 32, 32, 3)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    _, outs_eager = _run_executor(sm_pix, sm_yolo, plan, streams, frames, jit_segments=False)
    _, outs_jit = _run_executor(sm_pix, sm_yolo, plan, streams, frames, jit_segments=True)
    for s in streams:
        for a, b in zip(outs_eager[s.name], outs_jit[s.name]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                # fusion reassociates f32 reductions; sub-1e-3 abs drift is
                # the observed ceiling on these 32x32 models
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-3, rtol=1e-2)


# ---- batch-independent merging --------------------------------------------


def test_merge_batches_instance_norm_pix2pix(engines):
    """Instance-norm Pix2Pix is batch-independent, so merged micro-batches
    leave every frame's outputs unchanged vs the monolithic model."""
    from repro.serve import merge_flags_for

    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping", norm="instance")
    gen = Pix2PixGenerator(cfg)
    sm_pix = core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    sm_yolo = core.yolo_staged(ycfg, ym.init(jax.random.key(1)))
    assert merge_flags_for([sm_pix, sm_yolo]) == [True, False]
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines)
    ex = StreamExecutor(
        [sm_pix, sm_yolo],
        plan,
        streams,
        max_queue=8,
        microbatch=2,
        merge_batches=merge_flags_for([sm_pix, sm_yolo]),
    )
    frames = {
        s.name: [jax.random.normal(jax.random.key(17 * i + t), (1, 32, 32, 3)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    for t in range(2):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
    outs = ex.run_until_drained()
    # default jitted path: fusion tolerance pin vs the monolithic models
    for s in streams:
        sm = sm_pix if s.model_index == 0 else sm_yolo
        for f, o in zip(frames[s.name], outs[s.name]):
            for la, lb in zip(jax.tree.leaves(sm.run_all(f)), jax.tree.leaves(o)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-3, rtol=1e-2)
    # the two pix streams really ran merged: a tick-0 segment covers both
    merged = [e for e in ex.log if e.tick == 0 and "#f0,0" in e.work]
    assert merged, "expected a merged two-frame flight at tick 0"


# ---- server + metrics ------------------------------------------------------


def test_server_routes_requests_and_reports(staged_pair, engines):
    sm_pix, sm_yolo = staged_pair
    plan, streams = _plan_and_streams(sm_pix, sm_yolo, engines, n_pix=3)
    server = MultiStreamServer([sm_pix, sm_yolo], plan, streams, max_queue=2)
    for t in range(6):
        server.submit(0, jax.random.normal(jax.random.key(t), (1, 32, 32, 3)))
    server.submit(1, jax.random.normal(jax.random.key(99), (1, 32, 32, 3)))
    server.drain()
    rep = server.report()
    assert rep["frames"] == 7
    assert rep["aggregate_fps"] > 0
    assert rep["latency_p50_ms"] <= rep["latency_p99_ms"]
    # least-loaded assignment spreads the pix frames over all three streams
    per_pix = [rep["per_stream"][f"mri-{i}"]["completed"] for i in range(3)]
    assert sum(per_pix) == 6 and all(c >= 1 for c in per_pix)
    assert rep["per_stream"]["det-0"]["completed"] == 1
    # queue bound held under pressure
    assert all(q.high_water <= 2 for q in server.executor.queues)


def test_percentile_nearest_rank():
    xs = [float(i) for i in range(1, 101)]
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    assert percentile([3.0], 50) == 3.0
    assert np.isnan(percentile([], 50))
