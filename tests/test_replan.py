"""The online re-planning runtime: plan IR, live cost feedback, hot-swap.

Pins the new spine contracts: (a) every scheduler emits a valid typed
``PlanIR`` and the executor consumes only the IR, (b) a mid-stream plan
hot-swap preserves frame ordering and output equality vs an unswapped
run with zero dropped frames (in-flight frames finish on their admitted
routes), (c) ``OnlineCost`` is a magnitude-weighted calibration that
noise on near-empty spans cannot swing, and (d) the drift detector fires
under a sustained injected cost perturbation and stays quiet (hysteresis)
under transient noise."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.cost_model import ANALYTIC, OnlineCost
from repro.core.engine import EngineSpec, jetson_orin_engines
from repro.core.graph import LayerGraph, pointwise_meta
from repro.core.pipeline import StagedModel
from repro.core.plan_ir import PlanIR, PlanSegment, ir_from_routes, make_plan_ir
from repro.core.scheduler import ModelRoute, nmodel_schedule
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import ReplanConfig, Replanner, StreamExecutor, StreamSpec
from repro.serve.executor import SegmentObservation


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def staged_pair():
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    sm_pix = core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    sm_yolo = core.yolo_staged(ycfg, ym.init(jax.random.key(1)))
    return sm_pix, sm_yolo


def _toy_staged(n_layers=6, name="toy", flops=1e9):
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * 1.5 + 0.5}) for i in range(n_layers)]
    graph = LayerGraph(
        name,
        [pointwise_meta(i, f"mul{i}", "act", (1, 64), flops_per_elem=flops / 64) for i in range(n_layers)],
    ).renumber()
    return StagedModel(
        name=name,
        ops=ops,
        params=None,
        graph=graph,
        init_state=lambda x: {"x": x},
        finalize=lambda s: s["x"],
    )


def _toy_engines():
    e0 = EngineSpec("E0", 1, 1.0e12, 500e9, 50e9, ())
    e1 = EngineSpec("E1", 1, 1.0e12, 500e9, 50e9, ())
    return [e0, e1]


# ---- PlanIR ----------------------------------------------------------------


def test_plan_ir_validation_rejects_malformed():
    ok = make_plan_ir(("m",), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    assert ok.partitions == [3] and ok.n_layers == (6,)
    with pytest.raises(ValueError):  # gap
        make_plan_ir(("m",), ("E0",), [[(0, 0, 3), (0, 4, 6)]])
    with pytest.raises(ValueError):  # does not start at 0
        make_plan_ir(("m",), ("E0",), [[(0, 1, 6)]])
    with pytest.raises(ValueError):  # empty span
        make_plan_ir(("m",), ("E0",), [[(0, 0, 0)]])
    with pytest.raises(ValueError):  # unknown engine
        make_plan_ir(("m",), ("E0",), [[(3, 0, 6)]])
    with pytest.raises(ValueError):  # routes != models
        PlanIR(models=("a", "b"), engine_names=("E0",), segments=((PlanSegment(0, 0, 0, 0, 6),),))
    with pytest.raises(ValueError):  # coverage mismatch vs the staged model
        ok.validate_against([7])
    ok.validate_against([6])


def test_plan_ir_json_roundtrip_and_revision():
    ir = make_plan_ir(
        ("a", "b"),
        ("DLA", "GPU"),
        [[(0, 0, 2, 1e-3), (1, 2, 5, 2e-3)], [(1, 0, 3, 0.5e-3), (0, 3, 4, 0.1e-3)]],
        expected_cycle=3e-3,
        cost_provider="analytic",
        search="beam",
        kind="nmodel",
    )
    back = PlanIR.from_json(ir.to_json())
    assert back == ir
    assert ir.with_revision(3).revision == 3
    assert ir.partitions == [2, 3]
    assert [s.lo for s in ir.engine_spans(0)] == [0, 3]
    assert "DLA" in ir.describe()


def test_ir_from_routes_legacy_adapter():
    routes = [ModelRoute("toy", 2, [(0, 0, 2), (1, 2, 6)])]
    ir = ir_from_routes(routes, engine_names=["con", "flex"])
    assert ir.models == ("toy",)
    assert ir.engine_names == ("con", "flex")
    assert ir.partitions == [2]


def test_every_scheduler_emits_ir(engines):
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()
    y = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    plan = nmodel_schedule([g, y], [dla, gpu])
    assert plan.ir.kind == "nmodel" and plan.ir.partitions == plan.partitions
    assert plan.ir.expected_cycle == plan.cycle_time
    assert plan.ir.engine_names == ("DLA", "GPU")
    hx = core.haxconn_schedule(g, y, dla, gpu)
    assert hx.ir.kind == "haxconn" and hx.ir.partitions == [hx.p_a, hx.p_b]
    alone = core.standalone_schedule(g, dla, gpu)
    assert alone.ir.kind == "standalone" and alone.ir.n_layers == (len(g),)
    naive = core.naive_schedule(g, y, dla, gpu)
    assert naive.ir.kind == "naive" and naive.ir.n_layers == (len(g), len(y))
    for ir in (plan.ir, hx.ir, alone.ir, naive.ir):
        ir.validate_against(list(ir.n_layers))


def test_executor_consumes_ir_directly():
    sm = _toy_staged()
    ir = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    ex = StreamExecutor([sm], ir, [StreamSpec("s0", 0)], max_queue=4)
    assert ex.plan is ir and ex.plan_revision == 0
    assert ex.submit(0, jnp.ones((1, 64)))
    outs = ex.run_until_drained()
    np.testing.assert_array_equal(np.asarray(outs["s0"][0]), np.asarray(sm.run_all(jnp.ones((1, 64)))))


# ---- hot swap --------------------------------------------------------------


def test_hot_swap_mid_stream_preserves_order_and_outputs():
    """Swap while frames are in flight: zero drops, per-stream FIFO order,
    outputs bit-exact vs an unswapped run (eager segments), and in-flight
    frames finish on the route they were admitted under."""
    sm = _toy_staged()
    ir_a = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    ir_b = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 1), (1, 1, 6)]])
    streams = [StreamSpec("s0", 0), StreamSpec("s1", 0)]
    frames = {s.name: [jnp.full((1, 64), float(3 * i + t)) for t in range(4)] for i, s in enumerate(streams)}

    def run(swap_at=None):
        ex = StreamExecutor([sm], ir_a, streams, max_queue=8, jit_segments=False)
        for t in range(4):
            for i, s in enumerate(streams):
                assert ex.submit(i, frames[s.name][t])
        ticks = 0
        while ex.pending:
            if swap_at is not None and ticks == swap_at:
                assert ex.in_flight, "swap must happen with frames in flight"
                ex.swap_plan(ir_b)
            ex.tick()
            ticks += 1
        return ex

    ex_plain = run()
    ex_swap = run(swap_at=2)
    assert ex_swap.plan_revision == 1
    assert [e.revision for e in ex_swap.swap_events] == [1]
    # zero drops + identical outputs in identical per-stream order
    for s in streams:
        assert len(ex_swap.outputs[s.name]) == len(frames[s.name])
        for a, b in zip(ex_plain.outputs[s.name], ex_swap.outputs[s.name]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for s in streams:
        fids = [c.frame_id for c in ex_swap.completions if c.stream == s.name]
        assert fids == sorted(fids)
    # in-flight frames at the swap finished on the old [0:3)/[3:6) spans;
    # post-swap admissions took the new [0:1)/[1:6) spans
    spans = [e.work.split("[")[1].split(")")[0] for e in ex_swap.log if "[" in e.work]
    assert any(sp == "3:6" for sp in spans) and any(sp == "1:6" for sp in spans)


def test_hot_swap_pix_models_tolerance(staged_pair, engines):
    """Same mid-stream swap on the real serving pair under the default
    jitted path: outputs within the fusion tolerance of the unswapped run."""
    sm_pix, sm_yolo = staged_pair
    gpu, dla = engines
    plan = nmodel_schedule([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    p0, p1 = plan.partitions
    alt = nmodel_schedule(
        [sm_pix.graph, sm_yolo.graph], [dla, gpu], fixed=(max(1, p0 + 10), max(1, p1 // 2))
    )
    streams = [StreamSpec("mri-0", 0), StreamSpec("det-0", 1)]
    frames = {
        s.name: [jax.random.normal(jax.random.key(41 * i + t), (1, 32, 32, 3)) for t in range(3)]
        for i, s in enumerate(streams)
    }

    def run(swap):
        ex = StreamExecutor([sm_pix, sm_yolo], plan, streams, max_queue=8)
        for t in range(3):
            for i, s in enumerate(streams):
                assert ex.submit(i, frames[s.name][t])
        ex.tick()
        if swap:
            warmed = ex.prepare_plan(alt.ir)
            assert warmed > 0  # stage-0 shapes were seen, so warmup ran
            ex.swap_plan(alt.ir)
        ex.run_until_drained()
        return ex

    ex_plain, ex_swap = run(False), run(True)
    assert ex_swap.plan.partitions == alt.partitions
    for s in streams:
        for a, b in zip(ex_plain.outputs[s.name], ex_swap.outputs[s.name]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-3, rtol=1e-2)


def test_swap_plan_rejects_mismatched_models():
    sm = _toy_staged()
    ir = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    ex = StreamExecutor([sm], ir, [StreamSpec("s0", 0)])
    with pytest.raises(ValueError):
        ex.swap_plan(make_plan_ir(("other",), ("E0", "E1"), [[(0, 0, 6)]]))
    with pytest.raises(ValueError):  # wrong layer coverage
        ex.swap_plan(make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 5)]]))
    assert ex.prepare_plan(ir) == 0  # no frame seen yet -> nothing to warm


# ---- per-segment observation ----------------------------------------------


def test_profiled_ticks_emit_segment_observations():
    sm = _toy_staged()
    ir = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    ex = StreamExecutor([sm], ir, [StreamSpec("s0", 0)], profile_every=1)
    seen = []
    ex.on_segment = seen.append
    for t in range(3):
        ex.submit(0, jnp.ones((1, 64)) * t)
    ex.run_until_drained()
    assert ex.segment_obs and seen == ex.segment_obs
    for o in ex.segment_obs:
        assert o.wall_s > 0 and (o.lo, o.hi) in ((0, 3), (3, 6))
        assert o.revision == 0 and o.batch == 1


# ---- OnlineCost ------------------------------------------------------------


def test_online_cost_weighted_calibration(engines):
    gpu, _ = engines
    oc = OnlineCost(ANALYTIC, alpha=0.5)
    layer = pointwise_meta(0, "x", "act", (1, 1024), flops_per_elem=1e6)
    base = ANALYTIC.layer_time(layer, gpu)
    assert oc.scale("GPU") == 1.0 and oc.layer_time(layer, gpu) == base
    for _ in range(20):
        oc.observe("GPU", 2e-3, 1e-3)  # heavyweight samples: 2x
    assert oc.scale("GPU") == pytest.approx(2.0)
    # near-empty spans with absurd per-sample ratios (pure host overhead)
    # interleaved with the heavyweight samples barely move the weighted
    # scale — a ratio-of-EMAs would have exploded toward 1e6
    for _ in range(10):
        oc.observe("GPU", 1e-4, 1e-9)  # ratio 1e5 but negligible magnitude
        oc.observe("GPU", 2e-3, 1e-3)
    assert oc.scale("GPU") == pytest.approx(2.0, rel=0.2)
    assert oc.layer_time(layer, gpu) == pytest.approx(base * oc.scale("GPU"))
    assert oc.available(layer) == ANALYTIC.available(layer)
    with pytest.raises(ValueError):
        oc.save()  # analytic base has no timing cache
    with pytest.raises(ValueError):
        OnlineCost(alpha=0.0)


def test_make_cost_provider_online():
    from repro.core.cost_model import make_cost_provider

    oc = make_cost_provider("online")
    assert oc.name == "online" and oc.base.name == "blended"


# ---- drift detector + replan loop ------------------------------------------


def _toy_serving(delay=None, config=None):
    sm = _toy_staged(n_layers=8)
    engines = _toy_engines()
    plan = nmodel_schedule([sm.graph], engines)
    rp = Replanner([sm.graph], engines, config or ReplanConfig())
    ex = StreamExecutor([sm], plan, [StreamSpec("s0", 0)], max_queue=8, segment_delay_fn=delay)
    return sm, engines, plan, rp, ex


def _feed(rp, ex, walls):
    """Feed one synthetic profiled tick ({engine_index: wall_s}) and step.
    The single toy model's stage index equals its engine index."""
    for eng, wall in walls.items():
        seg = ex.plan.route(0)[eng]
        rp.observe(
            SegmentObservation(
                tick=ex.tick_count, model_index=0, stage=seg.stage, engine=seg.engine,
                lo=seg.lo, hi=seg.hi, wall_s=wall, batch=1, revision=ex.plan_revision,
            )
        )
    return rp.maybe_replan(ex)


def test_drift_detector_fires_under_sustained_skew():
    cfg = ReplanConfig(drift_threshold=0.5, hysteresis=3, cooldown_ticks=2, warmup_obs=2, min_improvement=0.01)
    sm, engines, plan, rp, ex = _toy_serving(config=cfg)
    e0 = rp._expected_base(0, 0, *plan.ir.route(0)[0].span)
    e1 = rp._expected_base(0, 1, *plan.ir.route(0)[1].span)
    # calibration: both engines run at 100x their analytic speed estimate
    for _ in range(4):
        assert _feed(rp, ex, {0: 100 * e0, 1: 100 * e1}) is None
    assert rp.calibrated
    base_drift = max(rp.drift().values())
    assert base_drift == pytest.approx(0.0, abs=1e-6)
    # engine 0 suddenly runs 4x slower: fires after `hysteresis` ticks
    events = []
    for k in range(cfg.hysteresis + 1):
        ev = _feed(rp, ex, {0: 400 * e0, 1: 100 * e1})
        if ev:
            events.append(ev)
    assert len(events) == 1
    ev = events[0]
    assert ev.drift["E0"] > cfg.drift_threshold
    assert ev.swapped  # moving work off E0 predicts a better cycle
    assert ex.plan_revision == 1
    assert ev.new_partitions != ev.old_partitions
    # the new plan puts less work on the slowed engine
    old_e0 = sum(s.hi - s.lo for s in plan.ir.engine_spans(0))
    new_e0 = sum(s.hi - s.lo for s in ex.plan.engine_spans(0))
    assert new_e0 < old_e0


def test_drift_detector_quiet_under_transient_noise():
    cfg = ReplanConfig(
        drift_threshold=0.5, hysteresis=3, cooldown_ticks=2, warmup_obs=2, ema_alpha=0.5
    )
    sm, engines, plan, rp, ex = _toy_serving(config=cfg)
    e0 = rp._expected_base(0, 0, *plan.ir.route(0)[0].span)
    e1 = rp._expected_base(0, 1, *plan.ir.route(0)[1].span)
    for _ in range(4):
        _feed(rp, ex, {0: 100 * e0, 1: 100 * e1})
    assert rp.calibrated
    # transient spikes with quiet ticks in between: the EMA decays below
    # the threshold before the hysteresis count fills, so it never fires
    for _ in range(5):
        assert _feed(rp, ex, {0: 400 * e0, 1: 100 * e1}) is None  # spike...
        for _ in range(3):
            assert _feed(rp, ex, {0: 100 * e0, 1: 100 * e1}) is None  # ...decay
    assert rp.events == [] and ex.plan_revision == 0


def test_replan_loop_end_to_end_recovers_partitions():
    """Full loop with real executor ticks: a sustained injected slowdown on
    one engine triggers a swap that shifts layers off it, with zero
    dropped frames."""
    sm = _toy_staged(n_layers=10, name="toy10")
    engines_t = _toy_engines()
    plan = nmodel_schedule([sm.graph], engines_t)
    pert = {"on": False}

    def delay(seg):
        # engine 1 suddenly stalls ~1ms per carried layer
        return 1e-3 * (seg.hi - seg.lo) if pert["on"] and seg.engine == 1 else 0.0

    cfg = ReplanConfig(
        drift_threshold=1.0, hysteresis=2, cooldown_ticks=4, profile_every=1,
        ema_alpha=0.5, min_improvement=0.01,
    )
    rp = Replanner([sm.graph], engines_t, cfg)
    ex = StreamExecutor([sm], plan, [StreamSpec("s0", 0)], max_queue=8, segment_delay_fn=delay)
    rp.attach(ex)
    submitted = 0

    def window(n, seed):
        nonlocal submitted
        for t in range(n):
            assert ex.submit(0, jnp.ones((1, 64)) * (seed + t))
            ex.tick()
            submitted += 1
        ex.run_until_drained()

    window(10, 0)
    rp.calibrate()
    window(6, 100)
    pert["on"] = True
    window(30, 200)
    assert any(e.swapped for e in rp.events), rp.summary()
    old_e1 = sum(s.hi - s.lo for s in plan.ir.engine_spans(1))
    new_e1 = sum(s.hi - s.lo for s in ex.plan.engine_spans(1))
    assert new_e1 < old_e1  # work moved off the stalled engine
    assert len(ex.completions) == submitted  # zero drops
    assert len(ex.outputs["s0"]) == submitted


def test_replanner_summary_and_config_validation():
    sm, engines, plan, rp, ex = _toy_serving()
    rp.attach(ex)
    s = rp.summary()
    assert s["replans"] == 0 and s["swaps"] == 0 and not s["calibrated"]
    with pytest.raises(ValueError):
        Replanner([sm.graph], _toy_engines()[:1]).attach(ex)  # engine count mismatch


def test_schedule_dataclass_still_serializable(engines):
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(img_size=16, base=4, deconv_mode="cropping")).layer_graph()
    plan = nmodel_schedule([g, g], [dla, gpu])
    d = dataclasses.asdict(plan.schedule)
    assert d["ir"]["models"] == (g.model_name, g.model_name)
