"""Unit tests for the nn layer: decode==full-forward consistency for every
attention/SSM flavour, module system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn


@pytest.fixture
def key():
    return jax.random.key(0)


def test_linear_shapes_and_axes(key):
    lin = nn.Linear(16, 32)
    p = lin.init(key)
    assert lin(p, jnp.ones((2, 16))).shape == (2, 32)
    assert lin.axes() == {"w": ("embed", "mlp")}
    ab = lin.abstract()
    assert ab["w"].shape == (16, 32)


def test_stacked_params(key):
    st = nn.Stacked(nn.Linear(8, 8), 4)
    p = st.init(key)
    assert p["w"].shape == (4, 8, 8)
    assert st.axes()["w"] == ("layers", "embed", "mlp")
    # stacked layers must differ (independent rng per layer)
    assert not np.allclose(p["w"][0], p["w"][1])


def test_rmsnorm_unit_scale(key):
    norm = nn.RMSNorm(64)
    p = norm.init(key)
    x = jax.random.normal(key, (4, 64)) * 10
    y = norm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    assert np.allclose(rms, 1.0, atol=1e-3)


def _decode_matches_forward(attn, p, x, window=None, atol=2e-4):
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = attn(p, x, pos, window=window)
    cache = attn.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = attn.decode(p, x[:, t : t + 1], cache, t, window=window)
        outs.append(y)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.float32(full), np.float32(dec), atol=atol)


def test_gqa_decode_matches(key):
    attn = nn.Attention(64, 8, 2, 16)
    _decode_matches_forward(attn, attn.init(key), jax.random.normal(key, (2, 8, 64)))


def test_gqa_softcap_window_decode_matches(key):
    attn = nn.Attention(64, 4, 1, 16, softcap=30.0)
    _decode_matches_forward(attn, attn.init(key), jax.random.normal(key, (2, 8, 64)), window=3)


def test_ring_buffer_cache_matches(key):
    """Window-sized (ring) cache must equal full-cache attention."""
    attn = nn.Attention(32, 4, 2, 8)
    p = attn.init(key)
    x = jax.random.normal(key, (1, 10, 32))
    pos = jnp.arange(10)[None]
    full = attn(p, x, pos, window=4)
    cache = attn.init_cache(1, 4, dtype=jnp.float32)  # ring = window size
    outs = []
    for t in range(10):
        y, cache = attn.decode(p, x[:, t : t + 1], cache, t, window=4)
        outs.append(y)
    np.testing.assert_allclose(np.float32(full), np.float32(jnp.concatenate(outs, 1)), atol=2e-4)


def test_mla_decode_and_absorb_match(key):
    mla = nn.MLAAttention(64, 4, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    p = mla.init(key)
    x = jax.random.normal(key, (2, 8, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    full = mla(p, x, pos)
    for absorb in (False, True):
        m2 = nn.MLAAttention(64, 4, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, absorb=absorb)
        cache = m2.init_cache(2, 8, dtype=jnp.float32)
        outs = []
        for t in range(8):
            y, cache = m2.decode(p, x[:, t : t + 1], cache, t)
            outs.append(y)
        np.testing.assert_allclose(np.float32(full), np.float32(jnp.concatenate(outs, 1)), atol=2e-4)


def test_chunked_attention_matches_dense(key):
    dense = nn.Attention(32, 4, 2, 8, attn_chunk=0)
    chunked = nn.Attention(32, 4, 2, 8, attn_chunk=4)
    p = dense.init(key)
    x = jax.random.normal(key, (2, 16, 32))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    np.testing.assert_allclose(
        np.float32(dense(p, x, pos, window=6)), np.float32(chunked(p, x, pos, window=6)), atol=2e-4
    )


def test_ssd_chunked_vs_naive_recurrence(key):
    b, s, h, p_, g, n = 2, 16, 4, 8, 2, 8
    x = jax.random.normal(key, (b, s, h, p_))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.key(2), (h,)))
    B = jax.random.normal(jax.random.key(3), (b, s, g, n))
    C = jax.random.normal(jax.random.key(4), (b, s, g, n))
    state = jnp.zeros((b, h, p_, n))
    Bh, Ch = jnp.repeat(B, h // g, 2), jnp.repeat(C, h // g, 2)
    ys = []
    for t in range(s):
        y, state = nn.ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    naive = jnp.stack(ys, 1)
    for chunk in (4, 8, 16, 5):  # incl. non-divisible (padding path)
        out = nn.ssd_chunked(x, dt, A, B, C, chunk=chunk)
        np.testing.assert_allclose(np.float32(out), np.float32(naive), atol=1e-4)


def test_mamba_block_decode_matches(key):
    mb = nn.Mamba2Block(32, d_state=16, head_dim=8, chunk=4)
    p = mb.init(key)
    x = jax.random.normal(key, (2, 8, 32))
    full = mb(p, x)
    cache = mb.init_cache(2, dtype=jnp.float32)
    outs = []
    for t in range(8):
        y, cache = mb.decode(p, x[:, t : t + 1], cache)
        outs.append(y)
    np.testing.assert_allclose(np.float32(full), np.float32(jnp.concatenate(outs, 1)), atol=2e-3)


def test_mrope_reduces_to_rope_for_text(key):
    x = jax.random.normal(key, (2, 6, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 6, 3))
    a = nn.apply_rope(x, pos)
    b = nn.apply_mrope(x, pos3, (3, 3, 2))
    np.testing.assert_allclose(np.float32(a), np.float32(b), atol=1e-5)


def test_conv_transpose_torch_semantics(key):
    # out = stride*(in-1) + k - 2*pad
    d = nn.ConvTranspose2D(3, 5, 4, 2, padding=1)
    p = d.init(key)
    assert d(p, jnp.ones((1, 8, 8, 3))).shape == (1, 16, 16, 5)
    d0 = nn.ConvTranspose2D(3, 5, 4, 2, padding=0)
    assert d0(d0.init(key), jnp.ones((1, 8, 8, 3))).shape == (1, 18, 18, 5)
