"""Fleet serving tests: DevicePool device binding, the sticky load-aware
FleetRouter, fleet/single bit-exactness, merged fleet metrics, the shared
thread-safe OnlineCost, and the 2-replica >= 1-replica goodput pin."""
import math
import threading

import jax
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.cost_model import OnlineCost
from repro.core.engine import DevicePool, jetson_orin_engines
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import (
    FleetRouter,
    FleetServer,
    MultiStreamServer,
    StreamSpec,
    TrafficConfig,
    build_server,
)
from repro.serve.metrics import router_imbalance


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def staged_pair():
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    sm_pix = core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    sm_yolo = core.yolo_staged(ycfg, ym.init(jax.random.key(1)))
    return sm_pix, sm_yolo


# ---- DevicePool ------------------------------------------------------------


def test_device_pool_single_device_fallback(engines):
    """On a 1-device host every replica binds the full virtual engine pair
    to that device and placement collapses to identity."""
    gpu, dla = engines
    pool = DevicePool((dla, gpu))
    assert pool.n_devices >= 1
    if pool.n_devices == 1:
        assert pool.replica_devices(0, 2) == pool.replica_devices(1, 2)
        fns = pool.place_fns(0, 2)
        tree = {"x": jax.numpy.ones((2, 2))}
        for fn in fns:
            assert fn(tree) is tree  # identity, no device_put overhead
    for r in range(3):
        assert len(pool.replica_devices(r, 3)) >= 1


def test_device_pool_discover_defaults():
    pool = DevicePool.discover()
    assert len(pool.engines) == 2
    assert [e.name for e in pool.engines] == ["DLA", "GPU"]


def test_engine_slice_binds_devices_without_changing_identity(engines):
    """Bound specs plan identically to the abstract pair: ``device`` is
    excluded from EngineSpec equality/hash, so one plan serves every
    replica slice."""
    gpu, dla = engines
    pool = DevicePool((dla, gpu))
    sliced = pool.engine_slice(0, 2)
    assert list(sliced) == [dla, gpu]
    assert all(e.device is not None for e in sliced)
    assert hash(sliced[0]) == hash(dla)
    assert dla.bound(None) == dla


def test_device_pool_validates_inputs(engines):
    gpu, dla = engines
    with pytest.raises(ValueError):
        DevicePool(())
    with pytest.raises(ValueError):
        DevicePool((dla, gpu), devices=[])


# ---- FleetRouter -----------------------------------------------------------


def test_router_seeded_determinism():
    arrivals = [f"s{i % 6}" for i in range(40)]
    results = []
    for _ in range(2):
        r = FleetRouter(3, seed=11)
        loads = [0, 0, 0]
        routed = []
        for name in arrivals:
            rep = r.route_arrival(name, loads, deadline_s=0.1)
            loads[rep] += 1
            if len(routed) % 5 == 4:  # periodic service drains the queues
                loads = [0, 0, 0]
            routed.append(rep)
        results.append((routed, dict(r.assignments), list(r.routed_frames)))
    assert results[0] == results[1]


def test_router_sticky_stream_invariant():
    r = FleetRouter(2, seed=0)
    first = r.assign("mri-0", [0, 0], deadline_s=0.05)
    # heavily favor the other replica: the stream must not move
    other_favored = [10**6, 10**6]
    other_favored[1 - first] = 0
    assert r.assign("mri-0", other_favored) == first
    assert r.replica_of("mri-0") == first


def test_router_deadline_pressure_tiebreak():
    r = FleetRouter(2, seed=0)
    a = r.assign("tight-0", [0, 0], deadline_s=0.01)
    b = r.assign("tight-1", [0, 0], deadline_s=0.01)
    assert a != b  # equal loads: accumulated pressure pushes b elsewhere


def test_router_bounded_imbalance_under_bursty_arrivals():
    """Bursts of arrivals over 8 equal-rate streams stay balanced: the
    least-loaded rule bounds max/mean routed frames well under the
    all-on-one worst case."""
    r = FleetRouter(2, seed=3)
    loads = [0, 0]
    for burst in range(10):
        for i in range(8):
            name = f"s{i}"
            for _ in range(3):  # bursty: 3 frames back-to-back per stream
                rep = r.route_arrival(name, loads, deadline_s=0.1)
                loads[rep] += 1
        loads = [0, 0]  # inter-burst drain
    assert router_imbalance(r.routed_frames) <= 1.5
    summ = r.summary()
    assert summ["streams_assigned"] == 8
    assert sum(summ["routed_frames"]) == 10 * 8 * 3


def test_router_validates_and_resets():
    with pytest.raises(ValueError):
        FleetRouter(0)
    r = FleetRouter(2, seed=0)
    r.route_arrival("a", [0, 0])
    r.reset_counts()
    assert r.routed_frames == [0, 0]
    assert r.replica_of("a") is not None  # assignments survive the reset


def test_router_imbalance_metric():
    assert router_imbalance([5, 5]) == 1.0
    assert router_imbalance([10, 0]) == 2.0
    assert router_imbalance([0, 0]) == 1.0
    assert math.isnan(router_imbalance([]))


# ---- fleet vs single executor ----------------------------------------------


def _drive_named(server, streams, frames, n_frames):
    for t in range(n_frames):
        for s in streams:
            server.offer(s.name, frames[s.name][t])
        server.tick()
    return server.drain()


def test_fleet_bit_exact_vs_single_executor(staged_pair, engines):
    """R=2 fleet outputs are bit-exact per stream vs the same seeded
    arrivals through one MultiStreamServer: sticky routing is placement
    only, never a numerics change (shared models -> same compiled
    segment executables on both paths)."""
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    streams = [StreamSpec("mri-0", 0), StreamSpec("mri-1", 0), StreamSpec("det-0", 1)]
    frames = {
        s.name: [jax.random.normal(jax.random.key(10 * i + t), (1, 32, 32, 3)) for t in range(3)]
        for i, s in enumerate(streams)
    }
    fleet = FleetServer(
        [sm_pix, sm_yolo], plan, streams, replicas=2,
        pool=DevicePool((dla, gpu)), max_queue=8,
    )
    single = MultiStreamServer([sm_pix, sm_yolo], plan, streams, max_queue=8)
    fleet_outs = _drive_named(fleet, streams, frames, 3)
    single_outs = _drive_named(single, streams, frames, 3)
    for s in streams:
        assert len(fleet_outs[s.name]) == 3
        for a, b in zip(fleet_outs[s.name], single_outs[s.name]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # every stream stuck to exactly one replica
    assert set(fleet.router.assignments) == {s.name for s in streams}


def test_fleet_report_merges_replica_metrics(staged_pair, engines):
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    streams = [StreamSpec("mri-0", 0), StreamSpec("mri-1", 0), StreamSpec("det-0", 1)]
    frames = {
        s.name: [jax.random.normal(jax.random.key(7 * i + t), (1, 32, 32, 3)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    fleet = FleetServer(
        [sm_pix, sm_yolo], plan, streams, replicas=2,
        pool=DevicePool((dla, gpu)), max_queue=8,
    )
    _drive_named(fleet, streams, frames, 2)
    fleet.finish()
    rep = fleet.report()
    assert rep["replicas"] == 2
    assert rep["frames"] == 6
    assert rep["frames"] == sum(r["frames"] for r in rep["per_replica"])
    assert rep["router_imbalance"] >= 1.0
    assert sum(rep["router"]["routed_frames"]) == 6
    assert rep["dispatch"] == "overlapped"


def test_fleet_closed_loop_submit_balances(staged_pair, engines):
    """Model-index submissions (closed loop) go to the least-loaded
    replica — with symmetric load both replicas end up serving frames."""
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    streams = [StreamSpec("mri-0", 0), StreamSpec("det-0", 1)]
    fleet = FleetServer(
        [sm_pix, sm_yolo], plan, streams, replicas=2,
        pool=DevicePool((dla, gpu)), max_queue=8,
    )
    for t in range(4):
        fleet.submit(0, jax.random.normal(jax.random.key(t), (1, 32, 32, 3)))
        fleet.pump()
    outs = fleet.drain()
    assert sum(len(v) for v in outs.values()) == 4
    assert all(c > 0 for c in fleet.router.routed_frames)


def test_fleet_validates_replicas(staged_pair, engines):
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    streams = [StreamSpec("mri-0", 0), StreamSpec("det-0", 1)]
    with pytest.raises(ValueError):
        FleetServer([sm_pix, sm_yolo], plan, streams, replicas=0)
    with pytest.raises(ValueError):
        FleetServer(
            [sm_pix, sm_yolo], plan, streams, replicas=2,
            pool=DevicePool((dla, gpu)), replanners=[None],
        )


def test_router_sticky_across_plan_hot_swap(staged_pair, engines):
    """A mid-stream ``swap_plan`` on one replica is a routing no-op: the
    swap changes where that replica's future segments run, never which
    replica owns a stream — assignments, per-stream ordering, and frame
    counts are identical before and after the swap."""
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu], max_cuts=1)
    alt = core.plan([sm_pix.graph, sm_yolo.graph], [dla, gpu], max_cuts=2)
    streams = [StreamSpec("mri-0", 0), StreamSpec("mri-1", 0), StreamSpec("det-0", 1)]
    frames = {
        s.name: [jax.random.normal(jax.random.key(13 * i + t), (1, 32, 32, 3)) for t in range(4)]
        for i, s in enumerate(streams)
    }
    fleet = FleetServer(
        [sm_pix, sm_yolo], plan, streams, replicas=2,
        pool=DevicePool((dla, gpu)), max_queue=8,
    )
    _drive_named(fleet, streams, {n: fs[:2] for n, fs in frames.items()}, 2)
    before = dict(fleet.router.assignments)
    assert set(before) == {s.name for s in streams}
    rev = fleet.servers[0].executor.swap_plan(alt)
    assert rev >= 1
    outs = _drive_named(fleet, streams, {n: fs[2:] for n, fs in frames.items()}, 2)
    assert fleet.router.assignments == before  # no stream migrated
    for s in streams:  # post-swap frames of replica 0's streams still served
        assert len(outs[s.name]) == 4
    assert fleet.report()["plan_revision"] == rev


# ---- facade + shared OnlineCost --------------------------------------------


def test_build_server_fleet_shares_one_online_cost():
    bundle = build_server(img=32, n_pix=2, n_yolo=1, replicas=2, replan=True)
    server = bundle.server
    assert isinstance(server, FleetServer)
    assert bundle.replicas == 2
    onlines = [s.replanner.online for s in server.servers]
    assert all(o is onlines[0] for o in onlines)  # one fleet-wide store
    assert bundle.replanner is server.servers[0].replanner


def test_build_server_single_replica_unchanged():
    bundle = build_server(img=32, n_pix=1, n_yolo=1, replicas=1)
    assert isinstance(bundle.server, MultiStreamServer)
    assert bundle.replicas == 1


def test_online_cost_threaded_observe_is_consistent():
    """Concurrent observes from replica executor threads never lose
    updates: the EMA store is lock-guarded."""
    oc = OnlineCost()
    n_threads, n_obs = 4, 200

    def feed(k):
        for i in range(n_obs):
            oc.observe("GPU", observed_s=2.0e-3, expected_s=1.0e-3)
            oc.scale("GPU")

    threads = [threading.Thread(target=feed, args=(k,)) for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every observation agreed on a 2x slowdown: the converged scale must
    # see exactly that, and the snapshot must be readable post-race
    assert oc.scale("GPU") == pytest.approx(2.0, rel=1e-6)
    assert "GPU" in oc.snapshot()


# ---- goodput scaling pin (nightly tier) ------------------------------------


@pytest.mark.slow
def test_fleet_2r_goodput_not_below_1r_same_load():
    """The paper's two-instance scaling claim: at the same total offered
    load (past one replica's capacity), the 2-replica fleet's
    goodput-under-SLO is at least the single replica's. Paired runs,
    up to 3 attempts: a spurious failure needs three independent losses
    on a noisy container, a real regression fails all three."""
    def run(replicas: int) -> float:
        bundle = build_server(
            img=32, n_pix=2, n_yolo=1, deadline_ms=80.0,
            traffic=TrafficConfig(process="poisson", rate_hz=60.0, seed=5),
            admission=True, replicas=replicas,
        )
        server = bundle.server
        for s in bundle.streams:  # warm compiles out of the window
            server.submit(s.model_index, bundle.frame_for(s.name, 0))
        server.drain()
        server.reset_metrics()
        return bundle.run_open_loop(1.0, max_wall_s=120.0)["goodput_fps"]

    pairs = []
    for _ in range(3):
        g1, g2 = run(1), run(2)
        pairs.append((g1, g2))
        if g2 >= g1:
            return
    raise AssertionError(
        f"2-replica goodput below single-replica in all attempts: {pairs}"
    )
