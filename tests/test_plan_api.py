"""The unified ``repro.core.plan`` entry point.

Pins the API-redesign contract: (a) ``plan(...)`` is bit-identical to
each legacy scheduler's ``.ir`` at the same settings, (b) the legacy
names still work but warn ``DeprecationWarning``, (c) ``max_cuts="auto"``
never plans worse than the single-cut budget and records the budget it
chose, and (d) the input adapters (StagedModel graphs, bare single
graph, fine granularity) route to the same searches."""
import jax
import pytest

from repro import core
from repro.core.api import AUTO_CUTS_CEILING
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def graphs():
    g_pix = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()
    g_yolo = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    return g_pix, g_yolo


def test_plan_matches_legacy_nmodel(engines, graphs):
    gpu, dla = engines
    g_pix, g_yolo = graphs
    with pytest.deprecated_call():
        legacy = core.nmodel_schedule([g_pix, g_yolo], [dla, gpu])
    assert core.plan([g_pix, g_yolo], [dla, gpu]) == legacy.ir


def test_plan_matches_legacy_haxconn_standalone_naive(engines, graphs):
    gpu, dla = engines
    g_pix, g_yolo = graphs
    with pytest.deprecated_call():
        hax = core.haxconn_schedule(g_pix, g_yolo, dla, gpu)
    assert core.plan([g_pix, g_yolo], [dla, gpu], kind="haxconn") == hax.ir
    with pytest.deprecated_call():
        solo = core.standalone_schedule(g_pix, dla, gpu)
    assert core.plan([g_pix], [dla, gpu], kind="standalone") == solo.ir
    # a bare graph is accepted for the one-graph kind
    assert core.plan(g_pix, [dla, gpu], kind="standalone") == solo.ir
    with pytest.deprecated_call():
        naive = core.naive_schedule(g_pix, g_yolo, dla, gpu)
    assert core.plan([g_pix, g_yolo], [dla, gpu], kind="naive") == naive.ir


def test_plan_fine_granularity_matches_legacy_on_expanded(engines, graphs):
    gpu, dla = engines
    g_pix, g_yolo = graphs
    with pytest.deprecated_call():
        legacy = core.nmodel_schedule([g_pix.expand(), g_yolo.expand()], [dla, gpu], stride=4)
    got = core.plan([g_pix, g_yolo], [dla, gpu], granularity="fine", stride=4)
    assert got == legacy.ir
    # already-expanded graphs pass through unchanged
    assert core.plan([g_pix.expand(), g_yolo.expand()], [dla, gpu], granularity="fine", stride=4) == got


def test_plan_accepts_staged_models(engines):
    gpu, dla = engines
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    sm = core.pix2pix_staged(cfg, {"generator": Pix2PixGenerator(cfg).init(jax.random.key(0))})
    assert core.plan([sm], [dla, gpu], kind="standalone") == core.plan(
        [sm.graph], [dla, gpu], kind="standalone"
    )


def test_plan_auto_cuts_never_worse_and_records_budget(engines, graphs):
    gpu, dla = engines
    g_pix, g_yolo = graphs
    k1 = core.plan([g_pix, g_yolo], [dla, gpu], max_cuts=1)
    auto = core.plan([g_pix, g_yolo], [dla, gpu], max_cuts="auto")
    assert auto.expected_cycle <= k1.expected_cycle
    assert 1 <= auto.cut_budget <= AUTO_CUTS_CEILING
    if auto.cut_budget > 1:
        # the chosen budget must have actually bought cycle time
        assert auto.expected_cycle < k1.expected_cycle


def test_plan_rejects_bad_inputs(engines, graphs):
    gpu, dla = engines
    g_pix, g_yolo = graphs
    with pytest.raises(ValueError, match="unknown plan kind"):
        core.plan([g_pix], [dla, gpu], kind="bogus")
    with pytest.raises(ValueError, match="granularity"):
        core.plan([g_pix], [dla, gpu], kind="standalone", granularity="medium")
    with pytest.raises(ValueError, match="one graph"):
        core.plan([g_pix, g_yolo], [dla, gpu], kind="standalone")
    with pytest.raises(ValueError, match="max_cuts"):
        core.plan([g_pix, g_yolo], [dla, gpu], max_cuts="many")
    with pytest.raises(TypeError, match="LayerGraph"):
        core.plan([42], [dla, gpu], kind="standalone")


def test_plan_fixed_and_cost_forwarding(engines, graphs):
    gpu, dla = engines
    g_pix, _ = graphs
    with pytest.deprecated_call():
        legacy = core.nmodel_schedule([g_pix, g_pix], [dla, gpu], fixed=(4, 53))
    got = core.plan([g_pix, g_pix], [dla, gpu], fixed=(4, 53))
    assert got == legacy.ir
    assert got.partitions == [4, 53]
    # a provider name resolves through make_cost_provider
    assert core.plan([g_pix, g_pix], [dla, gpu], cost="analytic") == core.plan(
        [g_pix, g_pix], [dla, gpu]
    )
