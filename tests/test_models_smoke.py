"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family, run one forward + one train step on CPU,
assert output shapes and no NaNs. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, build_model
from repro.train.optimizer import AdamW
from repro.train.steps import make_lm_train_step


def _smoke_batch(spec, B=2, S=16):
    cfg = spec.smoke
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
    if spec.family == "whisper":
        batch["frames"] = jnp.asarray(rng.normal(size=(B, cfg.n_frames, cfg.d_model)), jnp.float32)
    if getattr(cfg, "mrope_sections", None):
        pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None], (B, S, 3))
        batch["positions"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    import dataclasses

    cfg = dataclasses.replace(cfg, act_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(spec)
    B, S = batch["tokens"].shape

    # forward
    if spec.family == "whisper":
        logits, aux = model(params, batch["frames"], batch["tokens"])
    else:
        logits, aux = model(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"

    # one train step
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_lm_train_step(model, opt, loss_chunk=8))
    params2, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params changed
    changed = any(
        not np.allclose(a, b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert changed


@pytest.mark.parametrize("arch", ["gemma2_2b", "deepseek_v2_lite_16b", "mamba2_2_7b", "hymba_1_5b"])
def test_arch_smoke_decode(arch):
    """Prefill + decode consistency on the smoke configs."""
    spec = get_arch(arch)
    import dataclasses

    cfg = dataclasses.replace(spec.smoke, act_dtype=jnp.float32)
    if getattr(cfg, "moe", False):
        # "dropping" MoE: full-batch forward may drop tokens past expert
        # capacity while one-token decode never does; equality requires a
        # no-drop capacity. Drop behaviour itself is covered in test_nn.
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)), jnp.int32)
    full, _ = model(params, toks)
    caches = model.init_caches(2, 8, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, caches = model.decode_step(params, toks[:, t : t + 1], caches, t)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.float32(full), np.float32(dec), atol=5e-2, rtol=1e-2)


def test_param_count_estimates_close():
    """Analytic n_params (used for 6ND) within 2% of actual param counts."""
    for arch in ARCH_IDS:
        spec = get_arch(arch)
        model = build_model(spec.smoke)
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(model.abstract()))
        est = spec.smoke.n_params()
        assert abs(actual - est) / actual < 0.02, (arch, actual, est)
