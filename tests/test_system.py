"""End-to-end behaviour tests for the paper's system: the full MRI
reconstruction + diagnosis pipeline on synthetic phantoms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.data import PhantomConfig, phantom_batches
from repro.models import Pix2Pix, Pix2PixConfig, YOLOv8, YOLOv8Config
from repro.train.metrics import psnr, ssim, to_uint8_range
from repro.train.optimizer import Adam
from repro.train.steps import make_pix2pix_train_step


@pytest.mark.slow
def test_end_to_end_reconstruction_and_diagnosis_pipeline():
    """Train a tiny GAN on phantoms, then run the scheduled two-model
    pipeline (GAN recon + YOLO detect) and check reconstruction quality
    improves over an untrained model — the paper's standalone scheme."""
    img = 32
    cfg = Pix2PixConfig(img_size=img, base=8, deconv_mode="cropping")
    model = Pix2Pix(cfg)
    params0 = model.init(jax.random.key(0))
    g_opt = Adam(lr=2e-4, b1=0.5)
    d_opt = Adam(lr=2e-4, b1=0.5)
    opt_state = {"g": g_opt.init(params0["generator"]), "d": d_opt.init(params0["discriminator"])}
    step = jax.jit(make_pix2pix_train_step(model, g_opt, d_opt))
    data = phantom_batches(4, PhantomConfig(img_size=img), seed=0)
    params = params0
    for i in range(30):
        b = next(data)
        batch = {"src": jnp.asarray(b["src"]), "dst": jnp.asarray(b["dst"])}
        params, opt_state, m = step(params, opt_state, batch, jax.random.key(i))

    eval_b = next(phantom_batches(4, PhantomConfig(img_size=img), seed=99))
    src, dst = jnp.asarray(eval_b["src"]), jnp.asarray(eval_b["dst"])
    s0 = float(ssim(to_uint8_range(dst), to_uint8_range(model.generate(params0, src))).mean())
    s1 = float(ssim(to_uint8_range(dst), to_uint8_range(model.generate(params, src))).mean())
    assert s1 > s0, (s0, s1)

    # scheduled concurrent pipeline produces identical outputs to monolithic
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    gsm = core.pix2pix_staged(cfg, params)
    ycfg = YOLOv8Config(img_size=img)
    ym = YOLOv8(ycfg)
    yp = ym.init(jax.random.key(5))
    ysm = core.yolo_staged(ycfg, yp)
    plan = core.haxconn_schedule(gsm.graph, ysm.graph, dla, gpu)
    pipe = core.TwoModelPipeline(gsm, ysm, plan)
    frames = [src[i : i + 1] for i in range(2)]
    recon, det = pipe.run_stream(frames, frames)
    for f, r in zip(frames, recon):
        np.testing.assert_allclose(np.float32(gsm.run_all(f)), np.float32(r), atol=1e-5)
    assert set(det[0].keys()) == {"p3", "p4", "p5"}


def test_variant_weights_transfer_padded_to_cropping():
    """Surgery preserves weights: a model trained as 'padded' runs
    identically after the cropping substitution (the paper's zero-cost
    deployment path)."""
    cfg_p = Pix2PixConfig(img_size=32, base=8, deconv_mode="padded")
    model_p = Pix2Pix(cfg_p)
    params = model_p.init(jax.random.key(0))
    cfg_c = core.substitute_pix2pix(cfg_p, "cropping")
    model_c = Pix2Pix(cfg_c)
    x = jax.random.normal(jax.random.key(1), (1, 32, 32, 3))
    np.testing.assert_allclose(
        np.float32(model_p.generate(params, x)), np.float32(model_c.generate(params, x)), atol=1e-5
    )
