"""Open-loop traffic, SLO-aware admission, and graceful degradation.

The generators are pinned for determinism and rate sanity (seeded
processes are the whole point: a bench scenario must be replayable).
The serving pins drive a toy model at 3x its measured capacity with a
seeded burst and check the overload contract: queues stay bounded,
higher-priority tiers get strictly higher goodput-under-SLO, and the
shedding admission controller beats the queue-everything baseline on
goodput (the goodput-collapse argument: an unbounded queue keeps
throughput while every frame blows its deadline)."""
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.engine import EngineSpec
from repro.core.graph import LayerGraph, pointwise_meta
from repro.core.pipeline import StagedModel
from repro.serve import (
    ADMIT,
    DROP,
    SHED_RES,
    SHED_ROUTE,
    AdmissionConfig,
    MultiStreamServer,
    SLOPolicy,
    StreamSpec,
    TrafficConfig,
    arrival_times,
    merged_arrivals,
    run_open_loop,
    subsample_frame,
)

# ---- arrival generators ----------------------------------------------------


def _assert_valid_schedule(times, horizon):
    assert all(0.0 <= t < horizon for t in times)
    assert times == sorted(times)


def test_poisson_deterministic_and_rate():
    cfg = TrafficConfig(process="poisson", rate_hz=200.0, seed=3)
    a = arrival_times(cfg, 5.0)
    assert a == arrival_times(cfg, 5.0)  # seeded: replayable
    _assert_valid_schedule(a, 5.0)
    # 1000 expected arrivals, sigma ~= 32: a 5-sigma band is not flaky
    assert 840 <= len(a) <= 1160
    assert arrival_times(TrafficConfig(process="poisson", rate_hz=200.0, seed=4), 5.0) != a


def test_bursty_deterministic_and_burstier_than_poisson():
    cfg = TrafficConfig(
        process="bursty", rate_hz=100.0, seed=7, burst_factor=8.0, mean_burst_s=0.2, mean_quiet_s=0.8
    )
    a = arrival_times(cfg, 10.0)
    assert a == arrival_times(cfg, 10.0)
    _assert_valid_schedule(a, 10.0)
    assert len(a) > 0
    # burstiness shows up as inter-arrival variance above the exponential's
    gaps = [b - x for x, b in zip(a, a[1:])]
    mean = sum(gaps) / len(gaps)
    var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
    assert var > mean * mean  # exponential gaps would have var ~= mean^2


def test_diurnal_respects_peak_and_floor():
    cfg = TrafficConfig(process="diurnal", rate_hz=100.0, seed=5, period_s=2.0, floor=0.25)
    a = arrival_times(cfg, 10.0)
    assert a == arrival_times(cfg, 10.0)
    _assert_valid_schedule(a, 10.0)
    # thinned from the peak rate; mean intensity is between floor and peak
    assert 0.25 * 100.0 * 10.0 * 0.5 < len(a) < 100.0 * 10.0


def test_traffic_config_validation():
    with pytest.raises(ValueError):
        TrafficConfig(process="weibull")
    with pytest.raises(ValueError):
        TrafficConfig(rate_hz=0.0)
    with pytest.raises(ValueError):
        TrafficConfig(process="diurnal", floor=1.5)


def test_merged_arrivals_sorted_and_tagged():
    traffic = {
        "a": TrafficConfig(process="poisson", rate_hz=50.0, seed=1),
        "b": TrafficConfig(process="poisson", rate_hz=50.0, seed=2),
    }
    events = merged_arrivals(traffic, 2.0)
    assert [t for t, _ in events] == sorted(t for t, _ in events)
    assert {name for _, name in events} == {"a", "b"}


# ---- SLO + admission primitives --------------------------------------------


def test_slo_policy_deadline_and_tier():
    slo = SLOPolicy(deadline_ms=50.0, tier=2)
    assert slo.deadline_s == pytest.approx(0.05)
    assert slo.met(0.049) and not slo.met(0.051)
    with pytest.raises(ValueError):
        SLOPolicy(deadline_ms=0.0)
    with pytest.raises(ValueError):
        SLOPolicy(deadline_ms=10.0, tier=-1)


def test_admission_ladder_escalates_with_pressure():
    cfg = AdmissionConfig(shed_resolution_at=0.5, shed_route_at=0.75, drop_at=0.9)
    assert cfg.decide(0.0) == (ADMIT, 0)
    assert cfg.decide(0.49) == (ADMIT, 0)
    assert cfg.decide(0.5) == (SHED_RES, 1)
    assert cfg.decide(0.75) == (SHED_ROUTE, 2)
    assert cfg.decide(1.0) == (SHED_ROUTE, 2)
    assert AdmissionConfig(enabled=False).decide(1.0) == (ADMIT, 0)
    with pytest.raises(ValueError):
        AdmissionConfig(shed_resolution_at=0.8, shed_route_at=0.5)


def test_subsample_frame_strides_spatial_axes_only():
    f = jnp.ones((1, 8, 8, 3))
    assert subsample_frame(f, 2).shape == (1, 4, 4, 3)
    assert subsample_frame(jnp.ones((1, 64)), 2).shape == (1, 64)  # rank<3 untouched


# ---- open-loop serving under overload --------------------------------------


def _toy_staged(n_layers=4, name="toy"):
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * 1.5 + 0.5}) for i in range(n_layers)]
    graph = LayerGraph(
        name,
        [pointwise_meta(i, f"mul{i}", "act", (1, 64), flops_per_elem=1e9 / 64) for i in range(n_layers)],
    ).renumber()
    return StagedModel(
        name=name,
        ops=ops,
        params=None,
        graph=graph,
        init_state=lambda x: {"x": x},
        finalize=lambda s: s["x"],
    )


def _toy_server(tiers, deadline_ms, admission, max_queue, delay_s=2e-3):
    """One toy model fanned over len(tiers) streams with per-stream SLOs;
    segment_delay_fn makes the service time deterministic and dominant."""
    sm = _toy_staged()
    engines = [
        EngineSpec("E0", 1, 1.0e12, 500e9, 50e9, ()),
        EngineSpec("E1", 1, 1.0e12, 500e9, 50e9, ()),
    ]
    ir = core.plan([sm.graph], engines)
    streams = [
        StreamSpec(f"s{i}", 0, slo=SLOPolicy(deadline_ms=deadline_ms, tier=t))
        for i, t in enumerate(tiers)
    ]
    server = MultiStreamServer(
        [sm],
        ir,
        streams,
        max_queue=max_queue,
        jit_segments=False,
        admission=admission,
        resolution_flexible=True,
    )
    server.executor.segment_delay_fn = lambda seg: delay_s
    return server, streams


def _measure_capacity_fps(tiers) -> float:
    """Closed-loop aggregate FPS of the toy server — the 1x reference the
    open-loop scenarios scale from."""
    server, streams = _toy_server(tiers, deadline_ms=1e6, admission=None, max_queue=4)
    for t in range(10):
        for s in streams:
            server.submit(s.model_index, jnp.ones((1, 64)))
        server.pump()
    server.drain()
    return server.report()["aggregate_fps"]


TIERS = (0, 0, 1, 1)


def _drive_open_loop(rate_per_stream, admission, max_queue, horizon_s=1.2):
    server, streams = _toy_server(TIERS, deadline_ms=60.0, admission=admission, max_queue=max_queue)
    traffic = {
        s.name: TrafficConfig(process="bursty", rate_hz=rate_per_stream, seed=10 + i, burst_factor=4.0)
        for i, s in enumerate(streams)
    }
    rep = run_open_loop(
        server, traffic, lambda name: jnp.ones((1, 64)), horizon_s, max_wall_s=120.0
    )
    return server, rep


@pytest.fixture(scope="module")
def overload_runs():
    capacity = _measure_capacity_fps(TIERS)
    rate = 3.0 * capacity / len(TIERS)  # 3x capacity, split across streams
    shed_server, shed = _drive_open_loop(rate, AdmissionConfig(), max_queue=4)
    queue_server, queued = _drive_open_loop(rate, None, max_queue=64)
    return capacity, shed_server, shed, queue_server, queued


def test_burst_overload_queues_stay_bounded(overload_runs):
    _, shed_server, shed, _, _ = overload_runs
    ex = shed_server.executor
    assert all(q.high_water <= q.maxdepth for q in ex.queues)
    assert all(len(q) == 0 for q in ex.queues)  # drained
    # the controller actually engaged: arrivals were shed or dropped
    adm = shed["admission"]
    assert adm["offered"] > adm["admitted"]
    assert adm["dropped"] > 0


def test_burst_overload_tiers_priority_ordering(overload_runs):
    _, _, shed, _, _ = overload_runs
    t0, t1 = shed["tiers"][0], shed["tiers"][1]
    # both tiers were offered comparable load ...
    assert t0["offered"] > 0 and t1["offered"] > 0
    # ... but the higher-priority tier gets strictly higher goodput
    assert t0["goodput_fps"] > t1["goodput_fps"]
    # and the ledger balances per tier
    for tm in (t0, t1):
        assert tm["offered"] == tm["admitted"] + tm["shed_res"] + tm["shed_route"] + tm["dropped"]


def test_burst_overload_shedding_beats_queue_only_goodput(overload_runs):
    _, _, shed, _, queued = overload_runs
    # the queue-everything baseline admits more frames ...
    assert queued["admission"]["dropped"] <= shed["admission"]["dropped"]
    # ... but shedding wins on goodput-under-SLO (bounded waits keep the
    # admitted frames inside their deadline)
    assert shed["goodput_fps"] >= queued["goodput_fps"]
    # overload is visible to the replanner's load signal in both runs
    assert shed["slo_miss_rate_recent"] >= 0.0


def test_open_loop_report_carries_slo_keys(overload_runs):
    _, _, shed, _, _ = overload_runs
    for key in ("goodput_fps", "slo_miss_rate_recent", "tiers", "admission"):
        assert key in shed
    for tm in shed["tiers"].values():
        for key in ("offered", "goodput_fps", "slo_attainment", "latency_p99_ms"):
            assert key in tm


# ---- committed benchmark contract ------------------------------------------


def test_committed_bench_pins_openloop_contract():
    """The committed BENCH_serve.json must show the degradation story the
    README tells: at the top offered load, shedding admission control
    keeps p99 bounded and beats the queue-everything baseline on
    goodput-under-SLO."""
    import json
    import pathlib

    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    if not path.exists():
        pytest.skip("no committed BENCH_serve.json")
    payload = json.loads(path.read_text())
    ol = payload.get("openloop")
    if not ol:
        pytest.skip("committed bench predates the open-loop sweep")
    assert ol["shed_vs_queue_goodput_ratio"] >= 1.0
    assert ol["p99_bounded_at_top"]
    top = str(max(ol["load_factors"]))
    assert ol["points"][top]["dropped"] > 0  # the controller actually engaged
    assert ol["points"]["1.0"]["goodput_fps"] > 0.0  # trend gate key is live
