"""Multi-cut route planning end-to-end: search -> IR -> staging ->
execution -> re-planning.

Pins the refactor's load-bearing guarantees:
  (a) ``max_cuts=1`` is bit-identical to the legacy single-point planner
      (and, at N=2, to ``haxconn_schedule``) — partitions, cycle time,
      and per-engine occupancy,
  (b) raising ``max_cuts`` never worsens the analytic plan cost (the
      single-cut optimum is polished inside the multi-cut space), and on
      the bench-sized serving pair it strictly improves it,
  (c) a multi-cut plan is a pure re-orchestration: executed outputs are
      bit-exact (eager) vs the single-cut plan and vs ``run_all``,
  (d) mid-stream hot-swap from a single-cut to a multi-cut plan drops
      nothing and changes no output,
  (e) ``fixed=`` pins full routes (the re-planner's re-scoring form) and
      supports per-model ``None`` holes (the partial re-plan path),
  (f) the re-planner performs partial swaps (one drifted route) and
      escalates coarse -> fine planning after sustained drift, including
      the coarse-planning / fine-staging translation deployment,
  (g) ``EngineSpec.supports`` is memoized per (layer, engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import EngineSpec, jetson_orin_engines
from repro.core.graph import LayerGraph, pointwise_meta
from repro.core.pipeline import StagedModel
from repro.core.plan_ir import make_plan_ir, translate_ir
from repro.core.scheduler import RouteSpec, nmodel_schedule
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config
from repro.serve import ReplanConfig, Replanner, StreamExecutor, StreamSpec
from repro.serve.executor import SegmentObservation


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return gpu, dla


@pytest.fixture(scope="module")
def serving_graphs():
    pix = Pix2PixGenerator(Pix2PixConfig(deconv_mode="cropping")).layer_graph()
    yolo = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    return pix, yolo


@pytest.fixture(scope="module")
def staged_pair():
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    sm_pix = core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    sm_yolo = core.yolo_staged(ycfg, ym.init(jax.random.key(1)))
    return sm_pix, sm_yolo


def _toy_staged(n_layers=8, name="toy", flops=1e9):
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * 1.5 + 0.5}) for i in range(n_layers)]
    graph = LayerGraph(
        name,
        [pointwise_meta(i, f"mul{i}", "act", (1, 64), flops_per_elem=flops / 64) for i in range(n_layers)],
    ).renumber()
    return StagedModel(
        name=name,
        ops=ops,
        params=None,
        graph=graph,
        init_state=lambda x: {"x": x},
        finalize=lambda s: s["x"],
    )


def _toy_engines(f0=1.0e12, f1=1.0e12):
    return [
        EngineSpec("E0", 1, f0, 500e9, 50e9, ()),
        EngineSpec("E1", 1, f1, 500e9, 50e9, ()),
    ]


# ---- (a) max_cuts=1 is the legacy planner, bit-identical --------------------


@pytest.mark.parametrize("mode", ["padded", "cropping"])
@pytest.mark.parametrize("pair", ["self", "yolo"])
def test_max_cuts1_bit_identical_to_haxconn(mode, pair, engines):
    """The PR 2 pin, re-asserted through the multi-cut code path: the
    k-cut generalization at max_cuts=1 picks the same partitions, cycle
    time, and per-engine occupancy as the exact two-model search — bit
    identical, not just close."""
    gpu, dla = engines
    g = Pix2PixGenerator(Pix2PixConfig(deconv_mode=mode)).layer_graph()
    b = g if pair == "self" else YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    ref = core.haxconn_schedule(g, b, dla, gpu)
    plan = nmodel_schedule([g, b], [dla, gpu], max_cuts=1)
    assert plan.partitions == [ref.p_a, ref.p_b]
    assert plan.cycle_time == ref.schedule.cycle_time
    assert plan.engine_times["DLA"] == ref.phase["constrained"]
    assert plan.engine_times["GPU"] == ref.phase["flexible"]
    assert plan.cuts == [(ref.p_a,), (ref.p_b,)]
    assert plan.max_cuts == 1


def test_route_spec_validation():
    with pytest.raises(ValueError):
        RouteSpec((3,), (0,))  # 1 cut needs 2 segment engines
    with pytest.raises(ValueError):
        RouteSpec((5, 3), (0, 1, 0))  # cuts must increase
    r = RouteSpec((2, 5), (0, 1, 0))
    assert r.n_cuts == 2
    assert r.segments(8) == [(0, 0, 2), (1, 2, 5), (0, 5, 8)]


# ---- (b) plan cost never worse as max_cuts grows ----------------------------


def test_max_cuts2_never_worse_on_serving_graphs(engines, serving_graphs):
    """The acceptance bar: on both serving graphs (coarse and expanded),
    the max_cuts=2 analytic plan cost is never worse than max_cuts=1."""
    gpu, dla = engines
    pix, yolo = serving_graphs
    for graphs in ([pix, yolo], [pix.expand(), yolo.expand()]):
        p1 = nmodel_schedule(graphs, [dla, gpu], max_cuts=1)
        p2 = nmodel_schedule(graphs, [dla, gpu], max_cuts=2)
        assert p2.cycle_time <= p1.cycle_time
        assert all(len(c) <= 2 for c in p2.cuts)
        # the IR records the search *budget*, not the realized cut count:
        # a max_cuts=2 search whose optimum is single-cut must not ratchet
        # an inheriting re-planner down to budget 1
        assert p2.ir.cut_budget == 2 and p2.ir.max_cuts == 2
    p3 = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=3)
    p1 = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=1)
    assert p3.cycle_time <= p1.cycle_time


def test_multicut_strictly_improves_bench_pair(engines):
    """On the bench-sized (32px) pair the single cut cannot balance the
    engines; the 2-cut search finds a strictly cheaper plan that really
    uses a second cut."""
    gpu, dla = engines
    pix = Pix2PixGenerator(Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")).layer_graph()
    yolo = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    p1 = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=1)
    p2 = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=2)
    assert p2.cycle_time < p1.cycle_time
    assert max(len(c) for c in p2.cuts) == 2
    assert p2.ir.max_cuts == 2
    # the IR carries the multi-cut metadata
    assert p2.ir.cuts == tuple(tuple(c) for c in p2.cuts)
    assert p2.ir.cut_counts == tuple(len(c) for c in p2.cuts)


# ---- (e) fixed= full-route pinning + partial holes --------------------------


def test_fixed_route_specs_rescore_bit_exact(engines, serving_graphs):
    """Re-scoring a plan's own routes through ``fixed=`` reproduces its
    cycle time bit-exactly — the re-planner's incumbent-scoring contract."""
    gpu, dla = engines
    pix, yolo = serving_graphs
    plan = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=2)
    rescored = nmodel_schedule([pix, yolo], [dla, gpu], fixed=plan.ir.route_specs())
    assert rescored.cycle_time == plan.cycle_time
    assert rescored.cuts == plan.cuts
    assert rescored.search == "fixed"


def test_fixed_with_none_holds_other_models(engines, serving_graphs):
    """A ``None`` entry leaves one model free while the rest stay pinned —
    the partial re-plan path."""
    gpu, dla = engines
    pix, yolo = serving_graphs
    plan = nmodel_schedule([pix, yolo], [dla, gpu], max_cuts=1)
    specs = plan.ir.route_specs()
    partial = nmodel_schedule([pix, yolo], [dla, gpu], fixed=[specs[0], None], max_cuts=2)
    assert partial.cuts[0] == specs[0][0]  # pinned route untouched
    # the free model was genuinely searched (its plan stays optimal-or-
    # equal given the pin, so the cycle can't beat the joint optimum by
    # more than the pin allows — sanity: it evaluated and emitted)
    assert partial.cycle_time > 0
    assert len(partial.cuts[1]) in (1, 2)
    with pytest.raises(ValueError):
        nmodel_schedule([pix, yolo], [dla, gpu], fixed=[specs[0]])  # wrong arity
    with pytest.raises(ValueError):
        nmodel_schedule([pix, yolo], [dla, gpu], fixed=[((3,), (0, 9)), None])  # bad engine


# ---- (c) execution: pure re-orchestration, bit-exact eager ------------------


def test_multicut_plan_executes_bit_exact_vs_single_cut(engines, staged_pair):
    """The planned multi-cut routes run through the executor with outputs
    bit-equal (eager) to the single-cut plan's and to the monolithic
    models — routing is pure re-orchestration however many cuts it takes."""
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan1 = nmodel_schedule([sm_pix.graph, sm_yolo.graph], [dla, gpu], max_cuts=1)
    plan2 = nmodel_schedule([sm_pix.graph, sm_yolo.graph], [dla, gpu], max_cuts=2)
    assert plan2.cycle_time < plan1.cycle_time  # the second cut is load-bearing
    assert max(len(c) for c in plan2.cuts) == 2
    streams = [StreamSpec("mri", 0), StreamSpec("det", 1)]
    frames = [jax.random.normal(jax.random.key(i), (1, 32, 32, 3)) for i in range(3)]

    def run(plan):
        ex = StreamExecutor([sm_pix, sm_yolo], plan, streams, max_queue=8, jit_segments=False)
        for f in frames:
            assert ex.submit(0, f) and ex.submit(1, f)
            ex.tick()
        return ex.run_until_drained()

    outs1, outs2 = run(plan1), run(plan2)
    for k, sm in (("mri", sm_pix), ("det", sm_yolo)):
        for f, a, b in zip(frames, outs1[k], outs2[k]):
            ref = sm.run_all(f)
            for la, lb, lr in zip(jax.tree.leaves(a), jax.tree.leaves(b), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
                np.testing.assert_array_equal(np.asarray(lb), np.asarray(lr))


def test_run_route_and_check_route(staged_pair):
    sm_pix, _ = staged_pair
    n = sm_pix.n_layers
    spans = [(0, 2), (2, n - 1), (n - 1, n)]
    x = jax.random.normal(jax.random.key(7), (1, 32, 32, 3))
    np.testing.assert_array_equal(
        np.asarray(sm_pix.run_route(x, spans)), np.asarray(sm_pix.run_all(x))
    )
    with pytest.raises(ValueError):
        sm_pix.check_route([(0, 2), (3, n)])  # gap
    with pytest.raises(ValueError):
        sm_pix.check_route([(0, 2), (2, n - 1)])  # short coverage
    with pytest.raises(ValueError):
        sm_pix.check_route([(0, n), (n, n)])  # empty span


def test_fine_staged_multicut_plan_executes(engines):
    """A 2-cut plan on the expanded graphs stages sub-block executables
    and runs bit-exact (eager) vs the monolithic model."""
    gpu, dla = engines
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    params = {"generator": gen.init(jax.random.key(0))}
    sm_pix_f = core.pix2pix_staged(cfg, params, granularity="fine")
    ycfg = YOLOv8Config(img_size=32)
    yparams = YOLOv8(ycfg).init(jax.random.key(1))
    sm_yolo_f = core.yolo_staged(ycfg, yparams, granularity="fine")
    plan = nmodel_schedule([sm_pix_f.graph, sm_yolo_f.graph], [dla, gpu], max_cuts=2)
    streams = [StreamSpec("mri", 0), StreamSpec("det", 1)]
    ex = StreamExecutor([sm_pix_f, sm_yolo_f], plan, streams, max_queue=8, jit_segments=False)
    frames = [jax.random.normal(jax.random.key(i), (1, 32, 32, 3)) for i in range(2)]
    for f in frames:
        assert ex.submit(0, f) and ex.submit(1, f)
        ex.tick()
    outs = ex.run_until_drained()
    for k, sm in (("mri", sm_pix_f), ("det", sm_yolo_f)):
        for f, o in zip(frames, outs[k]):
            for la, lb in zip(jax.tree.leaves(sm.run_all(f)), jax.tree.leaves(o)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_executor_rejects_unstageable_multicut_plan(engines):
    """A span that cuts inside a fused stage callable is rejected up
    front (construction AND swap), not discovered mid-flight."""
    ycfg = YOLOv8Config(img_size=32)
    yparams = YOLOv8(ycfg).init(jax.random.key(1))
    sm = core.yolo_staged(ycfg, yparams, granularity="fine")
    bad_p = next(p for p in range(1, sm.n_layers) if not sm.graph[p - 1].cut_after)
    bad = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, bad_p), (1, bad_p, sm.n_layers)]])
    with pytest.raises(ValueError):
        StreamExecutor([sm], bad, [StreamSpec("det", 0)])
    ok_p = sm.graph.cut_points()[0]
    ok = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, ok_p), (1, ok_p, sm.n_layers)]])
    ex = StreamExecutor([sm], ok, [StreamSpec("det", 0)])
    with pytest.raises(ValueError):
        ex.swap_plan(bad)


# ---- (d) hot-swap single-cut -> multi-cut ----------------------------------


def test_hot_swap_single_to_multicut_zero_drops():
    """Swap a 2-segment plan for a 3-segment plan while frames are in
    flight: zero drops, per-stream FIFO order, outputs bit-exact vs an
    unswapped run; in-flight frames finish on their admitted 2-segment
    routes while new admissions take the 3-segment ones."""
    sm = _toy_staged(n_layers=6)
    ir_a = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 3), (1, 3, 6)]])
    ir_b = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 2), (1, 2, 4), (0, 4, 6)]])
    assert ir_b.cut_counts == (2,) and ir_b.max_cuts == 2
    streams = [StreamSpec("s0", 0), StreamSpec("s1", 0)]
    frames = {
        s.name: [jnp.full((1, 64), float(3 * i + t)) for t in range(4)]
        for i, s in enumerate(streams)
    }

    def run(swap_at=None):
        ex = StreamExecutor([sm], ir_a, streams, max_queue=8, jit_segments=False)
        for t in range(4):
            for i, s in enumerate(streams):
                assert ex.submit(i, frames[s.name][t])
        ticks = 0
        while ex.pending:
            if swap_at is not None and ticks == swap_at:
                assert ex.in_flight, "swap must happen with frames in flight"
                ex.swap_plan(ir_b)
            ex.tick()
            ticks += 1
        return ex

    ex_plain, ex_swap = run(), run(swap_at=2)
    assert ex_swap.plan_revision == 1
    assert ex_swap.swap_events[0].cuts == ((2, 4),)
    for s in streams:
        assert len(ex_swap.outputs[s.name]) == len(frames[s.name])  # zero drops
        for a, b in zip(ex_plain.outputs[s.name], ex_swap.outputs[s.name]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        fids = [c.frame_id for c in ex_swap.completions if c.stream == s.name]
        assert fids == sorted(fids)
    spans = [e.work.split("[")[1].split(")")[0] for e in ex_swap.log if "#f" in e.work]
    assert any(sp == "3:6" for sp in spans)  # old route finished in flight
    assert {"0:2", "2:4", "4:6"} <= set(spans)  # new 3-segment route ran


# ---- plan IR metadata -------------------------------------------------------


def test_plan_ir_multicut_metadata_roundtrip():
    ir = make_plan_ir(
        ("a", "b"),
        ("E0", "E1"),
        [[(0, 0, 2), (1, 2, 5), (0, 5, 9)], [(1, 0, 4), (0, 4, 9)]],
    )
    assert ir.cuts == ((2, 5), (4,))
    assert ir.cut_counts == (2, 1)
    assert ir.max_cuts == 2
    assert ir.route_specs() == [((2, 5), (0, 1, 0)), ((4,), (1, 0))]
    back = type(ir).from_json(ir.to_json())
    assert back.cuts == ir.cuts and back.route_specs() == ir.route_specs()
    assert "cuts=[2, 1]" in ir.describe()


def test_translate_ir_and_coarse_cut_inverse(serving_graphs):
    _, yolo = serving_graphs
    eg = yolo.expand()
    gpu, dla = jetson_orin_engines()
    plan = nmodel_schedule([yolo], [dla, gpu], max_cuts=2)
    fine_ir = translate_ir(plan.ir, [eg])
    assert fine_ir.n_layers == (len(eg),)
    for cs, fs in zip(plan.ir.segments[0], fine_ir.segments[0]):
        assert fs.lo == eg.fine_cut(cs.lo) and fs.hi == eg.fine_cut(cs.hi)
        assert eg.coarse_cut(fs.lo) == cs.lo and eg.coarse_cut(fs.hi) == cs.hi
    # a fine point strictly inside a coarse node has no coarse preimage
    interior = next(
        p for p in range(1, len(eg)) if all(hi != p for _, hi in eg.spans)
    )
    assert eg.coarse_cut(interior) is None
    assert eg.coarse_cut(0) == 0 and eg.coarse_cut(len(eg)) == len(eg.spans)


# ---- (g) supports memoization ----------------------------------------------


def test_supports_memoized_per_layer_and_engine():
    class Counting:
        def __init__(self):
            self.calls = 0

        def check(self, l):
            self.calls += 1
            return None

    c = Counting()
    eng = EngineSpec("E", 1, 1e12, 1e12, 32e9, (c,))
    prim = pointwise_meta(0, "p", "act", (1, 8))
    comp = prim.clone()
    comp.sublayers = [pointwise_meta(i, f"s{i}", "act", (1, 8)) for i in range(3)]
    for _ in range(5):
        assert eng.supports(prim) == []
    assert c.calls == 1  # memoized after the first walk
    first = eng.supports(comp)
    calls_after_composite = c.calls
    assert eng.supports(comp) is first  # cached object, no re-walk
    assert c.calls == calls_after_composite
    # a clone is a fresh object: re-checked, not served stale
    eng.supports(prim.clone())
    assert c.calls == calls_after_composite + 1


# ---- (f) re-planner: partial swaps + escalation -----------------------------


def _feed_all(rp, ex, engine_scale):
    """One synthetic profiled tick: every segment of every live route
    observed at ``engine_scale[engine] x`` its base expectation."""
    for mi in range(len(ex.models)):
        for seg in ex.plan.route(mi):
            expected = rp._expected_base(mi, seg.engine, seg.lo, seg.hi)
            rp.observe(
                SegmentObservation(
                    tick=ex.tick_count, model_index=mi, stage=seg.stage, engine=seg.engine,
                    lo=seg.lo, hi=seg.hi, wall_s=engine_scale[seg.engine] * expected,
                    batch=1, revision=ex.plan_revision,
                )
            )
    return rp.maybe_replan(ex)


def test_partial_swap_replans_only_drifted_route():
    """Two models, sustained skew on one engine: with a generous partial
    tolerance the re-planner swaps only the route carrying the most work
    on the drifted engine; the other model's route is untouched and the
    swap is recorded as partial."""
    sm_a = _toy_staged(n_layers=8, name="toyA")
    sm_b = _toy_staged(n_layers=8, name="toyB")
    engines = _toy_engines()
    plan = nmodel_schedule([sm_a.graph, sm_b.graph], engines)
    cfg = ReplanConfig(
        drift_threshold=0.5, hysteresis=2, cooldown_ticks=0, warmup_obs=2,
        min_improvement=0.01, partial_swaps=True, partial_tolerance=10.0,
    )
    rp = Replanner([sm_a.graph, sm_b.graph], engines, cfg)
    ex = StreamExecutor(
        [sm_a, sm_b], plan, [StreamSpec("a", 0), StreamSpec("b", 1)], max_queue=4
    )
    for _ in range(3):
        assert _feed_all(rp, ex, {0: 100.0, 1: 100.0}) is None
    assert rp.calibrated
    old_specs = ex.plan.route_specs()
    ev = None
    for _ in range(cfg.hysteresis + 1):
        ev = ev or _feed_all(rp, ex, {0: 400.0, 1: 100.0})
    assert ev is not None and ev.swapped and ev.partial
    new_specs = ex.plan.route_specs()
    changed = [i for i in range(2) if new_specs[i] != old_specs[i]]
    assert len(changed) == 1  # exactly the drifted route moved
    assert rp.swap_stalls[0].partial
    assert rp.summary()["partial_swaps"] == 1
    assert rp.summary()["swap_stall"]["partial_swaps"] == 1
    # the moved route carries less work on the slowed engine
    mi = changed[0]
    old_e0 = sum(hi - lo for (_, lo, hi) in RouteSpec(*old_specs[mi]).segments(8) if _ == 0)
    new_e0 = sum(hi - lo for (_, lo, hi) in RouteSpec(*new_specs[mi]).segments(8) if _ == 0)
    assert new_e0 < old_e0


def test_escalation_widens_stride_after_fires():
    """``escalate_after`` drift fires switch re-planning from the strided
    candidate set to ``escalate_stride`` — the full cut set."""
    sm = _toy_staged(n_layers=12, name="toy12")
    engines = _toy_engines()
    plan = nmodel_schedule([sm.graph], engines, stride=4)
    cfg = ReplanConfig(
        drift_threshold=0.5, hysteresis=2, cooldown_ticks=0, warmup_obs=2,
        min_improvement=0.0, stride=4, escalate_after=2, escalate_stride=1,
    )
    rp = Replanner([sm.graph], engines, cfg)
    ex = StreamExecutor([sm], plan, [StreamSpec("s", 0)], max_queue=4)
    for _ in range(3):
        _feed_all(rp, ex, {0: 100.0, 1: 100.0})
    assert rp.calibrated
    events = []
    scale = 100.0
    while len(events) < 2:
        scale *= 4.0  # keep drifting past each rebaseline
        for _ in range(cfg.hysteresis + 2):
            ev = _feed_all(rp, ex, {0: scale, 1: 100.0})
            if ev:
                events.append(ev)
                break
    assert not events[0].escalated  # first fire: still strided
    assert events[1].escalated and rp.escalated  # second fire: full cut set
    assert rp.summary()["escalated"] and rp.summary()["drift_fires"] >= 2


def test_escalation_translates_coarse_plans_onto_fine_staging():
    """The cheap-planning deployment: models staged fine, re-planner
    given the coarse graphs. Normal re-plans are made coarse and
    translated to fine indices; after escalation the planner searches the
    expansion itself (cuts inside composites become reachable)."""
    ycfg = YOLOv8Config(img_size=32)
    yparams = YOLOv8(ycfg).init(jax.random.key(1))
    sm_f = core.yolo_staged(ycfg, yparams, granularity="fine")
    coarse = YOLOv8(ycfg).layer_graph()
    eg = sm_f.graph
    engines = _toy_engines(f0=1.0e12, f1=2.0e12)
    coarse_plan = nmodel_schedule([coarse], engines)
    fine_ir = translate_ir(coarse_plan.ir, [eg])
    ex = StreamExecutor([sm_f], fine_ir, [StreamSpec("det", 0)], max_queue=4, jit_segments=False)
    cfg = ReplanConfig(
        drift_threshold=0.5, hysteresis=2, cooldown_ticks=0, warmup_obs=2,
        min_improvement=0.0, escalate_after=2, profile_every=1,
    )
    rp = Replanner([coarse], engines, cfg)
    rp.attach(ex)
    assert rp._translate  # coarse planning graphs, fine-staged executor
    for _ in range(3):
        _feed_all(rp, ex, {0: 100.0, 1: 100.0})
    assert rp.calibrated
    coarse_boundaries = {eg.fine_cut(p) for p in range(len(coarse) + 1)}
    events = []
    scale = 100.0
    while len(events) < 2:
        scale *= 4.0
        for _ in range(cfg.hysteresis + 2):
            ev = _feed_all(rp, ex, {0: scale, 1: 100.0})
            if ev:
                events.append(ev)
                break
    # pre-escalation plans are coarse-made: every cut lands on a coarse
    # boundary of the fine index space
    assert not events[0].escalated
    for cuts in events[0].new_cuts:
        assert all(c in coarse_boundaries for c in cuts)
    assert events[1].escalated
    # the escalated plan's IR is directly in fine indices and executable
    ex.prepare_plan(ex.plan)  # still stages cleanly after any swaps


def test_replanner_inherits_incumbent_max_cuts(engines, staged_pair):
    gpu, dla = engines
    sm_pix, sm_yolo = staged_pair
    plan2 = nmodel_schedule([sm_pix.graph, sm_yolo.graph], [dla, gpu], max_cuts=2)
    assert plan2.ir.max_cuts == 2
    ex = StreamExecutor(
        [sm_pix, sm_yolo], plan2, [StreamSpec("mri", 0), StreamSpec("det", 1)], max_queue=4
    )
    rp = Replanner([sm_pix.graph, sm_yolo.graph], [dla, gpu])
    rp.attach(ex)
    assert rp._active_max_cuts() == 2  # inherit the incumbent's budget
    rp2 = Replanner(
        [sm_pix.graph, sm_yolo.graph], [dla, gpu], ReplanConfig(max_cuts=3)
    )
    rp2.attach(ex)
    assert rp2._active_max_cuts() == 3  # explicit override wins
