"""Fused serving kernels + implementation-aware planning.

Parity: each fused Pallas block (conv+norm+act, deconv+crop+norm+act)
matches its pure-jnp oracle on serving shapes at f32/bf16. Planning: the
``--impl auto`` argmin is never analytically worse than forced ``xla``
on both serving graphs, the measured-cost plan binds ``pallas_fused``
segments that survive the PlanIR JSON round trip, and the executor
stages the fused variants end-to-end bit-compatibly with ``run_all``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.cost_model import ANALYTIC, MeasuredCost
from repro.core.engine import jetson_orin_engines
from repro.core.scheduler import _nmodel_schedule_impl as nmodel_schedule
from repro.kernels.fused.ops import conv_block, deconv_block
from repro.kernels.fused.ref import conv_block_ref, deconv_block_ref
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config


# (in_shape, kernel, stride, padding, cout, norm, act) — the serving-graph
# blocks the fused kernels replace (Pix2Pix down/up path, YOLO convs)
CONV_CASES = [
    ((1, 64, 64, 3), 4, 2, 1, 8, "none", "lrelu"),
    ((1, 32, 32, 8), 4, 2, 1, 16, "batch", "lrelu"),
    ((1, 64, 64, 3), 3, 2, 1, 16, "batch", "silu"),
    ((1, 32, 32, 16), 3, 2, 1, 32, "batch", "silu"),
    ((2, 16, 16, 8), 4, 2, 1, 16, "instance", "lrelu"),  # B>1 per-sample stats
    ((1, 16, 16, 8), 4, 2, 1, 16, "group", "lrelu"),
]
DECONV_CASES = [
    ((1, 4, 4, 64), 32, "batch", "relu"),
    ((1, 8, 8, 64), 16, "batch", "relu"),
    ((2, 8, 8, 16), 8, "instance", "relu"),
]


def _params(key, cin, cout, k):
    kw, kb = jax.random.split(key)
    w = jax.random.normal(kw, (k, k, cin, cout), jnp.float32) * 0.1
    b = jax.random.normal(kb, (cout,), jnp.float32) * 0.1
    gamma = jnp.ones((cout,), jnp.float32) * 1.1
    beta = jnp.zeros((cout,), jnp.float32) + 0.05
    return w, b, gamma, beta


@pytest.mark.parametrize("shape,k,stride,pad,cout,norm,act", CONV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_block_parity(shape, k, stride, pad, cout, norm, act, dtype):
    x = jax.random.normal(jax.random.key(0), shape).astype(dtype)
    w, b, gamma, beta = _params(jax.random.key(1), shape[-1], cout, k)
    groups = 4 if norm == "group" else 1
    got = conv_block(
        x, w, b, gamma, beta, stride=stride, padding=pad, norm=norm, groups=groups, act=act
    )
    want = conv_block_ref(
        x, w, b, gamma, beta, stride=stride, padding=pad, norm=norm, groups=groups, act=act
    )
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=atol)


@pytest.mark.parametrize("shape,cout,norm,act", DECONV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_deconv_block_parity(shape, cout, norm, act, dtype):
    x = jax.random.normal(jax.random.key(0), shape).astype(dtype)
    w, b, gamma, beta = _params(jax.random.key(1), shape[-1], cout, 4)
    got = deconv_block(x, w, b, gamma, beta, norm=norm, act=act)
    want = deconv_block_ref(x, w, b, gamma, beta, norm=norm, act=act)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=atol)


SPPF_CASES = [
    ((1, 8, 8, 16), 5, 3),  # the YOLO SPPF pyramid at serving scale
    ((2, 4, 4, 8), 5, 3),  # B>1: max/concat have no cross-sample coupling
    ((1, 8, 8, 4), 3, 2),
]


@pytest.mark.parametrize("shape,window,reps", SPPF_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sppf_pyramid_parity_exact(shape, window, reps, dtype):
    """The fused SPPF pool-pyramid is max/concat only — bit-exact vs the
    reduce_window oracle at BOTH dtypes, not merely close."""
    from repro.kernels.fused.ops import sppf_pyramid
    from repro.kernels.fused.ref import sppf_pyramid_ref

    x = jax.random.normal(jax.random.key(0), shape).astype(dtype)
    got = sppf_pyramid(x, window=window, reps=reps)
    want = sppf_pyramid_ref(x, window=window, reps=reps)
    assert got.shape == shape[:-1] + ((reps + 1) * shape[-1],)
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.float32(got), np.float32(want))


def test_yolo_fine_granularity_pins_sppf_variant_group():
    """At fine granularity the three SPPF pools form the one multi-op
    variant group (they substitute atomically as the fused pyramid);
    every other op keeps per-op substitution."""
    from repro.core.pipeline import yolo_staged
    from repro.models import YOLOv8

    ycfg = YOLOv8Config(img_size=32)
    sm = yolo_staged(ycfg, YOLOv8(ycfg).init(jax.random.key(0)), granularity="fine")
    multi = [(a, b) for a, b in sm.variant_groups if b - a > 1]
    assert len(multi) == 1
    a, b = multi[0]
    names = [sm.ops[i][0] for i in range(a, b)]
    assert names == ["sppf.pool1", "sppf.pool2", "sppf.pool3"]
    # single-op groups cover everything else exactly once
    covered = sorted(i for lo, hi in sm.variant_groups for i in range(lo, hi))
    assert covered == list(range(len(sm.ops)))


def test_conv_block_batchnorm_b2_matches_ref():
    # B>1 batch norm takes cross-sample statistics: the wrapper must route
    # to the fused jnp reference, not the per-sample Pallas kernel
    x = jax.random.normal(jax.random.key(0), (2, 16, 16, 8))
    w, b, gamma, beta = _params(jax.random.key(1), 8, 16, 4)
    got = conv_block(x, w, b, gamma, beta, stride=2, padding=1, norm="batch", act="lrelu")
    want = conv_block_ref(x, w, b, gamma, beta, stride=2, padding=1, norm="batch", act="lrelu")
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=1e-5)


# ---------------------------------------------------------------- planning


@pytest.fixture(scope="module")
def serving_graphs():
    g_pix = Pix2PixGenerator(
        Pix2PixConfig(img_size=64, base=8, deconv_mode="cropping")
    ).layer_graph()
    g_yolo = YOLOv8(YOLOv8Config(img_size=64)).layer_graph()
    return [g_pix, g_yolo]


@pytest.fixture(scope="module")
def engines():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    return [dla, gpu]


@pytest.mark.parametrize("provider", [ANALYTIC, MeasuredCost()], ids=["analytic", "measured"])
def test_auto_never_worse_than_xla_on_serving_pair(serving_graphs, engines, provider):
    p_xla = nmodel_schedule(serving_graphs, engines, provider=provider, impl="xla")
    p_auto = nmodel_schedule(serving_graphs, engines, provider=provider, impl="auto")
    assert p_auto.cycle_time <= p_xla.cycle_time * (1 + 1e-9)


@pytest.mark.parametrize("gi", [0, 1], ids=["pix2pix", "yolov8"])
def test_auto_never_worse_per_graph(serving_graphs, engines, gi):
    # the pin the CI gate rides on: per serving graph, impl-aware planning
    # never loses to forced xla (auto only switches a segment when the
    # fused candidate dominates component-wise)
    g = [serving_graphs[gi]]
    p_xla = nmodel_schedule(g, engines, impl="xla")
    p_auto = nmodel_schedule(g, engines, impl="auto")
    assert p_auto.cycle_time <= p_xla.cycle_time * (1 + 1e-9)


def test_measured_auto_binds_pallas_segments(serving_graphs, engines):
    plan = nmodel_schedule(serving_graphs, engines, provider=MeasuredCost(), impl="auto")
    ir = plan.ir
    assert ir.impl_mode == "auto"
    bindings = ir.impl_bindings()
    assert any(i == "pallas_fused" for b in bindings for i in b), bindings
    assert "pallas_fused" in ir.describe()


def test_default_plan_is_pure_xla(serving_graphs, engines):
    plan = nmodel_schedule(serving_graphs, engines)
    ir = plan.ir
    assert ir.impl_mode == "xla"
    assert all(i == "xla" for b in ir.impl_bindings() for i in b)
    assert "pallas" not in ir.describe()


def test_plan_ir_json_roundtrip_preserves_impl(serving_graphs, engines):
    from repro.core.plan_ir import PlanIR

    plan = nmodel_schedule(serving_graphs, engines, provider=MeasuredCost(), impl="auto")
    rt = PlanIR.from_json(plan.ir.to_json())
    assert rt.impl_mode == plan.ir.impl_mode
    assert rt.impl_bindings() == plan.ir.impl_bindings()


def test_plan_api_validates_impl(serving_graphs, engines):
    from repro.core import api

    with pytest.raises(ValueError):
        api.plan(serving_graphs, engines, impl="fused")


def test_measured_coverage_reports_both_impls(serving_graphs):
    mc = MeasuredCost()
    for g in serving_graphs:
        rep = mc.coverage_report(g)
        assert set(rep) == {"xla", "pallas_fused"}
        assert rep["pallas_fused"]["coverage"] > 0.5


# ---------------------------------------------------------------- execution


def test_server_executes_pallas_plan_matches_run_all():
    from repro.serve import MultiStreamServer, build_pix_yolo_serving, merge_flags_for

    models, plan, streams, _ = build_pix_yolo_serving(
        img=32, base=8, n_pix=1, n_yolo=1, impl="pallas"
    )
    assert any(i == "pallas_fused" for b in plan.ir.impl_bindings() for i in b)
    server = MultiStreamServer(
        models,
        plan,
        streams,
        max_queue=4,
        microbatch=1,
        merge_batches=merge_flags_for(models),
        dispatch="overlapped",
        jit_segments=True,
    )
    x = jax.random.normal(jax.random.key(0), (1, 32, 32, 3))
    for s in streams:
        server.submit(s.model_index, x)
    server.pump()
    outs = server.drain()
    for s, model in zip(streams, models):
        ref = model.run_all(x)
        for got in outs[s.name]:
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
                np.testing.assert_allclose(
                    np.float32(a), np.float32(b), atol=5e-3, rtol=1e-2
                )


# ------------------------------------------------- bare 1x1 head convs


def test_yolo_head_bare_convs_register_span1_fuse_groups():
    """The YOLO head's final box3/cls3 convs (conv+bias, no norm/act)
    carry span-1 ``pallas_fused`` fuse attrs on the expanded graph —
    one fused kernel per conv, exact at any batch (no batch-norm
    caveat)."""
    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph().expand()
    heads = {
        l.name: l.attrs["fuse"]
        for l in g
        if (l.name.endswith(".box3") or l.name.endswith(".cls3")) and "fuse" in l.attrs
    }
    # every detection scale registers both head convs
    assert {n.split(".")[0] for n in heads} == {"head3", "head4", "head5"}
    assert len(heads) == 6
    for fu in heads.values():
        assert fu["span"] == 1
        assert (fu["kind"], fu["norm"], fu["act"]) == ("conv", "none", "none")
        assert fu["flops"] > 0 and fu["bytes"] > 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_yolo_head_conv_fused_parity(dtype):
    """The fused norm-free/act-free conv_block matches the plain Conv2D
    head conv at both serving dtypes on the real head shapes."""
    from repro.nn.conv import Conv2D

    for i, (shape, cout) in enumerate(
        [((1, 4, 4, 64), 64), ((1, 2, 2, 128), 2), ((1, 1, 1, 256), 64)]
    ):
        cin = shape[-1]
        x = jax.random.normal(jax.random.key(2 * i), shape).astype(dtype)
        w = (jax.random.normal(jax.random.key(2 * i + 1), (1, 1, cin, cout)) * 0.1).astype(
            jnp.float32
        )
        b = (jax.random.normal(jax.random.key(100 + i), (cout,)) * 0.1).astype(jnp.float32)
        got = conv_block(x, w, b=b, stride=1, padding=0, norm="none", act="none")
        want = Conv2D(cin, cout, 1, 1, padding=0)({"w": w, "b": b}, x)
        assert got.dtype == want.dtype
        atol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.float32(got), np.float32(want), atol=atol)
