"""Deadline-aware continuous batching: bucketed executables, the
slack-driven coalescer, batch-aware costs, and the batching metrics.

The load-bearing pins:

* **Bit-exactness** — coalesced, bucket-padded batched execution produces
  outputs bit-identical to per-frame execution on the eager path; padded
  lanes are sliced off before any completion and are never observable.
* **Deadline safety** — a partial bucket only holds when every member's
  SLO slack clears the expected batched service time plus the hold
  window, so batching can never convert a meetable deadline into a miss
  (``held_then_missed`` pinned at 0).
* **batch=1 identity** — every batch-aware code path (costs, planner,
  executor) is bit-identical to the pre-batching behaviour at batch 1.
* **No starvation** — age-tiebroken admission means every same-tier
  stream completes frames under sustained 3x overload.

The ``hypothesis`` property tests are gated on availability (the suite
must pass without it); each has a deterministic seeded equivalent.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.cost_model import (
    ANALYTIC,
    MeasuredCost,
    OnlineCost,
    batch_amortization,
    segment_cost,
)
from repro.core.engine import EngineSpec, jetson_orin_engines
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.graph import LayerGraph, pointwise_meta
from repro.core.pipeline import StagedModel
from repro.core.plan_ir import PlanIR, make_plan_ir
from repro.serve import (
    BatchConfig,
    MultiStreamServer,
    SLOPolicy,
    StreamExecutor,
    StreamSpec,
    TrafficConfig,
    bucket_for,
    merge_metrics,
    metrics_from_payload,
    run_open_loop,
)
from repro.serve.metrics import ServeMetrics, TickStats, engine_wait_summary
from repro.serve.replanner import Replanner

# ---- BatchConfig -----------------------------------------------------------


def test_batch_config_buckets_and_validation():
    bc = BatchConfig(max_batch=8, hold_ms=2.0)
    assert bc.enabled and bc.buckets == (1, 2, 4, 8)
    assert [bc.bucket_for(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 8]
    assert bc.hold_s == pytest.approx(2e-3)
    # non-power-of-two cap: the ladder still ends exactly at max_batch
    assert BatchConfig(max_batch=6).buckets == (1, 2, 4, 6)
    assert bucket_for(5, 6) == 6
    off = BatchConfig()
    assert not off.enabled and off.buckets == (1,)
    for bad in (dict(max_batch=0), dict(hold_ms=-1.0), dict(min_slack_factor=-0.1)):
        with pytest.raises(ValueError):
            BatchConfig(**bad)


def test_batch_config_dict_roundtrip():
    bc = BatchConfig(max_batch=4, hold_ms=1.5, min_slack_factor=2.0)
    assert BatchConfig.from_dict(bc.to_dict()) == bc
    assert BatchConfig.from_dict(None) == BatchConfig()


# ---- coalesced execution is bit-exact --------------------------------------


def _toy_staged(n_layers=4, name="toy"):
    ops = [(f"mul{i}", lambda p, s: {"x": s["x"] * 1.5 + 0.5}) for i in range(n_layers)]
    graph = LayerGraph(
        name, [pointwise_meta(i, f"mul{i}", "act", (1, 8)) for i in range(n_layers)]
    ).renumber()
    return StagedModel(
        name=name,
        ops=ops,
        params=None,
        graph=graph,
        init_state=lambda x: {"x": x},
        finalize=lambda s: s["x"],
        batch_independent=True,
    )


def _toy_executor(n_streams=3, max_batch=4, hold_ms=0.0, slos=None, **kw):
    sm = _toy_staged()
    routes = make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 2), (1, 2, 4)]])
    streams = [
        StreamSpec(f"s{i}", 0, slo=slos[i] if slos else None) for i in range(n_streams)
    ]
    ex = StreamExecutor(
        [sm],
        routes,
        streams,
        max_queue=kw.pop("max_queue", 8),
        merge_batches=True,
        batching=BatchConfig(max_batch=max_batch, hold_ms=hold_ms),
        jit_segments=kw.pop("jit_segments", False),
        **kw,
    )
    return ex, sm, streams


def test_coalesced_bucket_padded_execution_bit_exact():
    """3 streams coalesce into a padded bucket-4 flight; every output is
    bit-identical to per-frame StagedModel.run_all (pads sliced off)."""
    ex, sm, streams = _toy_executor(n_streams=3, max_batch=4)
    frames = {
        s.name: [jax.random.normal(jax.random.key(10 * i + t), (1, 8)) for t in range(2)]
        for i, s in enumerate(streams)
    }
    for t in range(2):
        for i, s in enumerate(streams):
            assert ex.submit(i, frames[s.name][t])
        ex.run_until_drained()
    outs = ex.outputs
    for s in streams:
        for f, o in zip(frames[s.name], outs[s.name]):
            np.testing.assert_array_equal(np.asarray(sm.run_all(f)), np.asarray(o))
    # the flights really coalesced across streams: each round's 3 frames
    # ride one padded bucket-4 flight with 3 valid lanes
    assert ex.completions[0].batch == 3
    assert all(c.batch == 3 for c in ex.completions)


def test_coalescer_random_interleavings_bit_exact_seeded():
    """Deterministic equivalent of the hypothesis property: random
    per-stream frame counts over several rounds, everything bit-exact."""
    rng = np.random.default_rng(7)
    for trial in range(3):
        ex, sm, streams = _toy_executor(n_streams=4, max_batch=4, max_queue=16)
        frames = {s.name: [] for s in streams}
        for rnd in range(3):
            for i, s in enumerate(streams):
                for t in range(int(rng.integers(0, 3))):
                    f = jax.random.normal(
                        jax.random.key(1000 * trial + 100 * rnd + 10 * i + t), (1, 8)
                    )
                    if ex.submit(i, f):
                        frames[s.name].append(f)
            ex.tick()
        outs = ex.run_until_drained()
        for s in streams:
            assert len(outs[s.name]) == len(frames[s.name])
            for f, o in zip(frames[s.name], outs[s.name]):
                np.testing.assert_array_equal(np.asarray(sm.run_all(f)), np.asarray(o))


def test_property_coalescer_bit_exact():
    """Property form of the interleaving pin (skipped without hypothesis)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(
        counts=st.lists(
            st.lists(st.integers(min_value=0, max_value=2), min_size=3, max_size=3),
            min_size=1,
            max_size=3,
        )
    )
    def run(counts):
        ex, sm, streams = _toy_executor(n_streams=3, max_batch=4, max_queue=16)
        frames = {s.name: [] for s in streams}
        for rnd, per_stream in enumerate(counts):
            for i, n in enumerate(per_stream):
                for t in range(n):
                    f = jax.random.normal(jax.random.key(100 * rnd + 10 * i + t), (1, 8))
                    if ex.submit(i, f):
                        frames[streams[i].name].append(f)
            ex.tick()
        outs = ex.run_until_drained()
        for s in streams:
            for f, o in zip(frames[s.name], outs[s.name]):
                np.testing.assert_array_equal(np.asarray(sm.run_all(f)), np.asarray(o))

    run()


def test_swap_plan_mid_stream_with_batching_stays_exact():
    """A plan hot-swap between ticks leaves in-flight batched frames on
    their admitted routes and later buckets on the new one — outputs stay
    bit-exact throughout."""
    ex, sm, streams = _toy_executor(n_streams=3, max_batch=4, max_queue=16)
    frames = {s.name: [] for s in streams}
    for i, s in enumerate(streams):
        f = jax.random.normal(jax.random.key(i), (1, 8))
        assert ex.submit(i, f)
        frames[s.name].append(f)
    ex.tick()  # bucket in flight on the old routes
    ex.swap_plan(make_plan_ir((sm.name,), ("E0", "E1"), [[(0, 0, 1), (1, 1, 4)]]))
    for i, s in enumerate(streams):
        f = jax.random.normal(jax.random.key(100 + i), (1, 8))
        assert ex.submit(i, f)
        frames[s.name].append(f)
    outs = ex.run_until_drained()
    for s in streams:
        assert len(outs[s.name]) == 2
        for f, o in zip(frames[s.name], outs[s.name]):
            np.testing.assert_array_equal(np.asarray(sm.run_all(f)), np.asarray(o))


def test_pix2pix_instance_norm_coalesces_exactly(staged_pix_instance):
    """Real model pin: instance-norm Pix2Pix streams coalesce into one
    padded bucket and stay bit-exact on the eager path."""
    sm = staged_pix_instance
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    ir = core.plan([sm.graph], [dla, gpu])
    streams = [StreamSpec(f"p{i}", 0) for i in range(3)]
    ex = StreamExecutor(
        [sm],
        ir,
        streams,
        max_queue=4,
        merge_batches=True,
        batching=BatchConfig(max_batch=4),
        jit_segments=False,
    )
    frames = {
        s.name: jax.random.normal(jax.random.key(i), (1, 32, 32, 3))
        for i, s in enumerate(streams)
    }
    for i, s in enumerate(streams):
        assert ex.submit(i, frames[s.name])
    outs = ex.run_until_drained()
    for s in streams:
        np.testing.assert_array_equal(
            np.asarray(sm.run_all(frames[s.name])), np.asarray(outs[s.name][0])
        )
    assert ex.completions[0].batch == 3  # one coalesced flight, 3 valid lanes


@pytest.fixture(scope="module")
def staged_pix_instance():
    from repro.models import Pix2PixConfig, Pix2PixGenerator

    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping", norm="instance")
    gen = Pix2PixGenerator(cfg)
    return core.pix2pix_staged(cfg, {"generator": gen.init(jax.random.key(0))})


# ---- the slack-driven hold --------------------------------------------------


def _item(age_s: float, degrade: int = 0):
    return (0, jnp.ones((1, 8)), time.perf_counter() - age_s, degrade)


def test_hold_requires_slack_above_floor():
    slos = [SLOPolicy(deadline_ms=1e6, tier=0) for _ in range(2)]
    ex, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=5.0, slos=slos)
    now = time.perf_counter()
    # huge deadline, fresh frame: slack clears any floor -> hold
    assert ex._should_hold(0, [(0, _item(0.0))], now)
    # tight deadline: slack below the floor (hold window alone) -> admit
    tight = [SLOPolicy(deadline_ms=3.0, tier=0) for _ in range(2)]
    ex2, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=5.0, slos=tight)
    assert not ex2._should_hold(0, [(0, _item(0.0))], time.perf_counter())
    # once the service EMA knows batched service costs ~8ms, a 15ms
    # deadline no longer clears 1.5*8ms + 5ms even though it clears the
    # bare window -> admit rather than risk the merge
    mid = [SLOPolicy(deadline_ms=15.0, tier=0) for _ in range(2)]
    ex3, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=5.0, slos=mid)
    ex3._svc_ema[(0, 1)] = 8e-3
    assert not ex3._should_hold(0, [(0, _item(0.0))], time.perf_counter())
    ex3._svc_ema[(0, 1)] = 1e-4  # cheap batched service -> slack clears -> hold
    assert ex3._should_hold(0, [(0, _item(0.0))], time.perf_counter())


def test_hold_disabled_without_window_and_for_degraded():
    slos = [SLOPolicy(deadline_ms=1e6, tier=0) for _ in range(2)]
    # hold_ms=0: pure greedy coalescing, never holds
    ex, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=0.0, slos=slos)
    assert not ex._should_hold(0, [(0, _item(0.0))], time.perf_counter())
    # degraded members never wait on a merge they can't join
    ex2, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=5.0, slos=slos)
    assert not ex2._should_hold(0, [(0, _item(0.0, degrade=1))], time.perf_counter())


def test_hold_window_expiry_admits_partial_bucket():
    slos = [SLOPolicy(deadline_ms=1e6, tier=0) for _ in range(2)]
    ex, _, _ = _toy_executor(n_streams=2, max_batch=4, hold_ms=5.0, slos=slos)
    now = time.perf_counter()
    ex._hold_since[0] = now - 6e-3  # window (5ms) expired
    assert not ex._should_hold(0, [(0, _item(0.0))], now)


def test_held_frames_coalesce_then_complete_within_deadline():
    """A held partial bucket picks up a late co-rider, admits as one
    flight, and the completions are marked held with deadlines met
    (held_then_missed stays 0 — the deadline-safety pin)."""
    slos = [SLOPolicy(deadline_ms=1e6, tier=0) for _ in range(2)]
    ex, sm, streams = _toy_executor(n_streams=2, max_batch=2, hold_ms=50.0, slos=slos)
    f0 = jax.random.normal(jax.random.key(0), (1, 8))
    assert ex.submit(0, f0)
    ex.tick()
    assert len(ex.completions) == 0  # partial bucket held, frame still queued
    assert len(ex.queues[0]) == 1
    f1 = jax.random.normal(jax.random.key(1), (1, 8))
    assert ex.submit(1, f1)
    outs = ex.run_until_drained()
    np.testing.assert_array_equal(np.asarray(sm.run_all(f0)), np.asarray(outs["s0"][0]))
    np.testing.assert_array_equal(np.asarray(sm.run_all(f1)), np.asarray(outs["s1"][0]))
    assert [c.batch for c in ex.completions] == [2, 2]
    assert all(c.held for c in ex.completions)
    m = ServeMetrics([s.name for s in streams], slos={s.name: s.slo for s in streams})
    for c in ex.completions:
        m.record(c.stream, c.latency_s, batch=c.batch, held=c.held)
    assert m.held_frames == 2 and m.held_then_missed == 0


def test_hold_window_expiry_flushes_lone_frame():
    """With no co-rider ever arriving, the held frame is admitted solo
    once the window expires — a hold can only ever cost hold_ms."""
    slos = [SLOPolicy(deadline_ms=1e6, tier=0) for _ in range(2)]
    ex, sm, _ = _toy_executor(n_streams=2, max_batch=2, hold_ms=2.0, slos=slos)
    f0 = jax.random.normal(jax.random.key(0), (1, 8))
    assert ex.submit(0, f0)
    ex.tick()
    assert len(ex.completions) == 0
    deadline = time.perf_counter() + 2.0
    while not ex.completions and time.perf_counter() < deadline:
        time.sleep(1e-3)
        ex.tick()
    ex.run_until_drained()
    assert len(ex.completions) == 1
    assert ex.completions[0].batch == 1 and ex.completions[0].held


def test_property_hold_never_violates_slack():
    """Property form (skipped without hypothesis): for random member ages
    and deadlines, _should_hold never holds a member whose slack is at or
    below the floor."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(
        ages_ms=st.lists(st.floats(0.0, 20.0), min_size=1, max_size=4),
        deadline_ms=st.floats(1.0, 40.0),
    )
    def run(ages_ms, deadline_ms):
        slos = [SLOPolicy(deadline_ms=deadline_ms, tier=0) for _ in range(4)]
        ex, _, _ = _toy_executor(n_streams=4, max_batch=8, hold_ms=5.0, slos=slos)
        now = time.perf_counter()
        cands = [(i, _item(a * 1e-3)) for i, a in enumerate(ages_ms)]
        if ex._should_hold(0, cands, now):
            floor = ex.batching.min_slack_factor * ex.expected_service(0, 8) + ex.batching.hold_s
            for i, item in cands:
                slack = slos[i].deadline_s - (now - item[2])
                assert slack > floor

    run()


# ---- starvation regression (age tiebreak) ----------------------------------


def test_same_tier_streams_all_complete_under_overload():
    """Sustained 3x overload over 4 same-tier streams: with the age
    tiebreak no stream can lose the admission cut forever to round-robin
    phasing — every stream completes frames."""
    sm = _toy_staged()
    engines = [
        EngineSpec("E0", 1, 1.0e12, 500e9, 50e9, ()),
        EngineSpec("E1", 1, 1.0e12, 500e9, 50e9, ()),
    ]
    ir = core.plan([sm.graph], engines)
    streams = [
        StreamSpec(f"s{i}", 0, slo=SLOPolicy(deadline_ms=60.0, tier=0)) for i in range(4)
    ]
    server = MultiStreamServer(
        [sm], ir, streams, max_queue=2, jit_segments=False, resolution_flexible=True
    )
    delay = 2e-3
    server.executor.segment_delay_fn = lambda seg: delay
    # capacity ~ 1/(2 segments * delay) per frame; drive each stream at 3x
    # its fair share of that
    rate = 3.0 * (1.0 / (2 * delay)) / len(streams)
    traffic = {
        s.name: TrafficConfig(process="poisson", rate_hz=rate, seed=20 + i)
        for i, s in enumerate(streams)
    }
    run_open_loop(server, traffic, lambda name: jnp.ones((1, 8)), 1.0, max_wall_s=120.0)
    completed = {n: m.completed for n, m in server.metrics.streams.items()}
    assert all(c > 0 for c in completed.values()), completed


# ---- batch-aware costs + planner -------------------------------------------


def test_batch_amortization_curve():
    assert batch_amortization(1) == 1.0  # batch-1 costs bit-identical
    vals = [batch_amortization(b) for b in (1, 2, 4, 8, 64)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))  # monotone nonincreasing
    assert all(v > 0.75 for v in vals)  # amortizes only the fixed fraction


def test_segment_cost_batch1_identity_and_batched_cheaper():
    from repro.models import YOLOv8, YOLOv8Config

    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    c1 = segment_cost(g, 0, len(g), gpu, gpu, True)
    c1b = segment_cost(g, 0, len(g), gpu, gpu, True, batch=1)
    assert c1.elapsed == c1b.elapsed  # bit-identical, not approx
    c4 = segment_cost(g, 0, len(g), gpu, gpu, True, batch=4)
    assert c4.elapsed < c1.elapsed  # per-frame amortized


def test_plan_batch_validation_and_ir_roundtrip():
    from repro.models import YOLOv8, YOLOv8Config

    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    with pytest.raises(ValueError):
        core.plan([g], [dla, gpu], batch=0)
    with pytest.raises(ValueError):
        core.plan([g], [dla, gpu], kind="standalone", batch=4)
    p1 = core.plan([g], [dla, gpu])
    p4 = core.plan([g], [dla, gpu], batch=4)
    assert p1.batch == 1 and p4.batch == 4
    assert p4.expected_cycle < p1.expected_cycle  # amortized per-frame cycle
    rt = PlanIR.from_json(p4.to_json())
    assert rt.batch == 4


def test_online_cost_per_bucket_scale_ladder():
    online = OnlineCost(ANALYTIC)
    online.observe("GPU", 2.0, 1.0)  # engine-wide scale 2x
    online.observe("GPU|b4", 3.0, 1.0)  # bucket-4 residual 3x
    assert online.scale_for("GPU") == pytest.approx(2.0)
    assert online.scale_for("GPU", batch=4) == pytest.approx(3.0)
    # unseen bucket falls back to the engine-wide scale
    assert online.scale_for("GPU", batch=2) == pytest.approx(2.0)


def test_measured_cost_per_bucket_cache_keys():
    m = MeasuredCost()
    g = LayerGraph(
        "t", [pointwise_meta(0, "act0", "act", (1, 16, 16, 4), flops_per_elem=2.0)]
    ).renumber()
    gpu, _ = jetson_orin_engines()
    t1 = m.layer_time(g[0], gpu)
    t4 = m.layer_time(g[0], gpu, batch=4)
    assert t1 > 0 and t4 > 0
    import re

    keys = set(m._cache)
    assert any(k.endswith("|b4") for k in keys)  # per-bucket entry
    # batch-1 key keeps the legacy un-suffixed format
    assert any(not re.search(r"\|b\d+$", k) for k in keys)


# ---- metrics: occupancy ledger + wait breakdown ----------------------------


def test_metrics_batching_ledger_and_payload_roundtrip():
    m = ServeMetrics(["a", "b"], slos={"a": SLOPolicy(deadline_ms=50.0)})
    m.record("a", 0.01, batch=4, held=True)
    m.record("a", 0.01, batch=4)
    m.record("b", 0.02, batch=1)
    m.record("a", 0.09, batch=2, held=True)  # held AND missed its 50ms deadline
    assert m.batch_occupancy == {4: 2, 1: 1, 2: 1}
    assert m.mean_effective_batch() == pytest.approx((4 + 4 + 1 + 2) / 4)
    assert m.held_frames == 2 and m.held_then_missed == 1
    m.record_tick(TickStats(0, 0.01, 0.002, 3, engine_wait={"GPU": (1e-3, 2e-4, 5e-4)}))
    rt = metrics_from_payload(m.to_payload())
    assert rt.batch_occupancy == m.batch_occupancy
    assert rt.held_frames == 2 and rt.held_then_missed == 1
    assert rt.ticks[0].engine_wait == {"GPU": (1e-3, 2e-4, 5e-4)}
    rep = rt.report(1.0)
    assert rep["batching"]["occupancy"] == {"4": 2, "1": 1, "2": 1}
    assert rep["batching"]["mean_effective_batch"] == pytest.approx(2.75)
    merged = merge_metrics([m, rt])
    assert merged.batch_occupancy == {4: 4, 1: 2, 2: 2}
    assert merged.held_then_missed == 2


def test_metrics_payload_tolerates_legacy_tick_rows():
    m = ServeMetrics(["a"])
    m.record("a", 0.01)
    payload = m.to_payload()
    payload["ticks"] = [[0, 0.01, 0.0, 2]]  # pre-batching 4-element row
    rt = metrics_from_payload(payload)
    assert rt.ticks[0].engine_wait is None
    assert rt.batch_occupancy == {1: 1}


def test_engine_wait_summary_fractions():
    ticks = [
        TickStats(0, 0.010, 0.0, 2, engine_wait={"GPU": (4e-3, 1e-3, 5e-3)}),
        TickStats(1, 0.010, 0.0, 2, engine_wait={"GPU": (0.0, 0.0, 1e-2)}),
    ]
    s = engine_wait_summary(ticks)
    assert s["GPU"]["issue_s"] == pytest.approx(4e-3)
    assert s["GPU"]["resolve_s"] == pytest.approx(1.5e-2)
    total = s["GPU"]["issue_frac"] + s["GPU"]["transfer_frac"] + s["GPU"]["resolve_frac"]
    assert total == pytest.approx(1.0)


def test_executor_reports_engine_wait_breakdown():
    ex, sm, streams = _toy_executor(n_streams=2, max_batch=2)
    for i in range(2):
        assert ex.submit(i, jnp.ones((1, 8)))
    ex.run_until_drained()
    waited = [t for t in ex.tick_stats if t.engine_wait]
    assert waited, "no per-engine wait breakdown on any tick"
    for t in waited:
        for name, w in t.engine_wait.items():
            assert len(w) == 3 and all(x >= 0.0 for x in w)


# ---- replanner batch trigger ------------------------------------------------


class _ExecutorShim:
    def __init__(self, max_batch):
        self.batching = BatchConfig(max_batch=max_batch)


def test_replanner_batch_signal_hysteresis():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    from repro.models import YOLOv8, YOLOv8Config

    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    rp = Replanner([g], [dla, gpu])
    shim = _ExecutorShim(max_batch=4)
    # matching bucket: quiet
    rp._batch_ema = 1.0
    assert rp._batch_signal(shim) is None
    # sustained shift to bucket 4: fires only after `hysteresis` ticks
    rp._batch_ema = 3.6
    fires = [rp._batch_signal(shim) for _ in range(rp.config.hysteresis)]
    assert all(f is None for f in fires[:-1])
    assert fires[-1] == {"observed_batch": 4.0, "planned_batch": 1.0}
    # batching disabled: never fires regardless of the EMA
    rp2 = Replanner([g], [dla, gpu])
    rp2._batch_ema = 3.6
    assert rp2._batch_signal(_ExecutorShim(max_batch=1)) is None


def test_replanner_plans_at_observed_bucket():
    gpu, dla = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)
    from repro.models import YOLOv8, YOLOv8Config

    g = YOLOv8(YOLOv8Config(img_size=32)).layer_graph()
    rp = Replanner([g], [dla, gpu])
    rp._planned_batch = 4
    plan = rp._plan(OnlineCost(ANALYTIC))
    assert plan.batch == 4
