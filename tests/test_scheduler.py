"""The paper's core: constraints, surgery, HaX-CoNN schedules, pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.models import Pix2PixConfig, Pix2PixGenerator, YOLOv8, YOLOv8Config


@pytest.fixture(scope="module")
def engines():
    return jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)


@pytest.fixture(scope="module")
def graphs():
    return {
        mode: Pix2PixGenerator(Pix2PixConfig(deconv_mode=mode)).layer_graph()
        for mode in ("padded", "cropping", "conv")
    }


def test_padded_model_is_dla_illegal(engines, graphs):
    gpu, dla = engines
    ill, _ = core.check_graph(graphs["padded"], dla)
    # all 8 upsample deconvs carry padding=1 -> illegal (paper §V.A.2)
    assert len(ill) == 8
    assert all("deconv" in graphs["padded"][i].name for i in ill)
    for mode in ("cropping", "conv"):
        ill, _ = core.check_graph(graphs[mode], dla)
        assert not ill, f"{mode} must be fully DLA-legal"


def test_surgery_rewrites_match_direct_builds(engines, graphs):
    gpu, dla = engines
    for mode in ("cropping", "conv"):
        fixed, report = core.apply_surgery(graphs["padded"], dla, mode)
        assert len(report.replaced) == 8
        assert not report.remaining_illegal
        direct = graphs[mode]
        assert [l.kind for l in fixed] == [l.kind for l in direct]
        assert fixed.total_flops() == pytest.approx(direct.total_flops())


def test_surgery_conv_param_delta_close_to_paper(engines, graphs):
    """Paper: conv substitution adds 10,211,409 params (Table II)."""
    gpu, dla = engines
    _, report = core.apply_surgery(graphs["padded"], dla, "conv")
    assert abs(report.param_delta - 10_211_409) / 10_211_409 < 0.001 or abs(report.param_delta - 10_211_409) < 5000


def test_rejected_rules_exist():
    for name in ("avg_pool", "max_pool", "reduced_kernel"):
        assert core.RULES[name].quality == "rejected"


def test_standalone_schedule_fallback_utilization(engines, graphs):
    """Fig. 10: original model keeps the GPU busy; surgered models don't."""
    gpu, dla = engines
    assert core.peer_utilization(graphs["padded"], dla, gpu) > 0.1
    assert core.peer_utilization(graphs["cropping"], dla, gpu) == 0.0
    assert core.peer_utilization(graphs["conv"], dla, gpu) == 0.0


def test_standalone_original_faster_than_modified(engines, graphs):
    """Fig. 9: the original (fallback) model outruns the modified ones in
    STANDALONE mode — transitions cost less than the extra DLA layers."""
    gpu, dla = engines
    fps = {m: 1.0 / core.standalone_schedule(g, dla, gpu).cycle_time for m, g in graphs.items()}
    assert fps["padded"] > fps["conv"]


def test_naive_schedule_gpu_gain(engines, graphs):
    """Fig. 11: surgered models raise concurrent GPU throughput."""
    gpu, dla = engines
    yolo = YOLOv8(YOLOv8Config(img_size=256)).layer_graph()
    fps_orig = core.naive_schedule(graphs["padded"], yolo, dla, gpu).loads["GPU"].fps
    fps_crop = core.naive_schedule(graphs["cropping"], yolo, dla, gpu).loads["GPU"].fps
    assert fps_crop > fps_orig * 1.09  # paper: 9-18% (our cost model: more)


def test_haxconn_balances_surgered_models(engines, graphs):
    """Tables IV/VI: fallback-free models balance engine busy times."""
    gpu, dla = engines
    r = core.haxconn_schedule(graphs["cropping"], graphs["cropping"], dla, gpu)
    busy_gpu = r.schedule.loads["GPU"].busy
    busy_dla = r.schedule.loads["DLA"].busy
    assert abs(busy_gpu - busy_dla) / max(busy_gpu, busy_dla) < 0.15
    # partitions must be interior
    assert 0 < r.p_a < len(graphs["cropping"])
    assert 0 < r.p_b < len(graphs["cropping"])


def test_haxconn_surgered_beats_original(engines, graphs):
    gpu, dla = engines
    agg_orig = core.haxconn_schedule(graphs["padded"], graphs["padded"], dla, gpu).schedule.aggregate_fps
    agg_crop = core.haxconn_schedule(graphs["cropping"], graphs["cropping"], dla, gpu).schedule.aggregate_fps
    assert agg_crop > agg_orig * 1.1


def test_haxconn_fixed_partition_evaluation(engines, graphs):
    gpu, dla = engines
    r = core.haxconn_schedule(graphs["cropping"], graphs["cropping"], dla, gpu, fixed=(4, 53))
    assert (r.p_a, r.p_b) == (4, 53)
    assert r.schedule.cycle_time > 0


def test_schedule_timeline_renders(engines, graphs):
    gpu, dla = engines
    r = core.haxconn_schedule(graphs["cropping"], graphs["cropping"], dla, gpu)
    text = r.schedule.ascii_timeline()
    assert "DLA" in text and "GPU" in text and "ms" in text


# ---- executable pipeline --------------------------------------------------


def test_pipeline_stream_matches_monolithic(engines):
    gpu, dla = engines
    cfg = Pix2PixConfig(img_size=32, base=8, deconv_mode="cropping")
    gen = Pix2PixGenerator(cfg)
    params = {"generator": gen.init(jax.random.key(0))}
    gsm = core.pix2pix_staged(cfg, params)
    ycfg = YOLOv8Config(img_size=32)
    ym = YOLOv8(ycfg)
    yparams = ym.init(jax.random.key(1))
    ysm = core.yolo_staged(ycfg, yparams)
    plan = core.haxconn_schedule(gsm.graph, ysm.graph, dla, gpu)
    pipe = core.TwoModelPipeline(gsm, ysm, plan)
    frames = [jax.random.normal(jax.random.key(i), (1, 32, 32, 3)) for i in range(3)]
    outs_a, outs_b = pipe.run_stream(frames, frames)
    for f, o in zip(frames, outs_a):
        np.testing.assert_allclose(np.float32(gsm.run_all(f)), np.float32(o), atol=1e-5)
    for f, o in zip(frames, outs_b):
        ref = ym(yparams, f)
        for k in ref:
            np.testing.assert_allclose(np.float32(ref[k]), np.float32(o[k]), atol=1e-5)
    # steady state: both engines appear in every interior tick
    ticks = {}
    for e in pipe.log:
        ticks.setdefault(e.tick, set()).add(e.engine)
    interior = [t for t in ticks if 0 < t < max(ticks)]
    assert all(ticks[t] == {"con", "flex"} for t in interior)


def test_staged_ops_align_with_graph():
    for mode in ("padded", "cropping", "conv"):
        cfg = Pix2PixConfig(img_size=64, base=8, deconv_mode=mode)
        gen = Pix2PixGenerator(cfg)
        params = {"generator": gen.init(jax.random.key(0))}
        sm = core.pix2pix_staged(cfg, params)
        assert len(sm.ops) == len(sm.graph)
        x = jax.random.normal(jax.random.key(1), (1, 64, 64, 3))
        np.testing.assert_allclose(
            np.float32(gen(params["generator"], x)), np.float32(sm.run_all(x)), atol=1e-5
        )
