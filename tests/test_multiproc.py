"""Multi-process fleet tests: shared-memory frame transport, fleet-wide
calibration merge + atomic checkpointing, metrics payload round-trip,
router eviction, per-worker device slicing, 2-worker bit-exactness vs the
in-process oracle, worker-failure robustness, and the 2W >= 1W goodput
pin (nightly tier)."""
import json
import os
import threading

import jax
import numpy as np
import pytest

from repro.core.cost_model import OnlineCost
from repro.core.engine import DevicePool, jetson_orin_engines
from repro.serve import (
    FleetRouter,
    ProcFleetServer,
    ShmRing,
    TrafficConfig,
    build_server,
    merge_calibration,
    metrics_from_payload,
)
from repro.serve.metrics import ServeMetrics
from repro.serve.multiproc import _decode_frame, _encode_frame
from repro.serve.traffic import SLOPolicy

# ---- shared-memory ring -----------------------------------------------------


def test_shm_ring_roundtrip_and_slot_reuse():
    ring = ShmRing(4 * 8 * 8 * 3, slots=2)
    try:
        view = ShmRing(ring.slot_bytes, ring.slots, name=ring.name)  # worker side
        rng = np.random.default_rng(0)
        # 5 puts over 2 slots: round-robin reuse must never corrupt a
        # frame read before the next put lands in its slot
        for t in range(5):
            a = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
            desc = _encode_frame(a, ring)
            assert desc["via"] == "shm"
            np.testing.assert_array_equal(_decode_frame(desc, view), a)
        view.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_pipe_fallback_for_oversized_frames():
    ring = ShmRing(4 * 8 * 8 * 3, slots=2)
    try:
        big = np.ones((2, 8, 8, 3), np.float32)  # 2x the slot size
        desc = _encode_frame(big, ring)
        assert desc["via"] == "pipe"
        np.testing.assert_array_equal(_decode_frame(desc, ring), big)
        with pytest.raises(ValueError):
            ring.put(big)
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_validates_inputs():
    with pytest.raises(ValueError):
        ShmRing(0, slots=2)
    with pytest.raises(ValueError):
        ShmRing(16, slots=0)


# ---- calibration merge + OnlineCost state -----------------------------------


def test_merge_calibration_is_magnitude_weighted():
    """The merged scale is sum(num)/sum(den) over workers — a worker with
    10x the decayed magnitude carries ~10x the weight, the same
    weighted-ratio idiom OnlineCost.observe applies per sample."""
    heavy = {"GPU|xla": {"num": 20.0, "den": 10.0}}  # scale 2.0, big mass
    light = {"GPU|xla": {"num": 1.0, "den": 1.0}}  # scale 1.0, small mass
    m = merge_calibration([heavy, light])
    scale = m["GPU|xla"]["num"] / m["GPU|xla"]["den"]
    assert scale == pytest.approx(21.0 / 11.0)
    # mean-of-sums keeps the merged state in one worker's units, so a
    # push/pull/push cycle is a fixed point rather than doubling the mass
    again = merge_calibration([m, m])
    assert again["GPU|xla"] == pytest.approx(m["GPU|xla"])


def test_merge_calibration_skips_empty_and_nonpositive():
    m = merge_calibration(
        [{"GPU|xla": {"num": 0.0, "den": 1.0}}, {"DLA|xla": {"num": 2.0, "den": 1.0}}, {}]
    )
    assert set(m) == {"DLA|xla"}


def test_online_cost_state_roundtrip():
    a = OnlineCost()
    a.observe("GPU", observed_s=2.0e-3, expected_s=1.0e-3)
    a.observe("DLA", observed_s=0.5e-3, expected_s=1.0e-3)
    b = OnlineCost().load_state(a.state())
    assert b.scale("GPU") == pytest.approx(a.scale("GPU"))
    assert b.scale("DLA") == pytest.approx(a.scale("DLA"))
    # non-positive entries are rejected, existing state survives
    b.load_state({"GPU": {"num": -1.0, "den": 0.0}})
    assert b.scale("GPU") == pytest.approx(a.scale("GPU"))


def test_save_calibration_atomic_under_concurrent_writers(tmp_path):
    """N threads checkpointing the same path concurrently (the fleet's
    periodic sync vs a CLI exit save) never produce a torn file: every
    writer goes through a unique temp + os.replace, so any observable
    file content is one writer's complete JSON."""
    path = str(tmp_path / "calib.json")
    n_threads, n_saves = 4, 12
    stop = threading.Event()
    errors = []

    def writer(k):
        oc = OnlineCost()
        oc.observe("GPU", observed_s=(k + 2) * 1e-3, expected_s=1e-3)
        for _ in range(n_saves):
            oc.save_calibration(path)

    def reader():
        while not stop.is_set():
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        payload = json.load(f)
                    assert payload["version"] == 1 and payload["engines"]
                except (json.JSONDecodeError, AssertionError) as e:
                    errors.append(e)

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(n_threads)]
    observer = threading.Thread(target=reader)
    observer.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    observer.join()
    assert not errors, f"torn/partial calibration file observed: {errors[:3]}"
    # no temp files left behind, and the survivor round-trips
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []
    assert OnlineCost().load_calibration(path).calibrated


# ---- metrics payload round-trip ---------------------------------------------


def test_metrics_payload_roundtrip_exact():
    m = ServeMetrics(
        ["mri-0", "det-0"],
        slos={
            "mri-0": SLOPolicy(deadline_ms=50.0, tier=1, name="r"),
            "det-0": SLOPolicy(deadline_ms=30.0, tier=0, name="d"),
        },
        recent_window=8,
    )
    for name, lat in (("mri-0", 0.01), ("mri-0", 0.2), ("det-0", 0.005)):
        m.record_arrival(name)
        m.record_admission(name, "admit")
        m.record(name, lat)
    m.record_admission("det-0", "drop")
    from repro.serve.metrics import TickStats

    m.record_tick(TickStats(0, 0.02, 0.01, 3))
    r = metrics_from_payload(m.to_payload())
    assert r.report(1.0) == m.report(1.0)
    assert r.recent_slo_miss_rate() == m.recent_slo_miss_rate()
    assert r._recent.maxlen == m._recent.maxlen


# ---- router eviction --------------------------------------------------------


def test_router_evict_unpins_streams_and_excludes_replica():
    r = FleetRouter(2, seed=0)
    first = r.route_arrival("mri-0", [0, 0], deadline_s=0.05)
    other = 1 - first
    migrated = r.evict(first)
    assert migrated == ["mri-0"]
    assert r.alive == [other]
    assert r.replica_of("mri-0") is None
    # next arrival re-routes to the survivor, even when it looks loaded
    loads = [0, 0]
    loads[other] = 100
    assert r.route_arrival("mri-0", loads, deadline_s=0.05) == other
    assert r.evict(first) == []  # idempotent
    summ = r.summary()
    assert summ["alive"] == [other] and summ["evicted"] == [first]
    r.evict(other)
    with pytest.raises(RuntimeError):
        r.pick([0, 0])


# ---- per-worker device slicing ----------------------------------------------


def test_worker_pool_slices_devices():
    gpu, dla = jetson_orin_engines()
    devices = ["d0", "d1", "d2", "d3"]  # opaque placement targets
    pool = DevicePool((dla, gpu), devices=devices)
    sub0 = pool.worker_pool(0, 2)
    sub1 = pool.worker_pool(1, 2)
    assert sub0.devices == ["d0", "d1"] and sub1.devices == ["d2", "d3"]
    assert sub0.engines == pool.engines
    # more workers than devices: wraps, every worker still gets a device
    assert DevicePool((dla, gpu), devices=["d0"]).worker_pool(3, 4).devices == ["d0"]
    with pytest.raises(ValueError):
        pool.worker_pool(2, 2)


# ---- facade validation ------------------------------------------------------


def test_build_server_rejects_workers_with_replicas():
    with pytest.raises(ValueError, match="mutually exclusive"):
        build_server(img=32, n_pix=1, workers=2, replicas=2)


def test_build_server_rejects_provider_instance_for_workers():
    from repro.core.cost_model import make_cost_provider

    with pytest.raises(ValueError, match="name"):
        build_server(img=32, n_pix=1, workers=2, cost=make_cost_provider("analytic"))


def test_proc_fleet_rejects_unserializable_knobs(staged_plan_streams):
    plan, streams = staged_plan_streams
    from repro.serve import AdmissionConfig

    with pytest.raises(ValueError, match="cost provider name"):
        ProcFleetServer(plan, streams, workers=1, cost="bogus")
    with pytest.raises(ValueError, match="degrade_frame"):
        ProcFleetServer(
            plan, streams, workers=1,
            admission=AdmissionConfig(degrade_frame=lambda f, lvl: f),
        )
    with pytest.raises(ValueError, match="workers"):
        ProcFleetServer(plan, streams, workers=0)


@pytest.fixture(scope="module")
def staged_plan_streams():
    from repro import core
    from repro.serve import StreamSpec
    from repro.serve.demo import _build_pix_yolo_models

    models, _, (gpu, dla) = _build_pix_yolo_models(img=32, base=8, n_pix=1, n_yolo=1)
    plan = core.plan([m.graph for m in models], [dla, gpu])
    return plan, [StreamSpec("mri-0", 0), StreamSpec("det-0", 1)]


# ---- 2-worker fleet: bit-exactness + failure robustness ---------------------

_PROC_KW = dict(img=32, base=8, n_pix=2, n_yolo=1, seed=0, max_queue=8, jit_segments=False)


@pytest.fixture(scope="module")
def proc_fleet_outputs():
    """One 2-worker fleet session shared by the fast-tier proc tests:
    spawn cost is paid once; the eager (jit_segments=False) path keeps
    worker startup bounded and the outputs bit-exact-comparable."""
    ref = build_server(**_PROC_KW)
    fleet = build_server(**_PROC_KW, workers=2)
    frames = {
        s.name: [np.asarray(ref.frame_for(s.name, t)) for t in range(3)]
        for s in ref.streams
    }
    for t in range(3):
        for s in ref.streams:
            ref.server.offer(s.name, frames[s.name][t])
            fleet.server.offer(s.name, frames[s.name][t])
    out_ref = ref.server.drain()
    out_fleet = fleet.server.drain()
    report = fleet.server.report()
    yield fleet, out_ref, out_fleet, report
    fleet.close()


def test_proc_fleet_bit_exact_vs_in_process(proc_fleet_outputs):
    """Per-stream outputs from a 2-worker fleet are bit-exact vs a single
    in-process executor fed the same seeded arrivals: workers rebuild
    models from the same seeded params and the same PlanIR JSON, sticky
    routing preserves per-stream frame order, and frames round-trip the
    shared-memory ring in f32 without loss."""
    _, out_ref, out_fleet, _ = proc_fleet_outputs
    assert set(out_ref) == set(out_fleet)
    for name in out_ref:
        assert len(out_fleet[name]) == len(out_ref[name]) == 3
        for a, b in zip(out_ref[name], out_fleet[name]):
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_proc_fleet_report_merges_worker_ledgers(proc_fleet_outputs):
    fleet, _, _, rep = proc_fleet_outputs
    assert rep["workers"] == 2
    assert rep["alive_workers"] == [0, 1]
    assert rep["frames"] == 9  # 3 streams x 3 frames
    assert rep["frames"] == sum(r["frames"] for r in rep["per_worker"])
    assert sum(rep["router"]["routed_frames"]) == 9
    assert rep["worker_failures"] == []
    # every stream stuck to exactly one worker
    assert set(fleet.server.router.assignments) == {s.name for s in fleet.streams}


def test_proc_fleet_evicts_killed_worker_and_reroutes():
    """Satellite: a worker killed mid-session is detected on its next RPC,
    evicted from routing, its sticky streams migrate to survivors, and
    the failure is ledgered in the fleet report."""
    fleet = build_server(**_PROC_KW, workers=2)
    try:
        server = fleet.server
        for s in fleet.streams:  # establish sticky assignments
            server.offer(s.name, fleet.frame_for(s.name, 0))
        server.drain()
        victim = 1
        victim_streams = sorted(
            n for n, w in server.router.assignments.items() if w == victim
        )
        assert victim_streams, "router left worker 1 idle; test premise broken"
        server.handles[victim].process.kill()
        server.handles[victim].process.join(timeout=10.0)
        # keep offering: the dead worker's streams must re-route and serve
        for t in range(1, 3):
            for s in fleet.streams:
                server.offer(s.name, fleet.frame_for(s.name, t))
        outs = server.drain()
        for name in victim_streams:
            assert len(outs[name]) >= 1  # migrated frames actually served
        rep = server.report()
        assert rep["alive_workers"] == [0]
        assert server.router.summary()["evicted"] == [victim]
        (failure,) = [f for f in rep["worker_failures"] if f["worker"] == victim]
        assert failure["migrated_streams"] == victim_streams
        # the death may surface as EOF on recv or a broken pipe on send,
        # depending on which side of the RPC the kill lands on
        assert failure["reason"].startswith("offer")
        # survivors now own every stream
        assert set(server.router.assignments.values()) == {0}
    finally:
        fleet.close()


# ---- goodput scaling pin (nightly tier) ------------------------------------


@pytest.mark.slow
def test_proc_fleet_2w_goodput_not_below_1w_same_load():
    """Process-parallel replication contract: at the same total offered
    load (past one worker's capacity), the 2-worker fleet's goodput is at
    least the single worker's. Paired runs, up to 3 attempts — the same
    flake policy as the in-process fleet pin. Needs real processors: on
    a single-core host two workers only context-switch, so the contract
    is void there (the bench records the same applicability flag)."""
    cores = (
        len(os.sched_getaffinity(0))
        if hasattr(os, "sched_getaffinity")
        else (os.cpu_count() or 1)
    )
    if cores < 2:
        pytest.skip(f"needs >= 2 schedulable cores for process parallelism (got {cores})")

    def run(workers: int) -> float:
        fleet = build_server(
            img=32, n_pix=2, n_yolo=1, deadline_ms=80.0,
            traffic=TrafficConfig(process="poisson", rate_hz=60.0, seed=5),
            admission=True, workers=workers,
        )
        try:
            fleet.server.reset_metrics()
            return fleet.run_open_loop(1.0, max_wall_s=120.0)["goodput_fps"]
        finally:
            fleet.close()

    pairs = []
    for _ in range(3):
        g1, g2 = run(1), run(2)
        pairs.append((g1, g2))
        if g2 >= g1:
            return
    raise AssertionError(f"2-worker goodput below single-worker in all attempts: {pairs}")
