"""Hypothesis property tests on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro import core, nn
from repro.core.constraints import DLA_ANALOGUE_CONSTRAINTS
from repro.core.engine import jetson_orin_engines
from repro.core.graph import LayerGraph, conv_meta, pointwise_meta
from repro.models import Pix2PixConfig, Pix2PixGenerator
from repro.train.optimizer import AdamW
from repro.train.metrics import psnr, ssim, mse

GPU, DLA = jetson_orin_engines(constraints_dla=DLA_ANALOGUE_CONSTRAINTS)


@st.composite
def layer_graphs(draw):
    """Random conv/deconv chains with coherent shapes."""
    n = draw(st.integers(3, 12))
    h, c = 64, draw(st.sampled_from([3, 8, 16]))
    layers = []
    for i in range(n):
        kind = draw(st.sampled_from(["conv", "deconv", "act", "bn"]))
        if kind == "conv" and h >= 8:
            co = draw(st.sampled_from([8, 16, 32]))
            layers.append(conv_meta(i, f"conv{i}", 1, h, h, c, co, 4, 2, 1))
            h, c = h // 2, co
        elif kind == "deconv" and h <= 64:
            co = draw(st.sampled_from([8, 16]))
            pad = draw(st.sampled_from([0, 1]))
            layers.append(conv_meta(i, f"deconv{i}", 1, h, h, c, co, 4, 2, pad, transposed=True))
            h, c = 2 * h + (2 - 2 * pad), co
        else:
            layers.append(pointwise_meta(i, f"{kind}{i}", kind, (1, h, h, c)))
    return LayerGraph("hyp", layers).renumber()


@given(layer_graphs())
@settings(max_examples=25, deadline=None)
def test_surgery_removes_all_matched_illegality(g):
    fixed, report = core.apply_surgery(g, DLA, "cropping")
    ill, _ = core.check_graph(fixed, DLA)
    # cropping fixes every deconv-padding violation; nothing else is illegal
    assert not ill
    # surgery preserves total conv/deconv compute flops
    orig_flops = sum(l.flops for l in g if l.kind in ("conv", "deconv"))
    new_deconv_flops = sum(l.flops for l in fixed if l.kind == "deconv")
    assert new_deconv_flops <= orig_flops + 1e-6


@given(layer_graphs(), layer_graphs())
@settings(max_examples=15, deadline=None)
def test_haxconn_invariants(ga, gb):
    r = core.haxconn_schedule(ga, gb, DLA, GPU)
    s = r.schedule
    # partitions cover each model exactly once
    assert 1 <= r.p_a < len(ga) and 1 <= r.p_b < len(gb)
    # cycle >= each engine's busy time; idle fractions within [0,1]
    for e in ("DLA", "GPU"):
        assert s.cycle_time >= s.loads[e].busy - 1e-12
        assert -1e-9 <= s.idle_fraction(e) <= 1.0
    # optimal schedule can't be slower than a fixed midpoint schedule
    mid = core.haxconn_schedule(ga, gb, DLA, GPU, fixed=(len(ga) // 2, len(gb) // 2))
    assert s.cycle_time <= mid.schedule.cycle_time + 1e-12


@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from([16, 32]),
    st.sampled_from([(4, 2, 1)]),
)
@settings(max_examples=10, deadline=None)
def test_deconv_pad_equals_valid_plus_crop(seed, hw, ksp):
    """The paper's eq.(6) == eq.(5)+(7) equivalence, exact."""
    k, s, p = ksp
    key = jax.random.key(seed)
    x = jax.random.normal(key, (1, hw, hw, 4))
    w = jax.random.normal(jax.random.key(seed ^ 1), (k, k, 4, 6)) * 0.2
    pad = nn.ConvTranspose2D(4, 6, k, s, padding=p, use_bias=False)
    nopad = nn.ConvTranspose2D(4, 6, k, s, padding=0, use_bias=False)
    y_pad = pad({"w": w}, x)
    y_crop = nn.Crop2D(p)(None, nopad({"w": w}, x))
    np.testing.assert_allclose(np.float32(y_pad), np.float32(y_crop), atol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pix2pix_padded_equals_cropping_weights_interchangeable(seed):
    cfg_p = Pix2PixConfig(img_size=32, base=4, deconv_mode="padded")
    cfg_c = dataclasses.replace(cfg_p, deconv_mode="cropping")
    gp, gc = Pix2PixGenerator(cfg_p), Pix2PixGenerator(cfg_c)
    params = gp.init(jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed ^ 3), (1, 32, 32, 3))
    np.testing.assert_allclose(np.float32(gp(params, x)), np.float32(gc(params, x)), atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.floats(1e-4, 1e-1))
@settings(max_examples=10, deadline=None)
def test_adamw_step_bounded(seed, lr):
    """Adam update magnitude is bounded by ~lr per coordinate."""
    opt = AdamW(lr=lr, grad_clip_norm=None, weight_decay=0.0)
    p = {"w": jax.random.normal(jax.random.key(seed), (16,))}
    st_ = opt.init(p)
    g = {"w": jax.random.normal(jax.random.key(seed ^ 5), (16,)) * 100}
    p2, st_, _ = opt.update(g, st_, p)
    assert float(jnp.abs(p2["w"] - p["w"]).max()) <= 10.0 * lr + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_metric_identities(seed):
    img = jax.random.uniform(jax.random.key(seed), (1, 32, 32, 1)) * 255
    assert float(mse(img, img).mean()) == 0.0
    assert float(ssim(img, img).mean()) > 0.99
    assert float(psnr(img, img).mean()) > 80
    noisy = img + jax.random.normal(jax.random.key(seed ^ 7), img.shape) * 25
    assert float(ssim(img, noisy).mean()) < float(ssim(img, img).mean())
    assert float(psnr(img, noisy).mean()) < 40
